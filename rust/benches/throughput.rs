//! End-to-end throughput benchmarks against the AOT artifacts — the §4.3
//! measurement: what does Q-GaLore's quantize/dequantize traffic cost per
//! step relative to GaLore?  (The paper reports a 14.64% throughput
//! overhead on GPU.)
//!
//! Run: `make artifacts && cargo bench --bench throughput`

mod bench_harness;

use bench_harness::bench;
use qgalore::coordinator::trainer::{Trainer, TrainConfig};
use qgalore::manifest::Manifest;
use qgalore::optim::{BuildOptions, Method};
use qgalore::quant;
use qgalore::runtime::{HostTensor, Runtime};
use qgalore::scheduler::SchedulerConfig;
use qgalore::util::Pcg32;

const CFG: &str = "llama-tiny";

fn main() {
    let man = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP benches (run `make artifacts` first): {e}");
            return;
        }
    };

    println!("== model fwd/bwd artifacts ==");
    let entry = man.config(CFG).unwrap().clone();
    let init = man.load_init(CFG).unwrap();
    let mut rt = Runtime::new().unwrap();
    let mut rng = Pcg32::seeded(0);
    let b = man.batch;
    let s = entry.model.max_seq_len;
    let toks: Vec<i32> =
        (0..b * s).map(|_| rng.below(entry.model.vocab_size) as i32).collect();

    // fp operands
    let mut fp_ops = Vec::new();
    let mut off = 0;
    for (_, shape) in entry.fp_params.iter().chain(entry.linear_params.iter()) {
        let n: usize = shape.iter().product();
        fp_ops.push(HostTensor::F32(init[off..off + n].to_vec()));
        off += n;
    }
    fp_ops.push(HostTensor::I32(toks.clone()));
    fp_ops.push(HostTensor::I32(toks.clone()));

    // q8 operands (int8 linears)
    let mut q8_ops = Vec::new();
    let mut off = 0;
    for (_, shape) in &entry.fp_params {
        let n: usize = shape.iter().product();
        q8_ops.push(HostTensor::F32(init[off..off + n].to_vec()));
        off += n;
    }
    for (_, shape) in &entry.linear_params {
        let n: usize = shape.iter().product();
        let q = quant::quantize(&init[off..off + n], 8);
        off += n;
        q8_ops.push(HostTensor::I8(q.q));
        q8_ops.push(HostTensor::F32(q.scale));
        q8_ops.push(HostTensor::F32(q.zero));
    }
    q8_ops.push(HostTensor::I32(toks.clone()));
    q8_ops.push(HostTensor::I32(toks.clone()));

    let fwd_fp = entry.artifacts.get("fwd_bwd_fp").unwrap().clone();
    let fwd_q8 = entry.artifacts.get("fwd_bwd_q8").unwrap().clone();
    let r_fp = bench("fwd_bwd_fp (batch 4 x seq 64)", 3, 20, || {
        std::hint::black_box(rt.execute(&fwd_fp, &fp_ops).unwrap());
    });
    let r_q8 = bench("fwd_bwd_q8 (int8 weights)", 3, 20, || {
        std::hint::black_box(rt.execute(&fwd_q8, &q8_ops).unwrap());
    });
    println!(
        "    -> int8-weight fwd/bwd overhead vs fp: {:+.1}%",
        (r_q8.mean_ms / r_fp.mean_ms - 1.0) * 100.0
    );

    println!("\n== per-layer update artifacts (the §4.3 comparison) ==");
    let model = &entry.model;
    let (m, n, rank) = (model.dim, model.dim, model.rank);
    let mut rng = Pcg32::seeded(1);
    let g = rng.normal_vec(m * n, 0.0, 0.5);
    let w = rng.normal_vec(m * n, 0.0, 0.5);
    let p = rng.normal_vec(m * rank, 0.0, 0.1);
    let c = HostTensor::F32(vec![10.0, 1000.0]);
    let lr = HostTensor::F32(vec![0.01]);

    let galore_spec = man.update(&format!("galore_update_{m}x{n}_r{rank}")).unwrap().clone();
    let galore_ops = vec![
        HostTensor::F32(g.clone()),
        HostTensor::F32(p.clone()),
        HostTensor::F32(vec![0.0; rank * n]),
        HostTensor::F32(vec![0.0; rank * n]),
        HostTensor::F32(w.clone()),
        c.clone(),
        lr.clone(),
    ];
    let r_galore = bench(&format!("galore_update {m}x{n} r{rank}"), 3, 30, || {
        std::hint::black_box(rt.execute(&galore_spec, &galore_ops).unwrap());
    });

    let q4 = quant::quantize4(&p);
    let wq = quant::quantize(&w, 8);
    let st = quant::Adam8State::zeros(rank * n);
    let qgalore_spec = man.update(&format!("qgalore_update_{m}x{n}_r{rank}")).unwrap().clone();
    let qgalore_ops = vec![
        HostTensor::F32(g.clone()),
        HostTensor::U8(q4.packed.clone()),
        HostTensor::F32(q4.scale.clone()),
        HostTensor::F32(q4.zero.clone()),
        HostTensor::I8(st.mq.clone()),
        HostTensor::F32(st.ms.clone()),
        HostTensor::U8(st.vq.clone()),
        HostTensor::F32(st.vs.clone()),
        HostTensor::I8(wq.q.clone()),
        HostTensor::F32(wq.scale.clone()),
        HostTensor::F32(wq.zero.clone()),
        c.clone(),
        lr.clone(),
        HostTensor::F32({
            let mut nr = Pcg32::seeded(7);
            (0..m * n).map(|_| nr.next_f32()).collect()
        }),
    ];
    let r_qgalore = bench(&format!("qgalore_update {m}x{n} r{rank}"), 3, 30, || {
        std::hint::black_box(rt.execute(&qgalore_spec, &qgalore_ops).unwrap());
    });
    println!(
        "    -> Q-GaLore update overhead vs GaLore (quant/dequant+SR traffic): {:+.1}% (paper: +14.6%)",
        (r_qgalore.mean_ms / r_galore.mean_ms - 1.0) * 100.0
    );
    // RTN variant isolates the threefry RNG cost from the quant/dequant cost
    let rtn_spec = man
        .update(&format!("qgalore_rtn_update_{m}x{n}_r{rank}"))
        .unwrap()
        .clone();
    let rtn_ops = &qgalore_ops[..qgalore_ops.len() - 1]; // no noise operand
    let r_rtn = bench(&format!("qgalore_rtn_update {m}x{n} r{rank}"), 3, 30, || {
        std::hint::black_box(rt.execute(&rtn_spec, rtn_ops).unwrap());
    });
    println!(
        "    -> of which SR noise generation: {:+.1}% points",
        (r_qgalore.mean_ms - r_rtn.mean_ms) / r_galore.mean_ms * 100.0
    );

    println!("\n== end-to-end training step per method ==");
    for method in [Method::Full, Method::Adam8bit, Method::LoRa, Method::GaLore, Method::QGaLore] {
        let cfg = TrainConfig {
            cfg_name: CFG.into(),
            method,
            steps: 1000, // not actually run; just sizing the lr schedule
            lr_max: 0.005,
            warmup: 10,
            eval_every: 0,
            eval_batches: 2,
            n_documents: 256,
            seed: 3,
            opts: BuildOptions {
                seed: 3,
                sched: SchedulerConfig { base_interval: 10_000, ..Default::default() },
                ..Default::default()
            },
            log_every: u64::MAX,
            quiet: true,
        };
        let mut trainer = Trainer::new(&man, cfg).unwrap();
        // prime compile caches + first subspace refresh outside the timing
        trainer.step(0).unwrap();
        let mut step = 1u64;
        bench(&format!("train step [{method}]"), 2, 15, || {
            trainer.step(step).unwrap();
            step += 1;
        });
    }
}
