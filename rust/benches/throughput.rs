//! End-to-end throughput benchmarks.
//!
//! Part 1 (no artifacts needed): the blocked/parallel linalg engine vs the
//! naive single-threaded reference — GFLOP/s, speedup and parity for the
//! projection-shaped products on the Q-GaLore hot path — plus the
//! dispatch-overhead microbench (per-call latency of small repeated
//! matmuls: scoped-spawn vs the persistent worker pool).
//!
//! Part 2 (requires `make artifacts`): the §4.3 measurement against the AOT
//! HLO artifacts — what does Q-GaLore's quantize/dequantize traffic cost
//! per step relative to GaLore? (The paper reports a 14.64% throughput
//! overhead on GPU.)
//!
//! Run: `cargo bench --bench throughput` (part 1 always runs)

mod bench_harness;

use std::collections::BTreeMap;
use std::hint::black_box;

use bench_harness::{bench, BenchResult};
use qgalore::coordinator::trainer::{TrainConfig, Trainer};
use qgalore::coordinator::{
    serve, HostDataflowTrainer, HostMethod, HostStepConfig, MultiJobConfig, MultiJobCoordinator,
    ServeConfig, ServeEngine, ServeModel,
};
use qgalore::jsonx::Json;
use qgalore::linalg::{engine, KernelPath, Mat, PanelCache, PanelPack, ParallelCtx, WorkerPool};
use qgalore::manifest::Manifest;
use qgalore::optim::{BuildOptions, Method};
use qgalore::quant;
use qgalore::runtime::{HostTensor, Runtime};
use qgalore::scheduler::SchedulerConfig;
use qgalore::util::Pcg32;

const CFG: &str = "llama-tiny";

fn gflops(flops: usize, r: &BenchResult) -> f64 {
    flops as f64 / (r.mean_ms / 1e3) / 1e9
}

/// Old-vs-new engine comparison on the shapes that dominate Q-GaLore steps.
fn engine_benches() {
    println!("== linalg engine: blocked/parallel vs naive ==");
    let mut rng = Pcg32::seeded(0);

    // The acceptance shape: 512x512x512 dense matmul.
    let (m, k, n) = (512usize, 512usize, 512usize);
    let a = Mat::randn(m, k, &mut rng);
    let b = Mat::randn(k, n, &mut rng);
    let flops = 2 * m * k * n;
    let r_naive = bench("matmul 512x512x512 naive (old)", 1, 5, || {
        black_box(a.matmul_naive(&b));
    });
    println!("    -> {:.2} GFLOP/s (baseline)", gflops(flops, &r_naive));
    let want = a.matmul_naive(&b);
    for t in [1usize, 2, 4, 8] {
        let ctx = ParallelCtx::new(t);
        let r = bench(&format!("matmul 512x512x512 blocked, {t} threads"), 1, 5, || {
            black_box(a.matmul_with(&b, ctx));
        });
        let err = a.matmul_with(&b, ctx).rel_frobenius(&want);
        println!(
            "    -> {:.2} GFLOP/s | {:.2}x vs naive | parity rel-frobenius {:.1e}",
            gflops(flops, &r),
            r_naive.mean_ms / r.mean_ms,
            err
        );
    }

    // t_matmul at the same scale (the P^T G down-projection shape class).
    let r_tn = bench("t_matmul 512x512x512 naive (old)", 1, 5, || {
        black_box(a.t_matmul_naive(&b));
    });
    let want_t = a.t_matmul_naive(&b);
    for t in [1usize, 8] {
        let ctx = ParallelCtx::new(t);
        let r = bench(&format!("t_matmul 512x512x512 blocked, {t} threads"), 1, 5, || {
            black_box(a.t_matmul_with(&b, ctx));
        });
        let err = a.t_matmul_with(&b, ctx).rel_frobenius(&want_t);
        println!(
            "    -> {:.2} GFLOP/s | {:.2}x vs naive | parity rel-frobenius {:.1e}",
            gflops(flops, &r),
            r_tn.mean_ms / r.mean_ms,
            err
        );
    }

    // The per-step projected update at a 512-dim / rank-128 layer:
    // R = P^T G (INT4 P), then U = P R. Old path dequantizes P to fp32 and
    // runs the naive kernels; new path runs fused + parallel.
    println!("\n== Q-GaLore projected-update hot path (dim 512, rank 128) ==");
    let rank = 128usize;
    let g = Mat::randn(m, n, &mut rng);
    let p4 = quant::quantize4(&rng.normal_vec(m * rank, 0.0, 0.1));
    let flops_step = 2 * m * rank * n + 2 * m * rank * n;
    let r_old = bench("old: dequantize4 + naive P^T G + naive P R", 1, 5, || {
        let p = Mat::from_vec(m, rank, quant::dequantize4(&p4));
        let r = p.t_matmul_naive(&g);
        black_box(p.matmul_naive(&r));
    });
    println!("    -> {:.2} GFLOP/s per step (old)", gflops(flops_step, &r_old));
    for t in [1usize, 8] {
        let ctx = ParallelCtx::new(t);
        let r_new = bench(&format!("new: fused dequant4 engine, {t} threads"), 1, 5, || {
            let r = quant::dequant4_t_matmul(&p4, m, rank, &g, ctx);
            black_box(quant::dequant4_matmul(&p4, m, rank, &r, ctx));
        });
        println!(
            "    -> {:.2} GFLOP/s | per-step latency {:.3} ms (old {:.3} ms) | {:.2}x",
            gflops(flops_step, &r_new),
            r_new.mean_ms,
            r_old.mean_ms,
            r_old.mean_ms / r_new.mean_ms
        );
    }

    // Fused INT8-weight application W x (the forward shape class).
    let w8 = quant::quantize(&rng.normal_vec(m * k, 0.0, 0.5), 8);
    let x = Mat::randn(k, 64, &mut rng);
    let flops_wx = 2 * m * k * 64;
    let r_old8 = bench("old: dequantize int8 W + naive W x", 1, 8, || {
        let w = Mat::from_vec(m, k, quant::dequantize(&w8));
        black_box(w.matmul_naive(&x));
    });
    let ctx = ParallelCtx::new(8);
    let r_new8 = bench("new: fused dequant8_matmul, 8 threads", 1, 8, || {
        black_box(quant::dequant8_matmul(&w8, m, k, &x, ctx));
    });
    println!(
        "    -> int8 W x: {:.2} -> {:.2} GFLOP/s ({:.2}x, no fp32 W materialized)",
        gflops(flops_wx, &r_old8),
        gflops(flops_wx, &r_new8),
        r_old8.mean_ms / r_new8.mean_ms
    );
}

/// Microkernel-vs-baseline comparison: the register-blocked MRxNR kernel
/// bodies (explicit AVX2 where the CPU has it, plus the portable tiling)
/// against the PR-1/2 autovectorized row kernel, kept callable as
/// `KernelPath::Autovec` exactly like `ParallelCtx::scoped` is for the
/// pool.  Same shapes, same thread budgets, GFLOP/s side by side; every
/// row is also asserted bitwise-identical to the naive reference.
fn microkernel_benches() {
    println!("\n== microkernel vs autovectorized baseline (register-blocked MRxNR tiles) ==");
    let mut rng = Pcg32::seeded(3);
    // dense acceptance shape + the two projection-shaped products
    for (m, k, n) in [(512usize, 512usize, 512usize), (512, 128, 512), (1024, 512, 128)] {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let flops = 2 * m * k * n;
        let want = a.matmul_naive(&b);
        for t in [1usize, 8] {
            let ctx = ParallelCtx::new(t);
            let r_base = bench(
                &format!("matmul {m}x{k}x{n} autovec (baseline), {t} thr"),
                1,
                5,
                || {
                    black_box(engine::matmul_with_kernel(&a, &b, ctx, KernelPath::Autovec));
                },
            );
            let mut line = format!("    -> t={t}: autovec {:.2}", gflops(flops, &r_base));
            let mut paths = vec![KernelPath::Portable];
            if qgalore::linalg::simd_kernel_available() {
                paths.push(KernelPath::Simd);
            }
            for path in paths {
                let r = bench(
                    &format!("matmul {m}x{k}x{n} {path:?} microkernel, {t} thr"),
                    1,
                    5,
                    || {
                        black_box(engine::matmul_with_kernel(&a, &b, ctx, path));
                    },
                );
                assert_eq!(
                    engine::matmul_with_kernel(&a, &b, ctx, path).data,
                    want.data,
                    "{path:?} diverged from naive"
                );
                line.push_str(&format!(
                    " | {path:?} {:.2} GFLOP/s ({:.2}x vs autovec)",
                    gflops(flops, &r),
                    r_base.mean_ms / r.mean_ms
                ));
            }
            println!("{line}");
        }
    }
}

/// Prepacked-panel campaign benches: per-call fused dequantize (decode the
/// quantized projection inside every product) vs the cached `PanelPack`
/// entry points that decode once at refresh time and replay the panel on
/// every subsequent product.  Runs the three quantized ops on the
/// projection shapes (dim 512, rank 128), asserts every prepacked result
/// bitwise-identical to its fused twin, then adds dense kernel-path rows
/// (Portable vs Simd vs Simd512) so AVX-512 vs AVX2 is visible where the
/// hardware allows.  All rows land in `BENCH_kernels.json` alongside the
/// step-throughput trajectory in `BENCH_step.json`.
fn kernel_benches() {
    println!("\n== prepacked panel cache vs per-call fused dequant (dim 512, rank 128) ==");
    let mut rng = Pcg32::seeded(11);
    let (m, rank, n) = (512usize, 128usize, 512usize);
    let g = Mat::randn(m, n, &mut rng);
    let r_in = Mat::randn(rank, n, &mut rng);
    let p4 = quant::quantize4(&rng.normal_vec(m * rank, 0.0, 0.1));
    let pk4 = PanelPack::pack4(&p4, m, rank);
    let w8 = quant::quantize(&rng.normal_vec(m * rank, 0.0, 0.1), 8);
    let pk8 = PanelPack::pack8(&w8, m, rank);
    let mut rows: Vec<(String, usize, f64, f64)> = Vec::new();

    // One bench loop shared by the three quantized ops; each caller hands
    // in its fused and prepacked bodies as plain trait-object closures.
    let mut run_op = |label: &str,
                      flops: usize,
                      fused: &dyn Fn(ParallelCtx) -> Mat,
                      prepacked: &dyn Fn(ParallelCtx) -> Mat| {
        for t in [1usize, 8] {
            let ctx = ParallelCtx::new(t);
            assert_eq!(
                prepacked(ctx).data,
                fused(ctx).data,
                "{label} prepacked diverged from fused"
            );
            let r_fused = bench(&format!("{label} fused, {t} thr"), 2, 10, || {
                black_box(fused(ctx));
            });
            let r_pre = bench(&format!("{label} prepacked, {t} thr"), 2, 10, || {
                black_box(prepacked(ctx));
            });
            println!(
                "    -> {label} t={t}: fused {:.2} GFLOP/s | prepacked {:.2} GFLOP/s ({:.2}x)",
                gflops(flops, &r_fused),
                gflops(flops, &r_pre),
                r_fused.mean_ms / r_pre.mean_ms
            );
            rows.push((label.to_string(), t, gflops(flops, &r_fused), gflops(flops, &r_pre)));
        }
    };
    let flops_proj = 2 * m * rank * n;
    run_op(
        "dequant4_t_matmul",
        flops_proj,
        &|ctx| quant::dequant4_t_matmul(&p4, m, rank, &g, ctx),
        &|ctx| quant::dequant4_t_matmul_prepacked(&p4, &pk4, m, rank, &g, ctx),
    );
    run_op(
        "dequant4_matmul",
        flops_proj,
        &|ctx| quant::dequant4_matmul(&p4, m, rank, &r_in, ctx),
        &|ctx| quant::dequant4_matmul_prepacked(&p4, &pk4, m, rank, &r_in, ctx),
    );
    run_op(
        "dequant8_matmul",
        flops_proj,
        &|ctx| quant::dequant8_matmul(&w8, m, rank, &r_in, ctx),
        &|ctx| quant::dequant8_matmul_prepacked(&w8, &pk8, m, rank, &r_in, ctx),
    );

    // Dense kernel-path rows: the MR=4 x NR=8 AVX2 tile vs the MR=4 x NR=16
    // AVX-512 tile (which degrades to the portable NR=16 body off-hardware,
    // so the row always exists) vs the portable NR=8 tiling.
    println!("\n== dense kernel paths: Portable vs Simd vs Simd512 (512x512x512) ==");
    let a = Mat::randn(512, 512, &mut rng);
    let b = Mat::randn(512, 512, &mut rng);
    let flops_dense = 2 * 512usize * 512 * 512;
    let want = a.matmul_naive(&b);
    let mut dense_rows: Vec<(String, usize, f64)> = Vec::new();
    let mut paths = vec![KernelPath::Portable];
    if qgalore::linalg::simd_kernel_available() {
        paths.push(KernelPath::Simd);
    }
    paths.push(KernelPath::Simd512);
    for t in [1usize, 8] {
        let ctx = ParallelCtx::new(t);
        let mut line = format!("    -> t={t}:");
        for &path in &paths {
            let r = bench(&format!("dense 512^3 {path:?}, {t} thr"), 1, 5, || {
                black_box(engine::matmul_with_kernel(&a, &b, ctx, path));
            });
            assert_eq!(
                engine::matmul_with_kernel(&a, &b, ctx, path).data,
                want.data,
                "{path:?} diverged from naive"
            );
            let gf = gflops(flops_dense, &r);
            line.push_str(&format!(" {path:?} {gf:.2} GFLOP/s |"));
            dense_rows.push((format!("{path:?}"), t, gf));
        }
        line.pop();
        println!("{line}");
    }
    if !qgalore::linalg::simd512_kernel_available() {
        println!("    (avx512f not available: Simd512 rows ran the portable NR=16 fallback)");
    }

    let arr: Vec<Json> = rows
        .iter()
        .map(|(op, t, f, p)| {
            let mut row = BTreeMap::new();
            row.insert("op".to_string(), Json::Str(op.clone()));
            row.insert("threads".to_string(), Json::Num(*t as f64));
            row.insert("fused_gflops".to_string(), Json::Num(*f));
            row.insert("prepacked_gflops".to_string(), Json::Num(*p));
            row.insert("speedup".to_string(), Json::Num(p / f));
            Json::Obj(row)
        })
        .collect();
    let dense_arr: Vec<Json> = dense_rows
        .iter()
        .map(|(path, t, gf)| {
            let mut row = BTreeMap::new();
            row.insert("path".to_string(), Json::Str(path.clone()));
            row.insert("threads".to_string(), Json::Num(*t as f64));
            row.insert("gflops".to_string(), Json::Num(*gf));
            Json::Obj(row)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("kernel_campaign".to_string()));
    root.insert("dim".to_string(), Json::Num(m as f64));
    root.insert("rank".to_string(), Json::Num(rank as f64));
    root.insert(
        "avx512_hardware".to_string(),
        Json::Bool(qgalore::linalg::simd512_kernel_available()),
    );
    root.insert("prepacked_vs_fused".to_string(), Json::Arr(arr));
    root.insert("dense_paths".to_string(), Json::Arr(dense_arr));
    std::fs::write("BENCH_kernels.json", Json::Obj(root).dump())
        .expect("write BENCH_kernels.json");
    println!("    wrote BENCH_kernels.json");
}

/// Dispatch-overhead microbench: per-call latency on deliberately small
/// (sub-`PAR_MIN_FLOPS`) repeated matmuls, where dispatch cost dominates the
/// arithmetic — exactly the regime of Q-GaLore's many per-layer products.
/// `matmul_ungated` bypasses the serial gate so scoped-spawn (the PR-1
/// engine), the PR-2 single-FIFO pool, the PR-4 mutex-deque pool, and the
/// Chase-Lev pool are measured head to head; the gap to the serial
/// baseline is each substrate's dispatch tax.  The Chase-Lev pool runs
/// both over-decomposed (the default) and at 1 slab/worker, isolating the
/// cost of cutting finer tasks.
fn dispatch_benches() {
    println!(
        "\n== dispatch overhead: scoped spawn vs FIFO (PR 2) vs mutex-deque (PR 4) vs chase-lev =="
    );
    let mut rng = Pcg32::seeded(7);
    // explicit 4-worker pools so the comparison is like for like: the
    // global pool is sized to the machine's core count, not to the label
    let pool4_fifo = WorkerPool::leaked_fifo(4);
    let pool4_mutex = WorkerPool::leaked_mutex_steal(4);
    let pool4_steal = WorkerPool::leaked(4);
    for (m, k, n) in [(32usize, 32usize, 32usize), (64, 64, 64), (96, 96, 96)] {
        assert!(
            m * k * n < engine::PAR_MIN_FLOPS,
            "dispatch bench shapes must sit below the serial gate"
        );
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let iters = 200;
        let r_serial = bench(&format!("matmul {m}x{k}x{n} serial"), 20, iters, || {
            black_box(engine::matmul_ungated(&a, &b, ParallelCtx::serial()));
        });
        let scoped = ParallelCtx::scoped(4);
        let r_scoped = bench(&format!("matmul {m}x{k}x{n} scoped-spawn x4"), 20, iters, || {
            black_box(engine::matmul_ungated(&a, &b, scoped));
        });
        let fifo = ParallelCtx::with_pool(4, pool4_fifo);
        let r_fifo = bench(&format!("matmul {m}x{k}x{n} fifo-pool x4"), 20, iters, || {
            black_box(engine::matmul_ungated(&a, &b, fifo));
        });
        let mutex = ParallelCtx::with_pool(4, pool4_mutex);
        let r_mutex = bench(&format!("matmul {m}x{k}x{n} mutex-deque x4"), 20, iters, || {
            black_box(engine::matmul_ungated(&a, &b, mutex));
        });
        let steal = ParallelCtx::with_pool(4, pool4_steal);
        let r_steal = bench(&format!("matmul {m}x{k}x{n} chase-lev x4"), 20, iters, || {
            black_box(engine::matmul_ungated(&a, &b, steal));
        });
        let steal1 = steal.with_slabs_per_worker(1);
        let r_steal1 =
            bench(&format!("matmul {m}x{k}x{n} chase-lev x4, 1 slab/worker"), 20, iters, || {
                black_box(engine::matmul_ungated(&a, &b, steal1));
            });
        println!(
            "    -> per-call: serial {:.1} us | scoped {:.1} us | fifo {:.1} us | mutex-deque {:.1} us | chase-lev {:.1} us (1 slab/w {:.1} us) | dispatch tax {:.1} / {:.1} / {:.1} / {:.1} us",
            r_serial.mean_ms * 1e3,
            r_scoped.mean_ms * 1e3,
            r_fifo.mean_ms * 1e3,
            r_mutex.mean_ms * 1e3,
            r_steal.mean_ms * 1e3,
            r_steal1.mean_ms * 1e3,
            (r_scoped.mean_ms - r_serial.mean_ms) * 1e3,
            (r_fifo.mean_ms - r_serial.mean_ms) * 1e3,
            (r_mutex.mean_ms - r_serial.mean_ms) * 1e3,
            (r_steal.mean_ms - r_serial.mean_ms) * 1e3,
        );
    }
}

/// Many-small-jobs contention bench: several submitter threads hammering
/// tiny parallel matmuls at the same pool concurrently — the regime where
/// mutex-guarded queues serialize every push/pop while the Chase-Lev
/// pool's own-pops are wait-free and its steals a single CAS.  This is the
/// Q-GaLore steady state (every layer's `P^T g` / `P u` products land
/// together), and the shape of the ROADMAP item this layer closes.  The
/// PR-2 FIFO queue and the PR-4 mutex-deque pool run as baselines so the
/// mutex-deque vs Chase-Lev gap is reported side by side on live hardware.
fn contention_benches() {
    println!("\n== many-small-jobs contention: FIFO vs mutex-deque vs chase-lev ==");
    let mut rng = Pcg32::seeded(9);
    let a = Mat::randn(48, 48, &mut rng);
    let b = Mat::randn(48, 48, &mut rng);
    let jobs_per_submitter = 200;
    for workers in [4usize, 8] {
        let pools: [(&str, &'static WorkerPool); 3] = [
            ("fifo", WorkerPool::leaked_fifo(workers)),
            ("mutex-deque", WorkerPool::leaked_mutex_steal(workers)),
            ("chase-lev", WorkerPool::leaked(workers)),
        ];
        let mut means = [0f64; 3];
        for (pi, &(label, pool)) in pools.iter().enumerate() {
            let submitters = workers;
            let r = bench(
                &format!("{submitters} submitters x {jobs_per_submitter} jobs, {label} x{workers}"),
                1,
                5,
                || {
                    std::thread::scope(|s| {
                        for _ in 0..submitters {
                            s.spawn(|| {
                                let ctx = ParallelCtx::with_pool(4, pool);
                                for _ in 0..jobs_per_submitter {
                                    black_box(engine::matmul_ungated(&a, &b, ctx));
                                }
                            });
                        }
                    });
                },
            );
            means[pi] = r.mean_ms;
            let jobs = submitters * jobs_per_submitter;
            println!(
                "    -> {label} x{workers}: {:.2} ms for {jobs} jobs ({:.1} us/job, steals={})",
                r.mean_ms,
                r.mean_ms * 1e3 / jobs as f64,
                pool.stats().steals,
            );
        }
        println!(
            "    -> at {workers} workers: chase-lev vs fifo {:.2}x, chase-lev vs mutex-deque {:.2}x",
            means[0] / means[2],
            means[1] / means[2]
        );
    }
}

/// Sequential step vs dataflow step graph on the host reference trainer
/// (the same `StepGraphBuilder`/`run_graph` machinery `Trainer::step`
/// uses, minus the runtime): steps/sec at 1/4/8/16 workers, written to
/// `BENCH_step.json` so the step-throughput trajectory is tracked across
/// PRs.  Layers sit below the engine's serial gate on purpose — all the
/// parallelism must come from layer-level chain overlap, which is exactly
/// what the dataflow step adds.
fn step_benches() {
    println!("\n== dataflow step graph vs sequential step (host trainer, 12 layers) ==");
    // two shape groups so refresh waves are shape-batched; interval 4 so
    // waves land inside the timed window, not just at step 0
    let shapes: Vec<(usize, usize)> =
        (0..12).map(|i| if i % 3 == 2 { (64, 48) } else { (96, 96) }).collect();
    let cfg = HostStepConfig {
        method: HostMethod::Galore,
        rank: 8,
        sched: SchedulerConfig { base_interval: 4, ..Default::default() },
        seed: 5,
        ..HostStepConfig::default()
    };
    let mut rows = Vec::new();
    for workers in [1usize, 4, 8, 16] {
        let pool = WorkerPool::leaked(workers);
        let ctx = ParallelCtx::with_pool(workers, pool);
        let mut seq = HostDataflowTrainer::new(&shapes, cfg);
        let r_seq = bench(&format!("sequential step, {workers} workers"), 3, 30, || {
            black_box(seq.step_sequential(ctx));
        });
        let mut df = HostDataflowTrainer::new(&shapes, cfg);
        let r_df = bench(&format!("dataflow step, {workers} workers"), 3, 30, || {
            black_box(df.step_dataflow(ctx, pool).unwrap());
        });
        let sps_seq = 1e3 / r_seq.mean_ms;
        let sps_df = 1e3 / r_df.mean_ms;
        println!(
            "    -> {workers:>2} workers: sequential {sps_seq:.1} steps/s | dataflow {sps_df:.1} steps/s ({:.2}x)",
            sps_df / sps_seq
        );
        rows.push((workers, sps_seq, sps_df));
    }
    let arr: Vec<Json> = rows
        .iter()
        .map(|&(w, s, d)| {
            let mut row = BTreeMap::new();
            row.insert("workers".to_string(), Json::Num(w as f64));
            row.insert("sequential_steps_per_sec".to_string(), Json::Num(s));
            row.insert("dataflow_steps_per_sec".to_string(), Json::Num(d));
            Json::Obj(row)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("host_dataflow_step".to_string()));
    root.insert("layers".to_string(), Json::Num(shapes.len() as f64));
    root.insert("rows".to_string(), Json::Arr(arr));
    std::fs::write("BENCH_step.json", Json::Obj(root).dump()).expect("write BENCH_step.json");
    println!("    wrote BENCH_step.json");
}

/// Multi-tenant fine-tune serving bench: N concurrent jobs sharing one
/// base arena and one 16-worker pool, stepped in fair round-robin rounds
/// (`MultiJobCoordinator::round`).  The serving-economics question is
/// job-steps/sec as tenancy grows — per-job low-rank work is tiny, so
/// throughput should hold (or improve, as independent jobs fill worker
/// idle time) until the pool saturates.  Rows land in
/// `BENCH_multijob.json`.
fn multijob_benches() {
    println!("\n== multi-job coordinator: job-steps/s vs tenancy (16 workers) ==");
    let shapes: Vec<(usize, usize)> =
        (0..6).map(|i| if i % 3 == 2 { (32, 96) } else { (64, 64) }).collect();
    let cfg = MultiJobConfig {
        rank: 8,
        sched: SchedulerConfig { base_interval: 10, ..Default::default() },
        ..Default::default()
    };
    let workers = 16usize;
    let pool = WorkerPool::leaked(workers);
    let ctx = ParallelCtx::with_pool(workers, pool);
    let mut rows = Vec::new();
    for jobs in [1usize, 4, 16, 64] {
        let mut co = MultiJobCoordinator::new(&shapes, cfg, ctx);
        for j in 0..jobs {
            co.add_job(1000 + j as u64);
        }
        let r = bench(&format!("round, {jobs} jobs x {workers} workers"), 3, 15, || {
            black_box(co.round(pool).unwrap());
        });
        let jps = jobs as f64 / (r.mean_ms / 1e3);
        println!(
            "    -> {jobs:>2} jobs: {:.2} ms/round | {jps:.1} job-steps/s | delta/job {}",
            r.mean_ms,
            qgalore::util::human_bytes(co.job(0).delta_bytes())
        );
        rows.push((jobs, r.mean_ms, jps));
    }
    let arr: Vec<Json> = rows
        .iter()
        .map(|&(j, ms, jps)| {
            let mut row = BTreeMap::new();
            row.insert("jobs".to_string(), Json::Num(j as f64));
            row.insert("round_ms".to_string(), Json::Num(ms));
            row.insert("job_steps_per_sec".to_string(), Json::Num(jps));
            Json::Obj(row)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("multijob_serving".to_string()));
    root.insert("workers".to_string(), Json::Num(workers as f64));
    root.insert("layers".to_string(), Json::Num(shapes.len() as f64));
    root.insert("rank".to_string(), Json::Num(8.0));
    root.insert("rows".to_string(), Json::Arr(arr));
    std::fs::write("BENCH_multijob.json", Json::Obj(root).dump())
        .expect("write BENCH_multijob.json");
    println!("    wrote BENCH_multijob.json");
}

/// Batched serving bench: the heavy-traffic measurement.  One loaded,
/// prepacked model serving mixed score/generate request streams on a
/// 16-worker pool at growing concurrency; rows (requests/sec + p50/p99
/// completion latency) land in `BENCH_serve.json`.
fn serve_benches() {
    println!("\n== batched serving: requests/s and latency vs concurrency (16 workers) ==");
    let cfg = ServeConfig { vocab: 128, dim: 32, n_layers: 3, seed: 42 };
    let workers = 16usize;
    let pool = WorkerPool::leaked(workers);
    let ctx = ParallelCtx::with_pool(workers, pool);
    let engine = ServeEngine::new(ServeModel::from_seed(cfg).unwrap(), ctx);
    let mut rows = Vec::new();
    for n in [1usize, 8, 64, 256, 1000] {
        let reqs = serve::synth_requests(cfg.vocab, n, 77);
        let iters = if n >= 256 { 3 } else { 5 };
        let r = bench(&format!("serve batch, {n} requests x {workers} workers"), 1, iters, || {
            black_box(engine.serve_batch(&reqs, pool).unwrap());
        });
        let (_, lat) = engine.serve_batch_timed(&reqs, pool).unwrap();
        let rps = n as f64 / (r.mean_ms / 1e3);
        let p50 = serve::percentile(&lat, 50.0);
        let p99 = serve::percentile(&lat, 99.0);
        println!(
            "    -> {n:>4} concurrent: {:.2} ms/batch | {rps:.0} req/s | p50 {p50:.2} ms p99 {p99:.2} ms",
            r.mean_ms
        );
        rows.push((n, r.mean_ms, rps, p50, p99));
    }
    let arr: Vec<Json> = rows
        .iter()
        .map(|&(n, ms, rps, p50, p99)| {
            let mut row = BTreeMap::new();
            row.insert("concurrency".to_string(), Json::Num(n as f64));
            row.insert("batch_ms".to_string(), Json::Num(ms));
            row.insert("requests_per_sec".to_string(), Json::Num(rps));
            row.insert("p50_ms".to_string(), Json::Num(p50));
            row.insert("p99_ms".to_string(), Json::Num(p99));
            Json::Obj(row)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("serve".to_string()));
    root.insert("workers".to_string(), Json::Num(workers as f64));
    root.insert("vocab".to_string(), Json::Num(cfg.vocab as f64));
    root.insert("dim".to_string(), Json::Num(cfg.dim as f64));
    root.insert("layers".to_string(), Json::Num(cfg.n_layers as f64));
    root.insert("rows".to_string(), Json::Arr(arr));
    std::fs::write("BENCH_serve.json", Json::Obj(root).dump()).expect("write BENCH_serve.json");
    println!("    wrote BENCH_serve.json");
}

/// Pack-cache refresh-storm contention bench (PR-7 follow-on): many
/// tenants' panel packs rebuilt at once — the worst case for serving-time
/// pack churn (mass delta reloads, synchronized refresh waves).  Serial
/// rebuild vs concurrent submitter threads, each tenant repacking into
/// its own fresh `PanelCache`.
fn pack_storm_benches() {
    println!("\n== pack-cache refresh storm: 32 tenants repacking (256x32 INT4 panels) ==");
    let mut rng = Pcg32::seeded(21);
    let (m, rank) = (256usize, 32usize);
    let tenants: Vec<quant::Quant4Tensor> =
        (0..32).map(|_| quant::quantize4(&rng.normal_vec(m * rank, 0.0, 0.1))).collect();
    let r_serial = bench("pack storm, serial", 2, 10, || {
        for t in &tenants {
            let mut c = PanelCache::empty();
            black_box(c.get_or_pack4(t, m, rank));
        }
    });
    println!(
        "    -> serial: {:.3} ms for {} repacks ({:.1} us/pack)",
        r_serial.mean_ms,
        tenants.len(),
        r_serial.mean_ms * 1e3 / tenants.len() as f64
    );
    for submitters in [4usize, 8] {
        let chunk = tenants.len().div_ceil(submitters);
        let r = bench(&format!("pack storm, {submitters} submitters"), 2, 10, || {
            std::thread::scope(|s| {
                for ch in tenants.chunks(chunk) {
                    s.spawn(move || {
                        for t in ch {
                            let mut c = PanelCache::empty();
                            black_box(c.get_or_pack4(t, m, rank));
                        }
                    });
                }
            });
        });
        println!(
            "    -> {submitters} submitters: {:.3} ms ({:.2}x vs serial)",
            r.mean_ms,
            r_serial.mean_ms / r.mean_ms
        );
    }
}

fn main() {
    engine_benches();
    microkernel_benches();
    kernel_benches();
    dispatch_benches();
    contention_benches();
    pack_storm_benches();
    step_benches();
    multijob_benches();
    serve_benches();

    let man = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("\nSKIP artifact benches (run `make artifacts` first): {e}");
            return;
        }
    };

    println!("\n== model fwd/bwd artifacts ==");
    let entry = man.config(CFG).unwrap().clone();
    let init = man.load_init(CFG).unwrap();
    let rt = Runtime::new().unwrap();
    let mut rng = Pcg32::seeded(0);
    let b = man.batch;
    let s = entry.model.max_seq_len;
    let toks: Vec<i32> =
        (0..b * s).map(|_| rng.below(entry.model.vocab_size) as i32).collect();

    // fp operands
    let mut fp_ops = Vec::new();
    let mut off = 0;
    for (_, shape) in entry.fp_params.iter().chain(entry.linear_params.iter()) {
        let n: usize = shape.iter().product();
        fp_ops.push(HostTensor::F32(init[off..off + n].to_vec()));
        off += n;
    }
    fp_ops.push(HostTensor::I32(toks.clone()));
    fp_ops.push(HostTensor::I32(toks.clone()));

    // q8 operands (int8 linears)
    let mut q8_ops = Vec::new();
    let mut off = 0;
    for (_, shape) in &entry.fp_params {
        let n: usize = shape.iter().product();
        q8_ops.push(HostTensor::F32(init[off..off + n].to_vec()));
        off += n;
    }
    for (_, shape) in &entry.linear_params {
        let n: usize = shape.iter().product();
        let q = quant::quantize(&init[off..off + n], 8);
        off += n;
        q8_ops.push(HostTensor::I8(q.q));
        q8_ops.push(HostTensor::F32(q.scale));
        q8_ops.push(HostTensor::F32(q.zero));
    }
    q8_ops.push(HostTensor::I32(toks.clone()));
    q8_ops.push(HostTensor::I32(toks.clone()));

    let fwd_fp = entry.artifacts.get("fwd_bwd_fp").unwrap().clone();
    let fwd_q8 = entry.artifacts.get("fwd_bwd_q8").unwrap().clone();
    let r_fp = bench("fwd_bwd_fp (batch 4 x seq 64)", 3, 20, || {
        black_box(rt.execute(&fwd_fp, &fp_ops).unwrap());
    });
    let r_q8 = bench("fwd_bwd_q8 (int8 weights)", 3, 20, || {
        black_box(rt.execute(&fwd_q8, &q8_ops).unwrap());
    });
    println!(
        "    -> int8-weight fwd/bwd overhead vs fp: {:+.1}%",
        (r_q8.mean_ms / r_fp.mean_ms - 1.0) * 100.0
    );

    println!("\n== per-layer update artifacts (the §4.3 comparison) ==");
    let model = &entry.model;
    let (m, n, rank) = (model.dim, model.dim, model.rank);
    let mut rng = Pcg32::seeded(1);
    let g = rng.normal_vec(m * n, 0.0, 0.5);
    let w = rng.normal_vec(m * n, 0.0, 0.5);
    let p = rng.normal_vec(m * rank, 0.0, 0.1);
    let c = HostTensor::F32(vec![10.0, 1000.0]);
    let lr = HostTensor::F32(vec![0.01]);

    let galore_spec = man.update(&format!("galore_update_{m}x{n}_r{rank}")).unwrap().clone();
    let galore_ops = vec![
        HostTensor::F32(g.clone()),
        HostTensor::F32(p.clone()),
        HostTensor::F32(vec![0.0; rank * n]),
        HostTensor::F32(vec![0.0; rank * n]),
        HostTensor::F32(w.clone()),
        c.clone(),
        lr.clone(),
    ];
    let r_galore = bench(&format!("galore_update {m}x{n} r{rank}"), 3, 30, || {
        black_box(rt.execute(&galore_spec, &galore_ops).unwrap());
    });

    let q4 = quant::quantize4(&p);
    let wq = quant::quantize(&w, 8);
    let st = quant::Adam8State::zeros(rank * n);
    let qgalore_spec = man.update(&format!("qgalore_update_{m}x{n}_r{rank}")).unwrap().clone();
    let qgalore_ops = vec![
        HostTensor::F32(g.clone()),
        HostTensor::U8(q4.packed.clone()),
        HostTensor::F32(q4.scale.clone()),
        HostTensor::F32(q4.zero.clone()),
        HostTensor::I8(st.mq.clone()),
        HostTensor::F32(st.ms.clone()),
        HostTensor::U8(st.vq.clone()),
        HostTensor::F32(st.vs.clone()),
        HostTensor::I8(wq.q.clone()),
        HostTensor::F32(wq.scale.clone()),
        HostTensor::F32(wq.zero.clone()),
        c.clone(),
        lr.clone(),
        HostTensor::F32({
            let mut nr = Pcg32::seeded(7);
            (0..m * n).map(|_| nr.next_f32()).collect()
        }),
    ];
    let r_qgalore = bench(&format!("qgalore_update {m}x{n} r{rank}"), 3, 30, || {
        black_box(rt.execute(&qgalore_spec, &qgalore_ops).unwrap());
    });
    println!(
        "    -> Q-GaLore update overhead vs GaLore (quant/dequant+SR traffic): {:+.1}% (paper: +14.6%)",
        (r_qgalore.mean_ms / r_galore.mean_ms - 1.0) * 100.0
    );
    // RTN variant isolates the threefry RNG cost from the quant/dequant cost
    let rtn_spec = man
        .update(&format!("qgalore_rtn_update_{m}x{n}_r{rank}"))
        .unwrap()
        .clone();
    let rtn_ops = &qgalore_ops[..qgalore_ops.len() - 1]; // no noise operand
    let r_rtn = bench(&format!("qgalore_rtn_update {m}x{n} r{rank}"), 3, 30, || {
        black_box(rt.execute(&rtn_spec, rtn_ops).unwrap());
    });
    println!(
        "    -> of which SR noise generation: {:+.1}% points",
        (r_qgalore.mean_ms - r_rtn.mean_ms) / r_galore.mean_ms * 100.0
    );

    println!("\n== end-to-end training step per method ==");
    for method in [Method::Full, Method::Adam8bit, Method::LoRa, Method::GaLore, Method::QGaLore] {
        let cfg = TrainConfig {
            cfg_name: CFG.into(),
            method,
            steps: 1000, // not actually run; just sizing the lr schedule
            lr_max: 0.005,
            warmup: 10,
            eval_every: 0,
            eval_batches: 2,
            n_documents: 256,
            seed: 3,
            opts: BuildOptions {
                seed: 3,
                sched: SchedulerConfig { base_interval: 10_000, ..Default::default() },
                ..Default::default()
            },
            log_every: u64::MAX,
            quiet: true,
            dataflow: false,
        };
        let mut trainer = Trainer::new(&man, cfg).unwrap();
        // prime compile caches + first subspace refresh outside the timing
        trainer.step(0).unwrap();
        let mut step = 1u64;
        bench(&format!("train step [{method}]"), 2, 15, || {
            trainer.step(step).unwrap();
            step += 1;
        });
    }
}
