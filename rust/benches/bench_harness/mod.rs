#![allow(dead_code)]
//! Tiny manual bench harness (the offline dependency budget has no
//! criterion): warms up, runs timed iterations, reports mean / p50 / min.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub min_ms: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        p50_ms: samples[samples.len() / 2],
        min_ms: samples[0],
    };
    println!(
        "{:<44} {:>5} iters | mean {:>9.3} ms | p50 {:>9.3} ms | min {:>9.3} ms",
        r.name, r.iters, r.mean_ms, r.p50_ms, r.min_ms
    );
    r
}

/// Throughput helper: element count / mean time.
pub fn report_throughput(r: &BenchResult, elems: usize, unit: &str) {
    let per_s = elems as f64 / (r.mean_ms / 1e3);
    println!("    -> {:.2} M{unit}/s", per_s / 1e6);
}
