//! Substrate micro-benchmarks: quantization, linalg, data pipeline.
//!
//! These are the rust control-path costs; the training hot path itself is
//! measured by `benches/throughput.rs` against the HLO artifacts.
//!
//! Run: `cargo bench --bench substrates`

mod bench_harness;

use bench_harness::{bench, report_throughput};
use qgalore::data::{CorpusGenerator, Tokenizer};
use qgalore::linalg::{left_subspace, qr_orthonormal, Mat};
use qgalore::quant;
use qgalore::util::Pcg32;

fn main() {
    println!("== quantization (host mirrors of the L1 kernels) ==");
    let mut rng = Pcg32::seeded(0);
    let x = rng.normal_vec(1 << 20, 0.0, 1.0); // 1M elements
    let r = bench("quantize int8 (1M f32)", 2, 10, || {
        std::hint::black_box(quant::quantize(&x, 8));
    });
    report_throughput(&r, 1 << 20, "elem");
    let t8 = quant::quantize(&x, 8);
    let r = bench("dequantize int8 (1M)", 2, 10, || {
        std::hint::black_box(quant::dequantize(&t8));
    });
    report_throughput(&r, 1 << 20, "elem");
    let r = bench("sr_quantize int8 (1M)", 2, 10, || {
        let mut rng = Pcg32::seeded(1);
        std::hint::black_box(quant::sr_quantize(&x, 8, &mut rng));
    });
    report_throughput(&r, 1 << 20, "elem");
    let r = bench("quantize4 + pack (1M)", 2, 10, || {
        std::hint::black_box(quant::quantize4(&x));
    });
    report_throughput(&r, 1 << 20, "elem");

    println!("\n== linalg (subspace refresh control path) ==");
    // the largest layer shape of llama-tiny and a 10x stress shape
    for (m, n, rank) in [(128usize, 64usize, 16usize), (512, 512, 128)] {
        let g = Mat::randn(m, n, &mut rng);
        bench(
            &format!("left_subspace {m}x{n} r={rank} (2 iters)"),
            1,
            8,
            || {
                let mut r2 = Pcg32::seeded(2);
                std::hint::black_box(left_subspace(&g, rank, 2, &mut r2));
            },
        );
        let a = Mat::randn(m, rank, &mut rng);
        bench(&format!("qr_orthonormal {m}x{rank}"), 1, 10, || {
            std::hint::black_box(qr_orthonormal(&a));
        });
    }
    let a = Mat::randn(256, 256, &mut rng);
    let b = Mat::randn(256, 256, &mut rng);
    let r = bench("matmul 256x256x256", 1, 10, || {
        std::hint::black_box(a.matmul(&b));
    });
    report_throughput(&r, 2 * 256 * 256 * 256, "flop");

    println!("\n== data pipeline ==");
    let gen = CorpusGenerator::new(0);
    let r = bench("corpus: 100 documents", 1, 10, || {
        let mut r2 = Pcg32::seeded(3);
        for _ in 0..100 {
            std::hint::black_box(gen.document(&mut r2));
        }
    });
    let mut r2 = Pcg32::seeded(3);
    let docs: Vec<String> = (0..200).map(|_| gen.document(&mut r2)).collect();
    let total_bytes: usize = docs.iter().map(|d| d.len()).sum();
    report_throughput(&r, total_bytes / 2, "byte");
    let tok = Tokenizer::train(&docs, 512);
    let r = bench("tokenizer: encode 200 documents", 1, 10, || {
        for d in &docs {
            std::hint::black_box(tok.encode(d));
        }
    });
    report_throughput(&r, total_bytes, "byte");
}
