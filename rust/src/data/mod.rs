//! Data pipeline substrate: synthetic corpus -> tokenizer -> packed batches.
//!
//! The paper pre-trains on C4 (web text).  C4 is not available here, so we
//! build the closest synthetic equivalent that exercises the same code path
//! and gives a *learnable* distribution: a Zipf-weighted vocabulary emitted
//! through an order-2 Markov template grammar (clauses, punctuation,
//! sentence/paragraph structure).  Perplexity drops as a model learns the
//! bigram/template structure, so the method ordering the paper reports is
//! observable at tiny scale (substitution table, DESIGN.md §3).

pub mod batcher;
pub mod corpus;
pub mod tokenizer;

pub use batcher::{Batch, Batcher};
pub use corpus::CorpusGenerator;
pub use tokenizer::Tokenizer;

use crate::linalg::ParallelCtx;

/// Convenience: corpus -> tokenizer -> (train_ids, val_ids) for a vocab cap,
/// at the process-global worker budget.
pub fn build_dataset(
    vocab_size: usize,
    n_documents: usize,
    seed: u64,
) -> (Tokenizer, Vec<u32>, Vec<u32>) {
    build_dataset_with(vocab_size, n_documents, seed, ParallelCtx::global())
}

/// [`build_dataset`] with an explicit parallelism context.  Corpus
/// generation and tokenization both fan out over the worker pool
/// ([`CorpusGenerator::documents`], [`Tokenizer::encode_batch`]); document
/// `i` draws from its own PCG stream keyed by `(seed, i)`, so the dataset
/// is a pure function of its arguments — bitwise independent of worker
/// count (asserted by the tests below).
pub fn build_dataset_with(
    vocab_size: usize,
    n_documents: usize,
    seed: u64,
    ctx: ParallelCtx,
) -> (Tokenizer, Vec<u32>, Vec<u32>) {
    let gen = CorpusGenerator::new(seed);
    let docs = gen.documents(n_documents, seed, ctx);
    let n_val = (n_documents / 16).max(1);
    let tokenizer = Tokenizer::train(&docs, vocab_size);
    let mut train_ids = Vec::new();
    let mut val_ids = Vec::new();
    for (i, ids) in tokenizer.encode_batch(&docs, ctx).into_iter().enumerate() {
        if i < n_val {
            val_ids.extend(ids);
        } else {
            train_ids.extend(ids);
        }
    }
    (tokenizer, train_ids, val_ids)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_builds_and_splits() {
        let (tok, train, val) = build_dataset(512, 64, 42);
        assert!(tok.vocab_len() <= 512);
        assert!(train.len() > 10 * val.len() / 2);
        assert!(!val.is_empty());
        assert!(train.iter().all(|&t| (t as usize) < tok.vocab_len()));
    }

    #[test]
    fn dataset_deterministic() {
        let (_, a, _) = build_dataset(512, 16, 7);
        let (_, b, _) = build_dataset(512, 16, 7);
        assert_eq!(a, b);
    }

    #[test]
    fn dataset_independent_of_worker_count() {
        // the parallel pipeline must produce the identical corpus, token
        // streams and split whatever the worker budget is
        let (tok1, train1, val1) = build_dataset_with(512, 48, 11, ParallelCtx::serial());
        for t in [2usize, 8] {
            let (tokt, traint, valt) = build_dataset_with(512, 48, 11, ParallelCtx::new(t));
            assert_eq!(train1, traint, "train ids changed with {t} workers");
            assert_eq!(val1, valt, "val ids changed with {t} workers");
            assert_eq!(tok1.vocab_len(), tokt.vocab_len());
        }
    }
}
