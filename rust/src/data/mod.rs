//! Data pipeline substrate: synthetic corpus -> tokenizer -> packed batches.
//!
//! The paper pre-trains on C4 (web text).  C4 is not available here, so we
//! build the closest synthetic equivalent that exercises the same code path
//! and gives a *learnable* distribution: a Zipf-weighted vocabulary emitted
//! through an order-2 Markov template grammar (clauses, punctuation,
//! sentence/paragraph structure).  Perplexity drops as a model learns the
//! bigram/template structure, so the method ordering the paper reports is
//! observable at tiny scale (substitution table, DESIGN.md §3).

pub mod batcher;
pub mod corpus;
pub mod tokenizer;

pub use batcher::{Batch, Batcher};
pub use corpus::CorpusGenerator;
pub use tokenizer::Tokenizer;

use crate::util::Pcg32;

/// Convenience: corpus -> tokenizer -> (train_ids, val_ids) for a vocab cap.
pub fn build_dataset(
    vocab_size: usize,
    n_documents: usize,
    seed: u64,
) -> (Tokenizer, Vec<u32>, Vec<u32>) {
    let mut rng = Pcg32::seeded(seed);
    let gen = CorpusGenerator::new(seed);
    let docs: Vec<String> = (0..n_documents).map(|_| gen.document(&mut rng)).collect();
    let n_val = (n_documents / 16).max(1);
    let tokenizer = Tokenizer::train(&docs, vocab_size);
    let mut train_ids = Vec::new();
    let mut val_ids = Vec::new();
    for (i, d) in docs.iter().enumerate() {
        let ids = tokenizer.encode(d);
        if i < n_val {
            val_ids.extend(ids);
        } else {
            train_ids.extend(ids);
        }
    }
    (tokenizer, train_ids, val_ids)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_builds_and_splits() {
        let (tok, train, val) = build_dataset(512, 64, 42);
        assert!(tok.vocab_len() <= 512);
        assert!(train.len() > 10 * val.len() / 2);
        assert!(!val.is_empty());
        assert!(train.iter().all(|&t| (t as usize) < tok.vocab_len()));
    }

    #[test]
    fn dataset_deterministic() {
        let (_, a, _) = build_dataset(512, 16, 7);
        let (_, b, _) = build_dataset(512, 16, 7);
        assert_eq!(a, b);
    }
}
