//! Word-level tokenizer with byte fallback.
//!
//! Vocabulary is learned from the corpus by frequency: the top
//! `vocab_size - 256 - N_SPECIAL` words become single tokens; anything else
//! falls back to byte tokens, so *every* string round-trips losslessly
//! (the property real LLM tokenizers guarantee, and the property our
//! proptests pin down).

use std::collections::HashMap;

use crate::linalg::{par_map, ParallelCtx};

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const N_SPECIAL: u32 = 3;

/// First byte-fallback token id; bytes occupy [BYTE_BASE, BYTE_BASE+256).
pub const BYTE_BASE: u32 = N_SPECIAL;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    word_to_id: HashMap<String, u32>,
    id_to_word: Vec<String>, // indexed from WORD_BASE
    vocab_size: usize,
}

const WORD_BASE: u32 = BYTE_BASE + 256;

impl Tokenizer {
    /// Learn a vocabulary from documents. `vocab_size` caps total ids
    /// (specials + 256 bytes + words).
    pub fn train(docs: &[String], vocab_size: usize) -> Self {
        assert!(
            vocab_size > (WORD_BASE as usize),
            "vocab_size {vocab_size} must exceed byte+special base {WORD_BASE}"
        );
        let mut freq: HashMap<&str, usize> = HashMap::new();
        for d in docs {
            for w in d.split_whitespace() {
                *freq.entry(w).or_default() += 1;
            }
        }
        let mut by_freq: Vec<(&str, usize)> = freq.into_iter().collect();
        by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let n_words = vocab_size - WORD_BASE as usize;
        let mut word_to_id = HashMap::new();
        let mut id_to_word = Vec::new();
        for (i, (w, _)) in by_freq.into_iter().take(n_words).enumerate() {
            word_to_id.insert(w.to_string(), WORD_BASE + i as u32);
            id_to_word.push(w.to_string());
        }
        Tokenizer { word_to_id, id_to_word, vocab_size }
    }

    pub fn vocab_len(&self) -> usize {
        (WORD_BASE as usize) + self.id_to_word.len()
    }

    pub fn capacity(&self) -> usize {
        self.vocab_size
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids = vec![BOS];
        let mut prev_was_bytes = false;
        for w in text.split_whitespace() {
            match self.word_to_id.get(w) {
                Some(&id) => {
                    ids.push(id);
                    prev_was_bytes = false;
                }
                None => {
                    // adjacent byte-fallback words need an explicit space
                    // byte so decode can recover the boundary
                    if prev_was_bytes {
                        ids.push(BYTE_BASE + b' ' as u32);
                    }
                    for b in w.bytes() {
                        ids.push(BYTE_BASE + b as u32);
                    }
                    prev_was_bytes = true;
                }
            }
        }
        ids.push(EOS);
        ids
    }

    /// Encode a batch of documents, fanned out over the worker pool.
    /// `par_map` preserves item order and `encode` is a pure function, so
    /// the result is independent of worker count.
    pub fn encode_batch(&self, docs: &[String], ctx: ParallelCtx) -> Vec<Vec<u32>> {
        par_map(ctx, docs, |d| self.encode(d))
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut out = String::new();
        let mut bytes: Vec<u8> = Vec::new();
        let mut first = true;
        let flush =
            |bytes: &mut Vec<u8>, out: &mut String, first: &mut bool| {
                if !bytes.is_empty() {
                    if !*first {
                        out.push(' ');
                    }
                    out.push_str(&String::from_utf8_lossy(bytes));
                    bytes.clear();
                    *first = false;
                }
            };
        for &id in ids {
            if id == PAD || id == BOS || id == EOS {
                flush(&mut bytes, &mut out, &mut first);
                continue;
            }
            if id >= WORD_BASE {
                flush(&mut bytes, &mut out, &mut first);
                let w = &self.id_to_word[(id - WORD_BASE) as usize];
                if !first {
                    out.push(' ');
                }
                out.push_str(w);
                first = false;
            } else {
                // contiguous byte tokens build one word
                bytes.push((id - BYTE_BASE) as u8);
            }
        }
        flush(&mut bytes, &mut out, &mut first);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn docs() -> Vec<String> {
        vec![
            "the quick brown fox jumps over the lazy dog.".to_string(),
            "the dog sleeps. the fox runs.".to_string(),
        ]
    }

    #[test]
    fn frequent_words_get_ids() {
        let t = Tokenizer::train(&docs(), 512);
        let ids = t.encode("the fox");
        assert_eq!(ids.len(), 4); // BOS the fox EOS
        assert!(ids[1] >= WORD_BASE && ids[2] >= WORD_BASE);
    }

    #[test]
    fn unknown_words_fall_back_to_bytes() {
        let t = Tokenizer::train(&docs(), 512);
        let ids = t.encode("zzz");
        assert_eq!(ids.len(), 2 + 3);
        assert!(ids[1..4].iter().all(|&i| (BYTE_BASE..WORD_BASE).contains(&i)));
    }

    #[test]
    fn roundtrip_lossless() {
        let t = Tokenizer::train(&docs(), 512);
        for s in [
            "the quick brown fox",
            "completely unseen wörds — here",
            "mixed the known zzz unknown dog",
        ] {
            assert_eq!(t.decode(&t.encode(s)), s);
        }
    }

    #[test]
    fn vocab_capped() {
        let many: Vec<String> =
            (0..2000).map(|i| format!("word{i} appears here")).collect();
        let t = Tokenizer::train(&many, 300);
        assert!(t.vocab_len() <= 300);
    }

    #[test]
    fn encode_batch_matches_sequential_and_worker_count() {
        let t = Tokenizer::train(&docs(), 512);
        let texts: Vec<String> = (0..16)
            .map(|i| format!("the fox number{i} jumps over unknown{i} dog"))
            .collect();
        let want: Vec<Vec<u32>> = texts.iter().map(|s| t.encode(s)).collect();
        for ctx in [ParallelCtx::serial(), ParallelCtx::new(2), ParallelCtx::new(8)] {
            assert_eq!(t.encode_batch(&texts, ctx), want);
        }
    }

    #[test]
    fn ids_below_capacity() {
        let t = Tokenizer::train(&docs(), 400);
        for id in t.encode("the quick brown unknownzz") {
            assert!((id as usize) < t.capacity());
        }
    }
}
