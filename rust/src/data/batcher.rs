//! Sequence packer + deterministic shuffled batcher.
//!
//! The token stream is cut into non-overlapping windows of `seq_len + 1`;
//! each window yields `tokens = w[..S]`, `targets = w[1..]` (next-token
//! prediction).  Window order is shuffled once per epoch with a seeded
//! Fisher–Yates, so training is reproducible and epoch boundaries are
//! explicit — mirroring the "no data repetition within budget" setup the
//! paper uses for C4.

use crate::util::Pcg32;

#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,  // (batch, seq) row-major
    pub targets: Vec<i32>, // (batch, seq)
    pub batch: usize,
    pub seq: usize,
}

pub struct Batcher {
    windows: Vec<usize>, // start offsets into `ids`
    ids: Vec<u32>,
    pub batch: usize,
    pub seq: usize,
    cursor: usize,
    epoch: u64,
    seed: u64,
    /// batch materialized ahead of time by [`Batcher::prefetch`]; `next`
    /// drains it first, so prefetching never changes the batch sequence
    pending: Option<Batch>,
}

impl Batcher {
    pub fn new(ids: Vec<u32>, batch: usize, seq: usize, seed: u64) -> Self {
        let stride = seq + 1;
        let n = if ids.len() >= stride { (ids.len() - 1) / seq } else { 0 };
        // non-overlapping windows at stride `seq` (the +1 target overlaps)
        let windows: Vec<usize> =
            (0..n).map(|i| i * seq).filter(|&s| s + stride <= ids.len()).collect();
        let mut b =
            Batcher { windows, ids, batch, seq, cursor: 0, epoch: 0, seed, pending: None };
        b.reshuffle();
        b
    }

    pub fn n_windows(&self) -> usize {
        self.windows.len()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn reshuffle(&mut self) {
        let mut rng = Pcg32::new(self.seed, self.epoch.wrapping_add(1));
        rng.shuffle(&mut self.windows);
        self.cursor = 0;
    }

    /// Next batch; wraps to a new shuffled epoch when exhausted.  Returns
    /// the prefetched batch first if one is pending, so interleaving
    /// [`Batcher::prefetch`] anywhere between `next` calls leaves the
    /// batch sequence unchanged.
    pub fn next(&mut self) -> Batch {
        match self.pending.take() {
            Some(b) => b,
            None => self.compute_next(),
        }
    }

    /// Materialize the next batch ahead of time (the dataflow trainer
    /// calls this concurrently with the update graph).  Idempotent: a
    /// second call before `next` is a no-op.
    pub fn prefetch(&mut self) {
        if self.pending.is_none() {
            let b = self.compute_next();
            self.pending = Some(b);
        }
    }

    fn compute_next(&mut self) -> Batch {
        assert!(
            self.windows.len() >= self.batch,
            "need >= {} windows, have {}",
            self.batch,
            self.windows.len()
        );
        if self.cursor + self.batch > self.windows.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for bi in 0..self.batch {
            let start = self.windows[self.cursor + bi];
            let w = &self.ids[start..start + self.seq + 1];
            tokens.extend(w[..self.seq].iter().map(|&t| t as i32));
            targets.extend(w[1..].iter().map(|&t| t as i32));
        }
        self.cursor += self.batch;
        Batch { tokens, targets, batch: self.batch, seq: self.seq }
    }

    /// All validation batches (no shuffle, in order, drop remainder).
    pub fn sequential_batches(&self) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut sorted = self.windows.clone();
        sorted.sort_unstable();
        for chunk in sorted.chunks(self.batch) {
            if chunk.len() < self.batch {
                break;
            }
            let mut tokens = Vec::with_capacity(self.batch * self.seq);
            let mut targets = Vec::with_capacity(self.batch * self.seq);
            for &start in chunk {
                let w = &self.ids[start..start + self.seq + 1];
                tokens.extend(w[..self.seq].iter().map(|&t| t as i32));
                targets.extend(w[1..].iter().map(|&t| t as i32));
            }
            out.push(Batch { tokens, targets, batch: self.batch, seq: self.seq });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(n: usize) -> Vec<u32> {
        (0..n as u32).collect()
    }

    #[test]
    fn targets_shift_tokens_by_one() {
        let mut b = Batcher::new(ids(1000), 2, 16, 1);
        let batch = b.next();
        for row in 0..2 {
            for i in 0..15 {
                assert_eq!(
                    batch.tokens[row * 16 + i + 1],
                    batch.targets[row * 16 + i]
                );
            }
        }
    }

    #[test]
    fn epochs_cover_all_windows_once() {
        let mut b = Batcher::new(ids(16 * 10 + 1), 2, 16, 2);
        let n = b.n_windows();
        assert_eq!(n, 10);
        let mut starts = Vec::new();
        for _ in 0..5 {
            let batch = b.next();
            for row in 0..2 {
                starts.push(batch.tokens[row * 16] as usize);
            }
        }
        starts.sort_unstable();
        assert_eq!(starts, (0..10).map(|i| i * 16).collect::<Vec<_>>());
        assert_eq!(b.epoch(), 0);
        b.next();
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Batcher::new(ids(2000), 4, 32, 3);
        let mut b = Batcher::new(ids(2000), 4, 32, 3);
        for _ in 0..10 {
            assert_eq!(a.next().tokens, b.next().tokens);
        }
        let mut c = Batcher::new(ids(2000), 4, 32, 4);
        assert_ne!(a.next().tokens, c.next().tokens);
    }

    #[test]
    fn prefetch_does_not_change_the_batch_sequence() {
        let mut plain = Batcher::new(ids(2000), 4, 32, 7);
        let mut pre = Batcher::new(ids(2000), 4, 32, 7);
        for i in 0..30 {
            // interleave prefetch in several patterns, including across an
            // epoch wrap and double-prefetch (idempotence)
            if i % 3 == 0 {
                pre.prefetch();
            }
            if i % 7 == 0 {
                pre.prefetch();
                pre.prefetch();
            }
            let a = plain.next();
            let b = pre.next();
            assert_eq!(a.tokens, b.tokens, "batch {i} diverged");
            assert_eq!(a.targets, b.targets, "batch {i} diverged");
        }
        assert_eq!(plain.epoch(), pre.epoch());
    }

    #[test]
    fn sequential_batches_ordered() {
        let b = Batcher::new(ids(16 * 6 + 1), 2, 16, 5);
        let seq = b.sequential_batches();
        assert_eq!(seq.len(), 3);
        assert_eq!(seq[0].tokens[0], 0);
        assert_eq!(seq[1].tokens[0], 32 as i32);
    }
}
