//! Synthetic "C4-like" corpus generator.
//!
//! Documents are paragraphs of sentences produced by a small template
//! grammar over a Zipf-weighted word list, with an order-2 Markov kick:
//! the choice of each content word is biased by the previous one via a
//! deterministic affinity hash.  The result has (a) a long-tailed unigram
//! distribution, (b) strong local bigram structure a language model can
//! learn, and (c) enough entropy that it cannot be memorized by a tiny
//! model — perplexity curves behave qualitatively like real text.

use crate::linalg::{par_map, ParallelCtx};
use crate::util::Pcg32;

/// Base word inventory; inflections multiply this into a few thousand
/// surface forms.
const STEMS: &[&str] = &[
    "time", "year", "people", "way", "day", "man", "thing", "woman", "life",
    "child", "world", "school", "state", "family", "student", "group",
    "country", "problem", "hand", "part", "place", "case", "week", "company",
    "system", "program", "question", "work", "government", "number", "night",
    "point", "home", "water", "room", "mother", "area", "money", "story",
    "fact", "month", "lot", "right", "study", "book", "eye", "job", "word",
    "business", "issue", "side", "kind", "head", "house", "service", "friend",
    "father", "power", "hour", "game", "line", "end", "member", "law", "car",
    "city", "community", "name", "president", "team", "minute", "idea", "kid",
    "body", "information", "back", "parent", "face", "others", "level",
    "office", "door", "health", "person", "art", "war", "history", "party",
    "result", "change", "morning", "reason", "research", "girl", "guy",
    "moment", "air", "teacher", "force", "education",
];

const VERBS: &[&str] = &[
    "is", "has", "makes", "takes", "sees", "gets", "finds", "gives", "tells",
    "asks", "works", "seems", "feels", "tries", "leaves", "calls", "keeps",
    "holds", "turns", "shows", "plays", "runs", "moves", "lives", "believes",
    "brings", "happens", "writes", "provides", "sits", "stands", "loses",
    "pays", "meets", "includes", "continues", "sets", "learns", "changes",
    "leads", "understands", "watches", "follows", "stops", "creates",
    "speaks", "reads", "allows", "adds", "spends", "grows", "opens", "walks",
    "wins", "offers", "remembers", "loves", "considers", "appears", "buys",
    "waits", "serves", "dies", "sends", "expects", "builds",
];

const ADJS: &[&str] = &[
    "new", "good", "high", "old", "great", "big", "small", "large", "young",
    "different", "long", "little", "important", "bad", "right", "early",
    "social", "able", "late", "hard", "major", "better", "economic", "strong",
    "possible", "whole", "free", "military", "true", "federal", "human",
    "local", "sure", "clear", "recent", "certain", "personal", "open", "red",
    "difficult", "available", "likely", "short", "single", "medical",
    "current", "wrong", "private", "past", "foreign", "fine", "common",
    "poor", "natural", "significant", "similar", "hot", "dead", "central",
    "happy", "serious", "ready", "simple", "left", "physical", "general",
];

const FUNCTION_WORDS: &[&str] = &[
    "the", "of", "and", "a", "to", "in", "that", "it", "with", "as", "for",
    "on", "was", "at", "by", "this", "from", "or", "an", "but", "not",
    "what", "all", "were", "when", "we", "there", "can", "more", "if", "no",
    "out", "so", "up", "said", "about", "than", "into", "them", "only",
    "some", "could", "these", "two", "may", "then", "do", "first", "any",
    "my", "now", "such", "like", "our", "over", "even",
];

pub struct CorpusGenerator {
    /// deterministic "topic" hash salt — distinct seeds give distinct
    /// word-affinity structure (used to create distinct fine-tune "tasks").
    salt: u64,
    /// rotates every word pool, shifting the unigram head — labels in the
    /// fine-tune tasks each get a distinct rotation so their marginal word
    /// distributions differ strongly (a learnable topic signal)
    rot: usize,
}

impl CorpusGenerator {
    pub fn new(salt: u64) -> Self {
        CorpusGenerator { salt, rot: 0 }
    }

    /// Zipf-ish index into a slice: rank ~ 1/(k+1).
    fn zipf(&self, rng: &mut Pcg32, n: usize) -> usize {
        let u = rng.next_f32().max(1e-6);
        let h = ((n as f32).ln() * u).exp() - 1.0;
        (h as usize).min(n - 1)
    }

    /// Affinity-biased content-word pick: the previous word hash narrows the
    /// candidate window, creating learnable bigram structure.
    fn content_word(&self, rng: &mut Pcg32, prev_hash: u64, pool: &[&str]) -> &'static str {
        let window = 16.min(pool.len());
        let base = ((prev_hash ^ self.salt).wrapping_mul(0x9e3779b97f4a7c15) >> 33) as usize
            % (pool.len() - window + 1);
        let idx = (base + self.zipf(rng, window) + self.rot) % pool.len();
        // SAFETY of lifetimes: all pools are 'static string tables.
        unsafe { std::mem::transmute::<&str, &'static str>(pool[idx]) }
    }

    fn hash(w: &str) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for b in w.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    pub fn sentence(&self, rng: &mut Pcg32) -> String {
        let mut out = String::new();
        let mut prev = Self::hash("the");
        let clauses = 1 + rng.below(3);
        for c in 0..clauses {
            if c > 0 {
                out.push_str(", ");
                out.push_str(FUNCTION_WORDS[self.zipf(rng, FUNCTION_WORDS.len())]);
                out.push(' ');
            }
            let subj_adj = self.content_word(rng, prev, ADJS);
            prev = Self::hash(subj_adj);
            let subj = self.content_word(rng, prev, STEMS);
            prev = Self::hash(subj);
            let verb = self.content_word(rng, prev, VERBS);
            prev = Self::hash(verb);
            let obj_adj = self.content_word(rng, prev, ADJS);
            prev = Self::hash(obj_adj);
            let obj = self.content_word(rng, prev, STEMS);
            prev = Self::hash(obj);
            out.push_str("the ");
            out.push_str(subj_adj);
            out.push(' ');
            out.push_str(subj);
            out.push(' ');
            out.push_str(verb);
            out.push(' ');
            out.push_str(FUNCTION_WORDS[self.zipf(rng, FUNCTION_WORDS.len())]);
            out.push(' ');
            out.push_str(obj_adj);
            out.push(' ');
            out.push_str(obj);
        }
        out.push('.');
        out
    }

    pub fn document(&self, rng: &mut Pcg32) -> String {
        let sentences = 4 + rng.below(12);
        let mut doc = String::new();
        for s in 0..sentences {
            if s > 0 {
                doc.push(' ');
            }
            doc.push_str(&self.sentence(rng));
        }
        doc
    }

    /// Batch document generation over the worker pool (the data pipeline is
    /// embarrassingly parallel).  Document `i` draws from its own PCG
    /// stream keyed by `(seed, i)` — the same chunking discipline as
    /// `quant::uniform_noise` — so the corpus is a pure function of
    /// `(salt, seed, n)`, independent of worker count and of which worker
    /// generated which document (`par_map` preserves order).
    pub fn documents(&self, n: usize, seed: u64, ctx: ParallelCtx) -> Vec<String> {
        let idx: Vec<u64> = (0..n as u64).collect();
        par_map(ctx, &idx, |&i| self.document(&mut Pcg32::new(seed, i)))
    }

    /// A labeled classification example for the synthetic fine-tuning tasks
    /// (GLUE/MMLU substitute): `label` selects a salt, which changes the
    /// bigram affinity structure — the model must pick up distributional
    /// differences, like topic classification.
    pub fn labeled_example(&self, rng: &mut Pcg32, label: usize) -> String {
        let sub = CorpusGenerator {
            salt: self.salt ^ ((label as u64 + 1) * 0x9e37),
            rot: self.rot + label * 23,
        };
        // Each label also carries a signature clause (topic phrase):
        // p(signature words | label) is sharply peaked, so a model that
        // conditions on the label prefix can cut its loss on every sentence
        // — the learnable core of the classification task.
        let salt = self.salt as usize;
        let sig_adj = ADJS[(label * 17 + salt * 3 + 3) % ADJS.len()];
        let sig_stem = STEMS[(label * 29 + salt * 7 + 5) % STEMS.len()];
        let sig_verb = VERBS[(label * 11 + salt * 5 + 7) % VERBS.len()];
        let mut s = sub.sentence(rng);
        s.pop(); // drop the trailing '.'
        s.push_str(&format!(", the {sig_adj} {sig_stem} {sig_verb}."));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentences_look_sane() {
        let gen = CorpusGenerator::new(1);
        let mut rng = Pcg32::seeded(1);
        for _ in 0..20 {
            let s = gen.sentence(&mut rng);
            assert!(s.ends_with('.'));
            assert!(s.split_whitespace().count() >= 6);
        }
    }

    #[test]
    fn documents_are_deterministic_per_seed() {
        let gen = CorpusGenerator::new(2);
        let a = gen.document(&mut Pcg32::seeded(5));
        let b = gen.document(&mut Pcg32::seeded(5));
        let c = gen.document(&mut Pcg32::seeded(6));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn batch_documents_independent_of_worker_count() {
        // per-document PCG streams: the generated corpus must not depend on
        // how the batch was split over workers
        let gen = CorpusGenerator::new(2);
        let want = gen.documents(24, 5, ParallelCtx::serial());
        assert_eq!(want.len(), 24);
        for t in [2usize, 8] {
            assert_eq!(
                gen.documents(24, 5, ParallelCtx::new(t)),
                want,
                "corpus changed with {t} workers"
            );
        }
        // distinct documents and distinct seeds actually differ
        assert_ne!(want[0], want[1]);
        assert_ne!(gen.documents(24, 6, ParallelCtx::serial()), want);
    }

    #[test]
    fn unigram_distribution_is_long_tailed() {
        let gen = CorpusGenerator::new(3);
        let mut rng = Pcg32::seeded(7);
        let mut counts = std::collections::HashMap::<String, usize>::new();
        for _ in 0..200 {
            for w in gen.document(&mut rng).split_whitespace() {
                *counts.entry(w.trim_matches(&['.', ','][..]).to_string()).or_default() += 1;
            }
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        // head much heavier than tail
        assert!(freqs[0] > 10 * freqs[freqs.len() / 2].max(1));
        assert!(counts.len() > 100);
    }

    #[test]
    fn labels_shift_distribution() {
        let gen = CorpusGenerator::new(4);
        let mut rng = Pcg32::seeded(9);
        let mut count_a = std::collections::HashMap::<&str, usize>::new();
        let mut count_b = std::collections::HashMap::<&str, usize>::new();
        for _ in 0..300 {
            let sa = gen.labeled_example(&mut rng, 0);
            let sb = gen.labeled_example(&mut rng, 1);
            for w in sa.leak().split_whitespace() {
                *count_a.entry(w).or_default() += 1;
            }
            for w in sb.leak().split_whitespace() {
                *count_b.entry(w).or_default() += 1;
            }
        }
        // distributions must differ measurably (L1 distance over union)
        let keys: std::collections::HashSet<_> =
            count_a.keys().chain(count_b.keys()).collect();
        let total_a: usize = count_a.values().sum();
        let total_b: usize = count_b.values().sum();
        let mut l1 = 0f64;
        for k in keys {
            let pa = *count_a.get(*k).unwrap_or(&0) as f64 / total_a as f64;
            let pb = *count_b.get(*k).unwrap_or(&0) as f64 / total_b as f64;
            l1 += (pa - pb).abs();
        }
        assert!(l1 > 0.3, "label distributions too similar: {l1}");
    }
}
