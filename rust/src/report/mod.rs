//! Rendering helpers for the repro harness: aligned markdown tables and CSV
//! series files (the paper's figures are emitted as CSV so any plotter can
//! regenerate them).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// Simple aligned markdown table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            out.push('|');
            for i in 0..ncol {
                let _ = write!(out, " {:<w$} |", cells[i], w = widths[i]);
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{:-<w$}|", "", w = w + 2);
        }
        out.push('\n');
        for r in &self.rows {
            fmt_row(r, &widths, &mut out);
        }
        out
    }
}

/// Write a CSV with a header row (figure series).
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut s = header.join(",");
    s.push('\n');
    for r in rows {
        s.push_str(&r.join(","));
        s.push('\n');
    }
    std::fs::write(path, s).with_context(|| format!("writing {}", path.display()))
}

pub fn f(v: f32) -> String {
    format!("{v:.2}")
}

pub fn f4(v: f32) -> String {
    format!("{v:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "PPL"]);
        t.row(vec!["Full".into(), "34.06".into()]);
        t.row(vec!["Q-GaLore".into(), "34.88".into()]);
        let s = t.render();
        assert!(s.contains("| Method   | PPL   |"));
        assert!(s.lines().count() == 4);
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn csv_written() {
        // unique dir: a fixed path collides when test binaries run in
        // parallel (CI runs the suite at several thread counts at once)
        let p = crate::util::unique_temp_dir("report").join("qgalore_report_test.csv");
        write_csv(&p, &["step", "loss"], &[vec!["1".into(), "2.5".into()]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "step,loss\n1,2.5\n");
    }
}
