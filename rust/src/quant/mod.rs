//! Block-wise quantization — host mirror of the L1 Pallas kernels.
//!
//! The hot-path quantization runs inside the AOT HLO artifacts; this module
//! is the coordinator-side implementation used for (a) initializing the
//! quantized storage buffers, (b) the subspace scheduler's INT4 projection
//! refresh (control path, every ~200 steps), (c) checkpoint IO, and
//! (d) cross-checking the HLO kernels in integration tests.
//!
//! The arithmetic mirrors `python/compile/kernels/ref.py` — including
//! round-half-to-even, which `jnp.round` uses (NOT `f32::round`).  The hot
//! loops use reciprocal-multiply and magic-number rounding; both can differ
//! from the oracle by one code at exact tie boundaries, which every
//! cross-check (tests, integration) budgets for.
//!
//! # Storage formats and the epoch protocol
//!
//! Three packed formats carry the paper's bit-width matrix: [`QuantTensor`]
//! (i8 codes, the INT8 weight / 8-bit ablation format), [`Quant4Tensor`]
//! (two nibble codes per byte, the §3.3 projection format), and
//! [`Quant2Tensor`] (four 2-bit codes per byte, the Figure-3 2-bit
//! ablation).  Every tensor carries a process-unique **quantization epoch**
//! stamped at construction (each `quantize*` call draws a fresh one; an
//! in-place mutation must call `bump_epoch`).  The epoch is how derived
//! caches — the [`crate::linalg::packing`] panel packs — know whether they
//! still describe the tensor's contents: a subspace refresh produces a new
//! tensor with a new epoch, so a pack keyed to the old epoch can never be
//! read against the new codes (the `*_prepacked` entry points assert the
//! match).  `Clone` keeps the epoch: identical codes, identical decode.
//!
//! # Fused vs prepacked application
//!
//! The `dequant*_matmul` family applies packed tensors without a full fp32
//! copy, decoding bounded tiles per worker (see the fused section below).
//! In Q-GaLore's steady state the SAME frozen projection multiplies
//! hundreds of consecutive gradients between refreshes, so the
//! `*_prepacked` variants skip even the per-call decode: a
//! [`crate::linalg::packing::PanelPack`] decodes once at refresh time
//! (both orientations, identical `(code - zero) * scale` arithmetic via
//! `dequant_at`) and every later call feeds the microkernel the cached
//! panels directly.  Decode timing never touches per-element accumulation
//! order, so fused and prepacked results are bitwise identical — asserted
//! across the tail-class shape sweep in `tests/parity.rs` and the
//! scheduler-equivalence properties in `tests/proptests.rs`.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::linalg::packing::PanelPack;
use crate::linalg::{engine, Mat, ParallelCtx};
use crate::util::Pcg32;

/// Monotone source of quantization epochs.  Starts at 1 so 0 can never
/// collide with a real epoch (handy as a sentinel in caches).
static NEXT_EPOCH: AtomicU64 = AtomicU64::new(1);

/// Draw a process-unique epoch for a freshly produced code buffer.
fn fresh_epoch() -> u64 {
    NEXT_EPOCH.fetch_add(1, Ordering::Relaxed)
}

/// Paper §3.1: block size 256 everywhere; tensors smaller than one block use
/// a single block of their own size.
pub const BLOCK: usize = 256;
pub const EPS: f32 = 1e-8;

/// Effective block size for a tensor of `numel` elements.
pub fn block_for(numel: usize) -> usize {
    let b = BLOCK.min(numel);
    assert_eq!(numel % b, 0, "numel {numel} not divisible by block {b}");
    b
}

/// Round half-to-even via the classic magic-number trick: adding and
/// subtracting 1.5·2²³ forces the FPU to round at integer granularity with
/// the default (ties-even) rounding mode.  Exact for |v| < 2²², which every
/// in-range quantization code satisfies; the rare out-of-range value (a
/// degenerate block with scale floored at EPS) falls back to the library
/// call and is clamped afterwards anyway.  ~2.3x faster than
/// `f32::round_ties_even` in the quantize hot loop (§Perf).
#[inline]
fn fast_round_ties_even(v: f32) -> f32 {
    const MAGIC: f32 = 12_582_912.0; // 1.5 * 2^23
    if v.abs() < 4_194_304.0 {
        (v + MAGIC) - MAGIC
    } else {
        v.round_ties_even()
    }
}

fn qrange(bits: u32) -> (f32, f32) {
    let qmin = -(1i64 << (bits - 1)) as f32;
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    (qmin, qmax)
}

fn stats(block: &[f32], bits: u32) -> (f32, f32) {
    let (qmin, qmax) = qrange(bits);
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &x in block {
        mn = mn.min(x);
        mx = mx.max(x);
    }
    let scale = ((mx - mn) / (qmax - qmin)).max(EPS);
    let zero = qmin - (mn / scale).round_ties_even();
    (scale, zero)
}

/// INT8 (or narrower, stored in i8) block-quantized tensor.
#[derive(Clone, Debug)]
pub struct QuantTensor {
    pub q: Vec<i8>,
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
    pub bits: u32,
    pub block: usize,
    /// Quantization epoch (see the module docs): private so no code buffer
    /// can change identity without the epoch moving with it.
    epoch: u64,
}

impl QuantTensor {
    /// Assemble a tensor from raw parts (checkpoint IO, artifact outputs).
    /// Stamps a fresh epoch — the parts are a new code buffer as far as
    /// any panel cache is concerned.
    pub fn new(q: Vec<i8>, scale: Vec<f32>, zero: Vec<f32>, bits: u32, block: usize) -> Self {
        QuantTensor { q, scale, zero, bits, block, epoch: fresh_epoch() }
    }

    pub fn numel(&self) -> usize {
        self.q.len()
    }

    pub fn nblocks(&self) -> usize {
        self.scale.len()
    }

    /// Storage bytes actually held by this tensor (codes + per-block stats).
    pub fn storage_bytes(&self) -> usize {
        self.q.len() + self.scale.len() * 4 + self.zero.len() * 4
    }

    /// The quantization epoch this code buffer was stamped with.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Re-stamp after an in-place mutation of codes or stats, so stale
    /// panel packs keyed to the old epoch can never be read against the
    /// new contents.
    pub fn bump_epoch(&mut self) {
        self.epoch = fresh_epoch();
    }

    /// Decode the element at flat index `idx` — THE decode arithmetic
    /// (`(code - zero) * scale`), shared verbatim by the fused kernels and
    /// the panel packer so they cannot drift.
    #[inline]
    pub fn dequant_at(&self, idx: usize) -> f32 {
        let bi = idx / self.block;
        (self.q[idx] as f32 - self.zero[bi]) * self.scale[bi]
    }
}

/// Round-to-nearest block-wise quantization (paper §3.1).
///
/// Perf note (§Perf, EXPERIMENTS.md): reciprocal-multiply + magic-number
/// rounding in the inner loop: 74 -> 182 Melem/s on the 1M-element bench.
pub fn quantize(x: &[f32], bits: u32) -> QuantTensor {
    let block = block_for(x.len());
    let (qmin, qmax) = qrange(bits);
    let nb = x.len() / block;
    let mut q = Vec::with_capacity(x.len());
    let mut scale = Vec::with_capacity(nb);
    let mut zero = Vec::with_capacity(nb);
    for blk in x.chunks(block) {
        let (s, z) = stats(blk, bits);
        let inv = 1.0 / s;
        for &v in blk {
            let code = (fast_round_ties_even(v * inv) + z).clamp(qmin, qmax);
            q.push(code as i8);
        }
        scale.push(s);
        zero.push(z);
    }
    QuantTensor { q, scale, zero, bits, block, epoch: fresh_epoch() }
}

/// Stochastic-rounding quantization (paper §3.4): floor(v + u), u ~ U[0,1).
/// The caller supplies the RNG so runs replay exactly: one u64 is drawn
/// from it to key the noise, and each quantization block then draws from
/// its own PCG stream — a thread-count-independent chunking, so the result
/// is identical whether the fill runs serially or fanned over the worker
/// pool (gated by [`engine::PAR_MIN_CLONE_ELEMS`] like every marshalling
/// fan-out).
pub fn sr_quantize(x: &[f32], bits: u32, rng: &mut Pcg32) -> QuantTensor {
    sr_quantize_with(x, bits, rng, ParallelCtx::global())
}

/// [`sr_quantize`] with an explicit parallelism context.
pub fn sr_quantize_with(x: &[f32], bits: u32, rng: &mut Pcg32, ctx: ParallelCtx) -> QuantTensor {
    let block = block_for(x.len());
    let (qmin, qmax) = qrange(bits);
    let nb = x.len() / block;
    let mut scale = Vec::with_capacity(nb);
    let mut zero = Vec::with_capacity(nb);
    for blk in x.chunks(block) {
        let (s, z) = stats(blk, bits);
        scale.push(s);
        zero.push(z);
    }
    let base = rng.next_u64();
    let ctx = engine::clone_pool(x.len(), ctx);
    // per-block i8 chunks, not a full f32 intermediate: codes are produced
    // in their storage width, and par_map's order-preserving fan-out keeps
    // the block -> stream mapping independent of worker count
    let blocks: Vec<usize> = (0..nb).collect();
    let chunks: Vec<Vec<i8>> = engine::par_map(ctx, &blocks, |&bi| {
        let mut noise = Pcg32::new(base, bi as u64);
        let (s, z) = (scale[bi], zero[bi]);
        x[bi * block..(bi + 1) * block]
            .iter()
            .map(|&v| {
                let u = noise.next_f32();
                (v / s + z + u).floor().clamp(qmin, qmax) as i8
            })
            .collect()
    });
    let q: Vec<i8> = chunks.into_iter().flatten().collect();
    QuantTensor { q, scale, zero, bits, block, epoch: fresh_epoch() }
}

/// Chunk width of [`uniform_noise`]: each chunk draws from its own PCG
/// stream keyed by (seed, chunk index), so the fill is deterministic and
/// independent of worker count and chunk-to-worker assignment.
pub const NOISE_CHUNK: usize = 4096;

/// Deterministic parallel U[0,1) fill of `n` elements — the host-side SR
/// noise operand of the `qgalore_update` artifacts (generating it in-graph
/// with threefry cost ~1.7x the whole update on this backend;
/// EXPERIMENTS.md §Perf).  Serial below [`engine::PAR_MIN_CLONE_ELEMS`]
/// elements, else fanned over `ctx` on the worker pool.
pub fn uniform_noise(n: usize, seed: u64, ctx: ParallelCtx) -> Vec<f32> {
    if n == 0 {
        return Vec::new();
    }
    let rows = n.div_ceil(NOISE_CHUNK);
    let ctx = engine::clone_pool(n, ctx);
    let mut out = engine::par_rows(ctx, rows, NOISE_CHUNK, |r0, r1, slab| {
        for r in r0..r1 {
            let mut rng = Pcg32::new(seed, r as u64);
            for o in &mut slab[(r - r0) * NOISE_CHUNK..(r - r0 + 1) * NOISE_CHUNK] {
                *o = rng.next_f32();
            }
        }
    });
    out.truncate(n);
    out
}

pub fn dequantize(t: &QuantTensor) -> Vec<f32> {
    let mut out = Vec::with_capacity(t.q.len());
    for (bi, blk) in t.q.chunks(t.block).enumerate() {
        let (s, z) = (t.scale[bi], t.zero[bi]);
        for &c in blk {
            out.push((c as f32 - z) * s);
        }
    }
    out
}

/// INT4 nibble-packed tensor: two codes per byte (even index -> low nibble),
/// offset-binary within the nibble (code + 8).
#[derive(Clone, Debug)]
pub struct Quant4Tensor {
    pub packed: Vec<u8>,
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
    pub block: usize,
    /// logical element count (odd-length tensors pad the final high nibble)
    pub numel: usize,
    /// Quantization epoch (see the module docs).
    epoch: u64,
}

impl Quant4Tensor {
    pub fn numel(&self) -> usize {
        self.numel
    }

    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scale.len() * 4 + self.zero.len() * 4
    }

    /// The quantization epoch this code buffer was stamped with.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Re-stamp after an in-place mutation (see [`QuantTensor::bump_epoch`]).
    pub fn bump_epoch(&mut self) {
        self.epoch = fresh_epoch();
    }

    /// Reassemble a tensor from serialized parts (the delta-checkpoint
    /// load path).  Stamps a fresh epoch — any panel pack keyed to the
    /// tensor this was saved from is correctly treated as stale.
    pub fn from_parts(
        packed: Vec<u8>,
        scale: Vec<f32>,
        zero: Vec<f32>,
        block: usize,
        numel: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            packed.len() == numel.div_ceil(2),
            "quant4 from_parts: {} packed bytes for {numel} elems",
            packed.len()
        );
        anyhow::ensure!(block > 0, "quant4 from_parts: zero block size");
        let nb = numel.div_ceil(block);
        anyhow::ensure!(
            scale.len() == nb && zero.len() == nb,
            "quant4 from_parts: {}/{} scale/zero blocks for {nb} expected",
            scale.len(),
            zero.len()
        );
        Ok(Quant4Tensor { packed, scale, zero, block, numel, epoch: fresh_epoch() })
    }

    /// Decode the element at flat index `idx` — shared by the fused
    /// kernels and the panel packer (one arithmetic, zero drift).
    #[inline]
    pub fn dequant_at(&self, idx: usize) -> f32 {
        let bi = idx / self.block;
        (code4_at(&self.packed, idx) as f32 - self.zero[bi]) * self.scale[bi]
    }
}

/// Nibble-pack INT4 codes (two per byte). Odd lengths pad the trailing
/// high nibble with code 0; `unpack_int4` therefore returns an even count
/// and callers truncate to the logical length.
pub fn pack_int4(codes: &[i8]) -> Vec<u8> {
    codes
        .chunks(2)
        .map(|p| {
            let lo = (p[0] + 8) as u8 & 0xF;
            let hi = (p.get(1).copied().unwrap_or(0) + 8) as u8 & 0xF;
            lo | (hi << 4)
        })
        .collect()
}

pub fn unpack_int4(packed: &[u8]) -> Vec<i8> {
    let mut out = Vec::with_capacity(packed.len() * 2);
    for &b in packed {
        out.push((b & 0xF) as i8 - 8);
        out.push(((b >> 4) & 0xF) as i8 - 8);
    }
    out
}

/// Quantize to INT4 and nibble-pack (the projection-matrix format, §3.3).
pub fn quantize4(x: &[f32]) -> Quant4Tensor {
    let t = quantize(x, 4);
    Quant4Tensor {
        packed: pack_int4(&t.q),
        scale: t.scale,
        zero: t.zero,
        block: t.block,
        numel: x.len(),
        epoch: fresh_epoch(),
    }
}

pub fn dequantize4(t: &Quant4Tensor) -> Vec<f32> {
    let mut codes = unpack_int4(&t.packed);
    codes.truncate(t.numel);
    let mut out = Vec::with_capacity(codes.len());
    for (bi, blk) in codes.chunks(t.block).enumerate() {
        let (s, z) = (t.scale[bi], t.zero[bi]);
        for &c in blk {
            out.push((c as f32 - z) * s);
        }
    }
    out
}

/// 2-bit sub-byte-packed tensor: four codes per byte, ascending element
/// index from the least-significant bit pair, offset-binary within the
/// pair (code + 2, so codes −2..=1 pack as 0..=3).  The Figure-3 2-bit
/// ablation projection format — previously stored one i8 per code, 4× the
/// bytes this layout needs.
#[derive(Clone, Debug)]
pub struct Quant2Tensor {
    pub packed: Vec<u8>,
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
    pub block: usize,
    /// logical element count (lengths not divisible by 4 pad the final
    /// byte's high pairs with code 0)
    pub numel: usize,
    /// Quantization epoch (see the module docs).
    epoch: u64,
}

impl Quant2Tensor {
    pub fn numel(&self) -> usize {
        self.numel
    }

    pub fn nblocks(&self) -> usize {
        self.scale.len()
    }

    pub fn storage_bytes(&self) -> usize {
        self.packed.len() + self.scale.len() * 4 + self.zero.len() * 4
    }

    /// The quantization epoch this code buffer was stamped with.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Re-stamp after an in-place mutation (see [`QuantTensor::bump_epoch`]).
    pub fn bump_epoch(&mut self) {
        self.epoch = fresh_epoch();
    }

    /// Decode the element at flat index `idx` — shared by the fused
    /// kernels and the panel packer.
    #[inline]
    pub fn dequant_at(&self, idx: usize) -> f32 {
        let bi = idx / self.block;
        (code2_at(&self.packed, idx) as f32 - self.zero[bi]) * self.scale[bi]
    }
}

/// Pack 2-bit codes four to a byte (codes must lie in −2..=1, the
/// `qrange(2)` interval).  Lengths not divisible by 4 pad trailing pairs
/// with code 0; `unpack_int2` therefore returns a multiple of 4 and
/// callers truncate to the logical length.
pub fn pack_int2(codes: &[i8]) -> Vec<u8> {
    codes
        .chunks(4)
        .map(|p| {
            let mut byte = 0u8;
            for (i, &c) in p.iter().enumerate() {
                byte |= (((c + 2) as u8) & 0x3) << (2 * i);
            }
            byte
        })
        .collect()
}

pub fn unpack_int2(packed: &[u8]) -> Vec<i8> {
    let mut out = Vec::with_capacity(packed.len() * 4);
    for &b in packed {
        for i in 0..4 {
            out.push(((b >> (2 * i)) & 0x3) as i8 - 2);
        }
    }
    out
}

/// Quantize to 2 bits and sub-byte-pack (the Figure-3 ablation format).
pub fn quantize2(x: &[f32]) -> Quant2Tensor {
    let t = quantize(x, 2);
    Quant2Tensor {
        packed: pack_int2(&t.q),
        scale: t.scale,
        zero: t.zero,
        block: t.block,
        numel: x.len(),
        epoch: fresh_epoch(),
    }
}

pub fn dequantize2(t: &Quant2Tensor) -> Vec<f32> {
    let mut codes = unpack_int2(&t.packed);
    codes.truncate(t.numel);
    let mut out = Vec::with_capacity(codes.len());
    for (bi, blk) in codes.chunks(t.block).enumerate() {
        let (s, z) = (t.scale[bi], t.zero[bi]);
        for &c in blk {
            out.push((c as f32 - z) * s);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fused dequantize-matmul paths.
//
// Q-GaLore's INT4 projection and INT8 weights are applied without ever
// materializing a full fp32 copy: each worker dequantizes a bounded panel
// (a DEQUANT_ROW_TILE row group, or a transposed column sub-panel) into a
// reused scratch and feeds the engine's register-blocked microkernel —
// multi-row panels, so the kernel forms full MR x NR register tiles
// instead of degenerating to single-row edge work.
//
// Submission rides `engine::par_rows`, which hands the work-stealing pool
// one task per disjoint output slab — over-decomposed since the Chase-Lev
// rewrite (cost-model slab counts, or the pinned `slabs_per_worker`
// multiplier), so a straggler dequant slab is stolen rather than
// serializing the wave.  Each task owns its slab AND dequantizes into a
// per-thread scratch buffer (`with_dequant_scratch`: one thread-local
// allocation reused across every task a worker ever runs, instead of a
// fresh Vec per stolen slab), so wherever a task lands it writes only
// that thread's scratch and no steal interleaving can alias another
// worker's panel.  Every scratch element a tile reads is overwritten
// first, so reuse is invisible in the values.  The `deq` closures decode
// PACKED storage by absolute flat element index (via the tensors'
// `dequant_at`) and the row-group/sub-panel walks below are keyed by
// absolute output position, so slab boundaries change only who decodes
// which rows — never a decoded value or the per-element ascending-k
// accumulation order, both of which match `dequantize* -> Mat::*_naive`.
// Parity with the unfused reference is therefore bitwise for any worker
// count, any slab count, queue discipline (FIFO / mutex-deque baselines
// or Chase-Lev stealing), and steal order (asserted by tests/parity.rs
// and the scheduler-equivalence property in tests/proptests.rs).
//
// The `*_prepacked` variants skip the decode entirely: a PanelPack built
// at refresh time (same `dequant_at` arithmetic, epoch-checked against
// the tensor) IS the decoded panel, in both orientations, so each call
// reduces to `par_rows` + the microkernel over cached rows.  Identical
// panel values + identical accumulation order = identical bits.
// ---------------------------------------------------------------------------

/// Decode the INT4 code at flat index `idx` from a nibble-packed buffer.
#[inline]
fn code4_at(packed: &[u8], idx: usize) -> i8 {
    let b = packed[idx / 2];
    let nib = if idx % 2 == 0 { b & 0xF } else { b >> 4 };
    nib as i8 - 8
}

/// Decode the 2-bit code at flat index `idx` from a sub-byte-packed buffer.
#[inline]
fn code2_at(packed: &[u8], idx: usize) -> i8 {
    let b = packed[idx / 4];
    ((b >> (2 * (idx % 4))) & 0x3) as i8 - 2
}

/// Rows of dequantized scratch a plain-orientation worker feeds the
/// microkernel at once — a multiple of [`engine::MR`] so the kernel forms
/// full register tiles, bounded so scratch stays at O(tile * cols) floats.
const DEQUANT_ROW_TILE: usize = 8 * engine::MR;

thread_local! {
    /// Per-thread dequant scratch, reused across every fused-kernel task a
    /// worker (or helping submitter) ever runs.  Sized by the largest tile
    /// seen so far — bounded by [`DEQUANT_ROW_TILE`] / [`DEQUANT_PANEL_COLS`]
    /// times the operand's inner dimension — so steady-state training does
    /// zero allocator round-trips on the dequant path, where every stolen
    /// slab used to allocate (and free) its own Vec.
    static DEQUANT_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` on this thread's dequant scratch, grown to at least `len`
/// elements.  The slice may hold stale values from a previous task — every
/// caller fully overwrites the prefix it feeds the microkernel, so reuse
/// is invisible in the output bits.  Not reentrant: `f` must not dispatch
/// back into a fused dequant body on the same thread (the task bodies
/// below only decode + call the serial microkernel, so they cannot).
fn with_dequant_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    DEQUANT_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// Shared body of the plain-orientation fused paths:
/// `deq(A) (rows, cols) @ x (cols, n)` where `deq` decodes the flat
/// element index from whatever packed storage the caller owns.  Each
/// worker dequantizes [`DEQUANT_ROW_TILE`]-row groups into a reused
/// scratch panel and runs the microkernel on each group, so every storage
/// format shares one tile loop and cannot drift from the others.
fn dequant_rows_matmul(
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
    deq: impl Fn(usize) -> f32 + Sync,
) -> Mat {
    let n = x.cols;
    let ctx = engine::effective(ctx, rows, cols, n);
    let data = engine::par_rows(ctx, rows, n, |r0, r1, out| {
        with_dequant_scratch(DEQUANT_ROW_TILE.min(r1 - r0) * cols, |tile| {
            let mut rs = r0;
            while rs < r1 {
                let re = (rs + DEQUANT_ROW_TILE).min(r1);
                let tw = re - rs;
                let base = rs * cols;
                for (t, tb) in tile[..tw * cols].iter_mut().enumerate() {
                    *tb = deq(base + t);
                }
                engine::panel_matmul(
                    &tile[..tw * cols],
                    tw,
                    cols,
                    x,
                    &mut out[(rs - r0) * n..(re - r0) * n],
                );
                rs = re;
            }
        });
    });
    Mat { rows, cols: n, data }
}

/// `dequant(W) (rows, cols) @ x (cols, n)` for blockwise-INT8 `w`.
pub fn dequant8_matmul(
    w: &QuantTensor,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(w.q.len(), rows * cols, "dequant8_matmul: shape mismatch");
    assert_eq!(x.rows, cols, "dequant8_matmul: inner dim mismatch");
    dequant_rows_matmul(rows, cols, x, ctx, |idx| w.dequant_at(idx))
}

/// [`dequant8_matmul`] against a panel pack built at refresh time: the
/// per-call decode disappears.  Bitwise identical to the fused path (the
/// pack holds the same `dequant_at` values; the accumulation order never
/// changes).  Panics if `pack` does not match `w`'s epoch and shape — a
/// stale pack is a cache-invalidation bug, never silently read.
pub fn dequant8_matmul_prepacked(
    w: &QuantTensor,
    pack: &PanelPack,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(w.q.len(), rows * cols, "dequant8_matmul_prepacked: shape mismatch");
    assert_eq!(x.rows, cols, "dequant8_matmul_prepacked: inner dim mismatch");
    assert!(
        pack.matches8(w, rows, cols),
        "dequant8_matmul_prepacked: stale panel pack (epoch/shape mismatch)"
    );
    prepacked_rows_matmul(pack, rows, cols, x, ctx)
}

/// `dequant(P) (rows, cols) @ x (cols, n)` for nibble-packed INT4 `p` —
/// the up-projection `P u` applied straight from storage.
pub fn dequant4_matmul(
    p: &Quant4Tensor,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.numel(), rows * cols, "dequant4_matmul: shape mismatch");
    assert_eq!(x.rows, cols, "dequant4_matmul: inner dim mismatch");
    dequant_rows_matmul(rows, cols, x, ctx, |idx| p.dequant_at(idx))
}

/// [`dequant4_matmul`] against a panel pack — the up-projection `P u` with
/// zero per-call nibble decode (see [`dequant8_matmul_prepacked`] for the
/// contract; bitwise identical to the fused path, panics on a stale pack).
pub fn dequant4_matmul_prepacked(
    p: &Quant4Tensor,
    pack: &PanelPack,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.numel(), rows * cols, "dequant4_matmul_prepacked: shape mismatch");
    assert_eq!(x.rows, cols, "dequant4_matmul_prepacked: inner dim mismatch");
    assert!(
        pack.matches4(p, rows, cols),
        "dequant4_matmul_prepacked: stale panel pack (epoch/shape mismatch)"
    );
    prepacked_rows_matmul(pack, rows, cols, x, ctx)
}

/// `dequant(P) (rows, cols) @ x (cols, n)` for sub-byte-packed 2-bit `p`
/// (the Figure-3 ablation applied straight from storage).
pub fn dequant2_matmul(
    p: &Quant2Tensor,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.numel(), rows * cols, "dequant2_matmul: shape mismatch");
    assert_eq!(x.rows, cols, "dequant2_matmul: inner dim mismatch");
    dequant_rows_matmul(rows, cols, x, ctx, |idx| p.dequant_at(idx))
}

/// [`dequant2_matmul`] against a panel pack (see
/// [`dequant8_matmul_prepacked`] for the contract).
pub fn dequant2_matmul_prepacked(
    p: &Quant2Tensor,
    pack: &PanelPack,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.numel(), rows * cols, "dequant2_matmul_prepacked: shape mismatch");
    assert_eq!(x.rows, cols, "dequant2_matmul_prepacked: inner dim mismatch");
    assert!(
        pack.matches2(p, rows, cols),
        "dequant2_matmul_prepacked: stale panel pack (epoch/shape mismatch)"
    );
    prepacked_rows_matmul(pack, rows, cols, x, ctx)
}

/// Shared body of the plain-orientation prepacked paths: the pack's
/// forward panel IS `deq(A)`, so each slab goes straight to the
/// microkernel.  Same `par_rows` decomposition as the fused body — the
/// row-group loop there only partitioned rows, which never affects any
/// element's ascending-k accumulation — so bits match the fused path.
fn prepacked_rows_matmul(
    pack: &PanelPack,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    let n = x.cols;
    let ctx = engine::effective(ctx, rows, cols, n);
    let fwd = pack.fwd();
    let data = engine::par_rows(ctx, rows, n, |r0, r1, out| {
        engine::panel_matmul(&fwd[r0 * cols..r1 * cols], r1 - r0, cols, x, out);
    });
    Mat { rows, cols: n, data }
}

/// Max columns of dequantized transposed scratch a transposed-orientation
/// worker holds at once (mirrors the engine's transpose sub-paneling, so
/// serial calls never materialize the whole fp32 matrix).
const DEQUANT_PANEL_COLS: usize = 64;

/// Shared body of the transposed fused paths: `deq(A)^T @ x` for `A`
/// logically (rows, cols) and `x (rows, n)`, with `deq` decoding the flat
/// element index from the caller's packed storage.  Workers dequantize
/// bounded transposed column sub-panels into a reused scratch and feed the
/// microkernel — one tile loop for every storage format.
fn dequant_cols_t_matmul(
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
    deq: impl Fn(usize) -> f32 + Sync,
) -> Mat {
    let n = x.cols;
    let ctx = engine::effective(ctx, cols, rows, n);
    let data = engine::par_rows(ctx, cols, n, |j0, j1, out| {
        with_dequant_scratch(DEQUANT_PANEL_COLS.min(j1 - j0) * rows, |panel| {
            let mut js = j0;
            while js < j1 {
                let je = (js + DEQUANT_PANEL_COLS).min(j1);
                let pw = je - js;
                for i in 0..rows {
                    let base = i * cols;
                    for j in js..je {
                        panel[(j - js) * rows + i] = deq(base + j);
                    }
                }
                engine::panel_matmul(
                    &panel[..pw * rows],
                    pw,
                    rows,
                    x,
                    &mut out[(js - j0) * n..(je - j0) * n],
                );
                js = je;
            }
        });
    });
    Mat { rows: cols, cols: n, data }
}

/// `dequant(P)^T @ x` for `p` logically (rows, cols), `x (rows, n)` —
/// the down-projection `P^T g` applied straight from INT4 storage.
pub fn dequant4_t_matmul(
    p: &Quant4Tensor,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.numel(), rows * cols, "dequant4_t_matmul: shape mismatch");
    assert_eq!(x.rows, rows, "dequant4_t_matmul: inner dim mismatch");
    dequant_cols_t_matmul(rows, cols, x, ctx, |idx| p.dequant_at(idx))
}

/// [`dequant4_t_matmul`] against a panel pack: the down-projection
/// `P^T g` with zero per-call decode AND zero per-call transposition —
/// the pack stores the transposed orientation too.  Bitwise identical to
/// the fused path; panics on a stale pack.
pub fn dequant4_t_matmul_prepacked(
    p: &Quant4Tensor,
    pack: &PanelPack,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.numel(), rows * cols, "dequant4_t_matmul_prepacked: shape mismatch");
    assert_eq!(x.rows, rows, "dequant4_t_matmul_prepacked: inner dim mismatch");
    assert!(
        pack.matches4(p, rows, cols),
        "dequant4_t_matmul_prepacked: stale panel pack (epoch/shape mismatch)"
    );
    prepacked_cols_t_matmul(pack, rows, cols, x, ctx)
}

/// `dequant(P)^T @ x` for sub-byte-packed 2-bit `p` logically
/// (rows, cols), `x (rows, n)` — the 2-bit analogue of
/// [`dequant4_t_matmul`].
pub fn dequant2_t_matmul(
    p: &Quant2Tensor,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.numel(), rows * cols, "dequant2_t_matmul: shape mismatch");
    assert_eq!(x.rows, rows, "dequant2_t_matmul: inner dim mismatch");
    dequant_cols_t_matmul(rows, cols, x, ctx, |idx| p.dequant_at(idx))
}

/// [`dequant2_t_matmul`] against a panel pack (see
/// [`dequant4_t_matmul_prepacked`] for the contract).
pub fn dequant2_t_matmul_prepacked(
    p: &Quant2Tensor,
    pack: &PanelPack,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.numel(), rows * cols, "dequant2_t_matmul_prepacked: shape mismatch");
    assert_eq!(x.rows, rows, "dequant2_t_matmul_prepacked: inner dim mismatch");
    assert!(
        pack.matches2(p, rows, cols),
        "dequant2_t_matmul_prepacked: stale panel pack (epoch/shape mismatch)"
    );
    prepacked_cols_t_matmul(pack, rows, cols, x, ctx)
}

/// Shared body of the transposed prepacked paths: the pack's transposed
/// panel IS `deq(A)^T`, laid out row-major, so each slab goes straight to
/// the microkernel (see [`prepacked_rows_matmul`] for the bitwise
/// argument; the fused body's sub-panel loop also only partitioned
/// output rows).
fn prepacked_cols_t_matmul(
    pack: &PanelPack,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    let n = x.cols;
    let ctx = engine::effective(ctx, cols, rows, n);
    let tpose = pack.tpose();
    let data = engine::par_rows(ctx, cols, n, |j0, j1, out| {
        engine::panel_matmul(&tpose[j0 * rows..j1 * rows], j1 - j0, rows, x, out);
    });
    Mat { rows: cols, cols: n, data }
}

/// `dequant(P)^T @ x` for a generic i8-coded blockwise `p` logically
/// (rows, cols), `x (rows, n)` — the ablation bit-width analogue of
/// [`dequant4_t_matmul`]: 2-/8-bit projections (Figure 3) stay packed in
/// storage and are applied without materializing an fp32 copy.
pub fn dequant8_t_matmul(
    p: &QuantTensor,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.q.len(), rows * cols, "dequant8_t_matmul: shape mismatch");
    assert_eq!(x.rows, rows, "dequant8_t_matmul: inner dim mismatch");
    dequant_cols_t_matmul(rows, cols, x, ctx, |idx| p.dequant_at(idx))
}

/// [`dequant8_t_matmul`] against a panel pack (bitwise identical to the
/// fused path; panics on a stale pack).
pub fn dequant8_t_matmul_prepacked(
    p: &QuantTensor,
    pack: &PanelPack,
    rows: usize,
    cols: usize,
    x: &Mat,
    ctx: ParallelCtx,
) -> Mat {
    assert_eq!(p.q.len(), rows * cols, "dequant8_t_matmul_prepacked: shape mismatch");
    assert_eq!(x.rows, rows, "dequant8_t_matmul_prepacked: inner dim mismatch");
    assert!(
        pack.matches8(p, rows, cols),
        "dequant8_t_matmul_prepacked: stale panel pack (epoch/shape mismatch)"
    );
    prepacked_cols_t_matmul(pack, rows, cols, x, ctx)
}

/// Blockwise 8-bit Adam state (m: symmetric i8, v: non-negative u8), the
/// storage format threaded through the `adam8bit_*` HLO artifacts.
#[derive(Clone, Debug)]
pub struct Adam8State {
    pub mq: Vec<i8>,
    pub ms: Vec<f32>,
    pub vq: Vec<u8>,
    pub vs: Vec<f32>,
    pub block: usize,
}

impl Adam8State {
    pub fn zeros(numel: usize) -> Self {
        let block = block_for(numel);
        let nb = numel / block;
        Adam8State {
            mq: vec![0; numel],
            ms: vec![EPS / 127.0; nb],
            vq: vec![0; numel],
            vs: vec![EPS / 255.0; nb],
            block,
        }
    }

    pub fn storage_bytes(&self) -> usize {
        self.mq.len() + self.vq.len() + (self.ms.len() + self.vs.len()) * 4
    }
}

/// Update-magnitude safety clip (mirrors `ref.UPDATE_CLIP`).
pub const UPDATE_CLIP: f32 = 10.0;

/// Host-side reference of one blockwise 8-bit Adam step (mirrors
/// `kernels/adam8.py`); used by unit tests and the mock runtime.
///
/// `v` lives under the sqrt code map — `v = (code * vs)^2` — because linear
/// u8 codes underflow for small `v` and blow the update up to `m/eps`
/// (bitsandbytes solves the same problem with its dynamic code map).
pub fn adam8_step_host(
    g: &[f32],
    st: &mut Adam8State,
    c1: f32,
    c2: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
) -> Vec<f32> {
    let block = st.block;
    let mut update = Vec::with_capacity(g.len());
    for (bi, gb) in g.chunks(block).enumerate() {
        let ms = st.ms[bi];
        let vs = st.vs[bi];
        let mut m: Vec<f32> = st.mq[bi * block..(bi + 1) * block]
            .iter()
            .map(|&q| q as f32 * ms)
            .collect();
        let mut v: Vec<f32> = st.vq[bi * block..(bi + 1) * block]
            .iter()
            .map(|&q| {
                let s = q as f32 * vs;
                s * s
            })
            .collect();
        for i in 0..gb.len() {
            m[i] = beta1 * m[i] + (1.0 - beta1) * gb[i];
            v[i] = beta2 * v[i] + (1.0 - beta2) * gb[i] * gb[i];
            let up = (m[i] * c1) / ((v[i] * c2).sqrt() + eps);
            update.push(up.clamp(-UPDATE_CLIP, UPDATE_CLIP));
        }
        let m_absmax = m.iter().fold(0f32, |a, &x| a.max(x.abs())).max(EPS);
        let v_max = v.iter().fold(0f32, |a, &x| a.max(x)).max(EPS);
        let msn = m_absmax / 127.0;
        let vsn = v_max.sqrt() / 255.0;
        for i in 0..gb.len() {
            st.mq[bi * block + i] =
                fast_round_ties_even(m[i] / msn).clamp(-127.0, 127.0) as i8;
            st.vq[bi * block + i] =
                fast_round_ties_even(v[i].sqrt() / vsn).clamp(0.0, 255.0) as u8;
        }
        st.ms[bi] = msn;
        st.vs[bi] = vsn;
    }
    update
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        Pcg32::seeded(seed).normal_vec(n, 0.0, 2.0)
    }

    #[test]
    fn roundtrip_error_bounded() {
        for bits in [8u32, 4, 2] {
            let x = randvec(1024, 1);
            let t = quantize(&x, bits);
            let xh = dequantize(&t);
            for (bi, (xb, hb)) in x.chunks(256).zip(xh.chunks(256)).enumerate() {
                let bound = t.scale[bi] * 0.5 + 1e-6;
                for (a, b) in xb.iter().zip(hb) {
                    assert!((a - b).abs() <= bound, "bits={bits} err {}", (a - b).abs());
                }
            }
        }
    }

    #[test]
    fn codes_in_range() {
        let x = randvec(512, 2);
        for bits in [8u32, 4, 2] {
            let t = quantize(&x, bits);
            let lim = 1i16 << (bits - 1);
            assert!(t.q.iter().all(|&c| (c as i16) >= -lim && (c as i16) < lim));
        }
    }

    #[test]
    fn small_tensor_single_block() {
        let x = randvec(64, 3);
        let t = quantize(&x, 8);
        assert_eq!(t.block, 64);
        assert_eq!(t.nblocks(), 1);
    }

    #[test]
    fn int4_pack_roundtrip() {
        let x = randvec(512, 4);
        let t = quantize(&x, 4);
        let packed = pack_int4(&t.q);
        assert_eq!(unpack_int4(&packed), t.q);
    }

    #[test]
    fn quantize4_matches_quantize_then_pack() {
        let x = randvec(512, 5);
        let t4 = quantize4(&x);
        let t = quantize(&x, 4);
        assert_eq!(t4.packed, pack_int4(&t.q));
        let d4 = dequantize4(&t4);
        let d = dequantize(&t);
        assert_eq!(d4, d);
    }

    #[test]
    fn sr_unbiased() {
        let x = randvec(256, 6);
        let mut rng = Pcg32::seeded(7);
        let trials = 400;
        let mut acc = vec![0f64; 256];
        let mut scale0 = 0f32;
        for _ in 0..trials {
            let t = sr_quantize(&x, 8, &mut rng);
            scale0 = t.scale[0];
            for (a, b) in acc.iter_mut().zip(dequantize(&t)) {
                *a += b as f64;
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let mean = (a / trials as f64) as f32;
            assert!(
                (mean - x[i]).abs() < scale0 * 0.5,
                "i={i} mean={mean} x={}",
                x[i]
            );
        }
    }

    #[test]
    fn sr_accumulates_small_updates_rtn_does_not() {
        // The paper's §3.4 claim, at host level.
        let x = randvec(256, 8);
        let base = quantize(&x, 8);
        let delta = base.scale[0] * 0.05;
        let steps = 100;

        let mut t = base.clone();
        for _ in 0..steps {
            let w: Vec<f32> = dequantize(&t).iter().map(|v| v + delta).collect();
            t = quantize(&w, 8);
        }
        let drift_rtn: f32 = dequantize(&t)
            .iter()
            .zip(&x)
            .map(|(a, b)| a - b)
            .sum::<f32>()
            / 256.0;

        let mut rng = Pcg32::seeded(9);
        let mut t = base.clone();
        for _ in 0..steps {
            let w: Vec<f32> = dequantize(&t).iter().map(|v| v + delta).collect();
            t = sr_quantize(&w, 8, &mut rng);
        }
        let drift_sr: f32 = dequantize(&t)
            .iter()
            .zip(&x)
            .map(|(a, b)| a - b)
            .sum::<f32>()
            / 256.0;

        let want = delta * steps as f32;
        assert!(drift_rtn.abs() < 0.15 * want, "rtn drifted {drift_rtn} vs {want}");
        assert!(drift_sr > 0.6 * want, "sr drift {drift_sr} vs {want}");
    }

    #[test]
    fn adam8_host_reduces_quadratic() {
        let target: Vec<f32> = (0..256).map(|i| (i as f32 / 128.0) - 1.0).collect();
        let mut w = vec![0f32; 256];
        let mut st = Adam8State::zeros(256);
        for t in 1..150 {
            let g: Vec<f32> = w.iter().zip(&target).map(|(a, b)| a - b).collect();
            let c1 = 1.0 / (1.0 - 0.9f32.powi(t));
            let c2 = 1.0 / (1.0 - 0.999f32.powi(t));
            let up = adam8_step_host(&g, &mut st, c1, c2, 0.9, 0.999, 1e-8);
            for (wi, u) in w.iter_mut().zip(up) {
                *wi -= 0.05 * u;
            }
        }
        let loss: f32 =
            w.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / 256.0;
        assert!(loss < 1e-2, "loss {loss}");
    }

    #[test]
    fn storage_bytes_accounting() {
        let x = randvec(1024, 10);
        let t8 = quantize(&x, 8);
        assert_eq!(t8.storage_bytes(), 1024 + 4 * 4 + 4 * 4);
        let t4 = quantize4(&x);
        assert_eq!(t4.storage_bytes(), 512 + 4 * 4 + 4 * 4);
        // 2-bit packs four codes per byte: a quarter of the i8 bytes
        let t2 = quantize2(&x);
        assert_eq!(t2.storage_bytes(), 256 + 4 * 4 + 4 * 4);
    }

    #[test]
    fn epochs_are_unique_and_bumpable() {
        let x = randvec(512, 30);
        let a = quantize(&x, 8);
        let b = quantize(&x, 8);
        assert_ne!(a.epoch(), b.epoch(), "identical values are distinct code buffers");
        let c = a.clone();
        assert_eq!(a.epoch(), c.epoch(), "a clone holds identical codes");
        let mut d = quantize4(&x);
        let d0 = d.epoch();
        d.bump_epoch();
        assert_ne!(d.epoch(), d0, "bump must re-stamp");
        let mut e = quantize2(&x);
        let e0 = e.epoch();
        e.bump_epoch();
        assert_ne!(e.epoch(), e0);
        let g = QuantTensor::new(vec![0i8; 256], vec![1.0], vec![0.0], 8, 256);
        assert!(g.epoch() > 0, "constructor stamps a real epoch");
    }

    #[test]
    fn int2_pack_roundtrip() {
        let x = randvec(512, 31);
        let t = quantize(&x, 2);
        let packed = pack_int2(&t.q);
        assert_eq!(packed.len(), 128);
        assert_eq!(unpack_int2(&packed), t.q);
    }

    #[test]
    fn int2_odd_length_roundtrip() {
        let codes: Vec<i8> = (0..7i32).map(|i| ((i % 4) - 2) as i8).collect();
        let packed = pack_int2(&codes);
        assert_eq!(packed.len(), 2);
        let unpacked = unpack_int2(&packed);
        assert_eq!(&unpacked[..7], &codes[..]);
        // quantize2 round-trips non-multiple-of-4 lengths via numel
        let x = randvec(91, 32);
        let t = quantize2(&x);
        assert_eq!(t.numel(), 91);
        assert_eq!(dequantize2(&t).len(), 91);
    }

    #[test]
    fn quantize2_matches_quantize_then_pack() {
        let x = randvec(512, 33);
        let t2 = quantize2(&x);
        let t = quantize(&x, 2);
        assert_eq!(t2.packed, pack_int2(&t.q));
        assert_eq!(dequantize2(&t2), dequantize(&t));
    }

    #[test]
    fn dequant2_matmuls_match_unfused() {
        let mut rng = Pcg32::seeded(26);
        for (m, r, n) in [(1usize, 1usize, 1usize), (13, 7, 5), (64, 16, 9), (128, 32, 65)] {
            let p = quantize2(&rng.normal_vec(m * r, 0.0, 0.3));
            let pd = Mat::from_vec(m, r, dequantize2(&p));
            let xt = Mat::randn(m, n, &mut rng);
            let want_t = pd.t_matmul_naive(&xt);
            let x = Mat::randn(r, n, &mut rng);
            let want = pd.matmul_naive(&x);
            for t in [1usize, 2, 8] {
                let got_t = dequant2_t_matmul(&p, m, r, &xt, ParallelCtx::new(t));
                assert!(got_t.rel_frobenius(&want_t) <= 1e-5, "t_matmul {m}x{r}x{n} t={t}");
                let got = dequant2_matmul(&p, m, r, &x, ParallelCtx::new(t));
                assert!(got.rel_frobenius(&want) <= 1e-5, "matmul {m}x{r}x{n} t={t}");
            }
        }
    }

    #[test]
    fn prepacked_paths_match_fused_bitwise() {
        // the full tail-class sweep lives in tests/parity.rs; this is the
        // in-module smoke for all six prepacked/fused pairings
        let mut rng = Pcg32::seeded(27);
        let (m, r, n) = (64usize, 16usize, 9usize);
        let p4 = quantize4(&rng.normal_vec(m * r, 0.0, 0.3));
        let p8 = quantize(&rng.normal_vec(m * r, 0.0, 0.3), 8);
        let pack4 = PanelPack::pack4(&p4, m, r);
        let pack8 = PanelPack::pack8(&p8, m, r);
        let x = Mat::randn(r, n, &mut rng);
        let xt = Mat::randn(m, n, &mut rng);
        for t in [1usize, 8] {
            let ctx = ParallelCtx::new(t);
            assert_eq!(
                dequant4_matmul_prepacked(&p4, &pack4, m, r, &x, ctx).data,
                dequant4_matmul(&p4, m, r, &x, ctx).data,
                "int4 fwd t={t}"
            );
            assert_eq!(
                dequant4_t_matmul_prepacked(&p4, &pack4, m, r, &xt, ctx).data,
                dequant4_t_matmul(&p4, m, r, &xt, ctx).data,
                "int4 tpose t={t}"
            );
            assert_eq!(
                dequant8_matmul_prepacked(&p8, &pack8, m, r, &x, ctx).data,
                dequant8_matmul(&p8, m, r, &x, ctx).data,
                "int8 fwd t={t}"
            );
            assert_eq!(
                dequant8_t_matmul_prepacked(&p8, &pack8, m, r, &xt, ctx).data,
                dequant8_t_matmul(&p8, m, r, &xt, ctx).data,
                "int8 tpose t={t}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "stale panel pack")]
    fn stale_pack_is_never_read() {
        let mut rng = Pcg32::seeded(28);
        let vals = rng.normal_vec(64, 0.0, 0.3);
        let old = quantize4(&vals);
        let pack = PanelPack::pack4(&old, 8, 8);
        // a refresh produces a NEW tensor (fresh epoch) — the old pack
        // must refuse to be read against it even with identical values
        let refreshed = quantize4(&vals);
        let x = Mat::randn(8, 3, &mut rng);
        let _ = dequant4_matmul_prepacked(&refreshed, &pack, 8, 8, &x, ParallelCtx::serial());
    }

    #[test]
    fn int4_odd_length_roundtrip() {
        let codes: Vec<i8> = (0..7i32).map(|i| ((i % 16) - 8) as i8).collect();
        let packed = pack_int4(&codes);
        assert_eq!(packed.len(), 4);
        let unpacked = unpack_int4(&packed);
        assert_eq!(&unpacked[..7], &codes[..]);
        // quantize4 round-trips odd lengths via the numel field
        let x = randvec(91, 11);
        let t = quantize4(&x);
        assert_eq!(t.numel(), 91);
        assert_eq!(dequantize4(&t).len(), 91);
    }

    #[test]
    fn dequant8_matmul_matches_unfused() {
        let mut rng = Pcg32::seeded(12);
        for (m, k, n) in [(1usize, 1usize, 1usize), (7, 13, 5), (64, 64, 9), (128, 256, 33)] {
            let w = quantize(&rng.normal_vec(m * k, 0.0, 1.0), 8);
            let x = Mat::randn(k, n, &mut rng);
            let want = Mat::from_vec(m, k, dequantize(&w)).matmul_naive(&x);
            for t in [1usize, 2, 8] {
                let got = dequant8_matmul(&w, m, k, &x, ParallelCtx::new(t));
                assert!(got.rel_frobenius(&want) <= 1e-5, "{m}x{k}x{n} t={t}");
            }
        }
    }

    #[test]
    fn dequant4_matmul_matches_unfused() {
        let mut rng = Pcg32::seeded(13);
        for (m, k, n) in [(1usize, 1usize, 1usize), (7, 13, 5), (64, 64, 9), (128, 256, 33)] {
            let p = quantize4(&rng.normal_vec(m * k, 0.0, 0.3));
            let x = Mat::randn(k, n, &mut rng);
            let want = Mat::from_vec(m, k, dequantize4(&p)).matmul_naive(&x);
            for t in [1usize, 2, 8] {
                let got = dequant4_matmul(&p, m, k, &x, ParallelCtx::new(t));
                assert!(got.rel_frobenius(&want) <= 1e-5, "{m}x{k}x{n} t={t}");
            }
        }
    }

    #[test]
    fn dequant4_t_matmul_matches_unfused() {
        let mut rng = Pcg32::seeded(14);
        for (m, r, n) in [(1usize, 1usize, 1usize), (13, 7, 5), (64, 16, 9), (128, 32, 65)] {
            let p = quantize4(&rng.normal_vec(m * r, 0.0, 0.3));
            let x = Mat::randn(m, n, &mut rng);
            let want = Mat::from_vec(m, r, dequantize4(&p)).t_matmul_naive(&x);
            for t in [1usize, 2, 8] {
                let got = dequant4_t_matmul(&p, m, r, &x, ParallelCtx::new(t));
                assert!(got.rel_frobenius(&want) <= 1e-5, "{m}x{r}x{n} t={t}");
            }
        }
    }

    #[test]
    fn dequant8_t_matmul_matches_unfused() {
        // both ablation bit widths ride the same i8-coded path
        let mut rng = Pcg32::seeded(15);
        for bits in [8u32, 2] {
            for (m, r, n) in [(1usize, 1usize, 1usize), (13, 7, 5), (64, 16, 9), (128, 32, 65)] {
                let p = quantize(&rng.normal_vec(m * r, 0.0, 0.3), bits);
                let x = Mat::randn(m, n, &mut rng);
                let want = Mat::from_vec(m, r, dequantize(&p)).t_matmul_naive(&x);
                for t in [1usize, 2, 8] {
                    let got = dequant8_t_matmul(&p, m, r, &x, ParallelCtx::new(t));
                    assert!(
                        got.rel_frobenius(&want) <= 1e-5,
                        "bits={bits} {m}x{r}x{n} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn sr_quantize_thread_count_independent() {
        // 2^20 elements reaches the PAR_MIN_CLONE_ELEMS gate, so the t>1
        // calls really run on the pool; codes must not depend on it
        let x = randvec(1 << 20, 20);
        let mut r0 = Pcg32::seeded(5);
        let want = sr_quantize_with(&x, 8, &mut r0, ParallelCtx::serial());
        for t in [2usize, 8] {
            let mut r = Pcg32::seeded(5);
            let got = sr_quantize_with(&x, 8, &mut r, ParallelCtx::new(t));
            assert_eq!(got.q, want.q, "sr codes changed with {t} threads");
            assert_eq!(got.scale, want.scale);
            assert_eq!(got.zero, want.zero);
        }
    }

    #[test]
    fn uniform_noise_deterministic_and_thread_independent() {
        // straddles the chunk grid (truncated tail) and the parallel gate
        let n = (1 << 20) + 5;
        let a = uniform_noise(n, 7, ParallelCtx::serial());
        let b = uniform_noise(n, 7, ParallelCtx::new(8));
        assert_eq!(a, b, "noise fill depends on worker count");
        assert_eq!(a.len(), n);
        assert!(a.iter().all(|&v| (0.0..1.0).contains(&v)));
        let c = uniform_noise(n, 8, ParallelCtx::serial());
        assert_ne!(a, c, "distinct seeds must decorrelate");
        assert!(uniform_noise(0, 7, ParallelCtx::serial()).is_empty());
    }
}
