//! `qgalore` — leader binary / CLI launcher.
//!
//! The rust coordinator is self-contained once `make artifacts` has produced
//! the AOT HLO modules: every subcommand below runs without python.

use anyhow::{anyhow, Result};

use qgalore::cli::Args;
use qgalore::coordinator::{
    checkpoint, finetune, pretrain, serve, FinetuneConfig, MultiJobConfig, MultiJobCoordinator,
    ServeConfig, ServeEngine, ServeModel, ServeRequest, ServeResponse, TrainConfig,
};
use qgalore::linalg::{global_pool, set_global_threads, ParallelCtx};
use qgalore::manifest::Manifest;
use qgalore::memory;
use qgalore::model;
use qgalore::optim::{BuildOptions, Method};
use qgalore::repro::{self, ReproOptions};
use qgalore::scheduler::SchedulerConfig;
use qgalore::util::human_bytes;

const USAGE: &str = "\
qgalore — Q-GaLore: INT4-projection / INT8-weight low-rank LLM training

USAGE: qgalore <command> [flags]
       (global: --artifacts DIR, default `artifacts`;
                --threads N, persistent linalg worker-pool size, default
                QGALORE_THREADS env or all cores; spun up once at launch)

COMMANDS
  train      pre-train from scratch
             --method M --config C --steps N --lr F --seed N --interval N
             --proj-bits N --no-adaptive --no-sr --save PATH
             --dataflow (pipelined step graph; also QGALORE_DATAFLOW=1)
  finetune   fine-tune a checkpoint on a synthetic classification task
             --method M --config C --checkpoint PATH --steps N --labels N
             --task-salt N --seed N
             --save-delta PATH (write adapter/factor delta, QGDC format)
             --delta PATH      (resume from a saved delta)
  multijob   serve N concurrent fine-tune jobs on one shared base arena
             --jobs N --rounds N --layers N --dim N --rank N --lr F
             --seed N --interval N --delta-dir DIR (save per-job deltas)
  serve      batched forward-only scoring/generation on a loaded model
             --requests N --layers N --dim N --vocab N --seed N
             --ckpt PATH (base checkpoint; synthetic model if omitted)
             --delta PATH (per-user QGDC delta from finetune/multijob)
  repro      regenerate a paper table/figure
             <table1|table2|table3|table4|fig2|fig3|fig5|fig6|fig7|all>
             --steps N --out DIR --config C --seed N --verbose
  memory     analytic memory breakdown
             --config C [--method M] --tokens N
  inspect    summarize the artifact manifest
  modelcheck bounded-schedule exploration of the pool/run_graph concurrency
             core; exhaustive only in a `--cfg qgalore_modelcheck` build
             --bound N (preemption budget, default 2) --max-schedules N
  lint       repo-invariant lint pass (SAFETY comments, kernel fma,
             plan-path hash iteration, artifact unwraps)
             --root DIR (default rust/src, falling back to src)

METHODS: full adam8bit lowrank lora relora qlora galore galore8bit qgalore
CONFIGS: llama-micro llama-tiny llama-nano llama-small (trainable);
         llama-{60m,130m,350m,1b,7b}, llama3-8b, gemma-7b, mistral-7b,
         roberta-base (memory model only)";

fn parse_method(s: &str) -> Result<Method> {
    Method::parse(s).ok_or_else(|| anyhow!("unknown method {s:?}\n{USAGE}"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..], &["no-adaptive", "no-sr", "verbose", "dataflow"])?;
    let artifacts = args.str_or("artifacts", "artifacts");
    let threads = args.u64_or("threads", 0)?;
    if threads > 0 {
        set_global_threads(threads as usize);
    }
    // spin the persistent worker pool up exactly once, before any timed
    // work: every linalg call from here on is a queue push, not a spawn
    let _ = global_pool();

    match cmd.as_str() {
        "train" => {
            let man = Manifest::load(&artifacts)?;
            let method = parse_method(&args.str_or("method", "qgalore"))?;
            let config = args.str_or("config", "llama-tiny");
            let steps = args.u64_or("steps", 200)?;
            let seed = args.u64_or("seed", 0)?;
            let cfg = TrainConfig {
                cfg_name: config.clone(),
                method,
                steps,
                lr_max: args.f32_or("lr", 0.01)?,
                warmup: steps / 10,
                eval_every: (steps / 4).max(1),
                eval_batches: 8,
                n_documents: 512,
                seed,
                opts: BuildOptions {
                    seed,
                    sched: SchedulerConfig {
                        base_interval: args.u64_or("interval", 20)?,
                        adaptive: !args.bool("no-adaptive"),
                        ..Default::default()
                    },
                    proj_bits: args.u32_or("proj-bits", 4)?,
                    use_sr: !args.bool("no-sr"),
                    relora_merge_every: steps / 3,
                    pool: ParallelCtx::global(),
                },
                log_every: (steps / 20).max(1),
                quiet: false,
                dataflow: args.bool("dataflow") || qgalore::coordinator::dataflow_default(),
            };
            let save = args.flag("save").map(|s| s.to_string());
            args.reject_unknown()?;
            let r = pretrain(&man, cfg)?;
            println!(
                "\nfinal: val_loss {:.4} ppl {:.2} | live {} | svd {} ({:.0}% of GaLore) | {:.2} steps/s",
                r.final_val_loss,
                r.final_ppl,
                human_bytes(r.live_bytes),
                r.svd_count,
                r.svd_fraction * 100.0,
                r.steps_per_sec
            );
            if let Some(path) = save {
                checkpoint::save(
                    &path,
                    &r.final_params,
                    &checkpoint::CheckpointMeta {
                        cfg_name: config,
                        method: method.to_string(),
                        step: steps,
                        val_loss: r.final_val_loss,
                    },
                )?;
                println!("checkpoint saved to {path}");
            }
        }
        "finetune" => {
            let man = Manifest::load(&artifacts)?;
            let method = parse_method(&args.str_or("method", "qgalore"))?;
            let config = args.str_or("config", "llama-tiny");
            let ckpt = args.flag("checkpoint").map(|s| s.to_string());
            let seed = args.u64_or("seed", 0)?;
            let fcfg = FinetuneConfig {
                cfg_name: config.clone(),
                method,
                n_labels: args.usize_or("labels", 4)?,
                steps: args.u64_or("steps", 60)?,
                lr: args.f32_or("lr", 0.003)?,
                seed,
                task_salt: args.u64_or("task-salt", 17)?,
                n_eval_examples: 40,
                opts: BuildOptions { seed, ..Default::default() },
                quiet: false,
                save_delta: args.flag("save-delta").map(Into::into),
                resume_delta: args.flag("delta").map(Into::into),
            };
            args.reject_unknown()?;
            let init = match ckpt {
                Some(p) => checkpoint::load(&p)?.0,
                None => man.load_init(&config)?,
            };
            let r = finetune(&man, fcfg, &init)?;
            println!(
                "\naccuracy {:.1}% (per label: {:?}) | live {}",
                r.accuracy * 100.0,
                r.per_label_accuracy
                    .iter()
                    .map(|a| format!("{:.0}%", a * 100.0))
                    .collect::<Vec<_>>(),
                human_bytes(r.live_bytes)
            );
        }
        "multijob" => {
            let jobs = args.usize_or("jobs", 4)?;
            let rounds = args.u64_or("rounds", 50)?;
            let n_layers = args.usize_or("layers", 4)?;
            let dim = args.usize_or("dim", 64)?;
            let rank = args.usize_or("rank", 8)?;
            let seed = args.u64_or("seed", 0)?;
            let cfg = MultiJobConfig {
                rank,
                lr: args.f32_or("lr", 0.01)?,
                arena_seed: seed,
                sched: SchedulerConfig {
                    base_interval: args.u64_or("interval", 25)?,
                    ..Default::default()
                },
                ..Default::default()
            };
            let delta_dir = args.flag("delta-dir").map(std::path::PathBuf::from);
            args.reject_unknown()?;
            if jobs == 0 || n_layers == 0 {
                return Err(anyhow!("multijob needs at least one job and one layer"));
            }
            // blockwise-quantized buffers (base, projection, moments) need
            // numel <= 256 or a multiple of 256
            for numel in [dim * dim, rank * dim] {
                if numel > 256 && numel % 256 != 0 {
                    return Err(anyhow!(
                        "dim {dim} / rank {rank} give a quantized buffer of {numel} \
                         elems; need <= 256 or a multiple of 256"
                    ));
                }
            }
            let shapes = vec![(dim, dim); n_layers];
            let mut co = MultiJobCoordinator::new(&shapes, cfg, ParallelCtx::global());
            for j in 0..jobs {
                // distinct, seed-derived job identities
                co.add_job(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(j as u64 + 1));
            }
            println!(
                "multijob: {jobs} jobs x {n_layers} layers ({dim}x{dim}, rank {rank}) | \
                 shared base {} | delta/job {}",
                human_bytes(co.arena().base_bytes()),
                human_bytes(co.job(0).delta_bytes())
            );
            let pool = global_pool();
            let t0 = std::time::Instant::now();
            let mut losses = Vec::new();
            for _ in 0..rounds {
                losses = co.round(pool)?;
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            println!(
                "{rounds} rounds in {dt:.2}s | {:.1} job-steps/s | final losses {:?}",
                (jobs as u64 * rounds) as f64 / dt,
                losses.iter().map(|l| format!("{l:.4}")).collect::<Vec<_>>()
            );
            if let Some(dir) = delta_dir {
                std::fs::create_dir_all(&dir)?;
                for ji in 0..co.n_jobs() {
                    let path = dir.join(format!("job{ji}.delta"));
                    let ck = co.export_delta(ji, "multijob")?;
                    checkpoint::save_delta(&path, &ck)?;
                    println!("saved {} ({})", path.display(), human_bytes(ck.payload_bytes() as u64));
                }
            }
        }
        "serve" => {
            let requests = args.usize_or("requests", 64)?;
            let cfg = ServeConfig {
                vocab: args.usize_or("vocab", 320)?,
                dim: args.usize_or("dim", 64)?,
                n_layers: args.usize_or("layers", 3)?,
                seed: args.u64_or("seed", 0)?,
            };
            let ckpt = args.flag("ckpt").map(|s| s.to_string());
            let delta = args.flag("delta").map(|s| s.to_string());
            args.reject_unknown()?;
            let mut model = match &ckpt {
                Some(p) => {
                    let (m, meta) = ServeModel::from_checkpoint(p, cfg)?;
                    println!(
                        "loaded {p}: cfg {} method {} step {} val_loss {:.4}",
                        meta.cfg_name, meta.method, meta.step, meta.val_loss
                    );
                    m
                }
                None => ServeModel::from_seed(cfg)?,
            };
            if let Some(p) = &delta {
                model.apply_delta(&checkpoint::load_delta(p)?)?;
                println!(
                    "applied per-user delta {p} ({})",
                    human_bytes(model.delta_bytes() as u64)
                );
            }
            println!(
                "serve: {} layers x {}x{}, vocab {} | base {} (packed)",
                cfg.n_layers,
                cfg.dim,
                cfg.dim,
                cfg.vocab,
                human_bytes(model.base_bytes() as u64)
            );
            let engine = ServeEngine::new(model, ParallelCtx::global());
            let reqs = serve::synth_requests(cfg.vocab, requests, cfg.seed ^ 0xcafe);
            let pool = global_pool();
            let t0 = std::time::Instant::now();
            let (resps, lat) = engine.serve_batch_timed(&reqs, pool)?;
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            println!(
                "{requests} requests in {:.1} ms | {:.1} req/s | p50 {:.2} ms p99 {:.2} ms",
                dt * 1e3,
                requests as f64 / dt,
                serve::percentile(&lat, 50.0),
                serve::percentile(&lat, 99.0)
            );
            for (r, resp) in reqs.iter().zip(&resps).take(4) {
                match (r, resp) {
                    (
                        ServeRequest::Score { labels, .. },
                        ServeResponse::Score { nll, pred },
                    ) => println!(
                        "  score: pred {pred:?} of {labels} labels | nll {:?}",
                        nll.iter().map(|x| format!("{x:.3}")).collect::<Vec<_>>()
                    ),
                    (
                        ServeRequest::Generate { max_new, .. },
                        ServeResponse::Generate { tokens },
                    ) => println!("  generate: {max_new} new tokens -> {tokens:?}"),
                    _ => {}
                }
            }
        }
        "repro" => {
            let man = Manifest::load(&artifacts)?;
            let target = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("repro needs a target\n{USAGE}"))?
                .clone();
            let o = ReproOptions {
                steps: args.u64_or("steps", 150)?,
                out_dir: args.str_or("out", "results"),
                cfg_name: args.str_or("config", "llama-tiny"),
                seed: args.u64_or("seed", 0)?,
                quiet: !args.bool("verbose"),
            };
            args.reject_unknown()?;
            match target.as_str() {
                "table1" => repro::table1(&man, &o).map(|_| ())?,
                "table2" => repro::table2(&man, &o).map(|_| ())?,
                "table3" => repro::table3(&man, &o).map(|_| ())?,
                "table4" => repro::table4(&man, &o).map(|_| ())?,
                "fig2" => repro::fig2(&man, &o).map(|_| ())?,
                "fig3" => repro::fig3(&man, &o).map(|_| ())?,
                "fig5" => repro::fig5(&man, &o).map(|_| ())?,
                "fig6" => repro::fig6(&man, &o).map(|_| ())?,
                "fig7" => repro::fig7(&man, &o).map(|_| ())?,
                "all" => repro::all(&man, &o).map(|_| ())?,
                other => return Err(anyhow!("unknown repro target {other}\n{USAGE}")),
            }
        }
        "memory" => {
            let config = args.str_or("config", "llama-7b");
            let method = args.flag("method").map(|s| s.to_string());
            let tokens = args.usize_or("tokens", 2048)?;
            args.reject_unknown()?;
            let cfg = model::get_config(&config)
                .ok_or_else(|| anyhow!("unknown config {config}"))?;
            let methods: Vec<Method> = match method {
                Some(m) => vec![parse_method(&m)?],
                None => Method::ALL.to_vec(),
            };
            println!("{config}: {} params, rank {}\n", cfg.n_params(), cfg.rank);
            for m in methods {
                let b = memory::breakdown(&cfg, m, tokens);
                println!(
                    "{:<14} weights {:>9} | adapters {:>9} | m {:>9} | v {:>9} | proj {:>9} | grad {:>9} | act {:>9} | total {:>9}",
                    m.to_string(),
                    human_bytes(b.weights),
                    human_bytes(b.adapters),
                    human_bytes(b.optim_m),
                    human_bytes(b.optim_v),
                    human_bytes(b.projection),
                    human_bytes(b.gradients),
                    human_bytes(b.activations),
                    human_bytes(b.total()),
                );
            }
        }
        "modelcheck" => {
            let mcfg = qgalore::modelcheck::Config {
                preemption_bound: args.u32_or("bound", 2)?,
                max_schedules: args.u64_or("max-schedules", 250_000)?,
                ..Default::default()
            };
            args.reject_unknown()?;
            let report = qgalore::modelcheck::run_suite(&mcfg);
            if report.shimmed {
                println!("modelcheck: shadow-atomic build, exploration is exhaustive");
            } else {
                println!(
                    "modelcheck: std-atomic build — schedules are NOT enumerated; \
                     rebuild with RUSTFLAGS=\"--cfg qgalore_modelcheck\" for real \
                     exploration"
                );
            }
            let mut failed = 0usize;
            for (name, r) in &report.scenarios {
                match &r.violation {
                    None => println!(
                        "  ok   {name}: {} schedules{}",
                        r.schedules,
                        if r.exhausted { "" } else { " (budget hit)" }
                    ),
                    Some(v) => {
                        failed += 1;
                        println!("  FAIL {name} (schedule {}): {}", v.schedule_index, v.message);
                        for t in &v.trace {
                            println!("         {t}");
                        }
                    }
                }
            }
            if failed > 0 {
                return Err(anyhow!("modelcheck found {failed} violation(s)"));
            }
        }
        "lint" => {
            let root = args.flag("root").map(std::path::PathBuf::from);
            args.reject_unknown()?;
            let root = root.unwrap_or_else(|| {
                let nested = std::path::PathBuf::from("rust/src");
                if nested.is_dir() {
                    nested
                } else {
                    std::path::PathBuf::from("src")
                }
            });
            let findings = qgalore::modelcheck::lint_tree(&root)?;
            for f in &findings {
                println!("{f}");
            }
            if !findings.is_empty() {
                return Err(anyhow!(
                    "{} lint violation(s) under {}",
                    findings.len(),
                    root.display()
                ));
            }
            println!("lint clean: {}", root.display());
        }
        "inspect" => {
            args.reject_unknown()?;
            let man = Manifest::load(&artifacts)?;
            println!(
                "manifest: block={} scale={} betas=({}, {}) batch={}",
                man.block, man.galore_scale, man.beta1, man.beta2, man.batch
            );
            for (name, c) in &man.configs {
                println!(
                    "config {name}: dim={} layers={} vocab={} rank={} | {} model artifacts",
                    c.model.dim,
                    c.model.n_layers,
                    c.model.vocab_size,
                    c.model.rank,
                    c.artifacts.len()
                );
                for (an, a) in &c.artifacts {
                    println!(
                        "  {an:<16} {:>3} operands -> {:>2} results ({})",
                        a.operands.len(),
                        a.results.len(),
                        a.path.file_name().unwrap().to_string_lossy()
                    );
                }
            }
            println!("{} update artifacts", man.updates.len());
        }
        other => return Err(anyhow!("unknown command {other}\n{USAGE}")),
    }
    Ok(())
}
