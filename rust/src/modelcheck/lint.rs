//! Repo-invariant lint pass (`qgalore lint`).
//!
//! A handful of determinism/soundness invariants in this repo are written
//! prose — SAFETY comments on `unsafe` blocks, "never fma in kernels",
//! "never iterate hash collections on a plan path" — and prose rots.  This
//! module turns them into machine checks over `rust/src`:
//!
//! 1. **unsafe-safety-comment** (everywhere): every `unsafe {}` block must
//!    have a comment containing "SAFETY" on the same line or in the
//!    comment/attribute run directly above it.  `unsafe fn` / `unsafe impl`
//!    / `unsafe trait` signatures are exempt (they carry `# Safety` docs,
//!    and `deny(unsafe_op_in_unsafe_fn)` forces their bodies back through
//!    this rule).
//! 2. **kernel-mul-add** (`linalg/`, `quant/`): no `mul_add` — a fused
//!    multiply-add rounds once, the naive reference rounds twice, and the
//!    bitwise kernel contract dies.  Backed by `clippy.toml`'s
//!    `disallowed-methods`; this copy also catches non-method uses.
//! 3. **plan-hash-iteration** (`optim/`, `coordinator/`, `scheduler/`):
//!    no `HashMap`/`HashSet` in plan/join-order paths.  Their iteration
//!    order is randomized per process, so any plan built by walking one
//!    diverges between runs; use `BTreeMap`/`Vec` keyed deterministically.
//! 4. **artifact-unwrap** (`optim/`): no `.unwrap()` on a line touching
//!    `outputs` — artifact execution results flow back as `Result`/`Option`
//!    and must surface through `?` with context, not panic mid-step.
//!
//! Rules 2–4 skip `#[cfg(test)]` modules; rule 1 applies everywhere.  The
//! scanner strips comments, strings, and char literals first, so prose
//! mentioning `unsafe` or `mul_add` (like this paragraph) never trips a
//! rule.  A deliberate exception is suppressed in place with a comment
//! containing `lint: allow(<rule>)` on the flagged line or the line above.

use std::path::{Path, PathBuf};

use crate::Result;

/// One lint violation.
#[derive(Debug, Clone)]
pub struct LintFinding {
    pub file: String,
    /// 1-indexed line.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for LintFinding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

pub const RULE_UNSAFE: &str = "unsafe-safety-comment";
pub const RULE_MUL_ADD: &str = "kernel-mul-add";
pub const RULE_HASH: &str = "plan-hash-iteration";
pub const RULE_UNWRAP: &str = "artifact-unwrap";

const MSG_UNSAFE: &str = "unsafe block without a SAFETY comment on the line or in the \
     comment run directly above";
const MSG_MUL_ADD: &str = "fused multiply-add in a kernel module breaks the bitwise \
     contract with the naive reference (one rounding vs two)";
const MSG_HASH: &str = "hash collections have randomized iteration order; plan paths \
     must use BTreeMap/Vec for run-to-run determinism";
const MSG_UNWRAP: &str = "artifact outputs must be propagated with `?`/context, not \
     unwrapped";

/// Lint every `.rs` file under `root` (recursively, in sorted order).
pub fn lint_tree(root: &Path) -> Result<Vec<LintFinding>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    lint_paths(&files)
}

/// Lint an explicit list of files.
pub fn lint_paths(paths: &[PathBuf]) -> Result<Vec<LintFinding>> {
    let mut findings = Vec::new();
    for p in paths {
        let src = std::fs::read_to_string(p)
            .map_err(|e| crate::anyhow!("reading {}: {e}", p.display()))?;
        findings.extend(lint_source(&p.to_string_lossy(), &src));
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let rd = std::fs::read_dir(dir)
        .map_err(|e| crate::anyhow!("walking {}: {e}", dir.display()))?;
    for entry in rd {
        let path = entry.map_err(|e| crate::anyhow!("walking {}: {e}", dir.display()))?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint one file's source text.  `file` is used for rule dispatch (path
/// components select which rules apply) and for reporting.
pub fn lint_source(file: &str, src: &str) -> Vec<LintFinding> {
    let scrubbed = scrub(src);
    let raw_lines: Vec<&str> = src.lines().collect();
    let scrub_lines: Vec<&str> = scrubbed.lines().collect();
    let in_test = test_line_mask(&scrub_lines);
    let norm = file.replace('\\', "/");
    let mut findings = Vec::new();

    check_unsafe_blocks(file, &scrubbed, &raw_lines, &mut findings);

    let kernel = norm.contains("linalg/") || norm.contains("quant/");
    let plan = norm.contains("optim/")
        || norm.contains("coordinator/")
        || norm.contains("scheduler/");
    for (idx, line) in scrub_lines.iter().enumerate() {
        if in_test.get(idx).copied().unwrap_or(false) {
            continue;
        }
        if kernel && has_word(line, "mul_add") {
            findings.push(LintFinding {
                file: file.to_string(),
                line: idx + 1,
                rule: RULE_MUL_ADD,
                message: MSG_MUL_ADD.to_string(),
            });
        }
        if plan && (has_word(line, "HashMap") || has_word(line, "HashSet")) {
            findings.push(LintFinding {
                file: file.to_string(),
                line: idx + 1,
                rule: RULE_HASH,
                message: MSG_HASH.to_string(),
            });
        }
        if norm.contains("optim/") && line.contains(".unwrap(") && has_word(line, "outputs") {
            findings.push(LintFinding {
                file: file.to_string(),
                line: idx + 1,
                rule: RULE_UNWRAP,
                message: MSG_UNWRAP.to_string(),
            });
        }
    }

    findings.retain(|f| !suppressed(&raw_lines, f.line, f.rule));
    findings
}

/// True when the finding's line (or the one above) carries
/// `lint: allow(<rule>)`.
fn suppressed(raw_lines: &[&str], line: usize, rule: &str) -> bool {
    let tag = format!("lint: allow({rule})");
    let idx = line - 1;
    raw_lines.get(idx).is_some_and(|l| l.contains(&tag))
        || idx > 0 && raw_lines.get(idx - 1).is_some_and(|l| l.contains(&tag))
}

// ---------------------------------------------------------------------------
// rule 1: unsafe blocks
// ---------------------------------------------------------------------------

fn check_unsafe_blocks(
    file: &str,
    scrubbed: &str,
    raw_lines: &[&str],
    findings: &mut Vec<LintFinding>,
) {
    let bytes = scrubbed.as_bytes();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i] == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if scrubbed[i..].starts_with("unsafe")
            && !prev_is_ident(bytes, i)
            && !next_is_ident(bytes, i + 6)
        {
            let mut j = i + 6;
            while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                j += 1;
            }
            // only bare `unsafe {` blocks; `unsafe fn|impl|trait|extern`
            // signatures are governed by their `# Safety` doc sections
            if bytes.get(j) == Some(&b'{') && !has_safety_comment(raw_lines, line) {
                findings.push(LintFinding {
                    file: file.to_string(),
                    line,
                    rule: RULE_UNSAFE,
                    message: MSG_UNSAFE.to_string(),
                });
            }
            i += 6;
            continue;
        }
        i += 1;
    }
}

fn prev_is_ident(bytes: &[u8], i: usize) -> bool {
    i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_')
}

fn next_is_ident(bytes: &[u8], i: usize) -> bool {
    bytes.get(i).is_some_and(|b| b.is_ascii_alphanumeric() || *b == b'_')
}

/// "SAFETY" (case-insensitive) on the flagged line itself, or in the run of
/// comment/attribute/blank lines directly above it.
fn has_safety_comment(raw_lines: &[&str], line: usize) -> bool {
    let idx = line - 1;
    if raw_lines.get(idx).is_some_and(|l| contains_safety(l)) {
        return true;
    }
    let mut j = idx;
    for _ in 0..12 {
        if j == 0 {
            return false;
        }
        j -= 1;
        let raw = raw_lines[j];
        if contains_safety(raw) {
            return true;
        }
        let t = raw.trim_start();
        let is_comment = t.starts_with("//") || t.starts_with("/*") || t.starts_with('*');
        let is_attr = t.starts_with("#[") || t.starts_with("#![");
        if !(t.is_empty() || is_comment || is_attr) {
            // a code line intervenes — the comment run above has ended
            return false;
        }
    }
    false
}

fn contains_safety(line: &str) -> bool {
    line.to_ascii_lowercase().contains("safety")
}

// ---------------------------------------------------------------------------
// token + test-region helpers
// ---------------------------------------------------------------------------

/// Word-boundary containment: `needle` appears in `hay` not flanked by
/// identifier characters.
fn has_word(hay: &str, needle: &str) -> bool {
    let bytes = hay.as_bytes();
    let mut start = 0;
    while let Some(pos) = hay[start..].find(needle) {
        let at = start + pos;
        if !prev_is_ident(bytes, at) && !next_is_ident(bytes, at + needle.len()) {
            return true;
        }
        start = at + needle.len();
    }
    false
}

/// Per-line mask of `#[cfg(test)] mod ... { }` regions, computed on the
/// scrubbed text by brace counting.
fn test_line_mask(scrub_lines: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; scrub_lines.len()];
    let mut idx = 0;
    while idx < scrub_lines.len() {
        if scrub_lines[idx].contains("#[cfg(test)]") {
            // find the opening brace of the item this attribute decorates,
            // then its matching close
            let mut depth = 0i64;
            let mut opened = false;
            let mut j = idx;
            'scan: while j < scrub_lines.len() {
                for b in scrub_lines[j].bytes() {
                    match b {
                        b'{' => {
                            depth += 1;
                            opened = true;
                        }
                        b'}' => depth -= 1,
                        _ => {}
                    }
                    if opened && depth == 0 {
                        break 'scan;
                    }
                }
                j += 1;
            }
            for m in mask.iter_mut().take((j + 1).min(scrub_lines.len())).skip(idx) {
                *m = true;
            }
            idx = j + 1;
        } else {
            idx += 1;
        }
    }
    mask
}

// ---------------------------------------------------------------------------
// source scrubber
// ---------------------------------------------------------------------------

/// Replace the contents of comments, string literals, and char literals with
/// spaces (newlines preserved), so rules only ever see code.
fn scrub(src: &str) -> String {
    let bytes = src.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0usize;
    while i < bytes.len() {
        let b = bytes[i];
        match b {
            b'/' if bytes.get(i + 1) == Some(&b'/') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    out.push(b' ');
                    i += 1;
                }
            }
            b'/' if bytes.get(i + 1) == Some(&b'*') => {
                let mut depth = 1usize;
                out.extend_from_slice(b"  ");
                i += 2;
                while i < bytes.len() && depth > 0 {
                    if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        out.extend_from_slice(b"  ");
                        i += 2;
                    } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        out.extend_from_slice(b"  ");
                        i += 2;
                    } else {
                        out.push(if bytes[i] == b'\n' { b'\n' } else { b' ' });
                        i += 1;
                    }
                }
            }
            b'r' | b'b' if !prev_is_ident(bytes, i) && raw_str_prefix(bytes, i).is_some() => {
                let (skip, hashes) = raw_str_prefix(bytes, i).expect("checked above");
                for _ in 0..skip {
                    out.push(b' ');
                }
                i += skip;
                // consume until `"` followed by `hashes` hash marks
                while i < bytes.len() {
                    if bytes[i] == b'"' && count_hashes(bytes, i + 1) >= hashes {
                        for _ in 0..(1 + hashes) {
                            out.push(b' ');
                        }
                        i += 1 + hashes;
                        break;
                    }
                    out.push(if bytes[i] == b'\n' { b'\n' } else { b' ' });
                    i += 1;
                }
            }
            b'"' => {
                out.push(b' ');
                i += 1;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' => {
                            out.extend_from_slice(b"  ");
                            i += 2;
                        }
                        b'"' => {
                            out.push(b' ');
                            i += 1;
                            break;
                        }
                        b'\n' => {
                            out.push(b'\n');
                            i += 1;
                        }
                        _ => {
                            out.push(b' ');
                            i += 1;
                        }
                    }
                }
            }
            b'\'' => {
                // lifetime (`'a`, `'static`) vs char literal (`'x'`, `'\n'`)
                let is_char = match bytes.get(i + 1) {
                    Some(b'\\') => true,
                    Some(c) if c.is_ascii_alphanumeric() || *c == b'_' => {
                        bytes.get(i + 2) == Some(&b'\'')
                    }
                    Some(_) => true, // e.g. '∂', ''' — treat as literal
                    None => false,
                };
                if !is_char {
                    out.push(b'\'');
                    i += 1;
                } else {
                    out.push(b' ');
                    i += 1;
                    while i < bytes.len() {
                        match bytes[i] {
                            b'\\' => {
                                out.extend_from_slice(b"  ");
                                i += 2;
                            }
                            b'\'' => {
                                out.push(b' ');
                                i += 1;
                                break;
                            }
                            _ => {
                                out.push(b' ');
                                i += 1;
                            }
                        }
                    }
                }
            }
            _ => {
                out.push(b);
                i += 1;
            }
        }
    }
    // scrubbed output is pure ASCII (non-ASCII bytes were blanked)
    String::from_utf8(out).expect("scrubber emits ASCII + preserved ASCII code bytes")
}

/// `r"`, `r#"`, `b"`, `br#"`-style raw/byte string prefix at `i`: returns
/// (prefix length including the opening quote, hash count).
fn raw_str_prefix(bytes: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if bytes.get(j) == Some(&b'b') {
        j += 1;
    }
    if bytes.get(j) != Some(&b'r') {
        // plain `b"..."` byte strings go through the escaped-string arm
        return None;
    }
    j += 1;
    let hashes = count_hashes(bytes, j);
    j += hashes;
    if bytes.get(j) != Some(&b'"') {
        return None;
    }
    Some((j - i + 1, hashes))
}

fn count_hashes(bytes: &[u8], mut j: usize) -> usize {
    let start = j;
    while bytes.get(j) == Some(&b'#') {
        j += 1;
    }
    j - start
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(findings: &[LintFinding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---- seeded violations: the lint MUST fail on each ----------------

    #[test]
    fn flags_unsafe_block_without_safety_comment() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        let f = lint_source("linalg/fake.rs", src);
        assert_eq!(rules(&f), vec![RULE_UNSAFE], "{f:?}");
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn accepts_unsafe_block_with_safety_comment() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p valid\n    unsafe { *p }\n}\n";
        assert!(lint_source("linalg/fake.rs", src).is_empty());
        // ...including through an interleaved attribute, and lowercase
        let src2 = "fn f() {\n    // safety: cfg-gated\n    #[cfg(target_arch = \"x86_64\")]\n    unsafe { body() }\n}\n";
        assert!(lint_source("x.rs", src2).is_empty());
    }

    #[test]
    fn unsafe_fn_and_impl_signatures_are_exempt() {
        let src = "unsafe impl Send for X {}\n/// # Safety\n/// docs\npub unsafe fn g() {}\ntype C = unsafe fn(usize);\n";
        assert!(lint_source("x.rs", src).is_empty());
    }

    #[test]
    fn flags_mul_add_in_kernel_modules_only() {
        let src = "fn k(a: f32, b: f32, c: f32) -> f32 {\n    a.mul_add(b, c)\n}\n";
        assert_eq!(rules(&lint_source("linalg/fake.rs", src)), vec![RULE_MUL_ADD]);
        assert_eq!(rules(&lint_source("quant/fake.rs", src)), vec![RULE_MUL_ADD]);
        assert!(lint_source("report/fake.rs", src).is_empty());
    }

    #[test]
    fn flags_hash_collections_on_plan_paths() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules(&lint_source("optim/fake.rs", src)), vec![RULE_HASH]);
        assert_eq!(rules(&lint_source("coordinator/fake.rs", src)), vec![RULE_HASH]);
        assert_eq!(rules(&lint_source("scheduler/fake.rs", src)), vec![RULE_HASH]);
        assert!(lint_source("data/fake.rs", src).is_empty());
    }

    #[test]
    fn flags_unwrap_on_artifact_outputs_in_optim() {
        let src = "fn s() {\n    let v = outputs.pop().unwrap();\n    let _ = v;\n}\n";
        assert_eq!(rules(&lint_source("optim/fake.rs", src)), vec![RULE_UNWRAP]);
        // unwraps not touching outputs stay legal (Option-field invariants)
        let src2 = "fn s(o: Option<u8>) -> u8 {\n    o.unwrap()\n}\n";
        assert!(lint_source("optim/fake.rs", src2).is_empty());
    }

    // ---- precision: scrubbing, test regions, suppression ---------------

    #[test]
    fn prose_and_strings_never_trip_rules() {
        let src = concat!(
            "// an unsafe { block } in a comment, plus mul_add and HashMap\n",
            "/* unsafe { } */\n",
            "fn f() -> &'static str {\n",
            "    let _c = 'x';\n",
            "    let _e = '\\'';\n",
            "    let _r = r#\"unsafe { mul_add } HashMap\"#;\n",
            "    \"unsafe { } .unwrap( outputs mul_add HashMap\"\n",
            "}\n",
        );
        assert!(lint_source("optim/fake.rs", src).is_empty());
        assert!(lint_source("linalg/fake.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_modules_are_exempt_from_determinism_rules() {
        let src = concat!(
            "fn prod() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    use std::collections::HashMap;\n",
            "    fn t(a: f32) -> f32 { a.mul_add(a, a) }\n",
            "}\n",
        );
        assert!(lint_source("optim/fake.rs", src).is_empty());
        assert!(lint_source("linalg/fake.rs", src).is_empty());
        // ...but the same lines outside the test module are flagged
        let bad = "use std::collections::HashMap;\nfn prod() {}\n";
        assert_eq!(rules(&lint_source("optim/fake.rs", bad)), vec![RULE_HASH]);
    }

    #[test]
    fn inline_allow_suppresses_one_rule() {
        let src = concat!(
            "// deliberate: seeded corpus stats, order never observed\n",
            "// lint: allow(plan-hash-iteration)\n",
            "use std::collections::HashMap;\n",
        );
        assert!(lint_source("optim/fake.rs", src).is_empty());
        // the tag names ONE rule; others still fire
        let src2 = "// lint: allow(kernel-mul-add)\nuse std::collections::HashMap;\n";
        assert_eq!(rules(&lint_source("optim/fake.rs", src2)), vec![RULE_HASH]);
    }

    // ---- the acceptance gate: the tree itself lints clean ---------------

    #[test]
    fn repo_tree_is_lint_clean() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let findings = lint_tree(&root).expect("walk rust/src");
        assert!(
            findings.is_empty(),
            "lint violations in tree:\n{}",
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
