//! Mutant implementations that validate the checker itself.
//!
//! A model checker that never fires is indistinguishable from one that
//! explores nothing, so this module re-implements the two protocols under
//! test with deliberately planted bugs and asserts the explorer flags each
//! one (and does NOT flag the faithful configuration):
//!
//! * [`VChaseLev`] — a fixed-capacity, value-semantics transliteration of
//!   the deque's push/pop/steal over [`shadow`] atomics, parameterized by
//!   [`Weaken`].  Value semantics (`usize` ids, `0` = unpublished
//!   sentinel) mean an ordering bug surfaces as a clean assertion — a lost
//!   or doubled id — never as a double-free of a real boxed task.
//! * [`VGraph`] — the `run_graph` successor-release step parameterized by
//!   [`ReleasePolicy`]: the real last-dependency rule, a dropped release
//!   (lost node), and an every-dependency release (runs before its deps).
//!
//! These always use the shadow atomics directly (no shim), so the mutant
//! regression tests are live in EVERY build of the test suite, not only
//! under `--cfg qgalore_modelcheck`.

use std::sync::{Arc, Mutex};

use super::sched::{explore, Config, Report, Scenario};
use super::shadow::{fence, AtomicIsize, AtomicUsize};
use std::sync::atomic::Ordering;

/// Which ordering to weaken in [`VChaseLev`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Weaken {
    /// Faithful transliteration of the real orderings.
    None,
    /// `pop`'s SeqCst fence demoted to Release — the owner's speculative
    /// `bottom` decrement and its `top` read are no longer globally
    /// ordered against a thief's CAS, so owner and thief can both take
    /// the last element.
    PopFenceRelease,
    /// `push`'s Release fence dropped — the `bottom` publication can
    /// overtake the slot store, so a thief can claim a slot whose element
    /// write has not landed (it reads the `0` sentinel).
    PushSkipReleaseFence,
}

/// Fixed-capacity value-semantics Chase-Lev deque over shadow atomics.
/// Slot values are ids >= 1; `0` marks a never-published slot.
pub struct VChaseLev {
    top: AtomicIsize,
    bottom: AtomicIsize,
    slots: Vec<AtomicUsize>,
    mask: usize,
    weaken: Weaken,
}

impl VChaseLev {
    pub fn new(cap: usize, weaken: Weaken) -> Self {
        assert!(cap.is_power_of_two());
        VChaseLev {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            slots: (0..cap).map(|_| AtomicUsize::new(0)).collect(),
            mask: cap - 1,
            weaken,
        }
    }

    /// Owner-only push (the harness never overfills, so no grow path).
    pub fn push(&self, id: usize) {
        debug_assert!(id != 0, "0 is the unpublished-slot sentinel");
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        assert!(b - t < self.slots.len() as isize, "mutant harness overfilled the ring");
        self.slots[(b as usize) & self.mask].store(id, Ordering::Relaxed);
        if self.weaken != Weaken::PushSkipReleaseFence {
            fence(Ordering::Release);
        }
        self.bottom.store(b + 1, Ordering::Relaxed);
    }

    /// Owner-only pop.
    pub fn pop(&self) -> Option<usize> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        match self.weaken {
            Weaken::PopFenceRelease => fence(Ordering::Release),
            _ => fence(Ordering::SeqCst),
        }
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            let v = self.slots[(b as usize) & self.mask].load(Ordering::Relaxed);
            if t == b {
                if self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_err()
                {
                    self.bottom.store(b + 1, Ordering::Relaxed);
                    return None;
                }
                self.bottom.store(b + 1, Ordering::Relaxed);
            }
            Some(v)
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief steal.
    pub fn steal(&self) -> Option<usize> {
        loop {
            let t = self.top.load(Ordering::Acquire);
            fence(Ordering::SeqCst);
            let b = self.bottom.load(Ordering::Acquire);
            if t >= b {
                return None;
            }
            let v = self.slots[(t as usize) & self.mask].load(Ordering::Relaxed);
            if self.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed).is_ok() {
                return Some(v);
            }
        }
    }
}

/// Explore the canonical owner-vs-thief scenario over a [`VChaseLev`] with
/// the given weakening: the owner pushes ids {1, 2} then pops twice, a
/// thief steals twice; the finale asserts every pushed id was taken
/// exactly once (counting what is left in the ring) and no taker ever saw
/// the unpublished sentinel.
pub fn explore_deque(weaken: Weaken, cfg: &Config) -> Report {
    explore(cfg, || {
        let d = Arc::new(VChaseLev::new(4, weaken));
        let taken: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let owner = {
            let d = Arc::clone(&d);
            let taken = Arc::clone(&taken);
            Box::new(move || {
                d.push(1);
                d.push(2);
                for _ in 0..2 {
                    if let Some(v) = d.pop() {
                        assert!(v != 0, "owner popped an unpublished slot");
                        taken.lock().unwrap().push(v);
                    }
                }
            }) as Box<dyn FnOnce() + Send>
        };
        let thief = {
            let d = Arc::clone(&d);
            let taken = Arc::clone(&taken);
            Box::new(move || {
                for _ in 0..2 {
                    if let Some(v) = d.steal() {
                        assert!(v != 0, "thief stole an unpublished slot");
                        taken.lock().unwrap().push(v);
                    }
                }
            }) as Box<dyn FnOnce() + Send>
        };
        let finale = {
            let d = Arc::clone(&d);
            let taken = Arc::clone(&taken);
            Box::new(move || {
                let mut got = taken.lock().unwrap().clone();
                while let Some(v) = d.pop() {
                    assert!(v != 0, "drain found an unpublished slot");
                    got.push(v);
                }
                got.sort_unstable();
                assert_eq!(got, vec![1, 2], "ids lost or duplicated: {got:?}");
            }) as Box<dyn FnOnce() + Send>
        };
        Scenario { threads: vec![owner, thief], finale }
    })
}

/// Successor-release policy for [`VGraph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReleasePolicy {
    /// The real rule: the unique `fetch_sub` observing 1 takes the slot.
    LastDep,
    /// Decrement but never take — a finished dependency forgets to release,
    /// so the successor is stranded in its slot (lost node).
    Dropped,
    /// Take on EVERY decrement — the first finishing dependency releases
    /// the successor while other dependencies are still running.
    Every,
}

/// Value transliteration of `GraphProtocol`'s release step (payload = node
/// id), parameterized so broken policies can be planted.
pub struct VGraph {
    remaining: Vec<AtomicUsize>,
    succs: Vec<Vec<usize>>,
    slots: Vec<Mutex<Option<usize>>>,
    policy: ReleasePolicy,
}

impl VGraph {
    /// Build from dependency lists (same orientation as `GraphNode::deps`);
    /// non-root nodes are parked as their own ids.
    pub fn build(deps: &[Vec<usize>], policy: ReleasePolicy) -> Self {
        let n = deps.len();
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for (i, ds) in deps.iter().enumerate() {
            for &d in ds {
                succs[d].push(i);
                indeg[i] += 1;
            }
        }
        VGraph {
            remaining: indeg.iter().map(|&d| AtomicUsize::new(d)).collect(),
            succs,
            slots: indeg
                .iter()
                .enumerate()
                .map(|(i, &d)| Mutex::new((d > 0).then_some(i)))
                .collect(),
            policy,
        }
    }

    pub fn roots(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].lock().unwrap().is_none()).collect()
    }

    /// Node `i` finished: release successors per the configured policy.
    pub fn release(&self, i: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for &s in &self.succs[i] {
            let last = self.remaining[s].fetch_sub(1, Ordering::AcqRel) == 1;
            let take = match self.policy {
                ReleasePolicy::LastDep => last,
                ReleasePolicy::Dropped => false,
                ReleasePolicy::Every => true,
            };
            if take {
                if let Some(t) = self.slots[s].lock().unwrap().take() {
                    out.push(t);
                }
            }
        }
        out
    }

    pub fn stranded(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| self.slots[i].lock().unwrap().is_some()).collect()
    }
}

/// Explore the two-root join graph 0,1 -> 2 -> 3 under `policy` with two
/// virtual workers (worker k starts from root k, then drains whatever its
/// releases hand back).  The finale asserts every node completed exactly
/// once, each node ran only after all of its dependencies, and no payload
/// is stranded in a slot.
pub fn explore_graph(policy: ReleasePolicy, cfg: &Config) -> Report {
    let deps: Vec<Vec<usize>> = vec![vec![], vec![], vec![0, 1], vec![2]];
    explore(cfg, move || {
        let g = Arc::new(VGraph::build(&deps, policy));
        let done: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let deps = deps.clone();
        let worker = |root: usize| {
            let g = Arc::clone(&g);
            let done = Arc::clone(&done);
            let deps = deps.clone();
            Box::new(move || {
                let mut work = vec![root];
                while let Some(node) = work.pop() {
                    {
                        let mut log = done.lock().unwrap();
                        for &d in &deps[node] {
                            assert!(
                                log.contains(&d),
                                "node {node} ran before its dependency {d} completed"
                            );
                        }
                        log.push(node);
                    }
                    work.extend(g.release(node));
                }
            }) as Box<dyn FnOnce() + Send>
        };
        let finale = {
            let g = Arc::clone(&g);
            let done = Arc::clone(&done);
            let n = deps.len();
            Box::new(move || {
                let mut log = done.lock().unwrap().clone();
                log.sort_unstable();
                assert_eq!(
                    log,
                    (0..n).collect::<Vec<_>>(),
                    "nodes lost or completed more than once: {log:?}"
                );
                assert!(g.stranded().is_empty(), "payloads stranded: {:?}", g.stranded());
            }) as Box<dyn FnOnce() + Send>
        };
        Scenario { threads: vec![worker(0), worker(1)], finale }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        Config::default()
    }

    #[test]
    fn faithful_deque_transliteration_passes() {
        let r = explore_deque(Weaken::None, &cfg());
        assert!(r.ok(), "faithful orderings flagged: {:?}", r.violation);
        assert!(r.exhausted, "bounded tree not fully explored ({} schedules)", r.schedules);
        assert!(r.schedules > 10, "suspiciously few schedules: {}", r.schedules);
    }

    #[test]
    fn pop_fence_demoted_to_release_is_flagged() {
        let r = explore_deque(Weaken::PopFenceRelease, &cfg());
        assert!(
            !r.ok(),
            "checker missed the pop SeqCst->Release mutant after {} schedules",
            r.schedules
        );
    }

    #[test]
    fn push_missing_release_fence_is_flagged() {
        let r = explore_deque(Weaken::PushSkipReleaseFence, &cfg());
        assert!(
            !r.ok(),
            "checker missed the push release-fence-drop mutant after {} schedules",
            r.schedules
        );
    }

    #[test]
    fn faithful_release_policy_passes() {
        let r = explore_graph(ReleasePolicy::LastDep, &cfg());
        assert!(r.ok(), "last-dependency release flagged: {:?}", r.violation);
        assert!(r.exhausted, "bounded tree not fully explored ({} schedules)", r.schedules);
    }

    #[test]
    fn dropped_release_is_flagged() {
        let r = explore_graph(ReleasePolicy::Dropped, &cfg());
        assert!(!r.ok(), "checker missed the dropped-release mutant");
        let v = r.violation.unwrap();
        assert!(
            v.message.contains("lost") || v.message.contains("stranded"),
            "unexpected violation shape: {}",
            v.message
        );
    }

    #[test]
    fn double_release_is_flagged() {
        let r = explore_graph(ReleasePolicy::Every, &cfg());
        assert!(!r.ok(), "checker missed the every-dependency release mutant");
    }

    #[test]
    fn violation_reports_carry_schedule_index_and_stay_bounded() {
        // The smoke contract the CI leg relies on: mutants are found well
        // inside the schedule budget, and the report says where.
        let r = explore_deque(Weaken::PopFenceRelease, &cfg());
        let v = r.violation.expect("mutant must be flagged");
        assert_eq!(v.schedule_index, r.schedules - 1);
        assert!(r.schedules < 250_000, "mutant search blew the schedule budget");
    }
}
