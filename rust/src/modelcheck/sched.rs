//! DFS schedule explorer: virtual threads, decision recording, and a
//! store-buffer memory model (see the module doc in `mod.rs` for the design
//! rationale and the model's limits).
//!
//! One schedule = one full re-execution of the scenario under a recorded
//! decision list.  Exploration is depth-first: run to completion taking the
//! first option at every new decision point, then backtrack the deepest
//! decision that still has untried options and replay.  Preemption bounding
//! keeps the tree small: continuing the active thread is free, while context
//! switches, store deferrals, and buffer writebacks each spend one unit of
//! the preemption budget.
//!
//! Harness discipline (asserted informally, violated harnesses hang or
//! diverge):
//! * never hold a `std::sync` lock across a shadow-atomic operation;
//! * no spin loops without shadow ops inside (every blocking wait must pass
//!   through a decision point so the scheduler can hand the token over);
//! * the finale closure must capture `Arc`s to all state it checks, so
//!   buffered commit pointers outlive the thread bodies.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

const NO_ACTIVE: usize = usize::MAX;

/// Exploration limits.  The defaults keep a 2–3 thread scenario with ~40
/// shadow ops in the low thousands of schedules.
#[derive(Clone, Debug)]
pub struct Config {
    /// Budget spent by context switches, store deferrals and writebacks.
    pub preemption_bound: u32,
    /// Hard cap on explored schedules; exceeding it ends exploration with
    /// `exhausted == false`.
    pub max_schedules: u64,
    /// Per-schedule shadow-op cap (livelock guard).
    pub max_steps: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { preemption_bound: 2, max_schedules: 250_000, max_steps: 20_000 }
    }
}

/// A schedule under which a scenario invariant failed.
#[derive(Clone, Debug)]
pub struct Violation {
    pub message: String,
    /// 0-based index of the failing schedule in exploration order.
    pub schedule_index: u64,
    /// Recent scheduler events (switches, writebacks) leading to the failure.
    pub trace: Vec<String>,
}

/// Outcome of [`explore`].
#[derive(Clone, Debug)]
pub struct Report {
    /// Number of schedules executed (including a failing one, if any).
    pub schedules: u64,
    pub violation: Option<Violation>,
    /// True iff the bounded schedule tree was fully explored.
    pub exhausted: bool,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.violation.is_none()
    }
}

/// One concurrency scenario: thread bodies plus a single-threaded finale
/// that checks invariants after every body has joined.
pub struct Scenario {
    pub threads: Vec<Box<dyn FnOnce() + Send + 'static>>,
    pub finale: Box<dyn FnOnce() + Send + 'static>,
}

/// A store captured in a thread's write buffer.  `commit` performs the real
/// store; `addr` is the address of the underlying std atomic, `val` the
/// type-erased value (see `shadow.rs` for the encodings).
pub(crate) struct StoreEntry {
    pub(crate) addr: usize,
    pub(crate) val: u64,
    pub(crate) group: u64,
    pub(crate) commit: unsafe fn(usize, u64),
}

struct ThreadState {
    finished: bool,
    buffer: VecDeque<StoreEntry>,
    /// Release-epoch counter: a store may only overtake (write through past)
    /// buffered entries of its own epoch.
    group: u64,
}

struct SimCore {
    active: usize,
    threads: Vec<ThreadState>,
    /// DFS decision list: (chosen, total options) per decision point.
    decisions: Vec<(u32, u32)>,
    cursor: usize,
    preemptions: u32,
    bound: u32,
    steps: u64,
    max_steps: u64,
    trace: Vec<String>,
    failed: Option<String>,
}

pub(crate) struct SimShared {
    core: Mutex<SimCore>,
    cv: Condvar,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<SimShared>, usize)>> = const { RefCell::new(None) };
}

/// The sim handle installed on the calling OS thread, if any.  `None` means
/// shadow atomics delegate straight to the real std atomics.
pub(crate) fn current() -> Option<(Arc<SimShared>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

impl SimCore {
    /// Record or replay one decision with `n` options; trivial (n <= 1)
    /// decisions are not recorded.
    fn decide(&mut self, n: u32) -> u32 {
        if n <= 1 {
            return 0;
        }
        if self.cursor < self.decisions.len() {
            let (chosen, total) = self.decisions[self.cursor];
            if total != n {
                self.fail(format!(
                    "replay divergence at decision {}: recorded {} options, now {} \
                     (scenario factory must be deterministic)",
                    self.cursor, total, n
                ));
                return 0;
            }
            self.cursor += 1;
            chosen
        } else {
            self.decisions.push((0, n));
            self.cursor += 1;
            0
        }
    }

    fn runnable_others(&self, tid: usize) -> Vec<usize> {
        (0..self.threads.len()).filter(|&t| t != tid && !self.threads[t].finished).collect()
    }

    fn buffered_threads(&self) -> Vec<usize> {
        (0..self.threads.len()).filter(|&t| !self.threads[t].buffer.is_empty()).collect()
    }

    fn note(&mut self, msg: String) {
        if self.trace.len() >= 64 {
            self.trace.remove(0);
        }
        self.trace.push(msg);
    }

    /// Latch a violation: flush every buffer so teardown reads committed
    /// state, then release all threads to free-run to completion.
    fn fail(&mut self, msg: String) {
        if self.failed.is_some() {
            return;
        }
        for t in &mut self.threads {
            while let Some(e) = t.buffer.pop_front() {
                // SAFETY: the entry's commit fn was captured together with
                // the address of a live shadow atomic; the finale holds the
                // owning Arcs, so the target outlives every buffered entry.
                unsafe { (e.commit)(e.addr, e.val) };
            }
        }
        self.failed = Some(msg);
        self.active = NO_ACTIVE;
    }

    fn flush_own(&mut self, tid: usize) {
        while let Some(e) = self.threads[tid].buffer.pop_front() {
            // SAFETY: as in `fail` — the target atomic is kept alive by the
            // scenario's Arcs until after every commit pointer is drained.
            unsafe { (e.commit)(e.addr, e.val) };
        }
    }
}

impl SimShared {
    /// Block until `tid` holds the execution token, then run the scheduling
    /// decision for this op.  Returns false when the sim has failed and the
    /// caller should delegate to the real operation (free-run teardown).
    pub(crate) fn enter(&self, tid: usize) -> bool {
        let mut core = self.core.lock().unwrap();
        loop {
            if core.failed.is_some() {
                return false;
            }
            if core.active == tid {
                break;
            }
            core = self.cv.wait(core).unwrap();
        }
        core.steps += 1;
        if core.steps > core.max_steps {
            let cap = core.max_steps;
            core.fail(format!("step bound {cap} exceeded (livelock or unbounded retry loop)"));
            self.cv.notify_all();
            return false;
        }
        // Writebacks re-enter the decision loop: several buffered stores may
        // drain at one program point.
        loop {
            enum Opt {
                Run,
                Switch(usize),
                Writeback(usize),
            }
            let mut opts = vec![Opt::Run];
            if core.preemptions < core.bound {
                for t in core.runnable_others(tid) {
                    opts.push(Opt::Switch(t));
                }
                for t in core.buffered_threads() {
                    opts.push(Opt::Writeback(t));
                }
            }
            let choice = core.decide(opts.len() as u32) as usize;
            if core.failed.is_some() {
                self.cv.notify_all();
                return false;
            }
            match opts[choice] {
                Opt::Run => return true,
                Opt::Switch(t) => {
                    core.preemptions += 1;
                    core.active = t;
                    core.note(format!("switch {tid}->{t}"));
                    self.cv.notify_all();
                    loop {
                        if core.failed.is_some() {
                            return false;
                        }
                        if core.active == tid {
                            return true;
                        }
                        core = self.cv.wait(core).unwrap();
                    }
                }
                Opt::Writeback(t) => {
                    core.preemptions += 1;
                    if let Some(e) = core.threads[t].buffer.pop_front() {
                        // SAFETY: as in `SimCore::fail` — scenario Arcs keep
                        // the target atomic alive past all buffered commits.
                        unsafe { (e.commit)(e.addr, e.val) };
                    }
                    core.note(format!("writeback t{t}"));
                    // stay in the loop: the current thread still holds the
                    // token and decides again.
                }
            }
        }
    }

    /// Mark `tid` finished: flush its buffer and hand the token to another
    /// runnable thread (a free decision).
    fn finish(&self, tid: usize) {
        let mut core = self.core.lock().unwrap();
        core.flush_own(tid);
        core.threads[tid].finished = true;
        if core.active == tid || core.active == NO_ACTIVE {
            let next = core.runnable_others(tid);
            if next.is_empty() {
                core.active = NO_ACTIVE;
            } else {
                let k = core.decide(next.len() as u32) as usize;
                core.active = next[k.min(next.len() - 1)];
            }
        }
        self.cv.notify_all();
    }

    fn fail_from(&self, msg: String) {
        let mut core = self.core.lock().unwrap();
        core.fail(msg);
        self.cv.notify_all();
    }

    pub(crate) fn with_core<R>(&self, f: impl FnOnce(&mut SimCore) -> R) -> R {
        let mut core = self.core.lock().unwrap();
        f(&mut core)
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Explore every bounded schedule of the scenarios produced by `factory`.
/// The factory is invoked once per schedule and must be deterministic: same
/// threads, same per-thread shadow-op sequences given the same decisions.
pub fn explore(cfg: &Config, mut factory: impl FnMut() -> Scenario) -> Report {
    let mut decisions: Vec<(u32, u32)> = Vec::new();
    let mut schedules: u64 = 0;
    loop {
        let scenario = factory();
        let n_threads = scenario.threads.len();
        assert!(n_threads >= 1, "scenario needs at least one thread");
        let sim = Arc::new(SimShared {
            core: Mutex::new(SimCore {
                active: NO_ACTIVE,
                threads: (0..n_threads)
                    .map(|_| ThreadState { finished: false, buffer: VecDeque::new(), group: 0 })
                    .collect(),
                decisions: std::mem::take(&mut decisions),
                cursor: 0,
                preemptions: 0,
                bound: cfg.preemption_bound,
                steps: 0,
                max_steps: cfg.max_steps,
                trace: Vec::new(),
                failed: None,
            }),
            cv: Condvar::new(),
        });
        // Initial free decision: which thread runs first.
        {
            let mut core = sim.core.lock().unwrap();
            let first = core.decide(n_threads as u32) as usize;
            core.active = first.min(n_threads - 1);
        }
        let handles: Vec<_> = scenario
            .threads
            .into_iter()
            .enumerate()
            .map(|(tid, body)| {
                let sim = Arc::clone(&sim);
                std::thread::spawn(move || {
                    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&sim), tid)));
                    let r = catch_unwind(AssertUnwindSafe(body));
                    if let Err(p) = r {
                        sim.fail_from(format!("thread {tid} panicked: {}", panic_message(&*p)));
                    }
                    sim.finish(tid);
                    CURRENT.with(|c| *c.borrow_mut() = None);
                })
            })
            .collect();
        for h in handles {
            // A scenario thread that panics is already converted into a
            // violation above; the join itself cannot fail.
            let _ = h.join();
        }
        schedules += 1;
        let (mut failed, trace, used) = sim.with_core(|core| {
            (
                core.failed.take(),
                std::mem::take(&mut core.trace),
                std::mem::take(&mut core.decisions),
            )
        });
        if failed.is_none() {
            // Finale runs single-threaded with no sim installed: every
            // buffer was flushed at thread finish, so it sees final state.
            if let Err(p) = catch_unwind(AssertUnwindSafe(scenario.finale)) {
                failed = Some(format!("finale assertion failed: {}", panic_message(&*p)));
            }
        }
        if let Some(message) = failed {
            return Report {
                schedules,
                violation: Some(Violation { message, schedule_index: schedules - 1, trace }),
                exhausted: false,
            };
        }
        decisions = used;
        // Backtrack: advance the deepest decision with untried options.
        let mut advanced = false;
        while let Some(&(chosen, total)) = decisions.last() {
            if chosen + 1 < total {
                let last = decisions.len() - 1;
                decisions[last].0 += 1;
                advanced = true;
                break;
            }
            decisions.pop();
        }
        if !advanced {
            return Report { schedules, violation: None, exhausted: true };
        }
        if schedules >= cfg.max_schedules {
            return Report { schedules, violation: None, exhausted: false };
        }
    }
}

// ---- memory-model operations, called by the shadow atomics ------------

/// Is write-through past the buffered entries legal for a store to `addr`
/// in release-epoch `group`?  Coherence forbids overtaking a same-address
/// entry; release ordering forbids overtaking an earlier epoch.
fn must_defer(ts: &ThreadState, addr: usize) -> bool {
    ts.buffer.iter().any(|e| e.addr == addr || e.group < ts.group)
}

/// Shadow store.  `release` marks Release/AcqRel/SeqCst-release semantics;
/// `seq_cst` additionally forces a full flush + immediate commit.
pub(crate) fn sim_store(
    sim: &Arc<SimShared>,
    tid: usize,
    addr: usize,
    val: u64,
    commit: unsafe fn(usize, u64),
    release: bool,
    seq_cst: bool,
) {
    if !sim.enter(tid) {
        // SAFETY: free-run teardown; target alive per scenario contract.
        unsafe { commit(addr, val) };
        return;
    }
    let mut core = sim.core.lock().unwrap();
    if core.failed.is_some() {
        drop(core);
        // SAFETY: as above.
        unsafe { commit(addr, val) };
        return;
    }
    if seq_cst {
        core.flush_own(tid);
        drop(core);
        // SAFETY: committing under the exploration token; target alive.
        unsafe { commit(addr, val) };
        return;
    }
    if release {
        // fence(Release); store — the new epoch orders this store after
        // everything already buffered.
        core.threads[tid].group += 1;
    }
    let group = core.threads[tid].group;
    let forced = must_defer(&core.threads[tid], addr);
    let defer = if forced {
        true
    } else if core.preemptions < core.bound {
        let d = core.decide(2) == 1;
        if core.failed.is_some() {
            drop(core);
            sim.cv.notify_all();
            // SAFETY: free-run teardown; target alive per scenario contract.
            unsafe { commit(addr, val) };
            return;
        }
        if d {
            core.preemptions += 1;
        }
        d
    } else {
        false
    };
    if defer {
        core.threads[tid].buffer.push_back(StoreEntry { addr, val, group, commit });
    } else {
        drop(core);
        // SAFETY: committing under the exploration token; target alive.
        unsafe { commit(addr, val) };
    }
}

/// Shadow load with store-forwarding from the thread's own buffer.
pub(crate) fn sim_load(
    sim: &Arc<SimShared>,
    tid: usize,
    addr: usize,
    real: impl Fn() -> u64,
) -> u64 {
    if !sim.enter(tid) {
        return real();
    }
    let core = sim.core.lock().unwrap();
    if let Some(e) = core.threads[tid].buffer.iter().rev().find(|e| e.addr == addr) {
        return e.val;
    }
    drop(core);
    real()
}

/// Shadow fence.  SeqCst drains the calling thread's buffer synchronously;
/// Release/AcqRel opens a new epoch; Acquire is a no-op (the model does not
/// reorder loads).
pub(crate) fn sim_fence(sim: &Arc<SimShared>, tid: usize, release: bool, seq_cst: bool) {
    if !sim.enter(tid) {
        return;
    }
    let mut core = sim.core.lock().unwrap();
    if seq_cst {
        core.flush_own(tid);
    } else if release {
        core.threads[tid].group += 1;
    }
}

/// Shadow read-modify-write: drain the buffer, then run the real atomic op
/// under the token.  All RMWs are treated as at least AcqRel — the pool
/// only uses SeqCst/AcqRel RMWs, so nothing is weakened by the model here.
pub(crate) fn sim_rmw<R>(sim: &Arc<SimShared>, tid: usize, real: impl FnOnce() -> R) -> R {
    if sim.enter(tid) {
        let mut core = sim.core.lock().unwrap();
        core.flush_own(tid);
    }
    real()
}
