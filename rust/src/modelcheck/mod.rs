//! In-tree concurrency model checker + repo-invariant lint pass.
//!
//! # Why
//!
//! The whole reproduction rests on one systems claim: the stealing pool
//! and the `run_graph` executor produce bitwise-identical results to
//! sequential execution.  The stress suites and golden traces *sample*
//! interleavings; this module *enumerates* them (under a bound), so
//! ordering bugs in the Chase-Lev deque or the graph release protocol are
//! caught at analysis time instead of after a thousand lucky steal-seed
//! runs.
//!
//! # How — the shim, the scheduler, the memory model
//!
//! * **Shim** ([`crate::linalg::sync`]): `pool.rs` names its atomics
//!   through a one-line re-export layer.  Production builds get
//!   `std::sync::atomic` verbatim (zero cost); under
//!   `--cfg qgalore_modelcheck` the same names resolve to the [`shadow`]
//!   wrappers, so the checker executes the *real* deque and release code —
//!   not a transliteration that could drift.
//! * **Scheduler** ([`sched`]): N scenario closures run on real OS threads,
//!   but only the thread holding the execution token proceeds; every shadow
//!   operation is a decision point.  Exploration is DFS over recorded
//!   decision lists with full re-execution per schedule (CHESS-style), with
//!   *preemption bounding*: staying on the current thread is free, context
//!   switches / store deferrals / writebacks spend a small budget (default
//!   2).  Most ordering bugs need only 1–2 preemptions, so the bounded
//!   tree is both small and effective.
//! * **Memory model**: a PSO-style per-thread store buffer.  Non-SeqCst
//!   stores may be deferred (a budgeted branch) and commit later at
//!   explored writeback points; `Release` stores/fences open a new epoch
//!   that buffered stores cannot be overtaken across; SeqCst stores,
//!   fences, and all RMWs drain the buffer synchronously; loads forward
//!   from the thread's own buffer.
//!
//! # Limits (and why miri stays in CI)
//!
//! The model reorders *stores* but never *loads*, and treats every RMW as
//! at least AcqRel.  That is enough to distinguish the deque's documented
//! fence placements (the mutant tests prove it: weakening `pop`'s SeqCst
//! fence or dropping `push`'s Release fence is flagged), but it is not the
//! full C11 weak-memory semantics — load-load reordering and release-
//! sequence subtleties are miri's domain.  The two passes are
//! complementary: modelcheck exhausts schedules under a simplified memory
//! model; miri samples schedules under the precise model.  CI runs both.
//!
//! # Validation
//!
//! A checker that cannot fail is worthless, so [`mutants`] re-implements
//! the deque over shadow atomics with deliberately weakened orderings and
//! the release protocol with deliberately broken policies; regression
//! tests assert every mutant is flagged and the faithful configuration is
//! not.  [`checks`] then points the explorer at the real (shimmed)
//! `ChaseLev` / `GraphProtocol` code.
//!
//! # The lint pass ([`lint`])
//!
//! `qgalore lint` walks `rust/src` and enforces the repo's written
//! determinism/soundness invariants: `unsafe` blocks carry SAFETY
//! comments, kernel modules never call `mul_add` (fma would break bitwise
//! identity), plan/join-order paths never iterate hash collections, and
//! `optim` never unwraps artifact outputs.  `clippy.toml` backs up the
//! fma/hash rules with stock clippy's `disallowed_methods`.

pub mod checks;
pub mod lint;
pub mod mutants;
pub mod sched;
pub mod shadow;

pub use checks::{run_suite, SuiteReport};
pub use lint::{lint_paths, lint_tree, LintFinding};
pub use sched::{explore, Config, Report, Scenario, Violation};
