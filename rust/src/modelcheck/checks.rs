//! Exploration scenarios over the REAL concurrency core.
//!
//! Everything here drives the actual `linalg::pool` code — `ChaseLev<usize>`
//! and `GraphProtocol<usize>` — through the `linalg::sync` shim.  Under
//! `--cfg qgalore_modelcheck` the shim resolves to shadow atomics and the
//! explorer enumerates every bounded schedule; in ordinary builds the shim
//! is std and exploration degenerates to a handful of free-running
//! schedules (still a valid smoke test, no longer exhaustive).  The CI
//! `modelcheck` leg runs this suite in BOTH builds; `SuiteReport::shimmed`
//! records which one actually explored.
//!
//! The mutant validation for the checker itself lives in [`super::mutants`]
//! (value-semantics transliterations, instrumented in every build).  Real
//! code is only ever explored in its faithful configuration: a true
//! ordering bug found here would be a real pool bug, and the assertions
//! below are exactly the pool's exactly-once / release-once contracts.

use std::sync::{Arc, Mutex};

use super::sched::{explore, Config, Report, Scenario};
use crate::linalg::pool::{ChaseLev, GraphProtocol};

/// One named exploration result.
pub struct SuiteReport {
    pub scenarios: Vec<(&'static str, Report)>,
    /// True when this build routes `pool.rs` atomics through the shadow
    /// layer (`--cfg qgalore_modelcheck`) — i.e. the exploration above was
    /// real, not vacuous.
    pub shimmed: bool,
}

impl SuiteReport {
    pub fn ok(&self) -> bool {
        self.scenarios.iter().all(|(_, r)| r.ok())
    }
}

/// Owner push/pop vs one thief over the real deque — the `bottom`/`top`
/// SeqCst fence window.  Exactly-once on ids {1, 2}.
pub fn real_deque_fence_window(cfg: &Config) -> Report {
    explore_real_deque(cfg, 4, 2, 1)
}

/// Owner pushes through a ring growth (capacity 2, three pushes) while a
/// thief steals — the grow/publish window.  Exactly-once on ids {1, 2, 3}.
pub fn real_deque_growth(cfg: &Config) -> Report {
    explore_real_deque(cfg, 2, 3, 1)
}

/// Two thieves race the owner for a single element — the last-element CAS
/// arbitration.  Exactly-once on id {1}.
pub fn real_deque_two_thieves(cfg: &Config) -> Report {
    explore_real_deque(cfg, 4, 1, 2)
}

fn explore_real_deque(cfg: &Config, cap: usize, n_ids: usize, n_thieves: usize) -> Report {
    explore(cfg, || {
        let d: Arc<ChaseLev<usize>> = Arc::new(ChaseLev::with_capacity(cap));
        let taken: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let owner = {
            let d = Arc::clone(&d);
            let taken = Arc::clone(&taken);
            Box::new(move || {
                for id in 1..=n_ids {
                    d.push(id);
                }
                for _ in 0..n_ids {
                    if let Some(v) = d.pop() {
                        taken.lock().unwrap().push(v);
                    }
                }
            }) as Box<dyn FnOnce() + Send>
        };
        let mut threads = vec![owner];
        for _ in 0..n_thieves {
            let d = Arc::clone(&d);
            let taken = Arc::clone(&taken);
            threads.push(Box::new(move || {
                for _ in 0..2 {
                    if let Some(v) = d.steal() {
                        taken.lock().unwrap().push(v);
                    }
                }
            }) as Box<dyn FnOnce() + Send>);
        }
        let finale = {
            let d = Arc::clone(&d);
            let taken = Arc::clone(&taken);
            Box::new(move || {
                let mut got = taken.lock().unwrap().clone();
                while let Some(v) = d.pop() {
                    got.push(v);
                }
                got.sort_unstable();
                assert_eq!(
                    got,
                    (1..=n_ids).collect::<Vec<_>>(),
                    "real deque lost or duplicated ids: {got:?}"
                );
            }) as Box<dyn FnOnce() + Send>
        };
        Scenario { threads, finale }
    })
}

/// The real `GraphProtocol` release path on the two-root join graph
/// 0,1 -> 2 -> 3: two workers finish one root each; the LAST `fetch_sub`
/// must release node 2 exactly once, then node 3.  When `abort` is true,
/// worker 0 additionally requests an abort after its root (the panic
/// fail-fast path): payloads are skipped but every node still completes
/// and releases, so nothing is stranded.
pub fn real_graph_release(cfg: &Config, abort: bool) -> Report {
    explore(cfg, move || {
        let deps: Vec<Vec<usize>> = vec![vec![], vec![], vec![0, 1], vec![2]];
        let n = deps.len();
        let proto: Arc<GraphProtocol<usize>> = Arc::new(GraphProtocol::build(&deps));
        for i in 0..n {
            proto.park(i, i);
        }
        let done: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let ran: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let worker = |k: usize| {
            let proto = Arc::clone(&proto);
            let done = Arc::clone(&done);
            let ran = Arc::clone(&ran);
            Box::new(move || {
                let root = proto.roots()[k];
                let mut work = vec![proto.take(root).expect("root parked by the harness")];
                while let Some(node) = work.pop() {
                    // mirror run_graph's wrapped-task shape: skip the
                    // payload under abort, but always complete + release
                    if !proto.abort_requested() {
                        ran.lock().unwrap().push(node);
                    }
                    done.lock().unwrap().push(node);
                    if abort && k == 0 && node == root {
                        proto.request_abort();
                    }
                    work.extend(proto.release_successors(node));
                }
            }) as Box<dyn FnOnce() + Send>
        };
        let finale = {
            let proto = Arc::clone(&proto);
            let done = Arc::clone(&done);
            let ran = Arc::clone(&ran);
            Box::new(move || {
                let mut log = done.lock().unwrap().clone();
                log.sort_unstable();
                assert_eq!(
                    log,
                    (0..n).collect::<Vec<_>>(),
                    "graph nodes lost or completed more than once: {log:?}"
                );
                let stranded: Vec<usize> = (0..n).filter_map(|i| proto.take(i)).collect();
                assert!(stranded.is_empty(), "payloads stranded in slots: {stranded:?}");
                let mut ran = ran.lock().unwrap().clone();
                let total = ran.len();
                ran.sort_unstable();
                ran.dedup();
                assert_eq!(ran.len(), total, "a payload ran twice: {ran:?}");
            }) as Box<dyn FnOnce() + Send>
        };
        Scenario { threads: vec![worker(0), worker(1)], finale }
    })
}

/// Run every real-code scenario under `cfg`.
pub fn run_suite(cfg: &Config) -> SuiteReport {
    SuiteReport {
        scenarios: vec![
            ("deque/fence-window", real_deque_fence_window(cfg)),
            ("deque/growth", real_deque_growth(cfg)),
            ("deque/two-thieves", real_deque_two_thieves(cfg)),
            ("graph/release-once", real_graph_release(cfg, false)),
            ("graph/abort-skip", real_graph_release(cfg, true)),
        ],
        shimmed: cfg!(qgalore_modelcheck),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelcheck::shadow::AtomicUsize;
    use std::sync::atomic::Ordering;

    // ---- checker self-tests (instrumented in every build: they use the
    // shadow atomics directly, not the shim) ----------------------------

    /// The textbook lost update: two threads increment via load+store.
    /// The explorer MUST find the interleaving where one increment is lost
    /// — this is the canary that scheduling decisions actually interleave.
    #[test]
    fn explorer_finds_lost_update() {
        let r = explore(&Config::default(), || {
            let c = Arc::new(AtomicUsize::new(0));
            let inc = |c: &Arc<AtomicUsize>| {
                let c = Arc::clone(c);
                Box::new(move || {
                    let v = c.load(Ordering::SeqCst);
                    c.store(v + 1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send>
            };
            let finale = {
                let c = Arc::clone(&c);
                Box::new(move || {
                    assert_eq!(c.load(Ordering::SeqCst), 2, "lost update");
                }) as Box<dyn FnOnce() + Send>
            };
            Scenario { threads: vec![inc(&c), inc(&c)], finale }
        });
        assert!(!r.ok(), "explorer missed the load/store lost update");
    }

    /// The fetch_add version is race-free and the bounded tree must be
    /// fully explored without a violation.
    #[test]
    fn explorer_passes_fetch_add_counter() {
        let r = explore(&Config::default(), || {
            let c = Arc::new(AtomicUsize::new(0));
            let inc = |c: &Arc<AtomicUsize>| {
                let c = Arc::clone(c);
                Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send>
            };
            let finale = {
                let c = Arc::clone(&c);
                Box::new(move || {
                    assert_eq!(c.load(Ordering::SeqCst), 2, "lost update");
                }) as Box<dyn FnOnce() + Send>
            };
            Scenario { threads: vec![inc(&c), inc(&c)], finale }
        });
        assert!(r.ok(), "fetch_add counter flagged: {:?}", r.violation);
        assert!(r.exhausted);
    }

    /// Schedule counts must stay CI-friendly: every suite scenario
    /// completes inside a small fraction of the default budget.
    #[test]
    fn suite_schedule_counts_stay_bounded() {
        let report = run_suite(&Config::default());
        for (name, r) in &report.scenarios {
            assert!(r.ok(), "{name} flagged a violation: {:?}", r.violation);
            assert!(r.exhausted, "{name} did not exhaust its bounded tree");
            assert!(r.schedules < 100_000, "{name} exploded to {} schedules", r.schedules);
        }
    }

    // ---- real-code exploration properties (meaningful only when the
    // shim routes pool.rs through the shadow atomics) -------------------

    /// Under the shim, the real deque scenarios must explore genuinely
    /// many interleavings — a near-1 schedule count would mean the shim
    /// is not wired through and the "exploration" is vacuous.
    #[cfg(qgalore_modelcheck)]
    #[test]
    fn shimmed_exploration_is_not_vacuous() {
        let report = run_suite(&Config::default());
        assert!(report.shimmed);
        for (name, r) in &report.scenarios {
            assert!(r.schedules > 10, "{name}: only {} schedules — shim not wired?", r.schedules);
        }
    }
}
