//! Shadow atomics: drop-in stand-ins for `std::sync::atomic` types that
//! route every operation through the schedule explorer in `sched.rs` when a
//! sim is installed on the calling thread, and delegate to the wrapped std
//! atomic otherwise.
//!
//! Delegation makes the types safe to substitute crate-wide under
//! `--cfg qgalore_modelcheck`: the entire ordinary test suite runs
//! unchanged, and only threads spawned by [`super::sched::explore`] see
//! instrumented behavior.
//!
//! Value encoding is type-erased to `u64` for the store buffer:
//! `usize as u64`, `isize as i64 as u64`, `bool as u64`, pointers via
//! `usize`.  Each type supplies a monomorphic commit fn pointer that casts
//! the erased address/value back and performs the real store.

use std::sync::atomic::Ordering;

use super::sched::{current, sim_fence, sim_load, sim_rmw, sim_store};

fn is_seq_cst(o: Ordering) -> bool {
    matches!(o, Ordering::SeqCst)
}

fn is_release(o: Ordering) -> bool {
    matches!(o, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

/// Memory fence routed through the sim when one is installed.
pub fn fence(order: Ordering) {
    if let Some((sim, tid)) = current() {
        sim_fence(&sim, tid, is_release(order), is_seq_cst(order));
    } else {
        std::sync::atomic::fence(order);
    }
}

fn enc_usize(v: usize) -> u64 {
    v as u64
}

fn dec_usize(v: u64) -> usize {
    v as usize
}

fn enc_isize(v: isize) -> u64 {
    v as i64 as u64
}

fn dec_isize(v: u64) -> isize {
    v as i64 as isize
}

fn enc_u64(v: u64) -> u64 {
    v
}

fn dec_u64(v: u64) -> u64 {
    v
}

macro_rules! shadow_int {
    ($name:ident, $std:ident, $prim:ty, $commit:ident, $enc:ident, $dec:ident) => {
        #[doc = concat!("Shadow counterpart of [`std::sync::atomic::", stringify!($std), "`].")]
        #[derive(Debug, Default)]
        pub struct $name {
            inner: std::sync::atomic::$std,
        }

        unsafe fn $commit(addr: usize, val: u64) {
            let target = addr as *const std::sync::atomic::$std;
            // SAFETY: `addr` was produced from `&self.inner` of a live
            // shadow atomic; the scenario contract (finale holds the owning
            // Arcs) keeps it alive until every buffered entry is committed.
            unsafe { (*target).store($dec(val), Ordering::SeqCst) }
        }

        impl $name {
            pub const fn new(v: $prim) -> Self {
                Self { inner: std::sync::atomic::$std::new(v) }
            }

            fn addr(&self) -> usize {
                &self.inner as *const std::sync::atomic::$std as usize
            }

            pub fn load(&self, order: Ordering) -> $prim {
                if let Some((sim, tid)) = current() {
                    let real = || $enc(self.inner.load(Ordering::SeqCst));
                    $dec(sim_load(&sim, tid, self.addr(), real))
                } else {
                    self.inner.load(order)
                }
            }

            pub fn store(&self, v: $prim, order: Ordering) {
                if let Some((sim, tid)) = current() {
                    sim_store(
                        &sim,
                        tid,
                        self.addr(),
                        $enc(v),
                        $commit,
                        is_release(order),
                        is_seq_cst(order),
                    );
                } else {
                    self.inner.store(v, order);
                }
            }

            pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                if let Some((sim, tid)) = current() {
                    sim_rmw(&sim, tid, || self.inner.swap(v, order))
                } else {
                    self.inner.swap(v, order)
                }
            }

            pub fn compare_exchange(
                &self,
                cur: $prim,
                new: $prim,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$prim, $prim> {
                if let Some((sim, tid)) = current() {
                    sim_rmw(&sim, tid, || self.inner.compare_exchange(cur, new, success, failure))
                } else {
                    self.inner.compare_exchange(cur, new, success, failure)
                }
            }

            pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                if let Some((sim, tid)) = current() {
                    sim_rmw(&sim, tid, || self.inner.fetch_add(v, order))
                } else {
                    self.inner.fetch_add(v, order)
                }
            }

            pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                if let Some((sim, tid)) = current() {
                    sim_rmw(&sim, tid, || self.inner.fetch_sub(v, order))
                } else {
                    self.inner.fetch_sub(v, order)
                }
            }

            pub fn get_mut(&mut self) -> &mut $prim {
                self.inner.get_mut()
            }

            pub fn into_inner(self) -> $prim {
                self.inner.into_inner()
            }
        }
    };
}

shadow_int!(AtomicUsize, AtomicUsize, usize, commit_usize, enc_usize, dec_usize);
shadow_int!(AtomicIsize, AtomicIsize, isize, commit_isize, enc_isize, dec_isize);
shadow_int!(AtomicU64, AtomicU64, u64, commit_u64, enc_u64, dec_u64);

/// Shadow counterpart of [`std::sync::atomic::AtomicBool`].
#[derive(Debug, Default)]
pub struct AtomicBool {
    inner: std::sync::atomic::AtomicBool,
}

unsafe fn commit_bool(addr: usize, val: u64) {
    // SAFETY: `addr` points to the `inner` of a live shadow AtomicBool (see
    // the commit-fn contract in the module doc).
    unsafe { (*(addr as *const std::sync::atomic::AtomicBool)).store(val != 0, Ordering::SeqCst) }
}

impl AtomicBool {
    pub const fn new(v: bool) -> Self {
        Self { inner: std::sync::atomic::AtomicBool::new(v) }
    }

    fn addr(&self) -> usize {
        &self.inner as *const std::sync::atomic::AtomicBool as usize
    }

    pub fn load(&self, order: Ordering) -> bool {
        if let Some((sim, tid)) = current() {
            sim_load(&sim, tid, self.addr(), || self.inner.load(Ordering::SeqCst) as u64) != 0
        } else {
            self.inner.load(order)
        }
    }

    pub fn store(&self, v: bool, order: Ordering) {
        if let Some((sim, tid)) = current() {
            sim_store(
                &sim,
                tid,
                self.addr(),
                v as u64,
                commit_bool,
                is_release(order),
                is_seq_cst(order),
            );
        } else {
            self.inner.store(v, order);
        }
    }

    pub fn swap(&self, v: bool, order: Ordering) -> bool {
        if let Some((sim, tid)) = current() {
            sim_rmw(&sim, tid, || self.inner.swap(v, order))
        } else {
            self.inner.swap(v, order)
        }
    }

    pub fn get_mut(&mut self) -> &mut bool {
        self.inner.get_mut()
    }
}

/// Shadow counterpart of [`std::sync::atomic::AtomicPtr`].
#[derive(Debug, Default)]
pub struct AtomicPtr<T> {
    inner: std::sync::atomic::AtomicPtr<T>,
}

unsafe fn commit_ptr<T>(addr: usize, val: u64) {
    // SAFETY: `addr` points to the `inner` of a live shadow AtomicPtr<T>
    // (see the commit-fn contract in the module doc).
    unsafe {
        (*(addr as *const std::sync::atomic::AtomicPtr<T>))
            .store(val as usize as *mut T, Ordering::SeqCst)
    }
}

impl<T> AtomicPtr<T> {
    pub const fn new(p: *mut T) -> Self {
        Self { inner: std::sync::atomic::AtomicPtr::new(p) }
    }

    fn addr(&self) -> usize {
        &self.inner as *const std::sync::atomic::AtomicPtr<T> as usize
    }

    pub fn load(&self, order: Ordering) -> *mut T {
        if let Some((sim, tid)) = current() {
            let real = || self.inner.load(Ordering::SeqCst) as usize as u64;
            sim_load(&sim, tid, self.addr(), real) as usize as *mut T
        } else {
            self.inner.load(order)
        }
    }

    pub fn store(&self, p: *mut T, order: Ordering) {
        if let Some((sim, tid)) = current() {
            sim_store(
                &sim,
                tid,
                self.addr(),
                p as usize as u64,
                commit_ptr::<T>,
                is_release(order),
                is_seq_cst(order),
            );
        } else {
            self.inner.store(p, order);
        }
    }

    pub fn compare_exchange(
        &self,
        cur: *mut T,
        new: *mut T,
        success: Ordering,
        failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        if let Some((sim, tid)) = current() {
            sim_rmw(&sim, tid, || self.inner.compare_exchange(cur, new, success, failure))
        } else {
            self.inner.compare_exchange(cur, new, success, failure)
        }
    }

    pub fn get_mut(&mut self) -> &mut *mut T {
        self.inner.get_mut()
    }
}
