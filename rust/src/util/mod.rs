//! Small utilities: deterministic RNG, normal sampling, timers.
//!
//! We deliberately avoid external RNG crates: training runs must be exactly
//! replayable from a seed recorded in the experiment log, and the PCG-XSH-RR
//! generator below is 30 lines and fully specified here.

use std::time::Instant;

/// PCG-XSH-RR 64/32 (O'Neill 2014). Deterministic, seedable, fast.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    pub fn normal_vec(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| mean + std * self.next_normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Wall-clock stopwatch for coarse phase timing in metrics.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Parse an optional raw env-var value, warning loudly on malformed input
/// instead of silently falling back — the shared policy for every
/// `QGALORE_*` knob (`QGALORE_THREADS`, `QGALORE_KERNEL`,
/// `QGALORE_STEAL_SEED`, `QGALORE_SLABS_PER_WORKER`).  A typo in a CI
/// matrix job must not let the job quietly test a different configuration
/// than its name claims.
///
/// `raw` is the env value if the variable was set (`None` = unset, which is
/// not a warning); the value is trimmed before `parse` sees it.  Returns
/// `None` for both "unset" and "malformed" so callers chain their own
/// default with `unwrap_or*`.  Split from [`env_parse`] so unit tests can
/// drive the malformed path without mutating process env (racy under the
/// parallel test runner).
pub fn parse_env_or_warn<T>(
    var: &str,
    raw: Option<&str>,
    expected: &str,
    parse: impl FnOnce(&str) -> Option<T>,
) -> Option<T> {
    let raw = raw?;
    match parse(raw.trim()) {
        Some(v) => Some(v),
        None => {
            eprintln!(
                "warning: unrecognized {var}={raw:?} (want {expected}); using the default"
            );
            None
        }
    }
}

/// [`parse_env_or_warn`] reading the live process environment.
pub fn env_parse<T>(
    var: &str,
    expected: &str,
    parse: impl FnOnce(&str) -> Option<T>,
) -> Option<T> {
    let raw = std::env::var(var).ok();
    parse_env_or_warn(var, raw.as_deref(), expected, parse)
}

/// Mean of a slice (0.0 for empty — callers guard semantics).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// A fresh, collision-free scratch directory under the system temp dir
/// (pid + per-process counter), created before return.
///
/// Tests that write files must each use their own directory: fixed
/// `temp_dir()` subdir names collide between concurrently running test
/// binaries (lib + integration suites run in parallel processes) and
/// between a live run and a stale crashed one, turning unrelated tests
/// flaky.  The pid decorrelates processes, the counter decorrelates tests
/// within one process.
pub fn unique_temp_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "qgalore_{tag}_{}_{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir).expect("create unique temp dir");
    dir
}

/// Bytes -> human-readable string (GiB with paper-style "G" suffix).
pub fn human_bytes(b: u64) -> String {
    let g = b as f64 / 1e9;
    if g >= 1.0 {
        format!("{g:.2}G")
    } else if b as f64 >= 1e6 {
        format!("{:.0}MB", b as f64 / 1e6)
    } else {
        format!("{:.1}KB", b as f64 / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range_and_covers() {
        let mut r = Pcg32::seeded(7);
        let mut lo = 1.0f32;
        let mut hi = 0.0f32;
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            lo = lo.min(x);
            hi = hi.max(x);
        }
        assert!(lo < 0.01 && hi > 0.99);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(3);
        let xs = r.normal_vec(20_000, 0.0, 1.0);
        let m = mean(&xs);
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(2_000_000_000), "2.00G");
        assert_eq!(human_bytes(5_000_000), "5MB");
    }

    fn parse_u64(s: &str) -> Option<u64> {
        s.parse::<u64>().ok()
    }

    #[test]
    fn env_parse_unset_is_silent_none() {
        assert_eq!(parse_env_or_warn("QGALORE_TEST_VAR", None, "a u64", parse_u64), None);
    }

    #[test]
    fn env_parse_well_formed_value_parses() {
        let got = parse_env_or_warn("QGALORE_TEST_VAR", Some("42"), "a u64", parse_u64);
        assert_eq!(got, Some(42));
        // trimmed before the parser sees it, like every QGALORE_* knob
        let got = parse_env_or_warn("QGALORE_TEST_VAR", Some(" 7\n"), "a u64", parse_u64);
        assert_eq!(got, Some(7));
    }

    #[test]
    fn env_parse_malformed_value_falls_back() {
        // the warning itself goes to stderr; the contract under test is
        // that a malformed value yields None (so callers take the default)
        // rather than panicking or being mistaken for "unset + parsed"
        for bad in ["lots", "", "-3", "4x"] {
            let got = parse_env_or_warn("QGALORE_TEST_VAR", Some(bad), "a u64", parse_u64);
            assert_eq!(got, None, "malformed {bad:?} must fall back to the default");
        }
    }

    #[test]
    fn env_parse_reads_process_env() {
        // a variable that is certainly unset: silent None
        assert_eq!(env_parse("QGALORE_DEFINITELY_UNSET_TEST_VAR", "a u64", parse_u64), None);
    }
}
