//! # Q-GaLore — rust coordinator
//!
//! Reproduction of *"Q-GaLore: Quantized GaLore with INT4 Projection and
//! Layer-Adaptive Low-Rank Gradients"* as a three-layer system:
//!
//! * **L1** — Pallas kernels (block-wise quantization, stochastic rounding,
//!   low-rank projection, 8-bit Adam), authored in `python/compile/kernels/`.
//! * **L2** — JAX LLaMA-style model forward/backward and fused per-layer
//!   update steps, AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: the training coordinator.  It owns the data
//!   pipeline, all parameter/optimizer buffers (in their quantized storage
//!   formats), the **lazy layer-adaptive subspace scheduler** (the paper's
//!   coordination contribution), and drives the AOT executables through the
//!   PJRT CPU client.  Python never runs on the training path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! | module        | role |
//! |---------------|------|
//! | [`jsonx`]     | minimal JSON parser/serializer (manifest, configs, logs) |
//! | [`util`]      | PCG RNG, timing, small helpers |
//! | [`linalg`]    | dense matrices, QR, randomized subspace iteration, persistent worker pool (the SVD + matmul substrate) |
//! | [`quant`]     | block-wise INT8/INT4 quantization + stochastic rounding (host mirror of the L1 kernels) |
//! | [`data`]      | synthetic-C4 corpus, tokenizer, sequence packer/batcher |
//! | [`model`]     | model topology metadata + AOT ABI (mirrors `python/compile/configs.py`) |
//! | [`manifest`]  | typed view of `artifacts/manifest.json` |
//! | [`memory`]    | analytic memory model (paper Tables 1–4, Figure 5) |
//! | [`runtime`]   | PJRT client wrapper: load/compile/execute HLO-text artifacts |
//! | [`optim`]     | optimizer zoo: Full, 8-bit Adam, Low-Rank, LoRA, ReLoRA, QLoRA, GaLore, 8-bit GaLore, Q-GaLore |
//! | [`scheduler`] | lazy layer-wise subspace update scheduler |
//! | [`coordinator`] | trainer: step loop, eval, fine-tune driver, multi-job coordinator, batched serving engine, metrics, checkpoints |
//! | [`report`]    | markdown/CSV renderers for the repro harness |
//! | [`repro`]     | regenerates every table and figure of the paper |
//! | [`modelcheck`] | bounded-schedule model checker for the pool/run_graph concurrency core + repo-invariant lint pass |

// Every `unsafe` operation must sit in an explicit `unsafe {}` block — even
// inside `unsafe fn` — so the lint pass can demand a SAFETY comment per
// block and none hide behind an unsafe-fn signature.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod cli;
pub mod coordinator;
pub mod data;
pub mod jsonx;
pub mod linalg;
pub mod manifest;
pub mod memory;
pub mod model;
pub mod modelcheck;
pub mod optim;
pub mod report;
pub mod repro;
pub mod runtime;
pub mod scheduler;
pub mod quant;
pub mod util;

pub use anyhow::{anyhow, Result};
