//! Minimal JSON parser/serializer.
//!
//! The coordinator needs JSON for exactly three things: the AOT
//! `artifacts/manifest.json`, run configuration files, and metric logs.
//! Rather than pull serde into the dependency budget we implement the small
//! recursive-descent parser below (strings, numbers, bools, null, arrays,
//! objects; `\uXXXX` escapes; no trailing commas — i.e. strict JSON).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (Option-returning; callers use eyre for context) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        self.ws();
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        self.ws();
        let mut map = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":"v"},"n":-7}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn handles_utf8_strings() {
        let v = Json::parse("\"héllo — ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ∞"));
    }
}
