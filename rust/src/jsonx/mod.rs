//! Minimal JSON parser/serializer.
//!
//! The coordinator needs JSON for exactly three things: the AOT
//! `artifacts/manifest.json`, run configuration files, and metric logs.
//! Rather than pull serde into the dependency budget we implement the small
//! recursive-descent parser below (strings, numbers, bools, null, arrays,
//! objects; `\uXXXX` escapes including UTF-16 surrogate pairs; strict RFC
//! 8259 number grammar — no trailing commas, no leading zeros, no bare `1.`
//! — i.e. strict JSON).  A lone/unpaired surrogate escape decodes to U+FFFD
//! rather than erroring, matching how lossy decoders treat broken UTF-16.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (Option-returning; callers use eyre for context) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; `format!` would emit
                    // one and silently corrupt the document.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    /// Strict RFC 8259 grammar: `-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?`.
    /// `f64::parse` tolerates forms JSON forbids (`1.`, `01`, `+1`), so the
    /// scanner must validate the shape itself before handing the text over.
    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        match self.peek() {
            Some(b'0') => {
                self.i += 1;
                if matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    return Err(self.err("leading zero in number"));
                }
            }
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.i += 1;
                }
            }
            _ => return Err(self.err("expected digit")),
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("expected digit after decimal point"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("expected digit in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    /// Read four hex digits starting at byte offset `at`.  Strict: every
    /// byte must be an ASCII hex digit (`from_str_radix` would also accept
    /// a leading `+`, which JSON forbids).
    fn hex4(&self, at: usize) -> Result<u32, ParseError> {
        if at + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let mut v = 0u32;
        for &c in &self.b[at..at + 4] {
            let d = match c {
                b'0'..=b'9' => c - b'0',
                b'a'..=b'f' => c - b'a' + 10,
                b'A'..=b'F' => c - b'A' + 10,
                _ => return Err(self.err("bad \\u escape")),
            };
            v = v * 16 + u32::from(d);
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            // `self.i` is on the 'u'; hex digits follow it.
                            let cp = self.hex4(self.i + 1)?;
                            self.i += 4;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // High surrogate: a non-BMP char is escaped
                                // as a `\uD8xx\uDCxx` pair split across two
                                // escapes — peek for the low half and stitch
                                // the UTF-16 units back into one scalar.
                                let low = if self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u')
                                {
                                    self.hex4(self.i + 3).ok()
                                } else {
                                    None
                                };
                                match low {
                                    Some(lo) if (0xDC00..0xE000).contains(&lo) => {
                                        self.i += 6; // the low half's `\uXXXX`
                                        let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                        char::from_u32(c).unwrap_or('\u{fffd}')
                                    }
                                    // Unpaired high surrogate (next escape is
                                    // not a low half): lossy, don't consume.
                                    _ => '\u{fffd}',
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                '\u{fffd}' // lone low surrogate
                            } else {
                                char::from_u32(cp).unwrap_or('\u{fffd}')
                            };
                            out.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        self.ws();
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        self.ws();
        let mut map = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn unicode_escape_surrogate_pair() {
        // U+1F600 😀 = \uD83D\uDE00 — the pair is split across two escapes,
        // which is the only legal JSON spelling of a non-BMP char.
        assert_eq!(Json::parse("\"\\ud83d\\ude00\"").unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse("\"\\uD83D\\uDE00\"").unwrap(), Json::Str("😀".into()));
        assert_eq!(Json::parse("\"a\\uD83D\\uDE00b\"").unwrap(), Json::Str("a😀b".into()));
        // two consecutive pairs
        assert_eq!(
            Json::parse("\"\\ud83d\\ude00\\ud83e\\udd80\"").unwrap(),
            Json::Str("😀🦀".into())
        );
    }

    #[test]
    fn unicode_escape_lone_high_surrogate() {
        assert_eq!(Json::parse("\"\\ud83d\"").unwrap(), Json::Str("\u{fffd}".into()));
        assert_eq!(Json::parse("\"\\ud83dx\"").unwrap(), Json::Str("\u{fffd}x".into()));
        // high surrogate followed by a non-surrogate escape: the high half
        // is lossy, the following escape decodes normally
        assert_eq!(Json::parse("\"\\ud83d\\u0041\"").unwrap(), Json::Str("\u{fffd}A".into()));
        // high-high-low: the first high is unpaired, the second pairs up
        assert_eq!(
            Json::parse("\"\\ud83d\\ud83d\\ude00\"").unwrap(),
            Json::Str("\u{fffd}😀".into())
        );
    }

    #[test]
    fn unicode_escape_lone_low_surrogate() {
        assert_eq!(Json::parse("\"\\ude00\"").unwrap(), Json::Str("\u{fffd}".into()));
        assert_eq!(Json::parse("\"x\\ude00y\"").unwrap(), Json::Str("x\u{fffd}y".into()));
    }

    #[test]
    fn unicode_escape_malformed_still_errors() {
        assert!(Json::parse("\"\\u12\"").is_err()); // truncated hex
        assert!(Json::parse("\"\\u12g4\"").is_err()); // non-hex digit
        assert!(Json::parse("\"\\u+123\"").is_err()); // from_str_radix would take this
        assert!(Json::parse("\"\\ud83d\\u12\"").is_err()); // bad escape after lone high
    }

    #[test]
    fn number_grammar_accepts() {
        for (src, want) in [
            ("0", 0.0),
            ("-0", -0.0),
            ("7", 7.0),
            ("10", 10.0),
            ("0.5", 0.5),
            ("-0.5", -0.5),
            ("1e3", 1000.0),
            ("1E+3", 1000.0),
            ("1.25e-2", 0.0125),
            ("0e0", 0.0),
            ("123.456e2", 12345.6),
        ] {
            assert_eq!(Json::parse(src).unwrap(), Json::Num(want), "accept {src}");
        }
    }

    #[test]
    fn number_grammar_rejects() {
        for src in [
            "1.", "01", "-01", "00", ".5", "-", "-.5", "1e", "1e+", "1.e3", "0x1", "+1",
            "1.2.3", "--1", "Infinity", "NaN", "1_000",
        ] {
            assert!(Json::parse(src).is_err(), "reject {src}");
        }
    }

    #[test]
    fn dump_non_finite_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).dump(), "null");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":"v"},"n":-7}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn handles_utf8_strings() {
        let v = Json::parse("\"héllo — ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ∞"));
    }
}
