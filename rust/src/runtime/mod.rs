//! PJRT runtime: load, compile and execute the AOT HLO-text artifacts.
//!
//! One `PjRtClient::cpu()` per process; executables are compiled on first
//! use and cached by artifact name.  All host<->device traffic goes through
//! [`HostTensor`], a dtype-tagged host buffer that maps 1:1 onto the
//! manifest's `TensorSpec`s.
//!
//! Since the dataflow training step, [`Runtime::execute`] takes `&self`:
//! per-layer update chains run concurrently on the worker pool and all
//! share one `&Runtime`.  The executable cache and the per-artifact
//! execution counters sit behind mutexes, and cached executables are
//! `Arc`-shared so the cache lock is held only for the lookup — never
//! across an execution (holding it there would serialize every
//! concurrently-updating layer on one mutex).
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`) — see
//! DESIGN.md §2 for why serialized protos are rejected by xla_extension
//! 0.5.1.

pub mod tensor;

pub use tensor::HostTensor;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::manifest::ArtifactSpec;

pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// executions per artifact (perf accounting)
    exec_counts: Mutex<HashMap<String, u64>>,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            exec_counts: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn load(&self, spec: &ArtifactSpec) -> Result<()> {
        self.executable(spec).map(|_| ())
    }

    /// The cached executable for `spec`, compiling on first use.  First-use
    /// compilation happens under the cache lock, so two chains racing on a
    /// cold artifact compile it once.
    fn executable(&self, spec: &ArtifactSpec) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&spec.name) {
            return Ok(Arc::clone(exe));
        }
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .map_err(|e| anyhow!("parsing {}: {e}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
        let exe = Arc::new(exe);
        cache.insert(spec.name.clone(), Arc::clone(&exe));
        Ok(exe)
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.lock().unwrap().contains_key(name)
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Executions recorded for one artifact (perf accounting).
    pub fn exec_count(&self, name: &str) -> u64 {
        self.exec_counts.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Snapshot of all per-artifact execution counters.
    pub fn exec_counts(&self) -> HashMap<String, u64> {
        self.exec_counts.lock().unwrap().clone()
    }

    /// Execute an artifact. Operand order/dtypes/shapes must match the
    /// manifest spec; results are unpacked from the output tuple in spec
    /// order.  `&self`: concurrent per-layer chains share one runtime.
    pub fn execute(&self, spec: &ArtifactSpec, operands: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self.executable(spec)?;
        if operands.len() != spec.operands.len() {
            return Err(anyhow!(
                "{}: got {} operands, manifest expects {}",
                spec.name,
                operands.len(),
                spec.operands.len()
            ));
        }
        let mut literals = Vec::with_capacity(operands.len());
        for (t, s) in operands.iter().zip(&spec.operands) {
            literals.push(
                t.to_literal(&s.shape)
                    .with_context(|| format!("{}: operand {}", spec.name, s.name))?,
            );
        }
        let outs = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e}", spec.name))?;
        *self.exec_counts.lock().unwrap().entry(spec.name.clone()).or_default() += 1;
        let first = outs
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("{}: no output buffer", spec.name))?;
        let tuple = first
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} result: {e}", spec.name))?
            .to_tuple()
            .map_err(|e| anyhow!("untupling {} result: {e}", spec.name))?;
        if tuple.len() != spec.results.len() {
            return Err(anyhow!(
                "{}: got {} results, manifest expects {}",
                spec.name,
                tuple.len(),
                spec.results.len()
            ));
        }
        tuple
            .into_iter()
            .zip(&spec.results)
            .map(|(lit, s)| {
                HostTensor::from_literal(&lit, s.dtype, &s.shape)
                    .with_context(|| format!("{}: result {}", spec.name, s.name))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Runtime behaviour against real artifacts is covered in
    // rust/tests/integration.rs (requires `make artifacts`).
}
