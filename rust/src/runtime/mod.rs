//! PJRT runtime: load, compile and execute the AOT HLO-text artifacts.
//!
//! One `PjRtClient::cpu()` per process; executables are compiled on first
//! use and cached by artifact name.  All host<->device traffic goes through
//! [`HostTensor`], a dtype-tagged host buffer that maps 1:1 onto the
//! manifest's `TensorSpec`s.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`) — see
//! DESIGN.md §2 for why serialized protos are rejected by xla_extension
//! 0.5.1.

pub mod tensor;

pub use tensor::HostTensor;

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::manifest::ArtifactSpec;

pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// executions per artifact (perf accounting)
    pub exec_counts: HashMap<String, u64>,
}

impl Runtime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime { client, cache: HashMap::new(), exec_counts: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn load(&mut self, spec: &ArtifactSpec) -> Result<()> {
        if self.cache.contains_key(&spec.name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(&spec.path)
            .map_err(|e| anyhow!("parsing {}: {e}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
        self.cache.insert(spec.name.clone(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }

    /// Execute an artifact. Operand order/dtypes/shapes must match the
    /// manifest spec; results are unpacked from the output tuple in spec
    /// order.
    pub fn execute(
        &mut self,
        spec: &ArtifactSpec,
        operands: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        self.load(spec)?;
        if operands.len() != spec.operands.len() {
            return Err(anyhow!(
                "{}: got {} operands, manifest expects {}",
                spec.name,
                operands.len(),
                spec.operands.len()
            ));
        }
        let mut literals = Vec::with_capacity(operands.len());
        for (t, s) in operands.iter().zip(&spec.operands) {
            literals.push(
                t.to_literal(&s.shape)
                    .with_context(|| format!("{}: operand {}", spec.name, s.name))?,
            );
        }
        let exe = self.cache.get(&spec.name).expect("loaded above");
        let outs = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e}", spec.name))?;
        *self.exec_counts.entry(spec.name.clone()).or_default() += 1;
        let first = outs
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("{}: no output buffer", spec.name))?;
        let tuple = first
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} result: {e}", spec.name))?
            .to_tuple()
            .map_err(|e| anyhow!("untupling {} result: {e}", spec.name))?;
        if tuple.len() != spec.results.len() {
            return Err(anyhow!(
                "{}: got {} results, manifest expects {}",
                spec.name,
                tuple.len(),
                spec.results.len()
            ));
        }
        tuple
            .into_iter()
            .zip(&spec.results)
            .map(|(lit, s)| {
                HostTensor::from_literal(&lit, s.dtype, &s.shape)
                    .with_context(|| format!("{}: result {}", spec.name, s.name))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Runtime behaviour against real artifacts is covered in
    // rust/tests/integration.rs (requires `make artifacts`).
}
