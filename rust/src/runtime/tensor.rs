//! Dtype-tagged host tensors and their Literal conversions.

use anyhow::{anyhow, Result};

use crate::manifest::DType;

/// A host-side tensor buffer.  Shapes live in the manifest `TensorSpec`s;
/// the buffer only knows its element type and flat contents.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I8(Vec<i8>),
    U8(Vec<u8>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I8(_) => DType::I8,
            HostTensor::U8(_) => DType::U8,
            HostTensor::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I8(v) => v.len(),
            HostTensor::U8(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            other => Err(anyhow!("expected f32 tensor, got {:?}", other.dtype())),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            other => Err(anyhow!("expected f32 tensor, got {:?}", other.dtype())),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            HostTensor::I8(v) => Ok(v),
            other => Err(anyhow!("expected i8 tensor, got {:?}", other.dtype())),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            HostTensor::U8(v) => Ok(v),
            other => Err(anyhow!("expected u8 tensor, got {:?}", other.dtype())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            other => Err(anyhow!("expected i32 tensor, got {:?}", other.dtype())),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        v.first().copied().ok_or_else(|| anyhow!("empty tensor"))
    }

    fn bytes(&self) -> &[u8] {
        match self {
            // SAFETY: viewing an initialized f32 slice as bytes; the pointer
            // is valid for `len * 4` bytes and u8 has no alignment demands.
            HostTensor::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            // SAFETY: i8 and u8 have identical size/alignment; the slice is
            // initialized and lives as long as `self`.
            HostTensor::I8(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len())
            },
            HostTensor::U8(v) => v,
            // SAFETY: viewing an initialized i32 slice as bytes; the pointer
            // is valid for `len * 4` bytes and u8 has no alignment demands.
            HostTensor::I32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        }
    }

    fn element_type(&self) -> xla::ElementType {
        match self {
            HostTensor::F32(_) => xla::ElementType::F32,
            HostTensor::I8(_) => xla::ElementType::S8,
            HostTensor::U8(_) => xla::ElementType::U8,
            HostTensor::I32(_) => xla::ElementType::S32,
        }
    }

    /// Build an XLA literal with the given logical shape.
    pub fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal> {
        let numel: usize = shape.iter().product();
        if numel != self.len() {
            return Err(anyhow!(
                "shape {:?} ({numel} elems) does not match buffer len {}",
                shape,
                self.len()
            ));
        }
        xla::Literal::create_from_shape_and_untyped_data(
            self.element_type(),
            shape,
            self.bytes(),
        )
        .map_err(|e| anyhow!("literal creation: {e}"))
    }

    /// Read a literal back into a host buffer of the expected dtype.
    pub fn from_literal(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<Self> {
        let numel: usize = shape.iter().product();
        let t = match dtype {
            DType::F32 => HostTensor::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e}"))?,
            ),
            DType::I8 => HostTensor::I8(
                lit.to_vec::<i8>().map_err(|e| anyhow!("literal->i8: {e}"))?,
            ),
            DType::U8 => HostTensor::U8(
                lit.to_vec::<u8>().map_err(|e| anyhow!("literal->u8: {e}"))?,
            ),
            DType::I32 => HostTensor::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e}"))?,
            ),
        };
        if t.len() != numel {
            return Err(anyhow!(
                "literal has {} elements, spec shape {:?} wants {numel}",
                t.len(),
                shape
            ));
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_tags() {
        assert_eq!(HostTensor::F32(vec![1.0]).dtype(), DType::F32);
        assert_eq!(HostTensor::I8(vec![1]).dtype(), DType::I8);
        assert_eq!(HostTensor::U8(vec![1]).dtype(), DType::U8);
        assert_eq!(HostTensor::I32(vec![1]).dtype(), DType::I32);
    }

    #[test]
    fn accessor_type_checks() {
        let t = HostTensor::F32(vec![1.0, 2.0]);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i8().is_err());
        assert_eq!(t.scalar_f32().unwrap(), 1.0);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0]);
        assert!(t.to_literal(&[2, 2]).is_err());
    }

    #[test]
    fn literal_roundtrip_f32_and_i8() {
        let t = HostTensor::F32(vec![1.5, -2.25, 3.0, 0.0]);
        let lit = t.to_literal(&[2, 2]).unwrap();
        let back = HostTensor::from_literal(&lit, DType::F32, &[2, 2]).unwrap();
        assert_eq!(t, back);

        let t = HostTensor::I8(vec![-128, -1, 0, 127]);
        let lit = t.to_literal(&[4]).unwrap();
        let back = HostTensor::from_literal(&lit, DType::I8, &[4]).unwrap();
        assert_eq!(t, back);
    }
}
