//! Analytic memory model — exact byte arithmetic over tensor shapes and
//! storage dtypes, per training method.
//!
//! This reproduces the "estimated memory" columns of Tables 1–4 and the
//! Figure 5 end-to-end breakdown.  The paper accounts in BF16 (2 bytes) for
//! high-precision tensors; INT8 state costs 1 byte and INT4 projection 0.5
//! bytes.  Unlike the paper we *also* charge the per-block quantization
//! statistics (8 bytes per 256-element block — ~3% of an INT8 tensor),
//! because our coordinator really stores them.
//!
//! Figure 5 additionally counts gradients (zero-ish for the galore family:
//! the fused backward releases each layer's gradient right after its update,
//! so only the largest layer is ever resident) and activations.

use crate::model::ModelConfig;
use crate::optim::method::Method;

pub const BLOCK: usize = 256;
/// "High precision" element size — BF16 in the paper's accounting.
pub const HI: f64 = 2.0;

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub weights: u64,
    pub adapters: u64,
    pub optim_m: u64,
    pub optim_v: u64,
    pub projection: u64,
    pub gradients: u64,
    pub activations: u64,
}

impl Breakdown {
    /// The paper's "estimated memory" (Tables 1–4): weights + optimizer
    /// states (moments + projection + trainable adapters count here too).
    pub fn params_plus_optimizer(&self) -> u64 {
        self.weights + self.adapters + self.optim_m + self.optim_v + self.projection
    }

    /// Everything (Figure 5).
    pub fn total(&self) -> u64 {
        self.params_plus_optimizer() + self.gradients + self.activations
    }
}

fn quant_overhead(numel: usize) -> u64 {
    // one f32 scale + one f32 zero per block
    numel.div_ceil(BLOCK) as u64 * 8
}

fn int8_bytes(numel: usize) -> u64 {
    numel as u64 + quant_overhead(numel)
}

fn int4_bytes(numel: usize) -> u64 {
    (numel as u64).div_ceil(2) + quant_overhead(numel)
}

fn hi_bytes(numel: usize) -> u64 {
    (numel as f64 * HI) as u64
}

/// Memory breakdown for pre-training / full fine-tuning with `method`.
/// `tokens_in_flight` = batch * seq, used for the activation estimate
/// (calibrated so LLaMA-7B @ 2048 tokens gives the paper's ~2 GB).
pub fn breakdown(cfg: &ModelConfig, method: Method, tokens_in_flight: usize) -> Breakdown {
    let fp: Vec<usize> = cfg.fp_params().iter().map(|p| p.numel()).collect();
    let lins: Vec<(usize, usize)> = cfg
        .linear_params()
        .iter()
        .map(|p| (p.shape[0], p.shape[1]))
        .collect();
    let fp_numel: usize = fp.iter().sum();
    let lin_numel: usize = lins.iter().map(|(m, n)| m * n).sum();
    let total_numel = fp_numel + lin_numel;
    let r = cfg.rank;

    let mut b = Breakdown::default();

    match method {
        Method::Full | Method::Adam8bit => {
            b.weights = hi_bytes(total_numel);
            if method == Method::Full {
                // vanilla training holds all weight gradients (paper intro:
                // "42 GB for Adam optimizer states and weight gradients")
                b.gradients = hi_bytes(total_numel);
                b.optim_m = hi_bytes(total_numel);
                b.optim_v = hi_bytes(total_numel);
            } else {
                // the paper's 8-bit Adam baseline uses the fused backward
                // [19, 20]: only the largest layer gradient is resident
                let max_layer = lins
                    .iter()
                    .map(|(m, n)| m * n)
                    .chain(fp.iter().copied())
                    .max()
                    .unwrap_or(0);
                b.gradients = hi_bytes(max_layer);
                b.optim_m = int8_bytes(total_numel);
                b.optim_v = int8_bytes(total_numel);
            }
        }
        Method::LowRank => {
            // factors replace the linear weights entirely
            let fac_numel: usize = lins.iter().map(|(m, n)| m * r + r * n).sum();
            let trained = fp_numel + fac_numel;
            b.weights = hi_bytes(trained);
            b.gradients = hi_bytes(trained);
            b.optim_m = hi_bytes(trained);
            b.optim_v = hi_bytes(trained);
        }
        Method::LoRa | Method::ReLoRa | Method::QLoRa => {
            let ad_numel: usize = lins.iter().map(|(m, n)| m * r + r * n).sum();
            b.weights = if method == Method::QLoRa {
                int8_bytes(lin_numel) + hi_bytes(fp_numel)
            } else {
                hi_bytes(total_numel)
            };
            b.adapters = hi_bytes(ad_numel);
            b.gradients = hi_bytes(ad_numel);
            b.optim_m = hi_bytes(ad_numel);
            b.optim_v = hi_bytes(ad_numel);
        }
        Method::GaLore | Method::GaLore8bit | Method::QGaLore => {
            // GaLore projects along the *smaller* dimension: for a (m, n)
            // gradient the low-rank Adam state has r*min(m,n) elements and
            // the projection r*max(m,n).
            let state_numel: usize =
                lins.iter().map(|(m, n)| r * (*m).min(*n)).sum();
            let proj_numel: usize =
                lins.iter().map(|(m, n)| r * (*m).max(*n)).sum();
            b.weights = if method == Method::QGaLore {
                // paper: "quantize the entire model to 8-bits"
                int8_bytes(total_numel)
            } else {
                hi_bytes(total_numel)
            };
            b.projection = if method == Method::QGaLore {
                int4_bytes(proj_numel)
            } else {
                hi_bytes(proj_numel)
            };
            let st = |numel: usize| -> u64 {
                if method == Method::GaLore {
                    hi_bytes(numel)
                } else {
                    int8_bytes(numel)
                }
            };
            // fp (non-eligible) params — embedding, head, norms — carry
            // full-shape Adam states
            b.optim_m = st(state_numel) + st(fp_numel);
            b.optim_v = st(state_numel) + st(fp_numel);
            // fused backward: only the largest single layer gradient resident
            let max_layer = lins
                .iter()
                .map(|(m, n)| m * n)
                .chain(fp.iter().copied())
                .max()
                .unwrap_or(0);
            b.gradients = hi_bytes(max_layer);
        }
    }

    // Activation estimate: 4 live buffers of (tokens, dim) per layer, BF16.
    // LLaMA-7B @ 2048 tokens -> 2048*4096*2*32*4 = 2.1 GB (paper: "2 GB").
    b.activations = (tokens_in_flight as u64)
        * cfg.dim as u64
        * cfg.n_layers as u64
        * HI as u64
        * 4;
    b
}

/// Paper-style table row: params+optimizer estimate, formatted like "0.36G".
pub fn estimate_str(cfg: &ModelConfig, method: Method) -> String {
    crate::util::human_bytes(breakdown(cfg, method, 0).params_plus_optimizer())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::paper_config;

    fn gb(b: u64) -> f64 {
        b as f64 / 1e9
    }

    /// Table 1 column check (60M): Full 0.36G, GaLore 0.24G, Q-GaLore 0.18G.
    #[test]
    fn table1_60m_memory_matches_paper() {
        let cfg = paper_config("llama-60m").unwrap();
        let full = gb(breakdown(&cfg, Method::Full, 0).params_plus_optimizer());
        let galore = gb(breakdown(&cfg, Method::GaLore, 0).params_plus_optimizer());
        let qgalore = gb(breakdown(&cfg, Method::QGaLore, 0).params_plus_optimizer());
        assert!((full - 0.36).abs() < 0.06, "full {full}");
        assert!((galore - 0.24).abs() < 0.06, "galore {galore}");
        assert!((qgalore - 0.18).abs() < 0.07, "qgalore {qgalore}");
        assert!(qgalore < galore && galore < full);
    }

    /// Table 1 @ 1B: Full 7.80G, GaLore 4.38G, Q-GaLore 3.08G.
    #[test]
    fn table1_1b_memory_matches_paper() {
        let cfg = paper_config("llama-1b").unwrap();
        let full = gb(breakdown(&cfg, Method::Full, 0).params_plus_optimizer());
        let galore = gb(breakdown(&cfg, Method::GaLore, 0).params_plus_optimizer());
        let qgalore = gb(breakdown(&cfg, Method::QGaLore, 0).params_plus_optimizer());
        assert!((full - 7.8).abs() < 1.0, "full {full}");
        assert!((galore - 4.38).abs() < 0.6, "galore {galore}");
        // Our clean byte arithmetic gives Q-GaLore *at most* the paper's
        // 3.08G (the paper's own ratio claims are internally conservative);
        // the direction and ordering are the reproduced claim.
        assert!(qgalore <= 3.2 && qgalore > 1.2, "qgalore {qgalore}");
        // headline ratios: >= ~30% saving vs GaLore, >= ~60% vs Full
        let vs_galore = 1.0 - qgalore / galore;
        let vs_full = 1.0 - qgalore / full;
        assert!(vs_galore >= 0.25, "{vs_galore}");
        assert!(vs_full >= 0.55, "{vs_full}");
    }

    /// Table 2: 7B — 8-bit Adam 26G, 8-bit GaLore 18G, Q-GaLore 15G
    /// (end-to-end-ish: weights+optimizer+activations at 2048 tokens + CUDA
    /// overhead are in the paper number; our params+optimizer core must sit
    /// below and in the right order).
    #[test]
    fn table2_7b_ordering() {
        let cfg = paper_config("llama-7b").unwrap();
        let a8 = gb(breakdown(&cfg, Method::Adam8bit, 2048).total());
        let g8 = gb(breakdown(&cfg, Method::GaLore8bit, 2048).total());
        let qg = gb(breakdown(&cfg, Method::QGaLore, 2048).total());
        assert!(a8 > g8 && g8 > qg, "{a8} {g8} {qg}");
        // Q-GaLore must fit a 16 GB card with clear headroom
        assert!(qg < 16.0, "qgalore 7B total {qg}");
        // and 8-bit Adam must not
        assert!(a8 > 16.0, "adam8 7B total {a8}");
    }

    #[test]
    fn qlora_halves_lora_base() {
        let cfg = paper_config("llama-7b").unwrap();
        let lora = breakdown(&cfg, Method::LoRa, 0);
        let qlora = breakdown(&cfg, Method::QLoRa, 0);
        assert!(qlora.weights < lora.weights * 6 / 10);
        assert_eq!(qlora.adapters, lora.adapters);
    }

    #[test]
    fn fused_backward_gradient_negligible() {
        let cfg = paper_config("llama-7b").unwrap();
        let full = breakdown(&cfg, Method::Full, 0);
        let qg = breakdown(&cfg, Method::QGaLore, 0);
        assert!(qg.gradients < full.gradients / 50);
    }

    #[test]
    fn int4_projection_quarter_of_hi() {
        let cfg = paper_config("llama-1b").unwrap();
        let g = breakdown(&cfg, Method::GaLore, 0);
        let q = breakdown(&cfg, Method::QGaLore, 0);
        let ratio = q.projection as f64 / g.projection as f64;
        assert!((ratio - 0.28).abs() < 0.05, "{ratio}"); // 0.25 + block stats
    }

    #[test]
    fn activation_estimate_calibrated() {
        let cfg = paper_config("llama-7b").unwrap();
        let act = gb(breakdown(&cfg, Method::Full, 2048).activations);
        assert!((act - 2.1).abs() < 0.5, "{act}");
    }
}
