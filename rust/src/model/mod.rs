//! Model topology metadata — the rust mirror of `python/compile/configs.py`.
//!
//! The coordinator never re-derives tensor shapes from the HLO (the manifest
//! is authoritative at runtime); this module exists so tests can cross-check
//! the manifest against an independent statement of the ABI, and so the
//! memory model can be evaluated at paper scales without artifacts.

/// One trainable tensor in the flattening ABI.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// GaLore-eligible 2-D linear weight (projected + quantized in Q-GaLore).
    pub galore_eligible: bool,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn_dim: usize,
    pub max_seq_len: usize,
    pub rank: usize,
    /// tiny trainable configs tie the LM head to the embedding; the paper's
    /// scales have a separate head (affects only the memory model)
    pub tied_head: bool,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// fp (non-eligible) params in ABI order: embedding, per-layer norms,
    /// final norm.  Matches `configs.ModelConfig.fp_shapes`.
    pub fn fp_params(&self) -> Vec<ParamSpec> {
        let mut out = vec![ParamSpec {
            name: "tok_embedding".into(),
            shape: vec![self.vocab_size, self.dim],
            galore_eligible: false,
        }];
        for i in 0..self.n_layers {
            for suffix in ["attn_norm", "mlp_norm"] {
                out.push(ParamSpec {
                    name: format!("layers.{i}.{suffix}"),
                    shape: vec![self.dim],
                    galore_eligible: false,
                });
            }
        }
        out.push(ParamSpec {
            name: "final_norm".into(),
            shape: vec![self.dim],
            galore_eligible: false,
        });
        if !self.tied_head {
            out.push(ParamSpec {
                name: "lm_head".into(),
                shape: vec![self.vocab_size, self.dim],
                galore_eligible: false,
            });
        }
        out
    }

    /// GaLore-eligible linear weights in ABI order.  Matches
    /// `configs.ModelConfig.linear_shapes` (shape = [out, in]).
    pub fn linear_params(&self) -> Vec<ParamSpec> {
        let mut out = Vec::new();
        for i in 0..self.n_layers {
            let p = format!("layers.{i}.");
            let mk = |name: String, o: usize, inn: usize| ParamSpec {
                name,
                shape: vec![o, inn],
                galore_eligible: true,
            };
            out.push(mk(format!("{p}attn.wq"), self.dim, self.dim));
            out.push(mk(format!("{p}attn.wk"), self.dim, self.dim));
            out.push(mk(format!("{p}attn.wv"), self.dim, self.dim));
            out.push(mk(format!("{p}attn.wo"), self.dim, self.dim));
            out.push(mk(format!("{p}mlp.w1"), self.ffn_dim, self.dim));
            out.push(mk(format!("{p}mlp.w3"), self.ffn_dim, self.dim));
            out.push(mk(format!("{p}mlp.w2"), self.dim, self.ffn_dim));
        }
        out
    }

    pub fn all_params(&self) -> Vec<ParamSpec> {
        let mut v = self.fp_params();
        v.extend(self.linear_params());
        v
    }

    pub fn n_params(&self) -> usize {
        self.all_params().iter().map(|p| p.numel()).sum()
    }

    /// Distinct (out, in) linear shapes, in first-appearance order.
    pub fn unique_linear_dims(&self) -> Vec<(usize, usize)> {
        let mut seen = Vec::new();
        for p in self.linear_params() {
            let d = (p.shape[0], p.shape[1]);
            if !seen.contains(&d) {
                seen.push(d);
            }
        }
        seen
    }
}

/// Paper-scale configs (memory model only — matches configs.PAPER_CONFIGS).
pub fn paper_config(name: &str) -> Option<ModelConfig> {
    let c = |name: &str, vocab, dim, layers, heads, ffn, seq, rank| ModelConfig {
        name: name.into(),
        vocab_size: vocab,
        dim,
        n_layers: layers,
        n_heads: heads,
        ffn_dim: ffn,
        max_seq_len: seq,
        rank,
        tied_head: false,
    };
    match name {
        "llama-60m" => Some(c("llama-60m", 32000, 512, 8, 8, 1376, 1024, 128)),
        "llama-130m" => Some(c("llama-130m", 32000, 768, 12, 12, 2048, 1024, 256)),
        "llama-350m" => Some(c("llama-350m", 32000, 1024, 24, 16, 2736, 1024, 256)),
        "llama-1b" => Some(c("llama-1b", 32000, 2048, 24, 32, 5461, 1024, 512)),
        "llama-7b" => Some(c("llama-7b", 32000, 4096, 32, 32, 11008, 2048, 1024)),
        // fine-tuning targets (Tables 3–4 memory columns)
        "llama3-8b" => Some(c("llama3-8b", 128256, 4096, 32, 32, 14336, 8192, 1024)),
        "gemma-7b" => Some(c("gemma-7b", 256000, 3072, 28, 16, 24576, 8192, 768)),
        "mistral-7b" => Some(c("mistral-7b", 32000, 4096, 32, 32, 14336, 8192, 1024)),
        "roberta-base" => Some(c("roberta-base", 50265, 768, 12, 12, 3072, 512, 192)),
        _ => None,
    }
}

/// Trainable tiny configs (must match configs.CONFIGS in python).
pub fn tiny_config(name: &str) -> Option<ModelConfig> {
    let c = |name: &str, vocab, dim, layers, heads, ffn, seq| ModelConfig {
        name: name.into(),
        vocab_size: vocab,
        dim,
        n_layers: layers,
        n_heads: heads,
        ffn_dim: ffn,
        max_seq_len: seq,
        rank: (dim / 4).max(4),
        tied_head: true,
    };
    match name {
        "llama-micro" => Some(c("llama-micro", 512, 32, 1, 2, 64, 32)),
        "llama-tiny" => Some(c("llama-tiny", 512, 64, 2, 4, 128, 64)),
        "llama-nano" => Some(c("llama-nano", 1024, 128, 2, 4, 256, 64)),
        "llama-small" => Some(c("llama-small", 2048, 256, 4, 8, 512, 128)),
        _ => None,
    }
}

pub fn get_config(name: &str) -> Option<ModelConfig> {
    tiny_config(name).or_else(|| paper_config(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_scale() {
        let tiny = tiny_config("llama-tiny").unwrap();
        let n = tiny.n_params();
        // emb 512*64 + 2*(2 norms)*64 + final 64 + per layer (4*64*64 + 3 mlp)
        assert!(n > 100_000 && n < 500_000, "{n}");
        let b7 = paper_config("llama-7b").unwrap();
        let n7 = b7.n_params();
        assert!(
            (6.0e9..8.0e9).contains(&(n7 as f64)),
            "7B param count {n7}"
        );
    }

    #[test]
    fn paper_60m_is_60m() {
        let c = paper_config("llama-60m").unwrap();
        let n = c.n_params() as f64;
        assert!((40.0e6..80.0e6).contains(&n), "{n}");
    }

    #[test]
    fn linear_abi_order() {
        let c = tiny_config("llama-tiny").unwrap();
        let lins = c.linear_params();
        assert_eq!(lins.len(), 7 * c.n_layers);
        assert_eq!(lins[0].name, "layers.0.attn.wq");
        assert_eq!(lins[6].name, "layers.0.mlp.w2");
        assert_eq!(lins[6].shape, vec![64, 128]);
    }

    #[test]
    fn unique_dims_dedup() {
        let c = tiny_config("llama-tiny").unwrap();
        assert_eq!(
            c.unique_linear_dims(),
            vec![(64, 64), (128, 64), (64, 128)]
        );
    }

    #[test]
    fn all_params_fp_first() {
        let c = tiny_config("llama-micro").unwrap();
        let all = c.all_params();
        assert_eq!(all[0].name, "tok_embedding");
        assert!(!all[0].galore_eligible);
        assert!(all.last().unwrap().galore_eligible);
    }
}
