//! Multi-job fine-tune-as-a-service coordinator.
//!
//! The paper's fine-tuning claim (Q-GaLore matches QLoRA at equal memory)
//! is a serving-economics claim: millions of users each own a tiny
//! low-rank personalization on top of ONE shared quantized base.  This
//! module is that shape as a host-side subsystem:
//!
//! * [`BaseArena`] — the shared base: per layer, input statistics `X`, the
//!   INT8-quantized base weights `W0`, and the precomputed base response
//!   `X·W0`.  Built once, **read-only forever** — every concurrent job
//!   reads it, none may write it, so N tenants cost one base.
//! * [`JobState`] — everything a tenant owns: per layer an INT4-packed
//!   projection `P` (m×r), a trainable low-rank factor `L` (r×n), and
//!   blockwise 8-bit Adam moments on `L`; plus the job's lazy subspace
//!   scheduler and its private seed/counter streams.  The tenant's model
//!   is `W0 + P·L` — a few hundred KB of delta against a shared base.
//! * [`MultiJobCoordinator`] — N jobs × one `WorkerPool`.  Each call to
//!   [`MultiJobCoordinator::round`] advances **every** job by exactly one
//!   step (round-robin fairness: no job can starve another, a job's step
//!   count is always within one of any co-tenant's) by building ONE
//!   combined dependency graph over all jobs' per-layer chains and
//!   executing it with a single `WorkerPool::run_graph` — co-tenants'
//!   chains interleave freely on the stealing pool.
//!
//! # Per-job determinism contract
//!
//! A job's loss trace and final delta are **bitwise identical** whether it
//! runs alone or alongside any number of co-tenants, for any worker
//! count, steal seed, and slab setting (`tests/multijob.rs` fences this
//! in the PR-6 golden style).  The discipline is the same one
//! `HostDataflowTrainer` and `Galore::apply_update_dataflow` follow:
//!
//! * every value a step consumes is either owned by exactly one chain
//!   (one node per (job, layer)) or read-only shared (the arena);
//! * everything order-sensitive — update-noise counters, subspace sketch
//!   seeds — is drawn **serially in the plan phase** from job-local
//!   counters keyed only by the job's own seed, so co-tenants cannot
//!   perturb each other's streams;
//! * cross-layer reductions (loss sum, scheduler recording) happen
//!   serially at the join, in layer-index / plan order.
//!
//! [`MultiJobCoordinator::round_sequential`] executes the identical plans
//! serially; `round` must match it bitwise.
//!
//! # Delta checkpoints
//!
//! [`MultiJobCoordinator::export_delta`] serializes one job into the
//! versioned `QGDC` container of [`checkpoint`] (low-rank factors, packed
//! INT4 projection, Adam8 moments, scheduler + counter state);
//! [`MultiJobCoordinator::import_job`] restores it onto a compatible
//! arena such that save → load → resume reproduces the uninterrupted run
//! bitwise.

use std::sync::Mutex;

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::checkpoint::{
    CheckpointMeta, DeltaCheckpoint, DeltaSection, SectionData,
};
use crate::linalg::{
    left_subspace_batched, pack_cache_enabled, Mat, PanelCache, ParallelCtx, WorkerPool,
};
use crate::optim::StepGraphBuilder;
use crate::quant::{self, Adam8State, Quant4Tensor, QuantTensor};
use crate::scheduler::{SchedulerConfig, SubspaceScheduler};
use crate::util::Pcg32;

/// Power-iteration count at refresh time (mirrors the optimizer's).
const SUBSPACE_ITERS: usize = 2;
/// Domain salts separating a job's derived seed streams from each other.
const NOISE_SALT: u64 = 0x6e6f_6973_655f_6d6a; // "noise_mj"
const SKETCH_SALT: u64 = 0x736b_6574_6368_6d6a; // "sketchmj"
/// Stream id for per-job target data.
const DATA_STREAM: u64 = 0x0b5e;

/// splitmix64 over a (salted seed, counter) pair: the counter-addressable
/// seed derivation that makes every per-job random stream a pure function
/// of (job seed, counter value) — resumable from two u64s, untouchable by
/// co-tenants.
fn mix_seed(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[derive(Clone, Copy, Debug)]
pub struct MultiJobConfig {
    /// subspace rank of every job's delta (clamped per layer to min(m, n))
    pub rank: usize,
    pub lr: f32,
    /// weight of the counter-seeded uniform noise folded into each update
    /// (stands in for Q-GaLore's stochastic-rounding noise operand)
    pub noise_eps: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub sched: SchedulerConfig,
    /// seed of the shared base arena (X, W0) — part of the service
    /// identity: deltas only make sense against the arena they trained on
    pub arena_seed: u64,
}

impl Default for MultiJobConfig {
    fn default() -> Self {
        MultiJobConfig {
            rank: 8,
            lr: 1e-2,
            noise_eps: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            sched: SchedulerConfig::default(),
            arena_seed: 0,
        }
    }
}

/// One layer of the shared base: read-only after construction.
struct BaseLayer {
    m: usize,
    n: usize,
    /// input statistics (m, m)
    x: Mat,
    /// INT8 base weights — the storage format the service keeps resident
    /// once for all tenants
    w0q: QuantTensor,
    /// precomputed base response X·dequant(W0) (m, n): every job's
    /// residual starts from this shared term
    xw0: Mat,
}

/// The shared immutable base arena.
pub struct BaseArena {
    layers: Vec<BaseLayer>,
}

impl BaseArena {
    /// Build the base from layer shapes and the arena seed.  `ctx` only
    /// sets the worker budget of the setup matmuls — results are
    /// bits-invariant to it (engine contract).
    pub fn new(shapes: &[(usize, usize)], arena_seed: u64, ctx: ParallelCtx) -> Self {
        let mut rng = Pcg32::new(arena_seed, 0xba5e);
        let layers = shapes
            .iter()
            .map(|&(m, n)| {
                let xs = 1.0 / (m as f32).sqrt();
                let x = Mat::from_vec(m, m, rng.normal_vec(m * m, 0.0, xs));
                let w0 = rng.normal_vec(m * n, 0.0, 0.1);
                let w0q = quant::quantize(&w0, 8);
                let w0d = Mat::from_vec(m, n, quant::dequantize(&w0q));
                let xw0 = x.matmul_with(&w0d, ctx);
                BaseLayer { m, n, x, w0q, xw0 }
            })
            .collect();
        BaseArena { layers }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn shapes(&self) -> Vec<(usize, usize)> {
        self.layers.iter().map(|l| (l.m, l.n)).collect()
    }

    /// Resident bytes of the shared base (INT8 weights + f32 statistics).
    pub fn base_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| {
                l.w0q.storage_bytes() as u64 + (l.x.data.len() + l.xw0.data.len()) as u64 * 4
            })
            .sum()
    }
}

/// One layer of one tenant's delta state.
struct JobLayer {
    /// INT4-stored projection basis (m, r); None until the first refresh
    p4: Option<Quant4Tensor>,
    /// epoch-keyed panel pack of `p4` (speed cache; bits-neutral)
    pack: PanelCache,
    /// trainable low-rank factor (r, n) — the personalization itself
    l: Mat,
    /// blockwise 8-bit Adam moments on `l`
    st: Adam8State,
}

/// Everything one fine-tune job owns.
pub struct JobState {
    /// tenant identity: keys the job's target data and every derived
    /// random stream
    pub seed: u64,
    layers: Vec<JobLayer>,
    /// per-layer targets (m, n) — the job's "dataset"
    y: Vec<Mat>,
    pub sched: SubspaceScheduler,
    /// update-noise draw counter (consumed serially in walk order)
    noise_ctr: u64,
    /// sketch-seed draw counter (one per refresh shape-group)
    refresh_ctr: u64,
    step: u64,
    /// mean loss per completed step — the trace the golden tests pin
    pub loss_trace: Vec<f32>,
}

impl JobState {
    fn new(arena: &BaseArena, cfg: &MultiJobConfig, seed: u64) -> Self {
        let mut yrng = Pcg32::new(seed, DATA_STREAM);
        let mut layers = Vec::with_capacity(arena.layers.len());
        let mut y = Vec::with_capacity(arena.layers.len());
        for bl in &arena.layers {
            let r = cfg.rank.min(bl.m).min(bl.n);
            y.push(Mat::from_vec(bl.m, bl.n, yrng.normal_vec(bl.m * bl.n, 0.0, 1.0)));
            layers.push(JobLayer {
                p4: None,
                pack: PanelCache::empty(),
                l: Mat::zeros(r, bl.n),
                st: Adam8State::zeros(r * bl.n),
            });
        }
        let names: Vec<String> =
            (0..layers.len()).map(|i| format!("job{seed}.l{i}")).collect();
        JobState {
            seed,
            layers,
            y,
            sched: SubspaceScheduler::new(&names, cfg.sched),
            noise_ctr: 0,
            refresh_ctr: 0,
            step: 0,
            loss_trace: Vec::new(),
        }
    }

    fn next_noise_ctr(&mut self) -> u64 {
        self.noise_ctr += 1;
        self.noise_ctr
    }

    fn next_sketch_seed(&mut self) -> u64 {
        self.refresh_ctr += 1;
        mix_seed(self.seed ^ SKETCH_SALT, self.refresh_ctr)
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// Resident bytes of this tenant's delta (projection + factor +
    /// moments) — the quantity the serving-economics story is about.
    pub fn delta_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|jl| {
                jl.p4.as_ref().map_or(0, |p| p.storage_bytes() as u64)
                    + jl.l.data.len() as u64 * 4
                    + jl.st.storage_bytes() as u64
            })
            .sum()
    }
}

/// Immutable per-node task parameters (one per job per step): `Copy` into
/// every graph node of that job's chains.
#[derive(Clone, Copy)]
struct StepTaskCfg {
    lr: f32,
    noise_eps: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    /// Adam bias corrections of this job's (1-based) step
    c1: f32,
    c2: f32,
    job_seed: u64,
    ctx: ParallelCtx,
}

/// Residual, loss, and full-rank gradient of one (job, layer):
/// `resid = (X·W0 + X·(P·L)) − Y`, `g = Xᵀ·resid`.
fn layer_grad(base: &BaseLayer, jl: &JobLayer, y: &Mat, ctx: ParallelCtx) -> (Mat, f32) {
    let (m, n) = (base.m, base.n);
    let resid = match &jl.p4 {
        Some(p4) => {
            let r = jl.l.rows;
            let pack = jl.pack.get().filter(|pk| pk.matches4(p4, m, r));
            let pl = match pack {
                Some(pk) => quant::dequant4_matmul_prepacked(p4, pk, m, r, &jl.l, ctx),
                None => quant::dequant4_matmul(p4, m, r, &jl.l, ctx),
            };
            let xd = base.x.matmul_with(&pl, ctx);
            let mut data = Vec::with_capacity(m * n);
            for i in 0..m * n {
                data.push(base.xw0.data[i] + xd.data[i] - y.data[i]);
            }
            Mat::from_vec(m, n, data)
        }
        None => base.xw0.sub(y),
    };
    let f = resid.frobenius();
    let loss = f * f / (m * n) as f32;
    let g = base.x.t_matmul_with(&resid, ctx);
    (g, loss)
}

/// One low-rank delta update: down-project the gradient through the
/// job's INT4 basis, blockwise 8-bit Adam on the factor, apply with
/// counter-seeded noise (the SR-noise stand-in).
fn layer_update(jl: &mut JobLayer, base: &BaseLayer, cfg: StepTaskCfg, ctr: u64, g: &Mat) {
    let m = base.m;
    let p4 = jl.p4.as_ref().expect("projected layer refreshed at step 0");
    let r = jl.l.rows;
    let pack = jl.pack.get().filter(|pk| pk.matches4(p4, m, r));
    let gl = match pack {
        Some(pk) => quant::dequant4_t_matmul_prepacked(p4, pk, m, r, g, cfg.ctx),
        None => quant::dequant4_t_matmul(p4, m, r, g, cfg.ctx),
    };
    let u = quant::adam8_step_host(
        &gl.data, &mut jl.st, cfg.c1, cfg.c2, cfg.beta1, cfg.beta2, cfg.eps,
    );
    let noise = quant::uniform_noise(
        jl.l.data.len(),
        mix_seed(cfg.job_seed ^ NOISE_SALT, ctr),
        cfg.ctx,
    );
    for ((le, ue), ne) in jl.l.data.iter_mut().zip(&u).zip(&noise) {
        *le -= cfg.lr * (ue + cfg.noise_eps * (ne - 0.5));
    }
}

/// Install a freshly computed basis: overlap-vs-old similarity, INT4
/// storage + panel repack, and — because the base is immutable — the
/// personalization is *carried across the subspace change* by
/// re-expressing the old delta in the new basis (`L' = P'ᵀ·(P·L)`).
/// Moments reset with the subspace, as in the host dataflow trainer.
fn refresh_layer(jl: &mut JobLayer, base: &BaseLayer, cfg: StepTaskCfg, new_p: Mat) -> Option<f32> {
    let (m, n) = (base.m, base.n);
    let old_state = jl.p4.as_ref().map(|old| {
        let r_old = jl.l.rows;
        let pack = jl.pack.get().filter(|pk| pk.matches4(old, m, r_old));
        let prod = match pack {
            Some(pk) => quant::dequant4_t_matmul_prepacked(old, pk, m, r_old, &new_p, cfg.ctx),
            None => quant::dequant4_t_matmul(old, m, r_old, &new_p, cfg.ctx),
        };
        let f = prod.frobenius();
        let sim = f * f / r_old.min(new_p.cols).max(1) as f32;
        let delta = match pack {
            Some(pk) => quant::dequant4_matmul_prepacked(old, pk, m, r_old, &jl.l, cfg.ctx),
            None => quant::dequant4_matmul(old, m, r_old, &jl.l, cfg.ctx),
        };
        (sim, delta)
    });
    let r_new = new_p.cols;
    let q = quant::quantize4(&new_p.data);
    jl.pack.invalidate();
    if pack_cache_enabled() {
        jl.pack.get_or_pack4(&q, m, r_new);
    }
    jl.l = match &old_state {
        Some((_, delta)) => {
            let pack = jl.pack.get().filter(|pk| pk.matches4(&q, m, r_new));
            match pack {
                Some(pk) => quant::dequant4_t_matmul_prepacked(&q, pk, m, r_new, delta, cfg.ctx),
                None => quant::dequant4_t_matmul(&q, m, r_new, delta, cfg.ctx),
            }
        }
        None => Mat::zeros(r_new, n),
    };
    jl.st = Adam8State::zeros(r_new * n);
    jl.p4 = Some(q);
    old_state.map(|(sim, _)| sim)
}

/// The serially pre-assigned plan of one job's next step: every shared
/// decision (due membership, sketch seeds, noise counters) drawn from
/// job-local state in the sequential walk order.
struct JobPlan {
    step: u64,
    cfg: StepTaskCfg,
    /// non-due layers: (layer idx, noise counter), walk order
    now: Vec<(usize, u64)>,
    /// refresh waves: one per shape group, first-due order
    waves: Vec<WavePlan>,
}

struct WavePlan {
    seed: u64,
    /// (layer idx, noise counter), group walk order
    members: Vec<(usize, u64)>,
}

pub struct MultiJobCoordinator {
    pub cfg: MultiJobConfig,
    arena: BaseArena,
    jobs: Vec<JobState>,
    ctx: ParallelCtx,
}

impl MultiJobCoordinator {
    pub fn new(shapes: &[(usize, usize)], cfg: MultiJobConfig, ctx: ParallelCtx) -> Self {
        MultiJobCoordinator {
            arena: BaseArena::new(shapes, cfg.arena_seed, ctx),
            cfg,
            jobs: Vec::new(),
            ctx,
        }
    }

    pub fn arena(&self) -> &BaseArena {
        &self.arena
    }

    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    pub fn job(&self, ji: usize) -> &JobState {
        &self.jobs[ji]
    }

    /// Admit a new tenant; returns its job index.
    pub fn add_job(&mut self, seed: u64) -> usize {
        self.jobs.push(JobState::new(&self.arena, &self.cfg, seed));
        self.jobs.len() - 1
    }

    /// Flat bit pattern of one job's trained factors — what the golden
    /// tests compare between solo and co-tenant runs.
    pub fn export_factors(&self, ji: usize) -> Vec<f32> {
        let mut out = Vec::new();
        for jl in &self.jobs[ji].layers {
            out.extend_from_slice(&jl.l.data);
        }
        out
    }

    /// Plan one step of job `ji` (serial; draws the job's counters).
    fn plan_job(&mut self, ji: usize) -> JobPlan {
        let cfg = self.cfg;
        let ctx = self.ctx;
        let job = &mut self.jobs[ji];
        let step = job.step;
        let t = (step + 1) as i32;
        let tcfg = StepTaskCfg {
            lr: cfg.lr,
            noise_eps: cfg.noise_eps,
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            eps: cfg.eps,
            c1: 1.0 / (1.0 - cfg.beta1.powi(t)),
            c2: 1.0 / (1.0 - cfg.beta2.powi(t)),
            job_seed: job.seed,
            ctx,
        };
        let due = job.sched.plan_due(step);
        let nl = job.layers.len();
        let mut now = Vec::new();
        for idx in 0..nl {
            if !due.contains(&idx) {
                let ctr = job.next_noise_ctr();
                now.push((idx, ctr));
            }
        }
        // shape groups in first-due order, ONE sketch seed per group
        let mut groups: Vec<((usize, usize), u64, Vec<usize>)> = Vec::new();
        for &idx in &due {
            let key = (self.arena.layers[idx].m, self.arena.layers[idx].n);
            let gi = match groups.iter().position(|(k, _, _)| *k == key) {
                Some(gi) => gi,
                None => {
                    let seed = job.next_sketch_seed();
                    groups.push((key, seed, Vec::new()));
                    groups.len() - 1
                }
            };
            groups[gi].2.push(idx);
        }
        let waves = groups
            .into_iter()
            .map(|(_k, seed, members)| WavePlan {
                seed,
                members: members.into_iter().map(|idx| (idx, job.next_noise_ctr())).collect(),
            })
            .collect();
        JobPlan { step, cfg: tcfg, now, waves }
    }

    /// Advance every job one step, serially (the arbiter the graph path
    /// must match bitwise).  Returns each job's mean loss, job order.
    pub fn round_sequential(&mut self) -> Vec<f32> {
        let rank = self.cfg.rank;
        let mut out = Vec::with_capacity(self.jobs.len());
        for ji in 0..self.jobs.len() {
            let plan = self.plan_job(ji);
            let arena = &self.arena;
            let job = &mut self.jobs[ji];
            let nl = job.layers.len();
            let mut losses = vec![0f32; nl];
            for &(idx, ctr) in &plan.now {
                let (g, loss) = layer_grad(&arena.layers[idx], &job.layers[idx], &job.y[idx], plan.cfg.ctx);
                losses[idx] = loss;
                layer_update(&mut job.layers[idx], &arena.layers[idx], plan.cfg, ctr, &g);
            }
            for wave in &plan.waves {
                let mut grads = Vec::with_capacity(wave.members.len());
                for &(idx, _ctr) in &wave.members {
                    let (g, loss) =
                        layer_grad(&arena.layers[idx], &job.layers[idx], &job.y[idx], plan.cfg.ctx);
                    losses[idx] = loss;
                    grads.push(g);
                }
                let grefs: Vec<&Mat> = grads.iter().collect();
                let mut rng = Pcg32::new(wave.seed, 0x5eed);
                let new_ps =
                    left_subspace_batched(&grefs, rank, SUBSPACE_ITERS, &mut rng, plan.cfg.ctx);
                drop(grefs);
                for ((&(idx, ctr), g), new_p) in
                    wave.members.iter().zip(&grads).zip(new_ps)
                {
                    let sim =
                        refresh_layer(&mut job.layers[idx], &arena.layers[idx], plan.cfg, new_p);
                    job.sched.record_refresh(idx, plan.step, sim);
                    layer_update(&mut job.layers[idx], &arena.layers[idx], plan.cfg, ctr, g);
                }
            }
            let total: f32 = losses.iter().sum();
            let mean = total / nl as f32;
            job.loss_trace.push(mean);
            job.step += 1;
            out.push(mean);
        }
        out
    }

    /// Advance every job one step as ONE combined dependency graph on
    /// `pool` — the fair-scheduled service step.  Bitwise identical to
    /// [`Self::round_sequential`] per job, for any worker count / steal
    /// seed / co-tenant set.  A panic in any chain surfaces as this
    /// round's `Err`; no job's step counter advances.
    pub fn round(&mut self, pool: &WorkerPool) -> Result<Vec<f32>> {
        let njobs = self.jobs.len();
        if njobs == 0 {
            return Ok(Vec::new());
        }
        let rank = self.cfg.rank;
        let nl = self.arena.layers.len();

        // ---- plan phase (serial, job order; each job's plan reads only
        // its own state, so the plan stream is co-tenant-independent)
        let plans: Vec<JobPlan> = (0..njobs).map(|ji| self.plan_job(ji)).collect();

        // ---- execute phase: one combined graph over all jobs.  Scoped in
        // a block so the relay borrows of `self.jobs` end before the join
        // phase mutates job state; only plain data crosses out.
        let mut sim_records: Vec<(usize, usize, Option<f32>)> = Vec::new();
        let job_losses: Vec<Vec<f32>>;
        {
        let loss_slots: Vec<Vec<Mutex<Option<f32>>>> = (0..njobs)
            .map(|_| (0..nl).map(|_| Mutex::new(None)).collect())
            .collect();
        #[allow(clippy::type_complexity)]
        let g_slots: Vec<Vec<Vec<Mutex<Option<Mat>>>>> = plans
            .iter()
            .map(|p| {
                p.waves
                    .iter()
                    .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
                    .collect()
            })
            .collect();
        #[allow(clippy::type_complexity)]
        let proj_slots: Vec<Vec<Vec<Mutex<Option<Mat>>>>> = plans
            .iter()
            .map(|p| {
                p.waves
                    .iter()
                    .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
                    .collect()
            })
            .collect();
        let sim_slots: Vec<Vec<Vec<Mutex<Option<f32>>>>> = plans
            .iter()
            .map(|p| {
                p.waves
                    .iter()
                    .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
                    .collect()
            })
            .collect();
        #[allow(clippy::type_complexity)]
        let relay_slots: Vec<Vec<Vec<Mutex<Option<&mut JobLayer>>>>> = plans
            .iter()
            .map(|p| {
                p.waves
                    .iter()
                    .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
                    .collect()
            })
            .collect();
        let mut recordings: Vec<(usize, usize, usize, usize)> = Vec::new();
        let arena = &self.arena;
        let mut b = StepGraphBuilder::new();
        for (ji, (job, plan)) in self.jobs.iter_mut().zip(&plans).enumerate() {
            let mut layer_slots: Vec<Option<&mut JobLayer>> =
                job.layers.iter_mut().map(Some).collect();
            let ys: &Vec<Mat> = &job.y;
            let cfg = plan.cfg;
            for &(idx, ctr) in &plan.now {
                let jl = layer_slots[idx].take().expect("one chain per (job, layer)");
                let bl = &arena.layers[idx];
                let y = &ys[idx];
                let lslot = &loss_slots[ji][idx];
                b.node(&[], move || {
                    let (g, loss) = layer_grad(bl, jl, y, cfg.ctx);
                    *lslot.lock().unwrap() = Some(loss);
                    layer_update(jl, bl, cfg, ctr, &g);
                });
            }
            for (wi, wave) in plan.waves.iter().enumerate() {
                let mut grad_ids = Vec::with_capacity(wave.members.len());
                for (mi, &(idx, _ctr)) in wave.members.iter().enumerate() {
                    let jl = layer_slots[idx].take().expect("one chain per (job, layer)");
                    let bl = &arena.layers[idx];
                    let y = &ys[idx];
                    let lslot = &loss_slots[ji][idx];
                    let gslot = &g_slots[ji][wi][mi];
                    let rslot = &relay_slots[ji][wi][mi];
                    grad_ids.push(b.node(&[], move || {
                        let (g, loss) = layer_grad(bl, jl, y, cfg.ctx);
                        *lslot.lock().unwrap() = Some(loss);
                        *gslot.lock().unwrap() = Some(g);
                        *rslot.lock().unwrap() = Some(jl);
                    }));
                }
                let seed = wave.seed;
                let wave_g = &g_slots[ji][wi];
                let wave_p = &proj_slots[ji][wi];
                let basis = b.node(&grad_ids, move || {
                    let guards: Vec<_> = wave_g.iter().map(|s| s.lock().unwrap()).collect();
                    let grefs: Vec<&Mat> = guards
                        .iter()
                        .map(|gu| gu.as_ref().expect("grad node filled slot"))
                        .collect();
                    let mut rng = Pcg32::new(seed, 0x5eed);
                    let new_ps =
                        left_subspace_batched(&grefs, rank, SUBSPACE_ITERS, &mut rng, cfg.ctx);
                    drop(grefs);
                    drop(guards);
                    for (slot, p) in wave_p.iter().zip(new_ps) {
                        *slot.lock().unwrap() = Some(p);
                    }
                });
                for (mi, &(idx, ctr)) in wave.members.iter().enumerate() {
                    recordings.push((ji, wi, mi, idx));
                    let bl = &arena.layers[idx];
                    let gslot = &g_slots[ji][wi][mi];
                    let rslot = &relay_slots[ji][wi][mi];
                    let pslot = &proj_slots[ji][wi][mi];
                    let sslot = &sim_slots[ji][wi][mi];
                    b.node(&[basis], move || {
                        let jl = rslot.lock().unwrap().take().expect("grad node relayed layer");
                        let g = gslot.lock().unwrap().take().expect("grad node filled slot");
                        let new_p =
                            pslot.lock().unwrap().take().expect("basis node filled slot");
                        *sslot.lock().unwrap() = refresh_layer(jl, bl, cfg, new_p);
                        layer_update(jl, bl, cfg, ctr, &g);
                    });
                }
            }
        }
        b.run(pool)?;
        job_losses = loss_slots
            .iter()
            .map(|slots| {
                slots
                    .iter()
                    .map(|s| s.lock().unwrap().expect("every chain recorded its loss"))
                    .collect()
            })
            .collect();
        for (ji, wi, mi, idx) in recordings {
            sim_records.push((ji, idx, *sim_slots[ji][wi][mi].lock().unwrap()));
        }
        }

        // ---- join phase (serial): scheduler recording in plan order,
        // then per-job loss reduction in layer-index order — exactly the
        // orders the sequential walk uses
        for &(ji, idx, sim) in &sim_records {
            self.jobs[ji].sched.record_refresh(idx, plans[ji].step, sim);
        }
        let mut out = Vec::with_capacity(njobs);
        for (ji, job) in self.jobs.iter_mut().enumerate() {
            let total: f32 = job_losses[ji].iter().sum();
            let mean = total / nl as f32;
            job.loss_trace.push(mean);
            job.step += 1;
            out.push(mean);
        }
        Ok(out)
    }

    // -----------------------------------------------------------------
    // Delta checkpoints
    // -----------------------------------------------------------------

    /// Serialize job `ji` into a delta checkpoint (see the module docs
    /// for the determinism contract save → load → resume honors).
    pub fn export_delta(&self, ji: usize, cfg_name: &str) -> Result<DeltaCheckpoint> {
        let job = self.jobs.get(ji).ok_or_else(|| anyhow!("no job {ji}"))?;
        let mut sections = Vec::new();
        sections.push(DeltaSection {
            name: "job".into(),
            shape: vec![5],
            data: SectionData::U64(vec![
                job.seed,
                job.step,
                job.noise_ctr,
                job.refresh_ctr,
                self.cfg.rank as u64,
            ]),
        });
        for (i, jl) in job.layers.iter().enumerate() {
            let bl = &self.arena.layers[i];
            let has_proj = jl.p4.is_some() as u64;
            sections.push(DeltaSection {
                name: format!("layer{i}.meta"),
                shape: vec![4],
                data: SectionData::U64(vec![bl.m as u64, bl.n as u64, jl.l.rows as u64, has_proj]),
            });
            sections.push(DeltaSection {
                name: format!("layer{i}.lowrank"),
                shape: vec![jl.l.rows, jl.l.cols],
                data: SectionData::F32(jl.l.data.clone()),
            });
            if let Some(p4) = &jl.p4 {
                sections.push(DeltaSection {
                    name: format!("layer{i}.proj.packed"),
                    shape: vec![p4.packed.len()],
                    data: SectionData::U8(p4.packed.clone()),
                });
                sections.push(DeltaSection {
                    name: format!("layer{i}.proj.scale"),
                    shape: vec![p4.scale.len()],
                    data: SectionData::F32(p4.scale.clone()),
                });
                sections.push(DeltaSection {
                    name: format!("layer{i}.proj.zero"),
                    shape: vec![p4.zero.len()],
                    data: SectionData::F32(p4.zero.clone()),
                });
                sections.push(DeltaSection {
                    name: format!("layer{i}.proj.meta"),
                    shape: vec![2],
                    data: SectionData::U64(vec![p4.block as u64, p4.numel() as u64]),
                });
            }
            sections.push(DeltaSection {
                name: format!("layer{i}.adam8.mq"),
                shape: vec![jl.st.mq.len()],
                data: SectionData::I8(jl.st.mq.clone()),
            });
            sections.push(DeltaSection {
                name: format!("layer{i}.adam8.ms"),
                shape: vec![jl.st.ms.len()],
                data: SectionData::F32(jl.st.ms.clone()),
            });
            sections.push(DeltaSection {
                name: format!("layer{i}.adam8.vq"),
                shape: vec![jl.st.vq.len()],
                data: SectionData::U8(jl.st.vq.clone()),
            });
            sections.push(DeltaSection {
                name: format!("layer{i}.adam8.vs"),
                shape: vec![jl.st.vs.len()],
                data: SectionData::F32(jl.st.vs.clone()),
            });
            sections.push(DeltaSection {
                name: format!("layer{i}.adam8.meta"),
                shape: vec![1],
                data: SectionData::U64(vec![jl.st.block as u64]),
            });
            let ls = job.sched.layer(i);
            sections.push(DeltaSection {
                name: format!("layer{i}.sched"),
                shape: vec![3],
                data: SectionData::U64(vec![
                    ls.interval,
                    // Option<u64> as value+1, 0 = None
                    ls.last_refresh.map_or(0, |s| s + 1),
                    ls.svd_count,
                ]),
            });
            sections.push(DeltaSection {
                name: format!("layer{i}.sims"),
                shape: vec![ls.recent_sims.len()],
                data: SectionData::F32(ls.recent_sims.clone()),
            });
        }
        Ok(DeltaCheckpoint {
            meta: CheckpointMeta {
                cfg_name: cfg_name.to_string(),
                method: "multijob-delta".to_string(),
                step: job.step,
                val_loss: job.loss_trace.last().copied().unwrap_or(0.0),
            },
            sections,
        })
    }

    /// Restore a job from a delta checkpoint onto this arena; returns the
    /// new job index.  Resuming the restored job reproduces the
    /// uninterrupted run bitwise (the counters, scheduler state, and
    /// quantized buffers all round-trip exactly).
    pub fn import_job(&mut self, ckpt: &DeltaCheckpoint) -> Result<usize> {
        fn u64s(ck: &DeltaCheckpoint, name: &str) -> Result<Vec<u64>> {
            match &ck.section(name)?.data {
                SectionData::U64(v) => Ok(v.clone()),
                other => bail!("section {name:?}: expected u64 data, got {other:?}"),
            }
        }
        fn f32s(ck: &DeltaCheckpoint, name: &str) -> Result<Vec<f32>> {
            match &ck.section(name)?.data {
                SectionData::F32(v) => Ok(v.clone()),
                other => bail!("section {name:?}: expected f32 data, got {other:?}"),
            }
        }
        let jobv = u64s(ckpt, "job")?;
        ensure!(jobv.len() == 5, "job section has {} fields, want 5", jobv.len());
        let [seed, step, noise_ctr, refresh_ctr, rank] =
            [jobv[0], jobv[1], jobv[2], jobv[3], jobv[4]];
        ensure!(
            rank as usize == self.cfg.rank,
            "delta rank {rank} vs coordinator rank {}",
            self.cfg.rank
        );
        let mut job = JobState::new(&self.arena, &self.cfg, seed);
        job.step = step;
        job.noise_ctr = noise_ctr;
        job.refresh_ctr = refresh_ctr;
        for (i, jl) in job.layers.iter_mut().enumerate() {
            let bl = &self.arena.layers[i];
            let meta = u64s(ckpt, &format!("layer{i}.meta"))?;
            ensure!(meta.len() == 4, "layer{i}.meta has {} fields, want 4", meta.len());
            ensure!(
                meta[0] as usize == bl.m && meta[1] as usize == bl.n,
                "layer{i} shape mismatch: delta ({}, {}) vs arena ({}, {})",
                meta[0],
                meta[1],
                bl.m,
                bl.n
            );
            let r = meta[2] as usize;
            let lr_sec = ckpt.section(&format!("layer{i}.lowrank"))?;
            ensure!(
                lr_sec.shape == [r, bl.n],
                "layer{i}.lowrank shape {:?} vs ({r}, {})",
                lr_sec.shape,
                bl.n
            );
            jl.l = Mat::from_vec(r, bl.n, f32s(ckpt, &format!("layer{i}.lowrank"))?);
            if meta[3] != 0 {
                let pmeta = u64s(ckpt, &format!("layer{i}.proj.meta"))?;
                ensure!(pmeta.len() == 2, "layer{i}.proj.meta wants 2 fields");
                let packed = match &ckpt.section(&format!("layer{i}.proj.packed"))?.data {
                    SectionData::U8(v) => v.clone(),
                    other => bail!("layer{i}.proj.packed: expected u8, got {other:?}"),
                };
                let numel = pmeta[1] as usize;
                ensure!(
                    numel == bl.m * r,
                    "layer{i} projection numel {numel} vs m*r {}",
                    bl.m * r
                );
                let q = Quant4Tensor::from_parts(
                    packed,
                    f32s(ckpt, &format!("layer{i}.proj.scale"))?,
                    f32s(ckpt, &format!("layer{i}.proj.zero"))?,
                    pmeta[0] as usize,
                    numel,
                )?;
                jl.pack = PanelCache::empty();
                if pack_cache_enabled() {
                    jl.pack.get_or_pack4(&q, bl.m, r);
                }
                jl.p4 = Some(q);
            }
            let mq = match &ckpt.section(&format!("layer{i}.adam8.mq"))?.data {
                SectionData::I8(v) => v.clone(),
                other => bail!("layer{i}.adam8.mq: expected i8, got {other:?}"),
            };
            let vq = match &ckpt.section(&format!("layer{i}.adam8.vq"))?.data {
                SectionData::U8(v) => v.clone(),
                other => bail!("layer{i}.adam8.vq: expected u8, got {other:?}"),
            };
            let ms = f32s(ckpt, &format!("layer{i}.adam8.ms"))?;
            let vs = f32s(ckpt, &format!("layer{i}.adam8.vs"))?;
            let ameta = u64s(ckpt, &format!("layer{i}.adam8.meta"))?;
            ensure!(ameta.len() == 1, "layer{i}.adam8.meta wants 1 field");
            let block = ameta[0] as usize;
            ensure!(
                mq.len() == r * bl.n && vq.len() == r * bl.n,
                "layer{i} moment numel {} vs r*n {}",
                mq.len(),
                r * bl.n
            );
            ensure!(
                block > 0 && mq.len() % block == 0 && ms.len() == mq.len() / block
                    && vs.len() == mq.len() / block,
                "layer{i} moment block layout invalid (block {block}, {} scales)",
                ms.len()
            );
            jl.st = Adam8State { mq, ms, vq, vs, block };
            let sched = u64s(ckpt, &format!("layer{i}.sched"))?;
            ensure!(sched.len() == 3, "layer{i}.sched wants 3 fields");
            let ls = &mut job.sched.layers[i];
            ls.interval = sched[0];
            ls.last_refresh = if sched[1] == 0 { None } else { Some(sched[1] - 1) };
            ls.svd_count = sched[2];
            ls.recent_sims = f32s(ckpt, &format!("layer{i}.sims"))?;
        }
        self.jobs.push(job);
        Ok(self.jobs.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // shapes chosen so every quantized buffer (m*n INT8 base, m*r INT4
    // projection, r*n Adam8 moments) satisfies the blockwise-quantization
    // divisibility invariant at rank 8
    pub(super) fn shapes() -> Vec<(usize, usize)> {
        vec![(64, 64), (64, 64), (32, 96), (96, 32)]
    }

    pub(super) fn cfg() -> MultiJobConfig {
        MultiJobConfig {
            rank: 8,
            sched: SchedulerConfig { base_interval: 3, ..SchedulerConfig::default() },
            ..MultiJobConfig::default()
        }
    }

    #[test]
    fn round_matches_sequential_bitwise() {
        let ctx = ParallelCtx::serial();
        let mut a = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
        let mut b = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
        for seed in [7u64, 21, 900] {
            a.add_job(seed);
            b.add_job(seed);
        }
        let pool = WorkerPool::with_steal_seed(4, 13);
        for step in 0..7 {
            let la = a.round_sequential();
            let lb = b.round(&pool).unwrap();
            let la: Vec<u32> = la.iter().map(|x| x.to_bits()).collect();
            let lb: Vec<u32> = lb.iter().map(|x| x.to_bits()).collect();
            assert_eq!(la, lb, "losses diverged at round {step}");
        }
        for ji in 0..a.n_jobs() {
            assert_eq!(
                a.export_factors(ji),
                b.export_factors(ji),
                "job {ji} factors diverged"
            );
        }
    }

    #[test]
    fn losses_decrease() {
        let ctx = ParallelCtx::serial();
        let mut c = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
        c.add_job(3);
        let pool = WorkerPool::with_steal_seed(2, 5);
        let first = c.round(&pool).unwrap()[0];
        let mut last = first;
        for _ in 0..11 {
            last = c.round(&pool).unwrap()[0];
        }
        assert!(
            last < first,
            "job loss did not improve over 12 rounds: {first} -> {last}"
        );
    }

    #[test]
    fn import_rejects_rank_mismatch() {
        let ctx = ParallelCtx::serial();
        let mut c = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
        c.add_job(1);
        let pool = WorkerPool::with_steal_seed(2, 5);
        c.round(&pool).unwrap();
        let ck = c.export_delta(0, "test").unwrap();
        let mut other =
            MultiJobCoordinator::new(&shapes(), MultiJobConfig { rank: 4, ..cfg() }, ctx);
        assert!(other.import_job(&ck).is_err());
    }
}
