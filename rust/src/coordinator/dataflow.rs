//! Host-side reference dataflow trainer.
//!
//! [`HostDataflowTrainer`] drives the SAME step-graph machinery as
//! `Trainer::step` — `StepGraphBuilder` over `WorkerPool::run_graph`, one
//! chain per layer, shape-batched basis waves keyed by
//! `SubspaceScheduler::plan_due`, serial pre-assignment of every shared
//! decision, one serial join point — but with the per-layer "artifact"
//! replaced by an in-process least-squares problem (grad = Xᵀ(XW − Y),
//! INT4-projected momentum update, counter-seeded uniform noise).  The
//! xla stub cannot compile HLO artifacts, so this is how the determinism
//! and fault-containment contracts of the dataflow step are exercised
//! end-to-end in tests and benches (`tests/golden_trace.rs`,
//! `tests/proptests.rs`, `tests/pool_stress.rs`, `benches/throughput.rs`)
//! without a runtime.
//!
//! [`HostDataflowTrainer::step_sequential`] and
//! [`HostDataflowTrainer::step_dataflow`] must be bitwise-identical for
//! any worker count, steal seed, slab setting, and scheduling discipline;
//! every per-layer kernel they call is itself bits-invariant to the
//! `ParallelCtx` (the engine contract), so equality is decided purely by
//! the dataflow discipline: disjoint per-chain state, serially
//! pre-assigned seeds/counters, one reduction point.

use std::sync::Mutex;

use anyhow::Result;

use crate::linalg::{
    left_subspace_batched, pack_cache_enabled, Mat, PanelCache, ParallelCtx, WorkerPool,
};
use crate::optim::StepGraphBuilder;
use crate::quant;
use crate::scheduler::{SchedulerConfig, SubspaceScheduler};
use crate::util::Pcg32;

/// Power-iteration count at refresh time (mirrors the optimizer's).
const SUBSPACE_ITERS: usize = 2;

/// Which update rule each host layer runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HostMethod {
    /// dense: W -= lr·(G + ε·noise); no projection, no scheduler
    Full,
    /// projected update under the FIXED-interval scheduler
    LowRank,
    /// projected update under the adaptive lazy scheduler
    Galore,
}

#[derive(Clone, Copy, Debug)]
pub struct HostStepConfig {
    pub method: HostMethod,
    pub rank: usize,
    pub lr: f32,
    /// weight of the counter-seeded uniform noise folded into each update
    /// (stands in for Q-GaLore's stochastic-rounding noise operand)
    pub noise_eps: f32,
    pub sched: SchedulerConfig,
    pub seed: u64,
}

impl Default for HostStepConfig {
    fn default() -> Self {
        HostStepConfig {
            method: HostMethod::Galore,
            rank: 4,
            lr: 1e-3,
            noise_eps: 1e-3,
            sched: SchedulerConfig::default(),
            seed: 0,
        }
    }
}

/// One independent least-squares problem: minimize ||X W − Y||² over W.
struct HostLayer {
    m: usize,
    n: usize,
    x: Mat, // (m, m), fixed
    y: Mat, // (m, n), fixed
    w: Mat, // (m, n), trained
    /// INT4-stored left basis (m, r), refreshed under the scheduler
    p4: Option<quant::Quant4Tensor>,
    /// epoch-keyed panel pack of `p4` (built at refresh; the steady-state
    /// projection matmuls skip per-call nibble decode through it — bits
    /// are identical with the pack on or off)
    pack: PanelCache,
    /// low-rank momentum (r, n); reset at every refresh
    momentum: Option<Mat>,
}

/// Immutable parameters of one layer task, `Copy` into every graph node.
#[derive(Clone, Copy)]
struct TaskCfg {
    dense: bool,
    rank: usize,
    lr: f32,
    noise_eps: f32,
    ctx: ParallelCtx,
}

/// Gradient and loss of one layer against its fixed (X, Y).
fn layer_grad(layer: &HostLayer, ctx: ParallelCtx) -> (Mat, f32) {
    let resid = layer.x.matmul_with(&layer.w, ctx).sub(&layer.y);
    let f = resid.frobenius();
    let loss = f * f / (layer.m * layer.n) as f32;
    let g = layer.x.t_matmul_with(&resid, ctx);
    (g, loss)
}

/// One weight update.  Projected path mirrors the Q-GaLore data flow:
/// down-project through the stored INT4 basis, momentum EMA in the
/// subspace, up-project, apply with counter-seeded noise.
fn layer_update(layer: &mut HostLayer, cfg: TaskCfg, ctr: u64, g: &Mat) {
    let (m, n) = (layer.m, layer.n);
    let noise = quant::uniform_noise(m * n, ctr, cfg.ctx);
    let update = if cfg.dense {
        g.clone()
    } else {
        let p4 = layer.p4.as_ref().expect("projected layer refreshed at step 0");
        // the pack (built at refresh) serves every step until the next
        // refresh; when absent/stale (cache disabled) the fused per-call
        // decode produces the same bits
        let pack = layer.pack.get().filter(|pk| pk.matches4(p4, m, cfg.rank));
        let lowg = match pack {
            Some(pk) => quant::dequant4_t_matmul_prepacked(p4, pk, m, cfg.rank, g, cfg.ctx),
            None => quant::dequant4_t_matmul(p4, m, cfg.rank, g, cfg.ctx),
        };
        let mom = layer.momentum.as_mut().expect("momentum reset at refresh");
        for (me, ge) in mom.data.iter_mut().zip(&lowg.data) {
            *me = 0.9 * *me + 0.1 * ge;
        }
        match pack {
            Some(pk) => quant::dequant4_matmul_prepacked(p4, pk, m, cfg.rank, mom, cfg.ctx),
            None => quant::dequant4_matmul(p4, m, cfg.rank, mom, cfg.ctx),
        }
    };
    for ((we, ue), ne) in layer.w.data.iter_mut().zip(&update.data).zip(&noise) {
        *we -= cfg.lr * (ue + cfg.noise_eps * (ne - 0.5));
    }
}

/// Install a freshly computed basis: overlap-vs-old similarity (None
/// before the first refresh, computed through the OLD epoch's pack when
/// current), INT4 storage, panel repack for the new epoch, momentum
/// reset.  Runs inside the refresh wave's member node on the dataflow
/// path, so pack cost lands on the wave.
fn refresh_layer(layer: &mut HostLayer, cfg: TaskCfg, new_p: Mat) -> Option<f32> {
    let sim = layer.p4.as_ref().map(|old| {
        let r_old = old.numel() / layer.m;
        let prod = match layer.pack.get() {
            Some(pk) if pk.matches4(old, layer.m, r_old) => {
                quant::dequant4_t_matmul_prepacked(old, pk, layer.m, r_old, &new_p, cfg.ctx)
            }
            _ => quant::dequant4_t_matmul(old, layer.m, r_old, &new_p, cfg.ctx),
        };
        let f = prod.frobenius();
        f * f / r_old.min(new_p.cols).max(1) as f32
    });
    layer.momentum = Some(Mat::zeros(new_p.cols, layer.n));
    let r_new = new_p.cols;
    let q = quant::quantize4(&new_p.data);
    layer.pack.invalidate();
    if pack_cache_enabled() {
        layer.pack.get_or_pack4(&q, layer.m, r_new);
    }
    layer.p4 = Some(q);
    sim
}

pub struct HostDataflowTrainer {
    layers: Vec<HostLayer>,
    pub sched: SubspaceScheduler,
    method: HostMethod,
    rank: usize,
    lr: f32,
    noise_eps: f32,
    /// group sketch seeds (drawn serially, one per shape group per step)
    rng: Pcg32,
    /// update-noise counter (pre-assigned serially in walk order)
    noise_ctr: u64,
    step: u64,
    /// fault injection: panic inside the update chain of layer `.1` at
    /// step `.0` of the DATAFLOW path (tests/pool_stress.rs)
    pub fail_at: Option<(u64, usize)>,
}

impl HostDataflowTrainer {
    pub fn new(shapes: &[(usize, usize)], cfg: HostStepConfig) -> Self {
        let mut drng = Pcg32::new(cfg.seed, 0xda7a);
        let layers: Vec<HostLayer> = shapes
            .iter()
            .map(|&(m, n)| {
                let xs = 1.0 / (m as f32).sqrt();
                HostLayer {
                    m,
                    n,
                    x: Mat::from_vec(m, m, drng.normal_vec(m * m, 0.0, xs)),
                    y: Mat::from_vec(m, n, drng.normal_vec(m * n, 0.0, 1.0)),
                    w: Mat::from_vec(m, n, drng.normal_vec(m * n, 0.0, 0.1)),
                    p4: None,
                    pack: PanelCache::empty(),
                    momentum: None,
                }
            })
            .collect();
        let names: Vec<String> = (0..layers.len()).map(|i| format!("host{i}")).collect();
        let sched_cfg = match cfg.method {
            // LowRank models the fixed-interval baselines
            HostMethod::LowRank => SchedulerConfig { adaptive: false, ..cfg.sched },
            _ => cfg.sched,
        };
        HostDataflowTrainer {
            layers,
            sched: SubspaceScheduler::new(&names, sched_cfg),
            method: cfg.method,
            rank: cfg.rank,
            lr: cfg.lr,
            noise_eps: cfg.noise_eps,
            rng: Pcg32::new(cfg.seed, 0x5eed),
            noise_ctr: 0,
            step: 0,
            fail_at: None,
        }
    }

    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// Flat concatenation of every layer's trained weights — the bit
    /// pattern the equivalence tests compare.
    pub fn export_weights(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for l in &self.layers {
            out.extend_from_slice(&l.w.data);
        }
        out
    }

    fn task_cfg(&self, ctx: ParallelCtx) -> TaskCfg {
        TaskCfg {
            dense: self.method == HostMethod::Full,
            rank: self.rank,
            lr: self.lr,
            noise_eps: self.noise_eps,
            ctx,
        }
    }

    fn next_noise_ctr(&mut self) -> u64 {
        self.noise_ctr += 1;
        self.noise_ctr
    }

    /// The sequential reference step (mirrors `Galore::apply_update`):
    /// walk layers in index order, park due layers, run shape-batched
    /// refresh waves, update.  Returns the mean loss.
    pub fn step_sequential(&mut self, ctx: ParallelCtx) -> f32 {
        let step = self.step;
        let cfg = self.task_cfg(ctx);
        let mut total = 0f32;
        let mut due: Vec<(usize, Mat)> = Vec::new();
        for idx in 0..self.layers.len() {
            let (g, loss) = layer_grad(&self.layers[idx], ctx);
            total += loss;
            if !cfg.dense && self.sched.due(idx, step) {
                due.push((idx, g));
            } else {
                let ctr = self.next_noise_ctr();
                layer_update(&mut self.layers[idx], cfg, ctr, &g);
            }
        }
        // shape groups in first-due order, ONE sketch seed per group
        let mut groups: Vec<((usize, usize), u64, Vec<(usize, Mat)>)> = Vec::new();
        for (idx, g) in due {
            let key = (self.layers[idx].m, self.layers[idx].n);
            let gi = match groups.iter().position(|(k, _, _)| *k == key) {
                Some(gi) => gi,
                None => {
                    let seed = self.rng.next_u64();
                    groups.push((key, seed, Vec::new()));
                    groups.len() - 1
                }
            };
            groups[gi].2.push((idx, g));
        }
        let wave_size = ctx.threads.max(1);
        for (_shape, seed, mut members) in groups {
            while !members.is_empty() {
                let take = wave_size.min(members.len());
                let wave: Vec<(usize, Mat)> = members.drain(..take).collect();
                let grefs: Vec<&Mat> = wave.iter().map(|(_, g)| g).collect();
                let mut rng = Pcg32::new(seed, 0x5eed);
                let new_ps =
                    left_subspace_batched(&grefs, self.rank, SUBSPACE_ITERS, &mut rng, ctx);
                drop(grefs);
                for ((idx, g), new_p) in wave.into_iter().zip(new_ps) {
                    let sim = refresh_layer(&mut self.layers[idx], cfg, new_p);
                    self.sched.record_refresh(idx, step, sim);
                    let ctr = self.next_noise_ctr();
                    layer_update(&mut self.layers[idx], cfg, ctr, &g);
                }
            }
        }
        self.step += 1;
        total / self.layers.len() as f32
    }

    /// The dataflow step: same arithmetic as [`Self::step_sequential`],
    /// factored into a dependency graph on `pool`.  Non-due layers are
    /// one fused grad→update node each; a due layer contributes a grad
    /// node feeding its wave's basis node, which fans back out into the
    /// members' refresh+update nodes.  All shared decisions are planned
    /// serially up front; loss reduction and scheduler recording happen
    /// serially after the join.  A panic in any chain (including the
    /// injected `fail_at` fault) surfaces as this step's `Err`, the step
    /// counter does not advance, and the pool survives.
    pub fn step_dataflow(&mut self, ctx: ParallelCtx, pool: &WorkerPool) -> Result<f32> {
        let step = self.step;
        let cfg = self.task_cfg(ctx);
        let nl = self.layers.len();

        // ---- plan phase (serial): due snapshot, shape groups/waves,
        // noise counters in sequential-walk consumption order
        let due_set: Vec<usize> =
            if cfg.dense { Vec::new() } else { self.sched.plan_due(step) };
        let is_due = |idx: usize| due_set.contains(&idx);
        let mut now_ctrs: Vec<Option<u64>> = vec![None; nl];
        for (idx, slot) in now_ctrs.iter_mut().enumerate() {
            if !is_due(idx) {
                *slot = Some(self.next_noise_ctr());
            }
        }
        let mut groups: Vec<((usize, usize), u64, Vec<usize>)> = Vec::new();
        for &idx in &due_set {
            let key = (self.layers[idx].m, self.layers[idx].n);
            let gi = match groups.iter().position(|(k, _, _)| *k == key) {
                Some(gi) => gi,
                None => {
                    let seed = self.rng.next_u64();
                    groups.push((key, seed, Vec::new()));
                    groups.len() - 1
                }
            };
            groups[gi].2.push(idx);
        }
        struct WavePlan {
            seed: u64,
            members: Vec<(usize, u64)>, // (layer idx, noise counter)
        }
        let wave_size = ctx.threads.max(1);
        let mut waves: Vec<WavePlan> = Vec::new();
        for (_shape, seed, mut members) in groups {
            while !members.is_empty() {
                let take = wave_size.min(members.len());
                let wm: Vec<(usize, u64)> =
                    members.drain(..take).map(|idx| (idx, self.next_noise_ctr())).collect();
                waves.push(WavePlan { seed, members: wm });
            }
        }

        // ---- execute phase: the step graph
        let fail = self.fail_at;
        let loss_slots: Vec<Mutex<Option<f32>>> = (0..nl).map(|_| Mutex::new(None)).collect();
        let g_slots: Vec<Vec<Mutex<Option<Mat>>>> = waves
            .iter()
            .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
            .collect();
        let relay_slots: Vec<Vec<Mutex<Option<&mut HostLayer>>>> = waves
            .iter()
            .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
            .collect();
        let proj_slots: Vec<Vec<Mutex<Option<Mat>>>> = waves
            .iter()
            .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
            .collect();
        let sim_slots: Vec<Vec<Mutex<Option<f32>>>> = waves
            .iter()
            .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
            .collect();
        let mut recordings: Vec<(usize, usize, usize)> = Vec::new();
        let mut layer_slots: Vec<Option<&mut HostLayer>> =
            self.layers.iter_mut().map(Some).collect();
        let mut b = StepGraphBuilder::new();
        for idx in 0..nl {
            let Some(ctr) = now_ctrs[idx] else { continue };
            let layer = layer_slots[idx].take().expect("one chain per layer");
            let lslot = &loss_slots[idx];
            b.node(&[], move || {
                if fail == Some((step, idx)) {
                    panic!("injected dataflow fault at layer {idx}");
                }
                let (g, loss) = layer_grad(layer, cfg.ctx);
                *lslot.lock().unwrap() = Some(loss);
                layer_update(layer, cfg, ctr, &g);
            });
        }
        for (wi, wave) in waves.iter().enumerate() {
            let mut grad_ids = Vec::with_capacity(wave.members.len());
            for (mi, &(idx, _ctr)) in wave.members.iter().enumerate() {
                let layer = layer_slots[idx].take().expect("one chain per layer");
                let gslot = &g_slots[wi][mi];
                let rslot = &relay_slots[wi][mi];
                let lslot = &loss_slots[idx];
                grad_ids.push(b.node(&[], move || {
                    let (g, loss) = layer_grad(layer, cfg.ctx);
                    *lslot.lock().unwrap() = Some(loss);
                    *gslot.lock().unwrap() = Some(g);
                    *rslot.lock().unwrap() = Some(layer);
                }));
            }
            let seed = wave.seed;
            let wave_g = &g_slots[wi];
            let wave_p = &proj_slots[wi];
            let rank = self.rank;
            let basis = b.node(&grad_ids, move || {
                let guards: Vec<_> = wave_g.iter().map(|s| s.lock().unwrap()).collect();
                let grefs: Vec<&Mat> =
                    guards.iter().map(|gu| gu.as_ref().expect("grad node filled slot")).collect();
                let mut rng = Pcg32::new(seed, 0x5eed);
                let new_ps = left_subspace_batched(&grefs, rank, SUBSPACE_ITERS, &mut rng, cfg.ctx);
                drop(grefs);
                drop(guards);
                for (slot, p) in wave_p.iter().zip(new_ps) {
                    *slot.lock().unwrap() = Some(p);
                }
            });
            for (mi, &(idx, ctr)) in wave.members.iter().enumerate() {
                recordings.push((wi, mi, idx));
                let gslot = &g_slots[wi][mi];
                let rslot = &relay_slots[wi][mi];
                let pslot = &proj_slots[wi][mi];
                let sslot = &sim_slots[wi][mi];
                b.node(&[basis], move || {
                    if fail == Some((step, idx)) {
                        panic!("injected dataflow fault at layer {idx}");
                    }
                    let layer = rslot.lock().unwrap().take().expect("grad node relayed layer");
                    let g = gslot.lock().unwrap().take().expect("grad node filled slot");
                    let new_p = pslot.lock().unwrap().take().expect("basis node filled slot");
                    *sslot.lock().unwrap() = refresh_layer(layer, cfg, new_p);
                    layer_update(layer, cfg, ctr, &g);
                });
            }
        }
        b.run(pool)?;

        // ---- join phase (serial): loss reduction in layer index order,
        // scheduler recording in plan order — exactly the orders the
        // sequential walk uses
        let mut total = 0f32;
        for slot in &loss_slots {
            total += slot.lock().unwrap().expect("every chain recorded its loss");
        }
        for (wi, mi, idx) in recordings {
            let sim = *sim_slots[wi][mi].lock().unwrap();
            self.sched.record_refresh(idx, step, sim);
        }
        self.step += 1;
        Ok(total / nl as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_pair(method: HostMethod) {
        let cfg = HostStepConfig {
            method,
            rank: 2,
            sched: SchedulerConfig { base_interval: 2, ..SchedulerConfig::default() },
            seed: 9,
            ..HostStepConfig::default()
        };
        let shapes = [(12, 8), (12, 8), (10, 6)];
        let mut seq = HostDataflowTrainer::new(&shapes, cfg);
        let mut df = HostDataflowTrainer::new(&shapes, cfg);
        let pool = WorkerPool::with_steal_seed(4, 11);
        let ctx = ParallelCtx::serial();
        for s in 0..5 {
            let a = seq.step_sequential(ctx);
            let b = df.step_dataflow(ctx, &pool).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "loss diverged at step {s} ({method:?})");
        }
        assert_eq!(seq.export_weights(), df.export_weights(), "{method:?} weights diverged");
    }

    #[test]
    fn dataflow_matches_sequential_smoke() {
        run_pair(HostMethod::Full);
        run_pair(HostMethod::LowRank);
        run_pair(HostMethod::Galore);
    }
}
