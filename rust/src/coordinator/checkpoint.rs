//! Checkpoint IO: flat little-endian f32 params (ABI order, the
//! `Optimizer::export_flat` format) plus a JSON sidecar with metadata.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::jsonx::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointMeta {
    pub cfg_name: String,
    pub method: String,
    pub step: u64,
    pub val_loss: f32,
}

pub fn save(
    path: impl AsRef<Path>,
    params: &[f32],
    meta: &CheckpointMeta,
) -> Result<()> {
    let path = path.as_ref();
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    std::fs::write(path, &bytes)
        .with_context(|| format!("writing {}", path.display()))?;
    let mut obj = BTreeMap::new();
    obj.insert("cfg_name".into(), Json::Str(meta.cfg_name.clone()));
    obj.insert("method".into(), Json::Str(meta.method.clone()));
    obj.insert("step".into(), Json::Num(meta.step as f64));
    obj.insert("val_loss".into(), Json::Num(meta.val_loss as f64));
    obj.insert("numel".into(), Json::Num(params.len() as f64));
    std::fs::write(sidecar(path), Json::Obj(obj).dump())?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<(Vec<f32>, CheckpointMeta)> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("checkpoint {} has odd byte length", path.display()));
    }
    let params: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let meta_raw = std::fs::read_to_string(sidecar(path))?;
    let j = Json::parse(&meta_raw).map_err(|e| anyhow!("{e}"))?;
    let numel = j.get("numel").and_then(Json::as_usize).unwrap_or(params.len());
    if numel != params.len() {
        return Err(anyhow!("checkpoint numel mismatch: {} vs {}", numel, params.len()));
    }
    let meta = CheckpointMeta {
        cfg_name: j
            .get("cfg_name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        method: j.get("method").and_then(Json::as_str).unwrap_or_default().to_string(),
        step: j.get("step").and_then(Json::as_usize).unwrap_or(0) as u64,
        val_loss: j.get("val_loss").and_then(Json::as_f64).unwrap_or(0.0) as f32,
    };
    Ok((params, meta))
}

fn sidecar(path: &Path) -> std::path::PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".json");
    std::path::PathBuf::from(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qgalore_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("test.ckpt");
        let params: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        let meta = CheckpointMeta {
            cfg_name: "llama-tiny".into(),
            method: "Q-GaLore".into(),
            step: 123,
            val_loss: 4.5,
        };
        save(&p, &params, &meta).unwrap();
        let (got, gmeta) = load(&p).unwrap();
        assert_eq!(got, params);
        assert_eq!(gmeta, meta);
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("qgalore_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(load(&p).is_err());
    }
}
