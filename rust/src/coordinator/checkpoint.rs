//! Checkpoint IO.
//!
//! Two on-disk formats share this module:
//!
//! * **Flat checkpoints** ([`save`] / [`load`]): every model parameter as
//!   little-endian f32 in the `Optimizer::export_flat` ABI order, plus a
//!   JSON sidecar with metadata.  Size = full model; the pre-train /
//!   fine-tune handoff format.
//! * **Delta checkpoints** ([`save_delta`] / [`load_delta`]): the per-user
//!   personalization state of one fine-tune job — low-rank factors, the
//!   INT4-packed projection, quantized Adam moments, scheduler/counter
//!   state — as named, typed, shaped sections behind a versioned binary
//!   header (magic `QGDC`).  A few hundred KB per user instead of a full
//!   flat dump; the storage format of the fine-tune-as-a-service
//!   coordinator (`coordinator::multijob`) and of
//!   `Optimizer::export_delta`.
//!
//! Both formats write **atomically**: payload and sidecar each go to
//! `<file>.tmp` and are renamed into place, payload strictly before
//! sidecar.  A crash mid-save therefore leaves either the old pair, a
//! stray `.tmp`, or a new payload without its sidecar — never a sidecar
//! describing a torn payload.  Loaders treat a missing/invalid sidecar as
//! an error, so the half-written states are all unloadable.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonx::Json;
use crate::optim::FpTensor;

#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointMeta {
    pub cfg_name: String,
    pub method: String,
    pub step: u64,
    pub val_loss: f32,
}

/// Write `bytes` to `path` atomically: `<path>.tmp` + rename.  Readers
/// never observe a torn file — they see the old content or the new.
fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// Parse a JSON number field as u64 without a lossy `usize` round-trip
/// (`as_usize` truncates above 2^32 on 32-bit hosts).
fn get_u64(j: &Json, key: &str) -> Option<u64> {
    let f = j.get(key).and_then(Json::as_f64)?;
    if f.fract() == 0.0 && (0.0..=u64::MAX as f64).contains(&f) {
        Some(f as u64)
    } else {
        None
    }
}

pub fn save(
    path: impl AsRef<Path>,
    params: &[f32],
    meta: &CheckpointMeta,
) -> Result<()> {
    let path = path.as_ref();
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    // payload before sidecar: a crash between the two renames leaves a
    // payload without metadata (unloadable), never metadata blessing a
    // payload that was not fully committed
    atomic_write(path, &bytes)?;
    let mut obj = BTreeMap::new();
    obj.insert("cfg_name".into(), Json::Str(meta.cfg_name.clone()));
    obj.insert("method".into(), Json::Str(meta.method.clone()));
    obj.insert("step".into(), Json::Num(meta.step as f64));
    obj.insert("val_loss".into(), Json::Num(meta.val_loss as f64));
    obj.insert("numel".into(), Json::Num(params.len() as f64));
    atomic_write(&sidecar(path), Json::Obj(obj).dump().as_bytes())?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<(Vec<f32>, CheckpointMeta)> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("checkpoint {} has odd byte length", path.display()));
    }
    let params: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let sc = sidecar(path);
    let meta_raw = std::fs::read_to_string(&sc)
        .with_context(|| format!("reading sidecar {}", sc.display()))?;
    let j = Json::parse(&meta_raw)
        .map_err(|e| anyhow!("sidecar {}: {e}", sc.display()))?;
    // a sidecar without the size guard is indistinguishable from one
    // describing a different payload — reject instead of defaulting the
    // guard to whatever length the payload happens to have
    let numel = j
        .get("numel")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("sidecar {} is missing numel", sc.display()))?;
    if numel != params.len() {
        return Err(anyhow!("checkpoint numel mismatch: {} vs {}", numel, params.len()));
    }
    let meta = CheckpointMeta {
        cfg_name: j
            .get("cfg_name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        method: j.get("method").and_then(Json::as_str).unwrap_or_default().to_string(),
        step: get_u64(&j, "step").unwrap_or(0),
        val_loss: j.get("val_loss").and_then(Json::as_f64).unwrap_or(0.0) as f32,
    };
    Ok((params, meta))
}

fn sidecar(path: &Path) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".json");
    PathBuf::from(s)
}

// ---------------------------------------------------------------------------
// Delta checkpoints (magic QGDC, version 1)
// ---------------------------------------------------------------------------

/// On-disk magic of the delta payload.
const DELTA_MAGIC: [u8; 4] = *b"QGDC";
/// Current delta format version.  Loaders reject anything else: the format
/// carries optimizer state whose silent misinterpretation would corrupt a
/// user's personalization, so there is no cross-version leniency.
pub const DELTA_VERSION: u32 = 1;

/// Typed payload of one delta section.  The variants cover every storage
/// format a per-user delta holds: f32 low-rank factors and block scales,
/// i8/u8 quantized codes, u64 counters and scheduler state.
#[derive(Clone, Debug, PartialEq)]
pub enum SectionData {
    F32(Vec<f32>),
    I8(Vec<i8>),
    U8(Vec<u8>),
    U64(Vec<u64>),
}

impl SectionData {
    pub fn len(&self) -> usize {
        match self {
            SectionData::F32(v) => v.len(),
            SectionData::I8(v) => v.len(),
            SectionData::U8(v) => v.len(),
            SectionData::U64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn byte_len(&self) -> usize {
        match self {
            SectionData::F32(v) => v.len() * 4,
            SectionData::I8(v) => v.len(),
            SectionData::U8(v) => v.len(),
            SectionData::U64(v) => v.len() * 8,
        }
    }

    fn dtype_tag(&self) -> u8 {
        match self {
            SectionData::F32(_) => 0,
            SectionData::I8(_) => 1,
            SectionData::U8(_) => 2,
            SectionData::U64(_) => 3,
        }
    }
}

/// One named, shaped, typed section of a delta checkpoint (a low-rank
/// factor, a packed projection, one Adam moment buffer, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaSection {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: SectionData,
}

/// A per-user delta checkpoint: metadata plus named sections.
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaCheckpoint {
    pub meta: CheckpointMeta,
    pub sections: Vec<DeltaSection>,
}

impl DeltaCheckpoint {
    /// Look a section up by name (load paths: a missing section is a
    /// format error, not a default).
    pub fn section(&self, name: &str) -> Result<&DeltaSection> {
        self.sections
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("delta checkpoint is missing section {name:?}"))
    }

    /// Total payload bytes this checkpoint serializes to (header included).
    pub fn payload_bytes(&self) -> usize {
        let mut n = 4 + 4 + 4; // magic + version + n_sections
        for s in &self.sections {
            n += 4 + s.name.len(); // name_len + name
            n += 1; // dtype tag
            n += 4 + 8 * s.shape.len(); // ndim + dims
            n += 8 + s.data.byte_len(); // byte_len + payload
        }
        n
    }
}

/// Wrap an `Optimizer::export_delta` tensor list (LoRA adapters, LowRank
/// factor pairs) as a delta checkpoint — one f32 section per tensor, names
/// and shapes preserved.
pub fn delta_from_tensors(meta: CheckpointMeta, tensors: &[FpTensor]) -> DeltaCheckpoint {
    let sections = tensors
        .iter()
        .map(|t| DeltaSection {
            name: t.name.clone(),
            shape: t.shape.clone(),
            data: SectionData::F32(t.data.clone()),
        })
        .collect();
    DeltaCheckpoint { meta, sections }
}

/// Unwrap a tensor-only delta checkpoint (written via
/// [`delta_from_tensors`]) back into the `Optimizer::import_delta` list.
/// Sections of any non-f32 dtype are a format error — those belong to the
/// multijob coordinator's richer layout, not the optimizer-trait one.
pub fn tensors_from_delta(ckpt: &DeltaCheckpoint) -> Result<Vec<FpTensor>> {
    ckpt.sections
        .iter()
        .map(|s| match &s.data {
            SectionData::F32(v) => Ok(FpTensor {
                name: s.name.clone(),
                shape: s.shape.clone(),
                data: v.clone(),
            }),
            other => Err(anyhow!(
                "delta section {:?} has non-f32 data ({} elems) — not an \
                 optimizer tensor delta",
                s.name,
                other.len()
            )),
        })
        .collect()
}

/// Serialize and atomically write a delta checkpoint: binary payload at
/// `path` (magic `QGDC`, version, named sections), JSON sidecar at
/// `<path>.json` (metadata + payload size guard), payload strictly first.
pub fn save_delta(path: impl AsRef<Path>, ckpt: &DeltaCheckpoint) -> Result<()> {
    let path = path.as_ref();
    let mut bytes = Vec::with_capacity(ckpt.payload_bytes());
    bytes.extend_from_slice(&DELTA_MAGIC);
    bytes.extend_from_slice(&DELTA_VERSION.to_le_bytes());
    bytes.extend_from_slice(&(ckpt.sections.len() as u32).to_le_bytes());
    for s in &ckpt.sections {
        let numel: usize = s.shape.iter().product();
        if numel != s.data.len() {
            bail!(
                "delta section {:?}: shape {:?} ({numel} elems) vs {} data elems",
                s.name,
                s.shape,
                s.data.len()
            );
        }
        bytes.extend_from_slice(&(s.name.len() as u32).to_le_bytes());
        bytes.extend_from_slice(s.name.as_bytes());
        bytes.push(s.data.dtype_tag());
        bytes.extend_from_slice(&(s.shape.len() as u32).to_le_bytes());
        for &d in &s.shape {
            bytes.extend_from_slice(&(d as u64).to_le_bytes());
        }
        bytes.extend_from_slice(&(s.data.byte_len() as u64).to_le_bytes());
        match &s.data {
            SectionData::F32(v) => {
                for x in v {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
            SectionData::I8(v) => bytes.extend(v.iter().map(|&x| x as u8)),
            SectionData::U8(v) => bytes.extend_from_slice(v),
            SectionData::U64(v) => {
                for x in v {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    atomic_write(path, &bytes)?;
    let mut obj = BTreeMap::new();
    obj.insert("format".into(), Json::Str("qgalore-delta".into()));
    obj.insert("version".into(), Json::Num(DELTA_VERSION as f64));
    obj.insert("cfg_name".into(), Json::Str(ckpt.meta.cfg_name.clone()));
    obj.insert("method".into(), Json::Str(ckpt.meta.method.clone()));
    obj.insert("step".into(), Json::Num(ckpt.meta.step as f64));
    obj.insert("val_loss".into(), Json::Num(ckpt.meta.val_loss as f64));
    obj.insert("n_sections".into(), Json::Num(ckpt.sections.len() as f64));
    obj.insert("payload_bytes".into(), Json::Num(bytes.len() as f64));
    atomic_write(&sidecar(path), Json::Obj(obj).dump().as_bytes())?;
    Ok(())
}

/// Bounds-checked cursor over the delta payload — every read that would
/// run past the end is a clean truncation error, never a panic.
struct Cursor<'a> {
    bytes: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .off
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| {
                anyhow!(
                    "delta payload truncated: need {n} bytes at offset {}, have {}",
                    self.off,
                    self.bytes.len().saturating_sub(self.off)
                )
            })?;
        let s = &self.bytes[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }
}

/// Load and validate a delta checkpoint written by [`save_delta`].
///
/// Rejects: missing/unparseable/partial sidecar, sidecar whose
/// `payload_bytes`/`n_sections`/`version` disagree with the payload, bad
/// magic, unknown version, truncated payload, shape/byte-length
/// mismatches, and trailing garbage.
pub fn load_delta(path: impl AsRef<Path>) -> Result<DeltaCheckpoint> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading delta checkpoint {}", path.display()))?;
    let sc = sidecar(path);
    let meta_raw = std::fs::read_to_string(&sc)
        .with_context(|| format!("reading delta sidecar {}", sc.display()))?;
    let j = Json::parse(&meta_raw)
        .map_err(|e| anyhow!("delta sidecar {}: {e}", sc.display()))?;
    let side_version = get_u64(&j, "version")
        .ok_or_else(|| anyhow!("delta sidecar {} is missing version", sc.display()))?;
    let side_bytes = get_u64(&j, "payload_bytes")
        .ok_or_else(|| anyhow!("delta sidecar {} is missing payload_bytes", sc.display()))?;
    let side_sections = get_u64(&j, "n_sections")
        .ok_or_else(|| anyhow!("delta sidecar {} is missing n_sections", sc.display()))?;
    if side_bytes != bytes.len() as u64 {
        bail!(
            "delta payload size mismatch: sidecar says {side_bytes} bytes, file has {}",
            bytes.len()
        );
    }

    let mut c = Cursor { bytes: &bytes, off: 0 };
    let magic = c.take(4)?;
    if magic != DELTA_MAGIC {
        bail!("not a delta checkpoint (bad magic {magic:02x?})");
    }
    let version = c.u32()?;
    if version != DELTA_VERSION || side_version != DELTA_VERSION as u64 {
        bail!(
            "unsupported delta format version {version} (sidecar {side_version}, \
             this build reads {DELTA_VERSION})"
        );
    }
    let n_sections = c.u32()? as usize;
    if side_sections != n_sections as u64 {
        bail!("delta section count mismatch: sidecar {side_sections}, payload {n_sections}");
    }
    let mut sections = Vec::with_capacity(n_sections);
    for si in 0..n_sections {
        let name_len = c.u32()? as usize;
        let name = std::str::from_utf8(c.take(name_len)?)
            .map_err(|_| anyhow!("delta section {si}: name is not utf8"))?
            .to_string();
        let dtype = c.take(1)?[0];
        let ndim = c.u32()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u64()? as usize);
        }
        let numel: usize = shape.iter().product();
        let byte_len = c.u64()? as usize;
        let elem = match dtype {
            0 => 4,
            1 | 2 => 1,
            3 => 8,
            t => bail!("delta section {name:?}: unknown dtype tag {t}"),
        };
        if byte_len != numel * elem {
            bail!(
                "delta section {name:?}: shape {shape:?} wants {} bytes, header says {byte_len}",
                numel * elem
            );
        }
        let raw = c.take(byte_len)?;
        let data = match dtype {
            0 => SectionData::F32(
                raw.chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect(),
            ),
            1 => SectionData::I8(raw.iter().map(|&b| b as i8).collect()),
            2 => SectionData::U8(raw.to_vec()),
            3 => SectionData::U64(
                raw.chunks_exact(8)
                    .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
                    .collect(),
            ),
            _ => unreachable!("dtype validated above"),
        };
        sections.push(DeltaSection { name, shape, data });
    }
    if c.off != bytes.len() {
        bail!("delta payload has {} trailing bytes", bytes.len() - c.off);
    }
    let meta = CheckpointMeta {
        cfg_name: j
            .get("cfg_name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        method: j.get("method").and_then(Json::as_str).unwrap_or_default().to_string(),
        step: get_u64(&j, "step").unwrap_or(0),
        val_loss: j.get("val_loss").and_then(Json::as_f64).unwrap_or(0.0) as f32,
    };
    Ok(DeltaCheckpoint { meta, sections })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::unique_temp_dir;

    fn meta() -> CheckpointMeta {
        CheckpointMeta {
            cfg_name: "llama-tiny".into(),
            method: "Q-GaLore".into(),
            step: 123,
            val_loss: 4.5,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = unique_temp_dir("ckpt");
        let p = dir.join("test.ckpt");
        let params: Vec<f32> = (0..100).map(|i| i as f32 * 0.5).collect();
        let meta = meta();
        save(&p, &params, &meta).unwrap();
        let (got, gmeta) = load(&p).unwrap();
        assert_eq!(got, params);
        assert_eq!(gmeta, meta);
    }

    #[test]
    fn save_leaves_no_tmp_files() {
        let dir = unique_temp_dir("ckpt");
        let p = dir.join("clean.ckpt");
        save(&p, &[1.0, 2.0], &meta()).unwrap();
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty(), "stray tmp files after save: {stray:?}");
    }

    #[test]
    fn step_survives_u32_overflow() {
        let dir = unique_temp_dir("ckpt");
        let p = dir.join("big.ckpt");
        let m = CheckpointMeta { step: 5_000_000_000, ..meta() };
        save(&p, &[0.0; 4], &m).unwrap();
        assert_eq!(load(&p).unwrap().1.step, 5_000_000_000);
    }

    #[test]
    fn rejects_truncated() {
        let dir = unique_temp_dir("ckpt");
        let p = dir.join("bad.ckpt");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_missing_sidecar() {
        let dir = unique_temp_dir("ckpt");
        let p = dir.join("orphan.ckpt");
        // the state an interrupted save leaves: payload committed, no
        // sidecar yet
        std::fs::write(&p, 1.0f32.to_le_bytes()).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_sidecar_without_numel() {
        let dir = unique_temp_dir("ckpt");
        let p = dir.join("nonumel.ckpt");
        save(&p, &[1.0, 2.0], &meta()).unwrap();
        // strip the size guard: load must fail, not default it to the
        // payload length (which made the guard vacuous)
        std::fs::write(sidecar(&p), r#"{"cfg_name": "x", "step": 1}"#).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("numel"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_partial_sidecar() {
        let dir = unique_temp_dir("ckpt");
        let p = dir.join("torn.ckpt");
        save(&p, &[1.0, 2.0], &meta()).unwrap();
        let full = std::fs::read_to_string(sidecar(&p)).unwrap();
        std::fs::write(sidecar(&p), &full[..full.len() / 2]).unwrap();
        assert!(load(&p).is_err());
    }

    fn delta() -> DeltaCheckpoint {
        DeltaCheckpoint {
            meta: meta(),
            sections: vec![
                DeltaSection {
                    name: "layer0.lowrank".into(),
                    shape: vec![2, 3],
                    data: SectionData::F32(vec![0.5, -1.25, 3.0, f32::MIN_POSITIVE, 0.0, -0.0]),
                },
                DeltaSection {
                    name: "layer0.proj.packed".into(),
                    shape: vec![4],
                    data: SectionData::U8(vec![0x12, 0x34, 0x56, 0x78]),
                },
                DeltaSection {
                    name: "layer0.adam8.mq".into(),
                    shape: vec![3],
                    data: SectionData::I8(vec![-128, 0, 127]),
                },
                DeltaSection {
                    name: "job".into(),
                    shape: vec![3],
                    data: SectionData::U64(vec![u64::MAX, 0, 42]),
                },
            ],
        }
    }

    #[test]
    fn delta_roundtrip_bitwise() {
        let dir = unique_temp_dir("delta");
        let p = dir.join("user.delta");
        let ck = delta();
        save_delta(&p, &ck).unwrap();
        let got = load_delta(&p).unwrap();
        assert_eq!(got, ck);
        // f32 sections must round-trip bitwise, not just by value
        let SectionData::F32(a) = &ck.sections[0].data else { unreachable!() };
        let SectionData::F32(b) = &got.sections[0].data else { unreachable!() };
        let abits: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
        let bbits: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
        assert_eq!(abits, bbits);
    }

    #[test]
    fn delta_rejects_missing_sidecar() {
        let dir = unique_temp_dir("delta");
        let p = dir.join("orphan.delta");
        save_delta(&p, &delta()).unwrap();
        std::fs::remove_file(sidecar(&p)).unwrap();
        assert!(load_delta(&p).is_err());
    }

    #[test]
    fn delta_rejects_partial_sidecar() {
        let dir = unique_temp_dir("delta");
        let p = dir.join("torn.delta");
        save_delta(&p, &delta()).unwrap();
        let full = std::fs::read_to_string(sidecar(&p)).unwrap();
        std::fs::write(sidecar(&p), &full[..full.len() / 2]).unwrap();
        assert!(load_delta(&p).is_err());
    }

    #[test]
    fn delta_rejects_truncated_payload() {
        let dir = unique_temp_dir("delta");
        let p = dir.join("trunc.delta");
        save_delta(&p, &delta()).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 3]).unwrap();
        let err = load_delta(&p).unwrap_err().to_string();
        // size guard fires before the parser even runs
        assert!(err.contains("size mismatch"), "unexpected error: {err}");
    }

    #[test]
    fn delta_rejects_bad_magic_and_version() {
        let dir = unique_temp_dir("delta");
        let p = dir.join("magic.delta");
        save_delta(&p, &delta()).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[0] = b'X';
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_delta(&p).unwrap_err().to_string().contains("magic"));
        bytes[0] = b'Q';
        bytes[4] = 99; // version
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_delta(&p).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn delta_save_rejects_shape_mismatch() {
        let dir = unique_temp_dir("delta");
        let p = dir.join("shape.delta");
        let mut ck = delta();
        ck.sections[0].shape = vec![7];
        assert!(save_delta(&p, &ck).is_err());
    }
}
