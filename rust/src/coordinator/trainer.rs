//! Pre-training loop.
//!
//! One step = execute the method's fwd/bwd artifact, then hand the gradient
//! list to the optimizer.  Two step paths share that structure:
//!
//! * **Sequential** (`Optimizer::apply_update`, the default): walk the
//!   gradients tensor-by-tensor, running each tensor's fused update
//!   artifact and dropping the gradient immediately — the rust-side
//!   realization of the paper's fused-backward memory discipline (§3.5).
//!
//! * **Dataflow** (`TrainConfig::dataflow`, env `QGALORE_DATAFLOW`): the
//!   same per-tensor work, factored into a dependency graph on the
//!   work-stealing pool (`WorkerPool::run_graph`).  Each fp tensor and
//!   each linear layer's project→Adam8→update chain is an independent
//!   node owning that tensor's state; a due refresh becomes a basis node
//!   (one shape-batched `left_subspace_batched` wave) fanning into its
//!   member layers' update nodes; and the *next* batch is prefetched
//!   (`Batcher::prefetch`) concurrently with the whole update graph.
//!
//! The determinism contract makes the two paths bitwise-identical for any
//! worker count / steal seed / slab setting: per-chain state is disjoint
//! (commuting updates), every shared decision (accumulator folds, due
//! set via `SubspaceScheduler::plan_due`, group sketch seeds, SR noise
//! seeds) is pre-assigned serially in sequential-walk order, and there is
//! a single serial join point per step where cross-layer reductions
//! (loss check, scheduler recording) happen in layer order.  Pinned by
//! `tests/golden_trace.rs` and `tests/proptests.rs`; fault containment
//! (a panicking chain surfaces in `step()`'s `Result`, the pool
//! survives) by `tests/pool_stress.rs`.

use anyhow::{anyhow, Result};

use crate::data;
use crate::manifest::Manifest;
use crate::optim::{self, BuildOptions, Method, Optimizer, StepCtx};
use crate::runtime::{HostTensor, Runtime};
use crate::util::Stopwatch;

/// Env var enabling the dataflow step path for `TrainConfig::default()`
/// (`1/true/on` vs `0/false/off`; default off).
pub const DATAFLOW_ENV: &str = "QGALORE_DATAFLOW";

/// Default for `TrainConfig::dataflow`, from [`DATAFLOW_ENV`].
pub fn dataflow_default() -> bool {
    crate::util::env_parse(DATAFLOW_ENV, "1/true/on or 0/false/off", |s| {
        match s.to_ascii_lowercase().as_str() {
            "1" | "true" | "on" => Some(true),
            "0" | "false" | "off" => Some(false),
            _ => None,
        }
    })
    .unwrap_or(false)
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub cfg_name: String,
    pub method: Method,
    pub steps: u64,
    pub lr_max: f32,
    pub warmup: u64,
    pub eval_every: u64,
    /// max validation batches per eval (0 = all)
    pub eval_batches: usize,
    pub n_documents: usize,
    pub seed: u64,
    pub opts: BuildOptions,
    pub log_every: u64,
    pub quiet: bool,
    /// run the update phase as a dependency graph on the work-stealing
    /// pool, overlapped with next-batch prefetch (see the module docs)
    pub dataflow: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            cfg_name: "llama-tiny".into(),
            method: Method::QGaLore,
            steps: 200,
            lr_max: 0.01,
            warmup: 20,
            eval_every: 50,
            eval_batches: 8,
            n_documents: 512,
            seed: 0,
            opts: BuildOptions::default(),
            log_every: 25,
            quiet: false,
            dataflow: dataflow_default(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainResult {
    pub method: Method,
    pub train_losses: Vec<(u64, f32)>,
    pub val_losses: Vec<(u64, f32)>,
    pub final_val_loss: f32,
    pub final_ppl: f32,
    pub live_bytes: u64,
    pub svd_count: u64,
    pub svd_fraction: f64,
    pub steps_per_sec: f64,
    pub sim_history: Vec<(String, Vec<f32>)>,
    /// exported flat f32 params (ABI order) — the checkpoint
    pub final_params: Vec<f32>,
}

/// Linear warmup then cosine decay to 10% of peak.
///
/// Total-order safe: the post-warmup offset is a `saturating_sub` (a plain
/// `step - warmup` would panic in debug / wrap in release if a caller ever
/// evaluated the cosine branch with `step < warmup`), and progress clamps
/// at 1 so steps past `total` hold the floor LR instead of walking the
/// cosine back up.  In-range behavior is bit-identical to before.
pub fn lr_at(step: u64, total: u64, warmup: u64, lr_max: f32) -> f32 {
    if step < warmup.max(1) {
        return lr_max * (step as f32 + 1.0) / warmup.max(1) as f32;
    }
    let progress = (step.saturating_sub(warmup) as f32
        / (total.saturating_sub(warmup)).max(1) as f32)
        .min(1.0);
    let cosine = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
    lr_max * (0.1 + 0.9 * cosine)
}

pub struct Trainer<'m> {
    pub man: &'m Manifest,
    pub rt: Runtime,
    pub opt: Box<dyn Optimizer>,
    pub cfg: TrainConfig,
    train_batcher: data::Batcher,
    val_batches: Vec<data::Batch>,
}

impl<'m> Trainer<'m> {
    pub fn new(man: &'m Manifest, cfg: TrainConfig) -> Result<Self> {
        let entry = man.config(&cfg.cfg_name)?;
        let model = &entry.model;
        let (_tok, train_ids, val_ids) =
            data::build_dataset(model.vocab_size, cfg.n_documents, cfg.seed);
        let train_batcher =
            data::Batcher::new(train_ids, man.batch, model.max_seq_len, cfg.seed);
        let val_batcher =
            data::Batcher::new(val_ids, man.batch, model.max_seq_len, cfg.seed);
        let mut val_batches = val_batcher.sequential_batches();
        if cfg.eval_batches > 0 {
            val_batches.truncate(cfg.eval_batches);
        }
        if val_batches.is_empty() {
            return Err(anyhow!("validation split produced no batches; raise n_documents"));
        }
        let opt = optim::build(cfg.method, man, &cfg.cfg_name, cfg.opts)?;
        Ok(Trainer {
            man,
            rt: Runtime::new()?,
            opt,
            cfg,
            train_batcher,
            val_batches,
        })
    }

    /// Construct with an explicit initial checkpoint (fine-tuning path).
    pub fn with_optimizer(
        man: &'m Manifest,
        cfg: TrainConfig,
        opt: Box<dyn Optimizer>,
        train_ids: Vec<u32>,
        val_ids: Vec<u32>,
    ) -> Result<Self> {
        let entry = man.config(&cfg.cfg_name)?;
        let model = &entry.model;
        let train_batcher =
            data::Batcher::new(train_ids, man.batch, model.max_seq_len, cfg.seed);
        let val_batcher =
            data::Batcher::new(val_ids, man.batch, model.max_seq_len, cfg.seed);
        let mut val_batches = val_batcher.sequential_batches();
        if cfg.eval_batches > 0 {
            val_batches.truncate(cfg.eval_batches);
        }
        Ok(Trainer {
            man,
            rt: Runtime::new()?,
            opt,
            cfg,
            train_batcher,
            val_batches,
        })
    }

    /// One optimization step on the next batch; returns training loss.
    pub fn step(&mut self, step: u64) -> Result<f32> {
        let batch = self.train_batcher.next();
        let entry = self.man.config(&self.cfg.cfg_name)?;
        let fwd = entry
            .artifacts
            .get(self.opt.fwd_artifact())
            .ok_or_else(|| anyhow!("missing artifact {}", self.opt.fwd_artifact()))?
            .clone();
        let mut ops = self.opt.forward_operands();
        ops.push(HostTensor::I32(batch.tokens));
        ops.push(HostTensor::I32(batch.targets));
        let mut outs = self.rt.execute(&fwd, &ops)?;
        let grads = outs.split_off(1);
        let loss = outs.pop().unwrap().scalar_f32()?;
        if !loss.is_finite() {
            return Err(anyhow!("non-finite training loss at step {step}"));
        }
        let lr = lr_at(step, self.cfg.steps, self.cfg.warmup, self.cfg.lr_max);
        let ctx = StepCtx { rt: &self.rt, man: self.man, step: step + 1, lr };
        if self.cfg.dataflow {
            // Dataflow path: the whole update graph runs as one pool task
            // while a sibling task prefetches the next batch, so tokenize/
            // shuffle/copy overlaps the update chains.  A panic or Err in
            // any chain resurfaces here as this step's Err; the pool
            // itself survives (tests/pool_stress.rs).
            let wpool = self
                .cfg
                .opts
                .pool
                .worker_pool()
                .unwrap_or_else(crate::linalg::global_pool);
            let opt = &mut self.opt;
            let batcher = &mut self.train_batcher;
            let mut upd: Option<Result<()>> = None;
            {
                let upd = &mut upd;
                let ctx = &ctx;
                wpool.run_scoped(vec![
                    Box::new(move || *upd = Some(opt.apply_update_dataflow(ctx, grads, wpool))),
                    Box::new(move || batcher.prefetch()),
                ]);
            }
            upd.expect("update task ran")?;
        } else {
            self.opt.apply_update(&ctx, grads)?;
        }
        self.opt.on_step_end(&ctx)?;
        Ok(loss)
    }

    /// Mean validation loss over the held-out batches.
    pub fn evaluate(&mut self) -> Result<f32> {
        let entry = self.man.config(&self.cfg.cfg_name)?;
        let eval = entry
            .artifacts
            .get(self.opt.eval_artifact())
            .ok_or_else(|| anyhow!("missing artifact {}", self.opt.eval_artifact()))?
            .clone();
        let params = self.opt.forward_operands();
        let mut total = 0f64;
        for b in &self.val_batches {
            let mut ops = params.clone();
            ops.push(HostTensor::I32(b.tokens.clone()));
            ops.push(HostTensor::I32(b.targets.clone()));
            let outs = self.rt.execute(&eval, &ops)?;
            total += outs[0].scalar_f32()? as f64;
        }
        Ok((total / self.val_batches.len() as f64) as f32)
    }

    pub fn run(mut self) -> Result<TrainResult> {
        let sw = Stopwatch::start();
        let mut train_losses = Vec::new();
        let mut val_losses = Vec::new();
        for step in 0..self.cfg.steps {
            let loss = self.step(step)?;
            if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
                train_losses.push((step, loss));
                if !self.cfg.quiet {
                    println!(
                        "[{:>8}] step {step:>6} loss {loss:.4} lr {:.5}",
                        self.opt.method().to_string(),
                        lr_at(step, self.cfg.steps, self.cfg.warmup, self.cfg.lr_max)
                    );
                }
            }
            if self.cfg.eval_every > 0
                && (step + 1) % self.cfg.eval_every == 0
                && step + 1 != self.cfg.steps
            {
                let vl = self.evaluate()?;
                val_losses.push((step + 1, vl));
                if !self.cfg.quiet {
                    println!(
                        "[{:>8}] step {:>6} val_loss {vl:.4} ppl {:.2}",
                        self.opt.method().to_string(),
                        step + 1,
                        vl.exp()
                    );
                }
            }
        }
        let final_val = self.evaluate()?;
        val_losses.push((self.cfg.steps, final_val));
        let elapsed = sw.elapsed_s();
        let (svd_count, svd_fraction) =
            self.opt.svd_stats(self.cfg.steps).unwrap_or((0, 0.0));
        Ok(TrainResult {
            method: self.opt.method(),
            train_losses,
            val_losses,
            final_val_loss: final_val,
            final_ppl: final_val.exp(),
            live_bytes: self.opt.live_bytes(),
            svd_count,
            svd_fraction,
            steps_per_sec: self.cfg.steps as f64 / elapsed.max(1e-9),
            sim_history: self.opt.similarity_history().unwrap_or_default(),
            final_params: self.opt.export_flat()?,
        })
    }
}

/// Convenience wrapper: build a trainer from defaults and run it.
pub fn pretrain(man: &Manifest, cfg: TrainConfig) -> Result<TrainResult> {
    Trainer::new(man, cfg)?.run()
}

#[cfg(test)]
mod tests {
    use super::lr_at;

    const TOTAL: u64 = 100;
    const WARMUP: u64 = 10;
    const LR: f32 = 0.02;

    #[test]
    fn lr_ramp_boundary_step_warmup_minus_one() {
        // the last warmup step reaches exactly the peak: (w-1+1)/w == 1
        assert_eq!(lr_at(WARMUP - 1, TOTAL, WARMUP, LR), LR);
        // and the ramp below it is strictly increasing
        for s in 1..WARMUP {
            assert!(lr_at(s, TOTAL, WARMUP, LR) > lr_at(s - 1, TOTAL, WARMUP, LR));
        }
    }

    #[test]
    fn lr_cosine_boundary_step_warmup() {
        // first cosine step: progress 0, cos(0) = 1 -> peak LR (the
        // schedule is continuous across the warmup/cosine seam)
        let at_warmup = lr_at(WARMUP, TOTAL, WARMUP, LR);
        assert_eq!(at_warmup, LR * (0.1 + 0.9 * 1.0));
        assert!((at_warmup - LR).abs() < 1e-6 * LR);
        // and it decays monotonically from there to the end
        for s in (WARMUP + 1)..=TOTAL {
            assert!(lr_at(s, TOTAL, WARMUP, LR) <= lr_at(s - 1, TOTAL, WARMUP, LR));
        }
    }

    #[test]
    fn lr_boundary_step_total_hits_the_floor() {
        // progress 1, cos(pi) = -1 -> 10% of peak (f32 pi is inexact, so
        // compare with a small tolerance)
        let end = lr_at(TOTAL, TOTAL, WARMUP, LR);
        assert!((end - 0.1 * LR).abs() < 1e-4 * LR, "end lr {end}");
    }

    #[test]
    fn lr_beyond_total_holds_the_floor() {
        // clamped progress: the cosine must not walk back up past total
        let end = lr_at(TOTAL, TOTAL, WARMUP, LR);
        assert_eq!(lr_at(TOTAL + 1, TOTAL, WARMUP, LR), end);
        assert_eq!(lr_at(TOTAL + 10_000, TOTAL, WARMUP, LR), end);
    }

    #[test]
    fn lr_degenerate_schedules_never_underflow_or_blow_up() {
        // warmup 0, warmup == total, warmup > total: every step must give
        // a finite LR in (0, lr_max] — the saturating_sub guard in action
        for (total, warmup) in [(50u64, 0u64), (50, 50), (5, 10), (1, 0), (0, 0)] {
            for step in 0..=(total + warmup + 3) {
                let lr = lr_at(step, total, warmup, LR);
                assert!(
                    lr.is_finite() && lr > 0.0 && lr <= LR * 1.0001,
                    "lr_at({step}, {total}, {warmup}) = {lr}"
                );
            }
        }
    }
}
