//! Fine-tuning driver — the GLUE / MMLU substitute.
//!
//! Real downstream suites are unavailable offline, so we build synthetic
//! classification tasks that exercise the identical code path (DESIGN.md §3):
//! each "subject" (label) has its own corpus distribution (distinct Markov
//! affinity salt); a training window is `[label_token, subject text ...]`;
//! accuracy is label-prefix scoring — a held-out text is given once under
//! every label prefix and the model must assign the true label the lowest
//! per-row loss (executed through the `eval_rows_fp` artifact).

use std::path::PathBuf;

use anyhow::{anyhow, ensure, Result};

use super::checkpoint::{self, CheckpointMeta};
use crate::data::{tokenizer::BYTE_BASE, CorpusGenerator, Tokenizer};
use crate::manifest::Manifest;
use crate::optim::{self, BuildOptions, Method, StepCtx};
use crate::runtime::{HostTensor, Runtime};
use crate::util::Pcg32;

#[derive(Clone, Debug)]
pub struct FinetuneConfig {
    pub cfg_name: String,
    pub method: Method,
    /// number of subjects/classes (<= manifest batch size)
    pub n_labels: usize,
    pub steps: u64,
    pub lr: f32,
    pub seed: u64,
    /// distinguishes tasks (GLUE's 8 tasks = 8 salts)
    pub task_salt: u64,
    pub n_eval_examples: usize,
    pub opts: BuildOptions,
    pub quiet: bool,
    /// write the trained adapter/factor delta (QGDC format) here after the
    /// last step — only methods with a frozen/in-place base split support
    /// this (`Optimizer::export_delta`)
    pub save_delta: Option<PathBuf>,
    /// import a previously saved delta before training and continue from
    /// its recorded step.  The synthetic data stream restarts from
    /// `seed` (the bitwise resume guarantee lives in
    /// `coordinator::multijob`, not here).
    pub resume_delta: Option<PathBuf>,
}

impl Default for FinetuneConfig {
    fn default() -> Self {
        FinetuneConfig {
            cfg_name: "llama-tiny".into(),
            method: Method::QGaLore,
            n_labels: 4,
            steps: 60,
            lr: 0.003,
            seed: 0,
            task_salt: 17,
            n_eval_examples: 32,
            opts: BuildOptions::default(),
            quiet: true,
            save_delta: None,
            resume_delta: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct FinetuneResult {
    pub method: Method,
    pub accuracy: f32,
    pub per_label_accuracy: Vec<f32>,
    pub train_losses: Vec<(u64, f32)>,
    pub live_bytes: u64,
}

/// Label prefix token for class `l` (byte-fallback range: always in vocab).
fn label_token(l: usize) -> i32 {
    (BYTE_BASE as usize + 1 + l) as i32
}

/// Training window: every sentence is followed by its label token
/// (`s1 L s2 L ...`), so each window carries ~6 supervised "answer" signals
/// with short attention distance to the signature words — the dense version
/// of the answer-token protocol.  Returns (tokens, targets) of length seq.
fn train_window(
    gen: &CorpusGenerator,
    tok: &Tokenizer,
    rng: &mut Pcg32,
    label: usize,
    seq: usize,
) -> (Vec<i32>, Vec<i32>) {
    let mut ids: Vec<i32> = Vec::with_capacity(2 * seq);
    while ids.len() < seq + 1 {
        let s = gen.labeled_example(rng, label);
        ids.extend(tok.encode(&s).into_iter().map(|t| t as i32));
        ids.push(label_token(label));
    }
    let ids: Vec<i32> = ids.split_off(ids.len() - (seq + 1));
    (ids[..seq].to_vec(), ids[1..].to_vec())
}

/// Eval window: label-free content with a single answer slot at the end
/// (`[subject text ..., label_tok]`).
///
/// The label sits at the *end*, so training teaches p(label | content) and
/// the per-row eval loss between candidate labels differs only at the
/// answer position — the MMLU answer-letter protocol.  Returns
/// `(tokens, targets)` of length `seq` each: tokens = [c_0..c_{S-2}, label],
/// targets = [c_1..c_{S-2}, label, EOS].
fn label_window(
    gen: &CorpusGenerator,
    tok: &Tokenizer,
    rng: &mut Pcg32,
    label: usize,
    seq: usize,
) -> Result<(Vec<i32>, Vec<i32>)> {
    // seq = 0 underflows the fill loop's bound and seq = 1 leaves no
    // content token before the answer slot (content[1..] would panic)
    ensure!(seq >= 2, "label window needs seq >= 2 (content + answer slot), got {seq}");
    let mut content: Vec<i32> = Vec::with_capacity(2 * seq);
    while content.len() < seq - 1 {
        let s = gen.labeled_example(rng, label);
        content.extend(tok.encode(&s).into_iter().map(|t| t as i32));
    }
    // keep the *tail* so the window always ends on a complete sentence —
    // the label-signature clause sits immediately before the answer slot
    let content: Vec<i32> = content.split_off(content.len() - (seq - 1));
    let mut tokens = content.clone();
    tokens.push(label_token(label));
    let mut targets = content[1..].to_vec();
    targets.push(label_token(label));
    targets.push(crate::data::tokenizer::EOS as i32);
    Ok((tokens, targets))
}

/// Index of the smallest per-row loss, NaN-safe.  The old
/// `partial_cmp(..).unwrap()` panicked on any NaN row, and a raw
/// `f32::total_cmp` argmin is no better: negative NaN sorts *below* -inf
/// under total order, so one poisoned row would win every comparison and
/// be scored as the prediction.  NaN rows are excluded instead; returns
/// `None` when the slice is empty or every row is NaN.
pub(crate) fn argmin_loss(losses: &[f32]) -> Option<usize> {
    losses
        .iter()
        .enumerate()
        .filter(|(_, x)| !x.is_nan())
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
}

pub fn finetune(
    man: &Manifest,
    cfg: FinetuneConfig,
    pretrained: &[f32],
) -> Result<FinetuneResult> {
    let entry = man.config(&cfg.cfg_name)?;
    let model = entry.model.clone();
    let batch = man.batch;
    if cfg.n_labels > batch {
        return Err(anyhow!("n_labels {} exceeds artifact batch {batch}", cfg.n_labels));
    }
    let seq = model.max_seq_len;

    // Tokenizer vocabulary from a mixed corpus of all labels.
    let gen = CorpusGenerator::new(cfg.task_salt);
    let mut rng = Pcg32::new(cfg.seed, cfg.task_salt);
    let mut docs = Vec::new();
    for _ in 0..64 {
        for l in 0..cfg.n_labels {
            docs.push(gen.labeled_example(&mut rng, l));
        }
    }
    let tok = Tokenizer::train(&docs, model.vocab_size);

    // Classification-head init: label tokens are byte-fallback ids that
    // never occur in the pre-training corpus, so their (tied) embedding
    // rows are untrained noise.  Give them distinct, well-scaled directions
    // before fine-tuning — the standard "init the answer head" step, applied
    // identically for every method (critical for LoRA/QLoRA, whose frozen
    // base could otherwise never separate the answer logits).
    let mut init = pretrained.to_vec();
    {
        let dim = model.dim;
        // mean row norm of the trained embedding = target scale
        let emb = &pretrained[..model.vocab_size * dim];
        let mean_norm: f32 = emb
            .chunks(dim)
            .map(|r| r.iter().map(|x| x * x).sum::<f32>().sqrt())
            .sum::<f32>()
            / model.vocab_size as f32;
        let mut hrng = Pcg32::new(cfg.task_salt ^ 0x4ead, 7);
        for l in 0..cfg.n_labels {
            let row = label_token(l) as usize;
            let v = hrng.normal_vec(dim, 0.0, 1.0);
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            for (j, x) in v.iter().enumerate() {
                init[row * dim + j] = x / norm * mean_norm;
            }
        }
    }
    let mut opt =
        optim::build_with_init(cfg.method, man, &cfg.cfg_name, &init, cfg.opts)?;
    let rt = Runtime::new()?;
    let fwd = entry
        .artifacts
        .get(opt.fwd_artifact())
        .ok_or_else(|| anyhow!("missing artifact {}", opt.fwd_artifact()))?
        .clone();

    // ---- optional delta resume ----
    let mut start_step = 0u64;
    if let Some(path) = &cfg.resume_delta {
        let ckpt = checkpoint::load_delta(path)?;
        ensure!(
            ckpt.meta.cfg_name == cfg.cfg_name,
            "delta checkpoint is for config {:?}, this run uses {:?}",
            ckpt.meta.cfg_name,
            cfg.cfg_name
        );
        ensure!(
            ckpt.meta.method == cfg.method.to_string(),
            "delta checkpoint was trained with {}, this run uses {}",
            ckpt.meta.method,
            cfg.method
        );
        opt.import_delta(checkpoint::tensors_from_delta(&ckpt)?)?;
        start_step = ckpt.meta.step.min(cfg.steps);
        if !cfg.quiet {
            println!(
                "[ft {:>8}] resumed delta {} at step {start_step}",
                cfg.method.to_string(),
                path.display()
            );
        }
    }

    // ---- fine-tune loop ----
    let mut train_losses = Vec::new();
    for step in start_step..cfg.steps {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for bi in 0..batch {
            let label = bi % cfg.n_labels;
            let (t, g) = train_window(&gen, &tok, &mut rng, label, seq);
            tokens.extend(t);
            targets.extend(g);
        }
        let mut ops = opt.forward_operands();
        ops.push(HostTensor::I32(tokens));
        ops.push(HostTensor::I32(targets));
        let mut outs = rt.execute(&fwd, &ops)?;
        let grads = outs.split_off(1);
        let loss = outs.pop().unwrap().scalar_f32()?;
        if step % 10 == 0 || step + 1 == cfg.steps {
            train_losses.push((step, loss));
            if !cfg.quiet {
                println!("[ft {:>8}] step {step:>4} loss {loss:.4}", cfg.method.to_string());
            }
        }
        let ctx = StepCtx { rt: &rt, man, step: step + 1, lr: cfg.lr };
        opt.apply_update(&ctx, grads)?;
        opt.on_step_end(&ctx)?;
    }

    // ---- optional delta save (before eval, so eval failures cannot lose
    // the trained state) ----
    if let Some(path) = &cfg.save_delta {
        let meta = CheckpointMeta {
            cfg_name: cfg.cfg_name.clone(),
            method: cfg.method.to_string(),
            step: cfg.steps,
            val_loss: train_losses.last().map(|&(_, l)| l).unwrap_or(0.0),
        };
        let ckpt = checkpoint::delta_from_tensors(meta, &opt.export_delta()?);
        checkpoint::save_delta(path, &ckpt)?;
        if !cfg.quiet {
            println!(
                "[ft {:>8}] saved delta {} ({} bytes)",
                cfg.method.to_string(),
                path.display(),
                ckpt.payload_bytes()
            );
        }
    }

    // ---- accuracy eval: label-prefix scoring over exported params ----
    let flat = opt.export_flat()?;
    let rows = entry
        .artifacts
        .get("eval_rows_fp")
        .ok_or_else(|| anyhow!("missing eval_rows_fp artifact"))?
        .clone();
    // split flat into ABI operand list for the fp artifact
    let mut param_ops = Vec::new();
    {
        let mut off = 0usize;
        for (_, shape) in entry.fp_params.iter().chain(entry.linear_params.iter()) {
            let n: usize = shape.iter().product();
            param_ops.push(HostTensor::F32(flat[off..off + n].to_vec()));
            off += n;
        }
        assert_eq!(off, flat.len());
    }

    let mut eval_rng = Pcg32::new(cfg.seed ^ 0xea71u64, cfg.task_salt);
    let mut correct = vec![0usize; cfg.n_labels];
    let mut total = vec![0usize; cfg.n_labels];
    for ex in 0..cfg.n_eval_examples {
        let true_label = ex % cfg.n_labels;
        // held-out content generated under the true label
        let (content_tokens, content_targets) =
            label_window(&gen, &tok, &mut eval_rng, true_label, seq)?;
        // batch: identical content, each row scored under candidate label j
        // (tokens/targets differ only at the answer slot, so argmin of the
        // per-row loss is argmax p(label_j | content))
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for bi in 0..batch {
            let cand = label_token(bi % cfg.n_labels);
            let mut t = content_tokens.clone();
            *t.last_mut().unwrap() = cand;
            let mut g = content_targets.clone();
            g[seq - 2] = cand;
            tokens.extend(t);
            targets.extend(g);
        }
        let mut ops = param_ops.clone();
        ops.push(HostTensor::I32(tokens));
        ops.push(HostTensor::I32(targets));
        let outs = rt.execute(&rows, &ops)?;
        let losses = outs[0].as_f32()?.to_vec();
        if !cfg.quiet && ex < 6 {
            println!(
                "[ft eval] ex {ex} true {true_label} row losses {:?}",
                &losses[..cfg.n_labels]
            );
        }
        let pred = argmin_loss(&losses[..cfg.n_labels]).ok_or_else(|| {
            anyhow!("eval example {ex}: every candidate-row loss is NaN")
        })?;
        total[true_label] += 1;
        if pred == true_label {
            correct[true_label] += 1;
        }
    }
    let per_label: Vec<f32> = correct
        .iter()
        .zip(&total)
        .map(|(&c, &t)| if t == 0 { 0.0 } else { c as f32 / t as f32 })
        .collect();
    let accuracy = correct.iter().sum::<usize>() as f32
        / total.iter().sum::<usize>().max(1) as f32;

    Ok(FinetuneResult {
        method: cfg.method,
        accuracy,
        per_label_accuracy: per_label,
        train_losses,
        live_bytes: opt.live_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_is_nan_safe() {
        assert_eq!(argmin_loss(&[3.0, 1.0, 2.0]), Some(1));
        assert_eq!(argmin_loss(&[f32::NAN, 1.0, 0.5]), Some(2));
        // negative NaN sorts below -inf under total order; it must still lose
        assert_eq!(argmin_loss(&[-f32::NAN, 7.0]), Some(1));
        assert_eq!(argmin_loss(&[f32::NEG_INFINITY, 0.0]), Some(0));
        assert_eq!(argmin_loss(&[f32::NAN, f32::NAN]), None);
        assert_eq!(argmin_loss(&[]), None);
    }

    #[test]
    fn label_window_rejects_degenerate_seq() {
        let gen = CorpusGenerator::new(3);
        let mut rng = Pcg32::new(1, 2);
        let docs: Vec<String> = (0..16).map(|_| gen.labeled_example(&mut rng, 0)).collect();
        let tok = Tokenizer::train(&docs, 64);
        for seq in [0usize, 1] {
            let err = label_window(&gen, &tok, &mut rng, 0, seq).unwrap_err();
            assert!(err.to_string().contains("seq >= 2"), "seq {seq}: {err}");
        }
        let (t, g) = label_window(&gen, &tok, &mut rng, 0, 8).unwrap();
        assert_eq!((t.len(), g.len()), (8, 8));
        assert_eq!(*t.last().unwrap(), label_token(0));
        assert_eq!(g[6], label_token(0));
    }
}
