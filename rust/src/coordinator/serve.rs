//! Forward-only batched scoring/generation serving engine.
//!
//! The second consumer of the quantized microkernel beyond training: an
//! INT8 base model (embedding + square tanh-MLP layers, the same family
//! `finetune`/`multijob` train) loaded from a [`super::checkpoint`] file
//! (or synthesized from a seed), optionally specialized by a per-user
//! `QGDC` delta (the INT4 projection + low-rank factor pair
//! `coordinator::multijob` exports), answering two request kinds:
//!
//! * **Score** — `finetune.rs`'s label-prefix protocol: run the content
//!   tokens, read the logits of the label-prefix tokens, return per-label
//!   NLL and the argmin prediction.
//! * **Generate** — greedy decoding: run the prompt, then repeatedly emit
//!   the argmax token and feed it back, `max_new` times.
//!
//! # Request lifecycle
//!
//! `serve_batch` validates every request up front (fail the batch loudly,
//! never partially), **coalesces** requests into shape-uniform waves
//! (same kind + same token length + same decode budget — the shapes the
//! batched matmuls need), builds one [`StepGraphBuilder`] DAG with a
//! node chain per wave (prefill → readout for scoring; prefill → one
//! node per decode step → readout for generation), and runs the whole
//! graph on the shared [`WorkerPool`].  Waves race each other; inside a
//! wave the chain is sequential.  Responses come back in submission
//! order regardless of wave assignment.
//!
//! # Determinism contract (serving extension)
//!
//! A request's scores/tokens are **bitwise identical** served alone vs
//! batched among N strangers, at any worker count, under hostile steal
//! seeds.  This holds by construction: batching only widens the
//! activation matrix with more *columns*, and every kernel in the path
//! computes each output element from its own row and column with a fixed
//! ascending-k accumulation — neighboring columns never mix.  All
//! per-request readouts (embedding gather, log-sum-exp, argmax, NLL) are
//! per-column loops.  `tests/serve.rs` pins batched-vs-solo parity
//! across worker counts and steal seeds.
//!
//! Forward matmuls route through the PR-7 prepacked panel cache
//! ([`PanelCache`]): the base weights and any delta projection are
//! packed **once at load time**, so steady-state serving never decodes a
//! quantization code (when [`pack_cache_enabled`]; the fused fallback is
//! bitwise identical).

use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use super::checkpoint::{self, CheckpointMeta, DeltaCheckpoint, SectionData};
use super::finetune::argmin_loss;
use crate::data::tokenizer::BYTE_BASE;
use crate::linalg::{pack_cache_enabled, Mat, PanelCache, ParallelCtx, WorkerPool};
use crate::optim::StepGraphBuilder;
use crate::quant::{self, Quant4Tensor, QuantTensor};
use crate::util::Pcg32;

/// Label prefix token for class `l` — the same byte-fallback slot
/// `finetune`'s training windows use, so served scores line up with
/// fine-tuned checkpoints.
pub fn label_token(l: usize) -> u32 {
    BYTE_BASE + 1 + l as u32
}

/// Shape of the served model.  Must match the checkpoint it loads.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    /// Seed for [`ServeModel::from_seed`] (ignored on the checkpoint path).
    pub seed: u64,
}

impl ServeConfig {
    /// Parameter count of the flat weight vector this config expects.
    pub fn n_params(&self) -> usize {
        self.vocab * self.dim + self.n_layers * self.dim * self.dim
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.dim >= 1, "serve config: dim must be >= 1");
        ensure!(self.n_layers >= 1, "serve config: n_layers must be >= 1");
        ensure!(
            self.vocab > (BYTE_BASE + 1) as usize,
            "serve config: vocab {} leaves no room for label tokens (need > {})",
            self.vocab,
            BYTE_BASE + 1
        );
        for (what, numel) in [
            ("vocab*dim embedding", self.vocab * self.dim),
            ("dim*dim layer", self.dim * self.dim),
        ] {
            ensure!(
                numel <= 256 || numel % 256 == 0,
                "serve config: {what} ({numel} values) must be <= 256 or a \
                 multiple of 256 (blockwise quantization constraint)"
            );
        }
        Ok(())
    }
}

/// A per-user low-rank delta for one layer: the INT4 up-projection `P`
/// `(dim, rank)` and the f32 low-rank factor `L` `(rank, dim)`, applied
/// as `y += P (L z)` — exactly the factorization `multijob` trains.
struct LayerDelta {
    p4: Quant4Tensor,
    rank: usize,
    pack: PanelCache,
    l: Mat,
}

impl LayerDelta {
    /// `P @ lz` with the prepacked fast path and fused fallback.
    fn apply_up(&self, d: usize, lz: &Mat, ctx: ParallelCtx) -> Mat {
        match self.pack.get().filter(|pk| pk.matches4(&self.p4, d, self.rank)) {
            Some(pk) => quant::dequant4_matmul_prepacked(&self.p4, pk, d, self.rank, lz, ctx),
            None => quant::dequant4_matmul(&self.p4, d, self.rank, lz, ctx),
        }
    }
}

/// One frozen INT8 base layer plus its optional per-user delta.
struct ServeLayer {
    w0q: QuantTensor,
    pack: PanelCache,
    delta: Option<LayerDelta>,
}

impl ServeLayer {
    /// `dequant(W0) @ z` with the prepacked fast path and fused fallback.
    fn forward_base(&self, z: &Mat, d: usize, ctx: ParallelCtx) -> Mat {
        match self.pack.get().filter(|pk| pk.matches8(&self.w0q, d, d)) {
            Some(pk) => quant::dequant8_matmul_prepacked(&self.w0q, pk, d, d, z, ctx),
            None => quant::dequant8_matmul(&self.w0q, d, d, z, ctx),
        }
    }
}

/// A loaded, quantized, prepacked model ready to serve.  Immutable after
/// load (`apply_delta` is part of loading), so waves share it freely.
pub struct ServeModel {
    cfg: ServeConfig,
    /// `(vocab, dim)` tied embedding/readout matrix, blockwise INT8.
    emb: QuantTensor,
    emb_pack: PanelCache,
    layers: Vec<ServeLayer>,
}

impl ServeModel {
    pub fn cfg(&self) -> ServeConfig {
        self.cfg
    }

    /// Quantize a flat f32 parameter vector (embedding first, then each
    /// layer) into a served model, packing panels once if the pack cache
    /// is enabled.
    pub fn from_flat(cfg: ServeConfig, w: &[f32]) -> Result<Self> {
        cfg.validate()?;
        let want = cfg.n_params();
        ensure!(
            w.len() == want,
            "flat weights: {} values for a config wanting {want} \
             (vocab {} x dim {} + {} layers x dim^2)",
            w.len(),
            cfg.vocab,
            cfg.dim,
            cfg.n_layers
        );
        let (v, d) = (cfg.vocab, cfg.dim);
        let emb = quant::quantize(&w[..v * d], 8);
        let mut emb_pack = PanelCache::empty();
        if pack_cache_enabled() {
            emb_pack.get_or_pack8(&emb, v, d);
        }
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let off = v * d + i * d * d;
            let w0q = quant::quantize(&w[off..off + d * d], 8);
            let mut pack = PanelCache::empty();
            if pack_cache_enabled() {
                pack.get_or_pack8(&w0q, d, d);
            }
            layers.push(ServeLayer { w0q, pack, delta: None });
        }
        Ok(ServeModel { cfg, emb, emb_pack, layers })
    }

    /// A reproducible synthetic model (benches, tests, demo serving).
    pub fn from_seed(cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        let mut rng = Pcg32::new(cfg.seed, 0x5e4e);
        let scale = 1.0 / (cfg.dim as f32).sqrt();
        let w = rng.normal_vec(cfg.n_params(), 0.0, scale);
        Self::from_flat(cfg, &w)
    }

    /// Load base weights from a [`super::checkpoint`] file.
    pub fn from_checkpoint(
        path: impl AsRef<Path>,
        cfg: ServeConfig,
    ) -> Result<(Self, CheckpointMeta)> {
        let (params, meta) = checkpoint::load(path)?;
        let model = Self::from_flat(cfg, &params)?;
        Ok((model, meta))
    }

    /// Apply a per-user `QGDC` delta (the format `multijob::export_delta`
    /// writes): per layer, the INT4 projection `P (dim, rank)` and the
    /// low-rank factor `L (rank, dim)`.  Layers the job never refreshed
    /// (`has_proj == 0`) stay base-only.  Shape mismatches fail loudly —
    /// a delta trained against a different base must never be served.
    pub fn apply_delta(&mut self, ckpt: &DeltaCheckpoint) -> Result<()> {
        fn u64s(ck: &DeltaCheckpoint, name: &str) -> Result<Vec<u64>> {
            match &ck.section(name)?.data {
                SectionData::U64(v) => Ok(v.clone()),
                other => bail!("section {name:?}: expected u64 data, got {other:?}"),
            }
        }
        fn f32s(ck: &DeltaCheckpoint, name: &str) -> Result<Vec<f32>> {
            match &ck.section(name)?.data {
                SectionData::F32(v) => Ok(v.clone()),
                other => bail!("section {name:?}: expected f32 data, got {other:?}"),
            }
        }
        let d = self.cfg.dim;
        let jobv = u64s(ckpt, "job")?;
        ensure!(jobv.len() == 5, "job section has {} fields, want 5", jobv.len());
        let rank = jobv[4] as usize;
        ensure!(
            ckpt.section(&format!("layer{}.meta", self.cfg.n_layers)).is_err(),
            "delta has more layers than the serve model's {}",
            self.cfg.n_layers
        );
        let mut deltas = Vec::with_capacity(self.layers.len());
        for i in 0..self.layers.len() {
            let meta = u64s(ckpt, &format!("layer{i}.meta"))?;
            ensure!(meta.len() == 4, "layer{i}.meta wants 4 fields");
            let (m, n, r) = (meta[0] as usize, meta[1] as usize, meta[2] as usize);
            ensure!(m == d && n == d, "layer{i}: delta trained for ({m}, {n}), serve dim is {d}");
            ensure!(r == rank, "layer{i}: rank {r} disagrees with job rank {rank}");
            if meta[3] == 0 {
                deltas.push(None);
                continue;
            }
            let lsec = ckpt.section(&format!("layer{i}.lowrank"))?;
            ensure!(
                lsec.shape == [r, d],
                "layer{i}.lowrank shape {:?}, want [{r}, {d}]",
                lsec.shape
            );
            let ldata = match &lsec.data {
                SectionData::F32(v) => v.clone(),
                other => bail!("layer{i}.lowrank: expected f32 data, got {other:?}"),
            };
            let l = Mat::from_vec(r, d, ldata);
            let packed = match &ckpt.section(&format!("layer{i}.proj.packed"))?.data {
                SectionData::U8(v) => v.clone(),
                other => bail!("layer{i}.proj.packed: expected u8 data, got {other:?}"),
            };
            let scale = f32s(ckpt, &format!("layer{i}.proj.scale"))?;
            let zero = f32s(ckpt, &format!("layer{i}.proj.zero"))?;
            let pmeta = u64s(ckpt, &format!("layer{i}.proj.meta"))?;
            ensure!(pmeta.len() == 2, "layer{i}.proj.meta wants 2 fields");
            let numel = pmeta[1] as usize;
            ensure!(numel == d * r, "layer{i}: projection numel {numel}, want d*r = {}", d * r);
            let p4 = Quant4Tensor::from_parts(packed, scale, zero, pmeta[0] as usize, numel)?;
            let mut pack = PanelCache::empty();
            if pack_cache_enabled() {
                pack.get_or_pack4(&p4, d, r);
            }
            deltas.push(Some(LayerDelta { p4, rank: r, pack, l }));
        }
        for (layer, delta) in self.layers.iter_mut().zip(deltas) {
            layer.delta = delta;
        }
        Ok(())
    }

    /// Whether any layer carries a per-user delta.
    pub fn has_delta(&self) -> bool {
        self.layers.iter().any(|l| l.delta.is_some())
    }

    /// Quantized storage held by the frozen base (codes + block params +
    /// panel packs).
    pub fn base_bytes(&self) -> usize {
        let packs = |c: &PanelCache| c.get().map_or(0, |p| p.pack_bytes());
        self.emb.storage_bytes()
            + packs(&self.emb_pack)
            + self
                .layers
                .iter()
                .map(|l| l.w0q.storage_bytes() + packs(&l.pack))
                .sum::<usize>()
    }

    /// Storage held by the applied delta (zero when serving base-only).
    pub fn delta_bytes(&self) -> usize {
        self.layers
            .iter()
            .filter_map(|l| l.delta.as_ref())
            .map(|dl| {
                dl.p4.storage_bytes()
                    + dl.l.data.len() * std::mem::size_of::<f32>()
                    + dl.pack.get().map_or(0, |p| p.pack_bytes())
            })
            .sum()
    }

    /// One recurrent step over a batch: add each stream's token embedding
    /// to its own column, then run every layer (`tanh(W0 z [+ P L z])`).
    /// Columns never mix, so a column's values are independent of the
    /// batch it rides in — the serving determinism contract.
    pub fn step_tokens(&self, h: &Mat, toks: &[u32], ctx: ParallelCtx) -> Mat {
        let d = self.cfg.dim;
        let bsz = h.cols;
        assert_eq!(h.rows, d, "step_tokens: hidden state has {} rows, want {d}", h.rows);
        assert_eq!(toks.len(), bsz, "step_tokens: {} tokens for batch {bsz}", toks.len());
        let mut z = h.clone();
        for (col, &tk) in toks.iter().enumerate() {
            let base = tk as usize * d;
            for j in 0..d {
                z.data[j * bsz + col] += self.emb.dequant_at(base + j);
            }
        }
        for layer in &self.layers {
            let mut y = layer.forward_base(&z, d, ctx);
            if let Some(delta) = &layer.delta {
                let lz = delta.l.matmul_with(&z, ctx);
                let pz = delta.apply_up(d, &lz, ctx);
                for (yv, pv) in y.data.iter_mut().zip(&pz.data) {
                    *yv += *pv;
                }
            }
            for v in y.data.iter_mut() {
                *v = v.tanh();
            }
            z = y;
        }
        z
    }

    /// Run a shape-uniform wave of token streams from the zero state;
    /// returns the final hidden state `(dim, streams.len())`.
    pub fn prefill(&self, streams: &[&[u32]], ctx: ParallelCtx) -> Mat {
        assert!(!streams.is_empty(), "prefill: empty wave");
        let len = streams[0].len();
        assert!(len > 0, "prefill: empty stream");
        assert!(
            streams.iter().all(|s| s.len() == len),
            "prefill: wave streams must be shape-uniform"
        );
        let mut h = Mat::zeros(self.cfg.dim, streams.len());
        let mut toks = vec![0u32; streams.len()];
        for t in 0..len {
            for (col, s) in streams.iter().enumerate() {
                toks[col] = s[t];
            }
            h = self.step_tokens(&h, &toks, ctx);
        }
        h
    }

    /// Readout logits `(vocab, batch)` through the tied embedding.
    pub fn logits(&self, h: &Mat, ctx: ParallelCtx) -> Mat {
        let (v, d) = (self.cfg.vocab, self.cfg.dim);
        match self.emb_pack.get().filter(|pk| pk.matches8(&self.emb, v, d)) {
            Some(pk) => quant::dequant8_matmul_prepacked(&self.emb, pk, v, d, h, ctx),
            None => quant::dequant8_matmul(&self.emb, v, d, h, ctx),
        }
    }

    /// Label-prefix scoring readout for one batch column: per-label NLL
    /// (`lse − logit(label_token)`) and the NaN-safe argmin prediction.
    pub fn score_readout(
        &self,
        logits: &Mat,
        col: usize,
        labels: usize,
    ) -> (Vec<f32>, Option<usize>) {
        let lse = column_lse(logits, col);
        let bsz = logits.cols;
        let nll: Vec<f32> = (0..labels)
            .map(|l| lse - logits.data[label_token(l) as usize * bsz + col])
            .collect();
        let pred = argmin_loss(&nll);
        (nll, pred)
    }
}

/// Per-column log-sum-exp (max-shifted, ascending-row accumulation — one
/// fixed order, so batched equals solo bitwise).
fn column_lse(logits: &Mat, col: usize) -> f32 {
    let bsz = logits.cols;
    let mut mx = f32::NEG_INFINITY;
    for r in 0..logits.rows {
        mx = mx.max(logits.data[r * bsz + col]);
    }
    let mut s = 0f32;
    for r in 0..logits.rows {
        s += (logits.data[r * bsz + col] - mx).exp();
    }
    mx + s.ln()
}

/// Greedy token for one batch column: strict `>` scan, so ties go to the
/// lowest token id — deterministic at any batch width.
fn argmax_col(logits: &Mat, col: usize) -> u32 {
    let bsz = logits.cols;
    let mut best = 0usize;
    let mut bestv = logits.data[col];
    for r in 1..logits.rows {
        let v = logits.data[r * bsz + col];
        if v > bestv {
            best = r;
            bestv = v;
        }
    }
    best as u32
}

/// One serving request.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeRequest {
    /// Label-prefix scoring over `content`, reading `labels` classes.
    Score { content: Vec<u32>, labels: usize },
    /// Greedy generation: run `prompt`, then emit `max_new` tokens.
    Generate { prompt: Vec<u32>, max_new: usize },
}

/// The response to a [`ServeRequest`], same variant.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeResponse {
    Score { nll: Vec<f32>, pred: Option<usize> },
    Generate { tokens: Vec<u32> },
}

/// Coalescing key: requests sharing a key run as columns of one wave.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WaveKey {
    Score { len: usize },
    Generate { len: usize, max_new: usize },
}

fn wave_key(req: &ServeRequest) -> WaveKey {
    match req {
        ServeRequest::Score { content, .. } => WaveKey::Score { len: content.len() },
        ServeRequest::Generate { prompt, max_new } => {
            WaveKey::Generate { len: prompt.len(), max_new: *max_new }
        }
    }
}

/// Group request indices into shape-uniform waves, first-seen order.
/// Inside a wave, members keep submission order (they become columns in
/// that order — stable, so responses are reproducible).
fn coalesce(reqs: &[ServeRequest]) -> Vec<(WaveKey, Vec<usize>)> {
    let mut waves: Vec<(WaveKey, Vec<usize>)> = Vec::new();
    for (i, r) in reqs.iter().enumerate() {
        let k = wave_key(r);
        match waves.iter_mut().find(|(wk, _)| *wk == k) {
            Some((_, members)) => members.push(i),
            None => waves.push((k, vec![i])),
        }
    }
    waves
}

/// In-flight decode state for one generation wave.
struct GenState {
    h: Mat,
    out: Vec<Vec<u32>>,
}

/// Response plus completion latency (ms from batch start), per request.
type OutSlot = Mutex<Option<(ServeResponse, f64)>>;

/// The batched serving engine: a loaded model plus the parallelism
/// context its kernels run with.
pub struct ServeEngine {
    model: ServeModel,
    ctx: ParallelCtx,
}

impl ServeEngine {
    pub fn new(model: ServeModel, ctx: ParallelCtx) -> Self {
        ServeEngine { model, ctx }
    }

    pub fn model(&self) -> &ServeModel {
        &self.model
    }

    fn validate(&self, i: usize, req: &ServeRequest) -> Result<()> {
        let vocab = self.model.cfg.vocab;
        match req {
            ServeRequest::Score { content, labels } => {
                ensure!(!content.is_empty(), "request {i}: empty content");
                ensure!(*labels >= 1, "request {i}: need at least one label");
                let top = label_token(*labels - 1);
                ensure!(
                    (top as usize) < vocab,
                    "request {i}: label {} maps to token {top}, outside vocab {vocab}",
                    *labels - 1
                );
                for &tk in content {
                    ensure!((tk as usize) < vocab, "request {i}: token {tk} outside vocab {vocab}");
                }
            }
            ServeRequest::Generate { prompt, max_new } => {
                ensure!(!prompt.is_empty(), "request {i}: empty prompt");
                ensure!(*max_new >= 1, "request {i}: max_new must be >= 1");
                for &tk in prompt {
                    ensure!((tk as usize) < vocab, "request {i}: token {tk} outside vocab {vocab}");
                }
            }
        }
        Ok(())
    }

    /// Serve a single request, solo — the reference the batched path must
    /// match bitwise.
    pub fn serve_one(&self, req: &ServeRequest) -> Result<ServeResponse> {
        self.validate(0, req)?;
        match req {
            ServeRequest::Score { content, labels } => {
                let h = self.model.prefill(&[content.as_slice()], self.ctx);
                let logits = self.model.logits(&h, self.ctx);
                let (nll, pred) = self.model.score_readout(&logits, 0, *labels);
                Ok(ServeResponse::Score { nll, pred })
            }
            ServeRequest::Generate { prompt, max_new } => {
                let mut h = self.model.prefill(&[prompt.as_slice()], self.ctx);
                let mut tokens = Vec::with_capacity(*max_new);
                for t in 0..*max_new {
                    let logits = self.model.logits(&h, self.ctx);
                    let tk = argmax_col(&logits, 0);
                    tokens.push(tk);
                    if t + 1 < *max_new {
                        h = self.model.step_tokens(&h, &[tk], self.ctx);
                    }
                }
                Ok(ServeResponse::Generate { tokens })
            }
        }
    }

    /// Serve requests one at a time (no batching, no graph) — the solo
    /// baseline for parity tests and benches.
    pub fn serve_sequential(&self, reqs: &[ServeRequest]) -> Result<Vec<ServeResponse>> {
        reqs.iter().map(|r| self.serve_one(r)).collect()
    }

    /// Batched serving: responses in submission order.
    pub fn serve_batch(
        &self,
        reqs: &[ServeRequest],
        pool: &WorkerPool,
    ) -> Result<Vec<ServeResponse>> {
        Ok(self.serve_batch_timed(reqs, pool)?.0)
    }

    /// Batched serving, also reporting each request's completion latency
    /// in ms from batch start (its wave's finish time).  Latencies are
    /// wall-clock and NOT part of the determinism contract; responses are.
    pub fn serve_batch_timed(
        &self,
        reqs: &[ServeRequest],
        pool: &WorkerPool,
    ) -> Result<(Vec<ServeResponse>, Vec<f64>)> {
        for (i, r) in reqs.iter().enumerate() {
            self.validate(i, r)?;
        }
        let waves = coalesce(reqs);
        let ctx = self.ctx;
        let model = &self.model;
        let out_slots: Vec<OutSlot> = reqs.iter().map(|_| Mutex::new(None)).collect();
        // Per-wave relay slots; allocated up front so node closures can
        // borrow them for the whole graph's lifetime.
        let relays: Vec<Mutex<Option<Mat>>> = (0..waves.len()).map(|_| Mutex::new(None)).collect();
        let gen_states: Vec<Mutex<Option<GenState>>> =
            (0..waves.len()).map(|_| Mutex::new(None)).collect();

        let t0 = Instant::now();
        let mut b = StepGraphBuilder::new();
        for (wi, (key, members)) in waves.iter().enumerate() {
            match *key {
                WaveKey::Score { .. } => {
                    let streams: Vec<&[u32]> = members
                        .iter()
                        .map(|&ri| match &reqs[ri] {
                            ServeRequest::Score { content, .. } => content.as_slice(),
                            _ => unreachable!("score wave holds score requests"),
                        })
                        .collect();
                    let relay = &relays[wi];
                    let prefill = b.node(&[], move || {
                        *relay.lock().unwrap() = Some(model.prefill(&streams, ctx));
                    });
                    let members = members.clone();
                    let out = &out_slots;
                    b.node(&[prefill], move || {
                        let h = relay.lock().unwrap().take().unwrap();
                        let logits = model.logits(&h, ctx);
                        let ms = t0.elapsed().as_secs_f64() * 1e3;
                        for (col, &ri) in members.iter().enumerate() {
                            let labels = match &reqs[ri] {
                                ServeRequest::Score { labels, .. } => *labels,
                                _ => unreachable!("score wave holds score requests"),
                            };
                            let (nll, pred) = model.score_readout(&logits, col, labels);
                            *out[ri].lock().unwrap() =
                                Some((ServeResponse::Score { nll, pred }, ms));
                        }
                    });
                }
                WaveKey::Generate { max_new, .. } => {
                    let prompts: Vec<&[u32]> = members
                        .iter()
                        .map(|&ri| match &reqs[ri] {
                            ServeRequest::Generate { prompt, .. } => prompt.as_slice(),
                            _ => unreachable!("generate wave holds generate requests"),
                        })
                        .collect();
                    let bsz = members.len();
                    let state = &gen_states[wi];
                    let mut prev = b.node(&[], move || {
                        let h = model.prefill(&prompts, ctx);
                        *state.lock().unwrap() =
                            Some(GenState { h, out: vec![Vec::new(); bsz] });
                    });
                    for t in 0..max_new {
                        let last = t + 1 == max_new;
                        prev = b.node(&[prev], move || {
                            let mut st = state.lock().unwrap().take().unwrap();
                            let logits = model.logits(&st.h, ctx);
                            let toks: Vec<u32> =
                                (0..st.out.len()).map(|col| argmax_col(&logits, col)).collect();
                            for (col, &tk) in toks.iter().enumerate() {
                                st.out[col].push(tk);
                            }
                            if !last {
                                st.h = model.step_tokens(&st.h, &toks, ctx);
                            }
                            *state.lock().unwrap() = Some(st);
                        });
                    }
                    let members = members.clone();
                    let out = &out_slots;
                    b.node(&[prev], move || {
                        let st = state.lock().unwrap().take().unwrap();
                        let ms = t0.elapsed().as_secs_f64() * 1e3;
                        for (col, &ri) in members.iter().enumerate() {
                            *out[ri].lock().unwrap() =
                                Some((ServeResponse::Generate { tokens: st.out[col].clone() }, ms));
                        }
                    });
                }
            }
        }
        b.run(pool)?;

        let mut responses = Vec::with_capacity(reqs.len());
        let mut latencies = Vec::with_capacity(reqs.len());
        for (i, slot) in out_slots.into_iter().enumerate() {
            let (resp, ms) = slot
                .into_inner()
                .unwrap()
                .ok_or_else(|| anyhow!("request {i} left unserved (graph node skipped)"))?;
            responses.push(resp);
            latencies.push(ms);
        }
        Ok((responses, latencies))
    }
}

/// Nearest-rank percentile (`p` in 0..=100) over unsorted samples; NaN
/// for an empty slice.  Shared by the serve bench and the CLI report.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// A reproducible mixed request stream (3 scoring : 1 generation, a few
/// distinct shapes so coalescing always has multiple waves to build).
pub fn synth_requests(vocab: usize, n: usize, seed: u64) -> Vec<ServeRequest> {
    assert!(vocab > label_token(3) as usize, "synth_requests wants room for 4 labels");
    let mut rng = Pcg32::new(seed, 0x5eed);
    (0..n)
        .map(|i| {
            if i % 4 == 3 {
                let plen = if (i / 4) % 2 == 0 { 4 } else { 8 };
                let prompt = (0..plen).map(|_| rng.below(vocab) as u32).collect();
                ServeRequest::Generate { prompt, max_new: 6 }
            } else {
                let len = [6, 10, 14][i % 3];
                let content = (0..len).map(|_| rng.below(vocab) as u32).collect();
                ServeRequest::Score { content, labels: 4 }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> ServeModel {
        ServeModel::from_seed(ServeConfig { vocab: 8, dim: 4, n_layers: 2, seed: 7 }).unwrap()
    }

    #[test]
    fn coalesce_groups_by_shape_first_seen() {
        let reqs = vec![
            ServeRequest::Score { content: vec![1, 2], labels: 2 },
            ServeRequest::Generate { prompt: vec![1], max_new: 3 },
            ServeRequest::Score { content: vec![3, 4], labels: 4 },
            ServeRequest::Score { content: vec![1, 2, 3], labels: 2 },
            ServeRequest::Generate { prompt: vec![2], max_new: 3 },
            ServeRequest::Generate { prompt: vec![2], max_new: 4 },
        ];
        let waves = coalesce(&reqs);
        assert_eq!(waves.len(), 4);
        assert_eq!(waves[0], (WaveKey::Score { len: 2 }, vec![0, 2]));
        assert_eq!(waves[1], (WaveKey::Generate { len: 1, max_new: 3 }, vec![1, 4]));
        assert_eq!(waves[2], (WaveKey::Score { len: 3 }, vec![3]));
        assert_eq!(waves[3], (WaveKey::Generate { len: 1, max_new: 4 }, vec![5]));
    }

    #[test]
    fn invalid_configs_and_requests_are_rejected() {
        // vocab*dim = 300: neither <= 256 nor a multiple of 256
        assert!(ServeModel::from_seed(ServeConfig { vocab: 75, dim: 4, n_layers: 1, seed: 1 })
            .is_err());
        // no room for even one label token
        assert!(ServeModel::from_seed(ServeConfig { vocab: 4, dim: 4, n_layers: 1, seed: 1 })
            .is_err());
        // flat length mismatch
        assert!(ServeModel::from_flat(
            ServeConfig { vocab: 8, dim: 4, n_layers: 1, seed: 1 },
            &[0.0; 10]
        )
        .is_err());

        let engine = ServeEngine::new(tiny_model(), ParallelCtx::serial());
        let bad = [
            ServeRequest::Score { content: vec![], labels: 1 },
            ServeRequest::Score { content: vec![1], labels: 0 },
            // label_token(4) = 8, outside vocab 8
            ServeRequest::Score { content: vec![1], labels: 5 },
            ServeRequest::Score { content: vec![9], labels: 1 },
            ServeRequest::Generate { prompt: vec![], max_new: 1 },
            ServeRequest::Generate { prompt: vec![1], max_new: 0 },
            ServeRequest::Generate { prompt: vec![8], max_new: 1 },
        ];
        for req in &bad {
            assert!(engine.serve_one(req).is_err(), "must reject {req:?}");
            assert!(
                engine
                    .serve_batch(std::slice::from_ref(req), &WorkerPool::with_steal_seed(1, 5))
                    .is_err(),
                "batch must reject {req:?}"
            );
        }
    }

    #[test]
    fn batched_matches_sequential() {
        let engine = ServeEngine::new(tiny_model(), ParallelCtx::serial());
        let reqs = synth_requests(8, 10, 3);
        let solo = engine.serve_sequential(&reqs).unwrap();
        let pool = WorkerPool::with_steal_seed(3, 41);
        let (batched, lat) = engine.serve_batch_timed(&reqs, &pool).unwrap();
        assert_eq!(solo, batched);
        assert_eq!(lat.len(), reqs.len());
        assert!(lat.iter().all(|ms| ms.is_finite() && *ms >= 0.0));
    }

    #[test]
    fn score_and_generate_shapes() {
        let engine = ServeEngine::new(tiny_model(), ParallelCtx::serial());
        match engine
            .serve_one(&ServeRequest::Score { content: vec![1, 2, 3], labels: 3 })
            .unwrap()
        {
            ServeResponse::Score { nll, pred } => {
                assert_eq!(nll.len(), 3);
                assert!(nll.iter().all(|x| x.is_finite()));
                let want = argmin_loss(&nll);
                assert_eq!(pred, want);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        match engine
            .serve_one(&ServeRequest::Generate { prompt: vec![5, 1], max_new: 4 })
            .unwrap()
        {
            ServeResponse::Generate { tokens } => {
                assert_eq!(tokens.len(), 4);
                assert!(tokens.iter().all(|&tk| (tk as usize) < 8));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn empty_batch_is_ok() {
        let engine = ServeEngine::new(tiny_model(), ParallelCtx::serial());
        let pool = WorkerPool::with_steal_seed(2, 9);
        let (resps, lat) = engine.serve_batch_timed(&[], &pool).unwrap();
        assert!(resps.is_empty() && lat.is_empty());
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 99.0), 4.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn synth_requests_are_reproducible_and_valid() {
        let a = synth_requests(8, 20, 11);
        let b = synth_requests(8, 20, 11);
        assert_eq!(a, b);
        assert!(a.iter().any(|r| matches!(r, ServeRequest::Generate { .. })));
        assert!(a.iter().any(|r| matches!(r, ServeRequest::Score { .. })));
        let engine = ServeEngine::new(tiny_model(), ParallelCtx::serial());
        for (i, r) in a.iter().enumerate() {
            engine.validate(i, r).unwrap();
        }
    }
}
