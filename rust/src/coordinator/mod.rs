//! Training coordinator: the paper's end-to-end loops.
//!
//! * [`trainer`] — pre-training loop: data -> fwd/bwd artifact -> per-tensor
//!   update artifacts (fused-backward discipline), LR schedule, periodic
//!   validation, metrics.
//! * [`finetune`] — synthetic classification fine-tuning (the GLUE/MMLU
//!   substitute): label-conditioned corpora, label-prefix scoring accuracy.
//! * [`checkpoint`] — flat-f32 checkpoint save/load with JSON sidecar.

pub mod checkpoint;
pub mod finetune;
pub mod trainer;

pub use finetune::{finetune, FinetuneConfig, FinetuneResult};
pub use trainer::{pretrain, TrainConfig, TrainResult};
