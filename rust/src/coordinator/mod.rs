//! Training coordinator: the paper's end-to-end loops.
//!
//! * [`trainer`] — pre-training loop: data -> fwd/bwd artifact -> per-tensor
//!   update artifacts (fused-backward discipline), LR schedule, periodic
//!   validation, metrics.
//! * [`finetune`] — synthetic classification fine-tuning (the GLUE/MMLU
//!   substitute): label-conditioned corpora, label-prefix scoring accuracy.
//! * [`checkpoint`] — flat-f32 checkpoint save/load with JSON sidecar.
//! * [`dataflow`] — host-side reference dataflow trainer: the step-graph
//!   discipline of `Trainer::step` on in-process layers, so determinism /
//!   fault-injection tests and benches run without an executing runtime.

pub mod checkpoint;
pub mod dataflow;
pub mod finetune;
pub mod trainer;

pub use dataflow::{HostDataflowTrainer, HostMethod, HostStepConfig};
pub use finetune::{finetune, FinetuneConfig, FinetuneResult};
pub use trainer::{dataflow_default, pretrain, TrainConfig, TrainResult, DATAFLOW_ENV};
