//! Training coordinator: the paper's end-to-end loops.
//!
//! * [`trainer`] — pre-training loop: data -> fwd/bwd artifact -> per-tensor
//!   update artifacts (fused-backward discipline), LR schedule, periodic
//!   validation, metrics.
//! * [`finetune`] — synthetic classification fine-tuning (the GLUE/MMLU
//!   substitute): label-conditioned corpora, label-prefix scoring accuracy.
//! * [`checkpoint`] — checkpoint formats: the flat-f32 dump (full model,
//!   JSON sidecar) and the versioned `QGDC` per-user **delta** container
//!   (low-rank factors + quantized state, a few hundred KB per tenant).
//!   Both write atomically: `<path>.tmp` + rename, payload strictly
//!   before sidecar, so a crash can never leave a loadable-but-corrupt
//!   pair.
//! * [`dataflow`] — host-side reference dataflow trainer: the step-graph
//!   discipline of `Trainer::step` on in-process layers, so determinism /
//!   fault-injection tests and benches run without an executing runtime.
//! * [`multijob`] — multi-tenant fine-tune-as-a-service coordinator:
//!   N concurrent jobs share one `WorkerPool` and one immutable
//!   INT8-quantized base arena; per-job state is only the INT4
//!   projection + low-rank factor + Adam8 moments.  Each round advances
//!   every job one step through a single combined step graph
//!   (round-robin fair), and each job's trace is bitwise-identical to
//!   running it alone — see the module docs for the determinism contract
//!   and the delta checkpoint layout.
//! * [`serve`] — forward-only batched scoring/generation engine, the
//!   first piece of the heavy-traffic axis.  Request lifecycle: validate
//!   every request up front, coalesce into shape-uniform waves (kind +
//!   token length + decode budget), run all waves as one
//!   `StepGraphBuilder` DAG on the shared pool, return responses in
//!   submission order.  Loads a checkpoint (+ optional per-user `QGDC`
//!   delta) and packs every quantized matrix into the panel cache once
//!   at load time.  Determinism contract, extended to serving: a
//!   request's scores/tokens are bitwise identical served alone vs
//!   batched among N strangers, at any worker count, under hostile
//!   steal seeds (`tests/serve.rs`).

pub mod checkpoint;
pub mod dataflow;
pub mod finetune;
pub mod multijob;
pub mod serve;
pub mod trainer;

pub use dataflow::{HostDataflowTrainer, HostMethod, HostStepConfig};
pub use finetune::{finetune, FinetuneConfig, FinetuneResult};
pub use multijob::{BaseArena, JobState, MultiJobConfig, MultiJobCoordinator};
pub use serve::{ServeConfig, ServeEngine, ServeModel, ServeRequest, ServeResponse};
pub use trainer::{dataflow_default, pretrain, TrainConfig, TrainResult, DATAFLOW_ENV};
