//! Lazy layer-wise subspace exploration — the paper's §3.2 contribution.
//!
//! GaLore recomputes every layer's projection every `t` steps (t = 200).
//! Q-GaLore instead monitors, per layer, the cosine similarity between
//! consecutive projection matrices; when the last `k` refreshes were all
//! ≥ `threshold` similar, the layer's interval doubles (`t -> 2t`): its
//! subspace has converged ("early bird" layers stop paying for SVD).
//!
//! This module is pure state-machine logic (no linalg, no runtime) so every
//! transition is unit- and property-testable; the trainer feeds it cosine
//! similarities and it answers "is this layer's refresh due, and what
//! interval applies".

/// Per-layer adaptive interval state.
#[derive(Clone, Debug)]
pub struct LayerSubspaceState {
    pub name: String,
    /// current refresh interval in steps
    pub interval: u64,
    /// step of the most recent refresh (None before the first)
    pub last_refresh: Option<u64>,
    /// trailing window of cosine similarities between consecutive
    /// projections (most recent last), capacity = `window`
    pub recent_sims: Vec<f32>,
    /// number of SVD (subspace) computations performed for this layer
    pub svd_count: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// initial refresh interval (paper/GaLore default: 200)
    pub base_interval: u64,
    /// similarity threshold (paper default 0.4: "cosine similarity across
    /// the k intervals remains greater than a threshold (e.g. >= 40%)")
    pub threshold: f32,
    /// how many consecutive refreshes must clear the threshold (k)
    pub window: usize,
    /// adaptive doubling on/off (off = plain GaLore schedule)
    pub adaptive: bool,
    /// optional cap so intervals cannot grow unboundedly (0 = uncapped)
    pub max_interval: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            base_interval: 200,
            threshold: 0.4,
            window: 2,
            adaptive: true,
            max_interval: 0,
        }
    }
}

pub struct SubspaceScheduler {
    pub cfg: SchedulerConfig,
    pub layers: Vec<LayerSubspaceState>,
}

impl SubspaceScheduler {
    pub fn new(layer_names: &[String], cfg: SchedulerConfig) -> Self {
        let layers = layer_names
            .iter()
            .map(|n| LayerSubspaceState {
                name: n.clone(),
                interval: cfg.base_interval,
                last_refresh: None,
                recent_sims: Vec::new(),
                svd_count: 0,
            })
            .collect();
        SubspaceScheduler { cfg, layers }
    }

    pub fn layer(&self, idx: usize) -> &LayerSubspaceState {
        &self.layers[idx]
    }

    /// Is layer `idx` due for a subspace refresh at `step`?
    /// The first call (no projection yet) is always due.
    pub fn due(&self, idx: usize, step: u64) -> bool {
        match self.layers[idx].last_refresh {
            None => true,
            Some(last) => step.saturating_sub(last) >= self.layers[idx].interval,
        }
    }

    /// Steps until layer `idx` is next due at `step` (0 = due now).
    pub fn steps_until_due(&self, idx: usize, step: u64) -> u64 {
        match self.layers[idx].last_refresh {
            None => 0,
            Some(last) => {
                (last + self.layers[idx].interval).saturating_sub(step)
            }
        }
    }

    /// Snapshot of every layer index due at `step`, in layer order.
    ///
    /// Refresh *planning* must use this instead of polling [`Self::due`]
    /// per layer while a wave is being recorded: `record_refresh` runs
    /// per-layer inside a wave and can double a layer's interval (and set
    /// `last_refresh = step`) mid-wave, so a late `due()` read would
    /// observe a membership different from the one the wave was formed
    /// with.  The dataflow step planner takes this snapshot once, before
    /// any refresh of the step is recorded, and schedules waves from it.
    pub fn plan_due(&self, step: u64) -> Vec<usize> {
        (0..self.layers.len()).filter(|&idx| self.due(idx, step)).collect()
    }

    /// Record a refresh of layer `idx` at `step` with similarity `sim`
    /// between the outgoing and incoming projection (pass `None` for the
    /// first refresh, when there is no previous projection).
    ///
    /// Returns the (possibly doubled) interval now in effect.
    pub fn record_refresh(&mut self, idx: usize, step: u64, sim: Option<f32>) -> u64 {
        // `window == 0` must behave like the smallest meaningful window (1),
        // not like "always converged": unclamped, the trailing buffer
        // drained to empty on every push, `recent_sims.len() >= 0` was
        // vacuously true and `all()` over an empty window always passed —
        // so EVERY refresh doubled the interval, similarity ignored.
        let window = self.cfg.window.max(1);
        let st = &mut self.layers[idx];
        st.svd_count += 1;
        st.last_refresh = Some(step);
        if let Some(s) = sim {
            st.recent_sims.push(s);
            if st.recent_sims.len() > window {
                let excess = st.recent_sims.len() - window;
                st.recent_sims.drain(..excess);
            }
        }
        if self.cfg.adaptive
            && st.recent_sims.len() >= window
            && st.recent_sims.iter().all(|&s| s >= self.cfg.threshold)
        {
            st.interval = st.interval.saturating_mul(2);
            if self.cfg.max_interval > 0 {
                st.interval = st.interval.min(self.cfg.max_interval);
            }
            // converged streak consumed: require a fresh window before the
            // next doubling
            st.recent_sims.clear();
        }
        st.interval
    }

    /// Total subspace computations so far (across layers).
    pub fn total_svd_count(&self) -> u64 {
        self.layers.iter().map(|l| l.svd_count).sum()
    }

    /// SVD count a fixed-interval GaLore schedule would have used by `step`
    /// (for the Figure 7 normalization).
    pub fn galore_equivalent_count(&self, step: u64) -> u64 {
        let per_layer = step / self.cfg.base_interval + 1; // refresh at step 0
        per_layer * self.layers.len() as u64
    }

    /// Fraction of SVD calls spent vs plain GaLore (Figure 7 x-axis).
    pub fn svd_fraction(&self, step: u64) -> f64 {
        self.total_svd_count() as f64 / self.galore_equivalent_count(step) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(adaptive: bool) -> SubspaceScheduler {
        let names: Vec<String> = (0..3).map(|i| format!("layer{i}")).collect();
        SubspaceScheduler::new(
            &names,
            SchedulerConfig {
                base_interval: 10,
                threshold: 0.4,
                window: 2,
                adaptive,
                max_interval: 0,
            },
        )
    }

    #[test]
    fn first_refresh_always_due() {
        let s = sched(true);
        assert!(s.due(0, 0));
        assert!(s.due(2, 5));
    }

    #[test]
    fn due_follows_interval() {
        let mut s = sched(true);
        s.record_refresh(0, 0, None);
        assert!(!s.due(0, 5));
        assert!(s.due(0, 10));
    }

    #[test]
    fn interval_doubles_after_k_similar() {
        let mut s = sched(true);
        s.record_refresh(0, 0, None);
        assert_eq!(s.layer(0).interval, 10);
        s.record_refresh(0, 10, Some(0.9));
        assert_eq!(s.layer(0).interval, 10); // one similar: not yet
        let iv = s.record_refresh(0, 20, Some(0.8));
        assert_eq!(iv, 20); // two consecutive similar: doubled
        // streak consumed: needs a fresh window of 2 again
        s.record_refresh(0, 40, Some(0.95));
        assert_eq!(s.layer(0).interval, 20);
        s.record_refresh(0, 60, Some(0.95));
        assert_eq!(s.layer(0).interval, 40);
    }

    #[test]
    fn dissimilar_layer_never_doubles() {
        let mut s = sched(true);
        s.record_refresh(1, 0, None);
        for i in 1..20 {
            s.record_refresh(1, i * 10, Some(0.1));
        }
        assert_eq!(s.layer(1).interval, 10);
    }

    #[test]
    fn mixed_window_blocks_doubling() {
        let mut s = sched(true);
        s.record_refresh(0, 0, None);
        s.record_refresh(0, 10, Some(0.9));
        s.record_refresh(0, 20, Some(0.1)); // breaks the streak
        assert_eq!(s.layer(0).interval, 10);
        s.record_refresh(0, 30, Some(0.9));
        assert_eq!(s.layer(0).interval, 10);
        s.record_refresh(0, 40, Some(0.9));
        assert_eq!(s.layer(0).interval, 20);
    }

    #[test]
    fn non_adaptive_matches_galore_count() {
        let mut s = sched(false);
        let mut step = 0u64;
        while step <= 100 {
            for idx in 0..3 {
                if s.due(idx, step) {
                    s.record_refresh(idx, step, Some(0.99));
                }
            }
            step += 1;
        }
        // refreshes at steps 0,10,...,100 -> 11 per layer
        assert_eq!(s.total_svd_count(), 33);
        assert_eq!(s.galore_equivalent_count(100), 33);
        assert!((s.svd_fraction(100) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_saves_svd_calls_on_converged_layers() {
        let mut s = sched(true);
        let mut step = 0u64;
        while step <= 1000 {
            for idx in 0..3 {
                if s.due(idx, step) {
                    // layer 0 converges instantly, layer 1 never, layer 2 late
                    let sim = match idx {
                        0 => 0.99,
                        1 => 0.05,
                        _ => {
                            if step > 500 {
                                0.9
                            } else {
                                0.1
                            }
                        }
                    };
                    s.record_refresh(idx, step, Some(sim));
                }
            }
            step += 1;
        }
        let frac = s.svd_fraction(1000);
        assert!(frac < 0.75, "adaptive fraction {frac}");
        // the early-bird layer used far fewer refreshes than the restless one
        assert!(s.layer(0).svd_count * 2 < s.layer(1).svd_count);
    }

    #[test]
    fn converging_trace_drops_svd_fraction_below_40_percent() {
        // The paper's Figure 7 claim: on a converging run (cosine similarity
        // climbing past the threshold layer by layer) the lazy scheduler
        // spends well under 40% of plain GaLore's SVD budget.
        let names: Vec<String> = (0..4).map(|i| format!("layer{i}")).collect();
        let mut s = SubspaceScheduler::new(
            &names,
            SchedulerConfig {
                base_interval: 10,
                threshold: 0.4,
                window: 2,
                adaptive: true,
                max_interval: 0,
            },
        );
        let horizon = 2000u64;
        for step in 0..=horizon {
            for idx in 0..4 {
                if s.due(idx, step) {
                    // similarity converges at a per-layer pace: early layers
                    // immediately, late layers after a warmup phase
                    let warmup = 50 * (idx as u64 + 1);
                    let sim = if step < warmup { 0.1 } else { 0.9 };
                    s.record_refresh(idx, step, Some(sim));
                }
            }
        }
        let frac = s.svd_fraction(horizon);
        assert!(frac < 0.4, "converged trace still spent {frac} of GaLore's SVDs");
        // and intervals actually grew
        assert!(s.layer(0).interval > 10 * 8);
    }

    #[test]
    fn zero_window_does_not_double_unconditionally() {
        // regression: cfg.window == 0 made the convergence check vacuous
        // (empty similarity window, `all()` trivially true), so every
        // refresh — even the sim-less first one — doubled the interval
        let names = vec!["l".to_string()];
        let mut s = SubspaceScheduler::new(
            &names,
            SchedulerConfig {
                base_interval: 10,
                threshold: 0.4,
                window: 0,
                adaptive: true,
                max_interval: 0,
            },
        );
        s.record_refresh(0, 0, None);
        assert_eq!(s.layer(0).interval, 10, "sim-less first refresh must not double");
        for i in 1..=5u64 {
            s.record_refresh(0, i * 10, Some(0.1));
            assert_eq!(
                s.layer(0).interval,
                10,
                "below-threshold similarity must never double (refresh {i})"
            );
        }
        // clamped to window-of-1 semantics: one above-threshold sim doubles
        let iv = s.record_refresh(0, 60, Some(0.9));
        assert_eq!(iv, 20, "window=0 must act as window=1, not as never-double");
    }

    #[test]
    fn plan_due_is_immune_to_mid_wave_recording() {
        // the dataflow planning hazard: both layers are due, but recording
        // layer 0's refresh (which marks it refreshed at `step` and, with a
        // converged window, doubles its interval) must not change the
        // membership the wave was planned from
        let names: Vec<String> = (0..2).map(|i| format!("layer{i}")).collect();
        let mut s = SubspaceScheduler::new(
            &names,
            SchedulerConfig {
                base_interval: 10,
                threshold: 0.4,
                window: 1,
                adaptive: true,
                max_interval: 0,
            },
        );
        s.record_refresh(0, 0, None);
        s.record_refresh(1, 0, None);
        let step = 10u64;
        let plan = s.plan_due(step);
        assert_eq!(plan, vec![0, 1], "both layers due before the wave");
        // wave starts: layer 0's refresh lands (interval doubles, 10 -> 20)
        let iv = s.record_refresh(0, step, Some(0.9));
        assert_eq!(iv, 20);
        // a naive mid-wave `due()` poll now disagrees with the plan...
        assert!(!s.due(0, step), "due() flips as soon as the refresh is recorded");
        // ...but re-planning the same membership is pure and repeatable:
        // the snapshot taken before the wave is the scheduling contract
        assert_eq!(plan, vec![0, 1]);
        assert_eq!(s.plan_due(step), vec![1], "post-wave plan reflects the recording");
    }

    #[test]
    fn plan_due_matches_due_for_every_layer() {
        let mut s = sched(true);
        s.record_refresh(0, 0, None);
        s.record_refresh(1, 5, None);
        for step in 0..30 {
            let plan = s.plan_due(step);
            for idx in 0..3 {
                assert_eq!(
                    plan.contains(&idx),
                    s.due(idx, step),
                    "plan/due mismatch at step {step} layer {idx}"
                );
            }
        }
    }

    #[test]
    fn max_interval_caps_growth() {
        let names = vec!["l".to_string()];
        let mut s = SubspaceScheduler::new(
            &names,
            SchedulerConfig {
                base_interval: 10,
                threshold: 0.4,
                window: 1,
                adaptive: true,
                max_interval: 40,
            },
        );
        s.record_refresh(0, 0, None);
        for i in 1..10 {
            s.record_refresh(0, i * 100, Some(0.99));
        }
        assert_eq!(s.layer(0).interval, 40);
    }

    #[test]
    fn intervals_never_shrink() {
        let mut s = sched(true);
        s.record_refresh(0, 0, None);
        let mut prev = s.layer(0).interval;
        let sims = [0.9, 0.1, 0.9, 0.9, 0.05, 0.9, 0.9, 0.9];
        for (i, &sim) in sims.iter().enumerate() {
            s.record_refresh(0, (i as u64 + 1) * 10, Some(sim));
            let cur = s.layer(0).interval;
            assert!(cur >= prev);
            prev = cur;
        }
    }
}
