//! Typed view of `artifacts/manifest.json` — the AOT ABI contract.
//!
//! The manifest is written by `python/compile/aot.py` and is the single
//! source of truth for operand/result names, dtypes and shapes of every HLO
//! artifact.  The rust side trusts it (and cross-checks it against
//! `crate::model` expectations in integration tests).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::jsonx::Json;
use crate::model::ModelConfig;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I8,
    U8,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i8" => DType::I8,
            "u8" => DType::U8,
            "i32" => DType::I32,
            other => return Err(anyhow!("unknown dtype {other}")),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub operands: Vec<TensorSpec>,
    pub results: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub model: ModelConfig,
    pub fp_params: Vec<(String, Vec<usize>)>,
    pub linear_params: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub init_path: PathBuf,
    pub init_numel: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub block: usize,
    pub galore_scale: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub lora_alpha: f32,
    pub batch: usize,
    pub configs: BTreeMap<String, ConfigEntry>,
    pub updates: BTreeMap<String, ArtifactSpec>,
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("spec list not an array"))?;
    arr.iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("spec missing name"))?
                    .to_string(),
                dtype: DType::parse(
                    e.get("dtype")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("spec missing dtype"))?,
                )?,
                shape: e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("spec missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

fn parse_artifact(name: &str, j: &Json, dir: &Path) -> Result<ArtifactSpec> {
    Ok(ArtifactSpec {
        name: name.to_string(),
        path: dir.join(
            j.get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing path"))?,
        ),
        operands: parse_specs(
            j.get("operands").ok_or_else(|| anyhow!("{name}: no operands"))?,
        )?,
        results: parse_specs(
            j.get("results").ok_or_else(|| anyhow!("{name}: no results"))?,
        )?,
    })
}

fn parse_named_shapes(j: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("param list not an array"))?
        .iter()
        .map(|e| {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("param missing name"))?
                .to_string();
            let shape = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("param missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<usize>>>()?;
            Ok((name, shape))
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&raw).map_err(|e| anyhow!("{e}"))?;

        let gf = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("manifest missing {k}"))
        };

        let mut configs = BTreeMap::new();
        for (name, cj) in j
            .get("configs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing configs"))?
        {
            let gi = |k: &str| -> Result<usize> {
                cj.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("config {name} missing {k}"))
            };
            let model = ModelConfig {
                name: name.clone(),
                vocab_size: gi("vocab_size")?,
                dim: gi("dim")?,
                n_layers: gi("n_layers")?,
                n_heads: gi("n_heads")?,
                ffn_dim: gi("ffn_dim")?,
                max_seq_len: gi("max_seq_len")?,
                rank: gi("rank")?,
                tied_head: true, // all trainable (artifact-bearing) configs tie the LM head
            };
            let mut artifacts = BTreeMap::new();
            for (an, aj) in cj
                .get("artifacts")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("config {name}: no artifacts"))?
            {
                artifacts.insert(an.clone(), parse_artifact(an, aj, &dir)?);
            }
            let init = cj.get("init").ok_or_else(|| anyhow!("config {name}: no init"))?;
            configs.insert(
                name.clone(),
                ConfigEntry {
                    model,
                    fp_params: parse_named_shapes(
                        cj.get("fp_params").ok_or_else(|| anyhow!("no fp_params"))?,
                    )?,
                    linear_params: parse_named_shapes(
                        cj.get("linear_params").ok_or_else(|| anyhow!("no linear_params"))?,
                    )?,
                    artifacts,
                    init_path: dir.join(
                        init.get("path")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("init missing path"))?,
                    ),
                    init_numel: init
                        .get("numel")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("init missing numel"))?,
                },
            );
        }

        let mut updates = BTreeMap::new();
        for (an, aj) in j
            .get("updates")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing updates"))?
        {
            updates.insert(an.clone(), parse_artifact(an, aj, &dir)?);
        }

        Ok(Manifest {
            dir,
            block: gf("block")? as usize,
            galore_scale: gf("galore_scale")? as f32,
            beta1: gf("beta1")? as f32,
            beta2: gf("beta2")? as f32,
            eps: gf("eps")? as f32,
            lora_alpha: gf("lora_alpha")? as f32,
            batch: gf("batch")? as usize,
            configs,
            updates,
        })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("config {name} not in manifest (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()))
    }

    pub fn update(&self, name: &str) -> Result<&ArtifactSpec> {
        self.updates
            .get(name)
            .ok_or_else(|| anyhow!("update artifact {name} not in manifest"))
    }

    /// Load the flat f32 init checkpoint for a config.
    pub fn load_init(&self, cfg: &str) -> Result<Vec<f32>> {
        let entry = self.config(cfg)?;
        let bytes = std::fs::read(&entry.init_path)
            .with_context(|| format!("reading {}", entry.init_path.display()))?;
        if bytes.len() != entry.init_numel * 4 {
            return Err(anyhow!(
                "init checkpoint size mismatch: {} bytes, expected {}",
                bytes.len(),
                entry.init_numel * 4
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}
