//! Minimal CLI argument parser (the dependency budget has no clap: this
//! workspace builds fully offline).
//!
//! Grammar: `qgalore [--global value]* <subcommand> [positional] [--flag
//! [value]]*`.  Boolean flags take no value; every other flag takes exactly
//! one.  Unknown flags are hard errors so typos cannot silently fall back to
//! defaults.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    /// flags consumed so far (for unknown-flag detection)
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse raw argv (after the subcommand).  `bool_flags` lists flags that
    /// take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if bool_flags.contains(&name) {
                    a.bools.push(name.to_string());
                    i += 1;
                } else {
                    let val = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    a.flags.insert(name.to_string(), val.clone());
                    i += 2;
                }
            } else {
                a.positional.push(tok.clone());
                i += 1;
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.seen.borrow_mut().push(name.to_string());
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    pub fn u32_or(&self, name: &str, default: u32) -> Result<u32> {
        Ok(self.u64_or(name, default as u64)? as u32)
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad float {v:?}")),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        self.seen.borrow_mut().push(name.to_string());
        self.bools.iter().any(|b| b == name)
    }

    /// Error if any provided flag was never queried (unknown flag).
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                return Err(anyhow!("unknown flag --{k}"));
            }
        }
        for k in &self.bools {
            if !seen.iter().any(|s| s == k) {
                return Err(anyhow!("unknown flag --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(
            &argv(&["table1", "--steps", "50", "--verbose"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.u64_or("steps", 0).unwrap(), 50);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["--steps"]), &[]).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = Args::parse(&argv(&["--nope", "1"]), &[]).unwrap();
        let _ = a.u64_or("steps", 0);
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(&argv(&["--steps", "abc"]), &[]).unwrap();
        assert!(a.u64_or("steps", 0).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv(&[]), &[]).unwrap();
        assert_eq!(a.str_or("config", "llama-tiny"), "llama-tiny");
        assert_eq!(a.f32_or("lr", 0.01).unwrap(), 0.01);
    }
}
