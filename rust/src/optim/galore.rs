//! The GaLore family: GaLore (fp), 8-bit GaLore, and Q-GaLore.
//!
//! All three share the pipeline
//!
//!   grad (m,n) --P^T--> low-rank state (r,n) --Adam--> update --P--> dW
//!
//! and differ only in storage formats (paper Figure 1):
//!
//! | variant     | weights | projection | Adam states |
//! |-------------|---------|------------|-------------|
//! | GaLore      | fp      | fp         | fp          |
//! | 8-bit GaLore| fp      | fp         | blockwise INT8 |
//! | Q-GaLore    | INT8 + stochastic rounding | packed INT4 | blockwise INT8 |
//!
//! The subspace itself is recomputed on the *control path* under the lazy
//! layer-adaptive scheduler (`crate::scheduler`), via the **shape-batched**
//! refresh (`linalg::left_subspace_batched`): layers due in the same step
//! whose gradients share (m, n) are grouped, share one range sketch, and
//! present the worker pool with a single stacked (L*m, n) range-finder
//! product instead of L small dispatches.  The per-step update runs through
//! the fused `*_update_{m}x{n}_r{r}` HLO artifacts built from the L1
//! Pallas kernels.

use std::sync::Mutex;

use anyhow::{anyhow, ensure, Result};

use crate::linalg::{
    left_subspace_batched, pack_cache_enabled, par_map, subspace_overlap_with, Mat, PanelCache,
    ParallelCtx, WorkerPool,
};
use crate::manifest::ConfigEntry;
use crate::quant::{self, Adam8State, Quant2Tensor, Quant4Tensor, QuantTensor};
use crate::runtime::HostTensor;
use crate::scheduler::{SchedulerConfig, SubspaceScheduler};
use crate::util::Pcg32;

use super::{
    next_out, run_adam_8bit, run_adam_fp, split_init, AdamFp, FpTensor, Method, Optimizer,
    StepCtx, StepGraphBuilder,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GaloreKind {
    /// paper "GaLore": fp everything
    Fp,
    /// paper "8-bit GaLore": 8-bit Adam states
    Bit8,
    /// paper "Q-GaLore": INT8 weights + INT4 projection + 8-bit Adam
    Quantized,
}

/// How many power-iteration steps `left_subspace` uses at refresh time.
const SUBSPACE_ITERS: usize = 2;

/// Gradients are accumulated over this many steps leading into each
/// refresh, so the subspace is computed from a lower-variance estimate
/// (the paper's large-batch gradients are naturally low-variance; our tiny
/// testbed batches are not).  Control-path-only buffers: at most the layers
/// within `ACCUM_WINDOW` of their refresh hold one f32 gradient copy.
const ACCUM_WINDOW: u64 = 8;

struct Layer {
    name: String,
    m: usize,
    n: usize,
    // weight storage (exactly one is Some, per kind)
    w_fp: Option<FpTensor>,
    w_q: Option<QuantTensor>,
    // projection storage (at most one is Some): fp for GaLore / the 16-bit
    // ablation, nibble-packed INT4 for default Q-GaLore, sub-byte-packed
    // 2-bit for the Figure-3 stress width, generic i8-coded QuantTensor
    // for the 8-bit ablation width
    p_fp: Option<Mat>,
    p_q4: Option<Quant4Tensor>,
    p_q2: Option<Quant2Tensor>,
    p_q: Option<QuantTensor>,
    // epoch-keyed dequantized panel pack of the current projection (speed
    // cache only — rebuilt at refresh, not counted by `live_bytes`, and
    // never consulted when stale, so bits are pack-independent)
    pack: PanelCache,
    // low-rank Adam state storage
    st_fp: Option<AdamFp>,
    st_8: Option<Adam8State>,
}

pub struct Galore {
    kind: GaloreKind,
    rank: usize,
    /// whether the lazy adaptive scheduler is enabled (Q-GaLore: yes;
    /// plain/8-bit GaLore baselines: fixed interval).  Exposed for the
    /// Figure 7 ablation.
    pub fp: Vec<FpTensor>,
    fp_states_fp: Vec<AdamFp>,
    fp_states_8: Vec<Adam8State>,
    layers: Vec<Layer>,
    pub sched: SubspaceScheduler,
    /// per-layer gradient accumulator feeding the next subspace refresh
    grad_accum: Vec<Option<(Vec<f32>, u32)>>,
    sim_history: Vec<Vec<f32>>,
    rng: Pcg32,
    sr_seed: i32,
    /// worker budget for subspace refreshes / fused dequant products
    pub pool: ParallelCtx,
    /// projection quantization bits for the Figure 3 ablation (Q-GaLore
    /// default 4; set 8/16 to widen, 2 to stress).  16 = keep fp.
    pub proj_bits: u32,
    /// stochastic rounding (Q-GaLore default) vs round-to-nearest (Fig. 6)
    pub use_sr: bool,
}

impl Galore {
    pub fn new(
        kind: GaloreKind,
        entry: &ConfigEntry,
        init: &[f32],
        sched_cfg: SchedulerConfig,
        seed: u64,
        pool: ParallelCtx,
    ) -> Self {
        let (fp, lin) = split_init(init, &entry.fp_params, &entry.linear_params);
        let rank = entry.model.rank;
        let mut layers = Vec::new();
        for t in lin {
            let (m, n) = (t.shape[0], t.shape[1]);
            let state_numel = rank * n;
            let layer = match kind {
                GaloreKind::Fp => Layer {
                    name: t.name.clone(),
                    m,
                    n,
                    w_fp: Some(t),
                    w_q: None,
                    p_fp: None,
                    p_q4: None,
                    p_q2: None,
                    p_q: None,
                    pack: PanelCache::empty(),
                    st_fp: Some(AdamFp::zeros(state_numel)),
                    st_8: None,
                },
                GaloreKind::Bit8 => Layer {
                    name: t.name.clone(),
                    m,
                    n,
                    w_fp: Some(t),
                    w_q: None,
                    p_fp: None,
                    p_q4: None,
                    p_q2: None,
                    p_q: None,
                    pack: PanelCache::empty(),
                    st_fp: None,
                    st_8: Some(Adam8State::zeros(state_numel)),
                },
                GaloreKind::Quantized => Layer {
                    name: t.name.clone(),
                    m,
                    n,
                    w_fp: None,
                    w_q: Some(quant::quantize(&t.data, 8)),
                    p_fp: None,
                    p_q4: None,
                    p_q2: None,
                    p_q: None,
                    pack: PanelCache::empty(),
                    st_fp: None,
                    st_8: Some(Adam8State::zeros(state_numel)),
                },
            };
            layers.push(layer);
        }
        let (fp_states_fp, fp_states_8) = match kind {
            GaloreKind::Fp => (
                fp.iter().map(|t| AdamFp::zeros(t.numel())).collect(),
                Vec::new(),
            ),
            _ => (
                Vec::new(),
                fp.iter().map(|t| Adam8State::zeros(t.numel())).collect(),
            ),
        };
        let names: Vec<String> = layers.iter().map(|l| l.name.clone()).collect();
        let n_layers = layers.len();
        Galore {
            kind,
            rank,
            fp,
            fp_states_fp,
            fp_states_8,
            layers,
            sched: SubspaceScheduler::new(&names, sched_cfg),
            grad_accum: vec![None; n_layers],
            sim_history: vec![Vec::new(); n_layers],
            rng: Pcg32::new(seed, 0x5eed),
            sr_seed: 1,
            pool,
            proj_bits: if kind == GaloreKind::Quantized { 4 } else { 16 },
            use_sr: true,
        }
    }

    /// The immutable per-layer task parameters, detached from `&self` so
    /// per-layer step-graph nodes can each carry a copy.
    fn task_cfg(&self) -> LayerTaskCfg {
        LayerTaskCfg {
            kind: self.kind,
            rank: self.rank,
            proj_bits: self.proj_bits,
            use_sr: self.use_sr,
            pool: self.pool,
        }
    }

    /// Draw the next stochastic-rounding noise seed, iff this optimizer
    /// consumes one per layer update.  Both step paths draw through this
    /// single counter — the sequential walk at update time, the dataflow
    /// planner up front in the same order — so the noise stream is
    /// identical between them.
    fn next_sr_seed(&mut self) -> Option<i32> {
        if self.kind == GaloreKind::Quantized && self.use_sr {
            self.sr_seed = self.sr_seed.wrapping_add(1);
            Some(self.sr_seed)
        } else {
            None
        }
    }

    /// Group due layers by (m, n) in first-due order; each new group draws
    /// ONE sketch seed from the optimizer RNG.  Serial by construction, so
    /// the grouping and the seed stream are independent of worker count and
    /// shared verbatim by the sequential and dataflow paths.
    #[allow(clippy::type_complexity)]
    fn group_due_layers(
        &mut self,
        due: Vec<(usize, Vec<f32>)>,
    ) -> Vec<((usize, usize), u64, Vec<(usize, Vec<f32>)>)> {
        let mut groups: Vec<((usize, usize), u64, Vec<(usize, Vec<f32>)>)> = Vec::new();
        for (idx, g) in due {
            let key = (self.layers[idx].m, self.layers[idx].n);
            let gi = match groups.iter().position(|(k, _, _)| *k == key) {
                Some(gi) => gi,
                None => {
                    let seed = self.rng.next_u64();
                    groups.push((key, seed, Vec::new()));
                    groups.len() - 1
                }
            };
            groups[gi].2.push((idx, g));
        }
        groups
    }

    /// Step 1 of a layer update: fold `g` into the pre-refresh gradient
    /// accumulator; returns whether the layer's refresh is due this step.
    fn pre_refresh(&mut self, step: u64, idx: usize, g: &[f32]) -> bool {
        if self.sched.steps_until_due(idx, step) < ACCUM_WINDOW {
            match &mut self.grad_accum[idx] {
                Some((acc, count)) => {
                    for (a, x) in acc.iter_mut().zip(g) {
                        *a += x;
                    }
                    *count += 1;
                }
                slot => *slot = Some((g.to_vec(), 1)),
            }
        }
        self.sched.due(idx, step)
    }

    /// Consume the layer's accumulator into the low-variance mean-gradient
    /// matrix a refresh computes its basis from. Called per wave so at most
    /// one wave of mean-gradient matrices is materialized at a time.
    fn take_mean_grad(&mut self, idx: usize, g: &[f32]) -> Mat {
        let (m, n) = (self.layers[idx].m, self.layers[idx].n);
        match self.grad_accum[idx].take() {
            Some((acc, count)) => {
                Mat::from_vec(m, n, acc.into_iter().map(|x| x / count as f32).collect())
            }
            None => Mat::from_vec(m, n, g.to_vec()),
        }
    }

}

/// Immutable parameters of a single layer-update task, `Copy` so every
/// node of the step graph carries its own (no `&self` into the graph).
#[derive(Clone, Copy)]
struct LayerTaskCfg {
    kind: GaloreKind,
    rank: usize,
    proj_bits: u32,
    use_sr: bool,
    pool: ParallelCtx,
}

fn update_artifact(cfg: LayerTaskCfg, m: usize, n: usize) -> String {
    let prefix = match cfg.kind {
        GaloreKind::Fp => "galore_update",
        GaloreKind::Bit8 => "galore8bit_update",
        GaloreKind::Quantized if cfg.use_sr => "qgalore_update",
        GaloreKind::Quantized => "qgalore_rtn_update",
    };
    format!("{prefix}_{m}x{n}_r{}", cfg.rank)
}

/// Rotation-invariant overlap ||P_old^T P_new||_F^2 / r in [0, 1] with
/// the layer's outgoing projection (None before the first refresh) —
/// the quantity the paper's "cosine similarity between adjacent
/// projection matrices" measures modulo the within-subspace rotation
/// that randomized solvers leave free. Quantized-stored projections go
/// through the fused `dequant*_t_matmul`, so the old basis is never
/// materialized in fp32 — except via the layer's panel pack when one is
/// current (built at the *previous* refresh), which skips even the
/// per-call decode.
fn overlap_with_old(layer: &Layer, new_p: &Mat, pool: ParallelCtx) -> Option<f32> {
    if let Some(p) = &layer.p_fp {
        return Some(subspace_overlap_with(p, new_p, pool));
    }
    let overlap = |prod: Mat, r_old: usize| {
        let f = prod.frobenius();
        f * f / r_old.min(new_p.cols).max(1) as f32
    };
    if let Some(q) = &layer.p_q4 {
        let r_old = q.numel() / layer.m;
        let prod = match layer.pack.get() {
            Some(pk) if pk.matches4(q, layer.m, r_old) => {
                quant::dequant4_t_matmul_prepacked(q, pk, layer.m, r_old, new_p, pool)
            }
            _ => quant::dequant4_t_matmul(q, layer.m, r_old, new_p, pool),
        };
        return Some(overlap(prod, r_old));
    }
    if let Some(q) = &layer.p_q2 {
        let r_old = q.numel() / layer.m;
        let prod = match layer.pack.get() {
            Some(pk) if pk.matches2(q, layer.m, r_old) => {
                quant::dequant2_t_matmul_prepacked(q, pk, layer.m, r_old, new_p, pool)
            }
            _ => quant::dequant2_t_matmul(q, layer.m, r_old, new_p, pool),
        };
        return Some(overlap(prod, r_old));
    }
    // 8-bit ablation storage: same fused discipline, i8 codes
    layer.p_q.as_ref().map(|q| {
        let r_old = q.numel() / layer.m;
        let prod = match layer.pack.get() {
            Some(pk) if pk.matches8(q, layer.m, r_old) => {
                quant::dequant8_t_matmul_prepacked(q, pk, layer.m, r_old, new_p, pool)
            }
            _ => quant::dequant8_t_matmul(q, layer.m, r_old, new_p, pool),
        };
        overlap(prod, r_old)
    })
}

/// Store a freshly computed basis in the layer's storage format, and
/// rebuild the layer's panel pack for the new epoch (unless the cache is
/// disabled).  Runs once per refresh — inside the refresh wave's member
/// node on the dataflow path, so the pack cost lands on the wave, not on
/// the steady-state steps that reap it.
fn store_projection(layer: &mut Layer, cfg: LayerTaskCfg, new_p: Mat) {
    let r_new = new_p.cols;
    layer.pack.invalidate();
    match cfg.kind {
        GaloreKind::Fp | GaloreKind::Bit8 => layer.p_fp = Some(new_p),
        GaloreKind::Quantized => {
            if cfg.proj_bits >= 16 {
                layer.p_fp = Some(new_p);
            } else if cfg.proj_bits == 4 {
                let q = quant::quantize4(&new_p.data);
                if pack_cache_enabled() {
                    layer.pack.get_or_pack4(&q, layer.m, r_new);
                }
                layer.p_q4 = Some(q);
            } else if cfg.proj_bits == 2 {
                // Figure 3 stress width: sub-byte packed, 4 codes/byte,
                // so `live_bytes` reports a quarter of the i8 footprint.
                let q = quant::quantize2(&new_p.data);
                if pack_cache_enabled() {
                    layer.pack.get_or_pack2(&q, layer.m, r_new);
                }
                layer.p_q2 = Some(q);
            } else {
                // 8-bit ablation width: stored PACKED as a generic
                // QuantTensor and applied through the fused dequant
                // paths, so `live_bytes` reports the packed size the
                // ablation measures — not an fp32 copy.
                let q = quant::quantize(&new_p.data, cfg.proj_bits);
                if pack_cache_enabled() {
                    layer.pack.get_or_pack8(&q, layer.m, r_new);
                }
                layer.p_q = Some(q);
            }
        }
    }
}

/// The fused update step of one layer (hot path, HLO artifact).  The
/// projection must already be current, and for the SR variant the noise
/// seed must have been drawn via `Galore::next_sr_seed` — a free function
/// over ONE `&mut Layer` precisely so concurrent step-graph chains own
/// disjoint state.
fn run_layer_update(
    layer: &mut Layer,
    cfg: LayerTaskCfg,
    ctx: &StepCtx,
    g: Vec<f32>,
    sr_seed: Option<i32>,
) -> Result<()> {
    let (m, n) = (layer.m, layer.n);
    let art = ctx.man.update(&update_artifact(cfg, m, n))?.clone();
    let c = ctx.corrections();
    let lr = ctx.lr_operand();
    match cfg.kind {
        GaloreKind::Fp => {
            let p = layer.p_fp.as_ref().expect("refreshed above");
            let st = layer.st_fp.as_mut().unwrap();
            let w = layer.w_fp.as_mut().unwrap();
            let outs = ctx.rt.execute(
                &art,
                &[
                    HostTensor::F32(g),
                    HostTensor::F32(p.data.clone()),
                    HostTensor::F32(std::mem::take(&mut st.m)),
                    HostTensor::F32(std::mem::take(&mut st.v)),
                    HostTensor::F32(std::mem::take(&mut w.data)),
                    c,
                    lr,
                ],
            )?;
            let mut it = outs.into_iter();
            w.data = next_out(&mut it, "updated weights")?.into_f32()?;
            st.m = next_out(&mut it, "Adam m")?.into_f32()?;
            st.v = next_out(&mut it, "Adam v")?.into_f32()?;
        }
        GaloreKind::Bit8 => {
            let p = layer.p_fp.as_ref().expect("refreshed above");
            let st = layer.st_8.as_mut().unwrap();
            let w = layer.w_fp.as_mut().unwrap();
            let outs = ctx.rt.execute(
                &art,
                &[
                    HostTensor::F32(g),
                    HostTensor::F32(p.data.clone()),
                    HostTensor::I8(std::mem::take(&mut st.mq)),
                    HostTensor::F32(std::mem::take(&mut st.ms)),
                    HostTensor::U8(std::mem::take(&mut st.vq)),
                    HostTensor::F32(std::mem::take(&mut st.vs)),
                    HostTensor::F32(std::mem::take(&mut w.data)),
                    c,
                    lr,
                ],
            )?;
            let mut it = outs.into_iter();
            w.data = next_out(&mut it, "updated weights")?.into_f32()?;
            st.mq = match next_out(&mut it, "Adam8 mq")? {
                HostTensor::I8(v) => v,
                t => return Err(anyhow!("mq dtype {:?}", t.dtype())),
            };
            st.ms = next_out(&mut it, "Adam8 ms")?.into_f32()?;
            st.vq = match next_out(&mut it, "Adam8 vq")? {
                HostTensor::U8(v) => v,
                t => return Err(anyhow!("vq dtype {:?}", t.dtype())),
            };
            st.vs = next_out(&mut it, "Adam8 vs")?.into_f32()?;
        }
        GaloreKind::Quantized => {
            // The INT4 artifact path requires packed nibbles; the
            // ablation storages (sub-byte 2-bit, generic i8 codes, or
            // fp32) re-pack on the fly (hot path stays INT4 in the
            // default config).
            let (p4, ps, pz) = match (&layer.p_q4, &layer.p_q2, &layer.p_q, &layer.p_fp) {
                (Some(q), _, _, _) => (q.packed.clone(), q.scale.clone(), q.zero.clone()),
                (None, Some(q), _, _) => {
                    let q4 = quant::quantize4(&quant::dequantize2(q));
                    (q4.packed, q4.scale, q4.zero)
                }
                (None, None, Some(q), _) => {
                    let q4 = quant::quantize4(&quant::dequantize(q));
                    (q4.packed, q4.scale, q4.zero)
                }
                (None, None, None, Some(pf)) => {
                    let q = quant::quantize4(&pf.data);
                    (q.packed, q.scale, q.zero)
                }
                _ => return Err(anyhow!("layer {} has no projection", layer.name)),
            };
            let st = layer.st_8.as_mut().unwrap();
            let w = layer.w_q.as_mut().unwrap();
            let mut ops = vec![
                HostTensor::F32(g),
                HostTensor::U8(p4),
                HostTensor::F32(ps),
                HostTensor::F32(pz),
                HostTensor::I8(std::mem::take(&mut st.mq)),
                HostTensor::F32(std::mem::take(&mut st.ms)),
                HostTensor::U8(std::mem::take(&mut st.vq)),
                HostTensor::F32(std::mem::take(&mut st.vs)),
                HostTensor::I8(std::mem::take(&mut w.q)),
                HostTensor::F32(std::mem::take(&mut w.scale)),
                HostTensor::F32(std::mem::take(&mut w.zero)),
                c,
                lr,
            ];
            if cfg.use_sr {
                // SR noise is generated host-side (counter-based PCG
                // keeps runs replayable; generating it in-graph with
                // threefry cost ~1.7x the whole GaLore update on this
                // backend — EXPERIMENTS.md §Perf), via the
                // chunk-streamed parallel fill so big layers fan the
                // fill over the worker pool without the result ever
                // depending on worker count.  The seed was drawn from the
                // optimizer's counter during (serial) planning — see
                // `Galore::next_sr_seed`.  The RTN ablation artifact takes
                // no noise operand.
                let seed = sr_seed.expect("SR noise seed drawn during planning");
                ops.push(HostTensor::F32(quant::uniform_noise(
                    m * n,
                    seed as u64,
                    cfg.pool,
                )));
            }
            let outs = ctx.rt.execute(&art, &ops)?;
            let mut it = outs.into_iter();
            w.q = match next_out(&mut it, "updated INT8 weights")? {
                HostTensor::I8(v) => v,
                t => return Err(anyhow!("wq dtype {:?}", t.dtype())),
            };
            w.scale = next_out(&mut it, "weight scales")?.into_f32()?;
            w.zero = next_out(&mut it, "weight zeros")?.into_f32()?;
            st.mq = match next_out(&mut it, "Adam8 mq")? {
                HostTensor::I8(v) => v,
                t => return Err(anyhow!("mq dtype {:?}", t.dtype())),
            };
            st.ms = next_out(&mut it, "Adam8 ms")?.into_f32()?;
            st.vq = match next_out(&mut it, "Adam8 vq")? {
                HostTensor::U8(v) => v,
                t => return Err(anyhow!("vq dtype {:?}", t.dtype())),
            };
            st.vs = next_out(&mut it, "Adam8 vs")?.into_f32()?;
        }
    }
    Ok(())
}

impl Optimizer for Galore {
    fn method(&self) -> Method {
        match self.kind {
            GaloreKind::Fp => Method::GaLore,
            GaloreKind::Bit8 => Method::GaLore8bit,
            GaloreKind::Quantized => Method::QGaLore,
        }
    }

    fn fwd_artifact(&self) -> &'static str {
        match self.kind {
            GaloreKind::Quantized => "fwd_bwd_q8",
            _ => "fwd_bwd_fp",
        }
    }

    fn eval_artifact(&self) -> &'static str {
        match self.kind {
            GaloreKind::Quantized => "eval_fwd_q8",
            _ => "eval_fwd_fp",
        }
    }

    fn forward_operands(&self) -> Vec<HostTensor> {
        // operand marshalling is pure buffer cloning — fan the layers out
        // over the persistent pool (memory-bound, but scales with core
        // count); tiny models stay serial, dispatch would exceed the memcpy
        let kind = self.kind;
        let total: usize = self.fp.iter().map(|t| t.numel()).sum::<usize>()
            + self.layers.iter().map(|l| l.m * l.n).sum::<usize>();
        let pool = crate::linalg::clone_pool(total, self.pool);
        let mut ops: Vec<HostTensor> =
            par_map(pool, &self.fp, |t| HostTensor::F32(t.data.clone()));
        let per_layer: Vec<Vec<HostTensor>> = par_map(pool, &self.layers, |l| match kind {
            GaloreKind::Quantized => {
                let w = l.w_q.as_ref().unwrap();
                vec![
                    HostTensor::I8(w.q.clone()),
                    HostTensor::F32(w.scale.clone()),
                    HostTensor::F32(w.zero.clone()),
                ]
            }
            _ => vec![HostTensor::F32(l.w_fp.as_ref().unwrap().data.clone())],
        });
        ops.extend(per_layer.into_iter().flatten());
        ops
    }

    fn apply_update(&mut self, ctx: &StepCtx, grads: Vec<HostTensor>) -> Result<()> {
        let n_fp = self.fp.len();
        ensure!(
            grads.len() == n_fp + self.layers.len(),
            "GaLore update: {} gradient tensors for {} fp params + {} layers",
            grads.len(),
            n_fp,
            self.layers.len()
        );
        // The fused-backward discipline: consume and drop each gradient
        // right after its tensor's update (paper §3.5). Layers whose
        // subspace refresh falls due this step park their gradient — a
        // MOVE out of the already-resident grads vec, so parking allocates
        // nothing, it only delays the free to the owning wave below. The
        // allocations a refresh makes (mean-gradient matrices, subspace
        // bases, iteration scratch) happen per wave, so they are capped by
        // the wave size = `pool.threads`, not the layer count, even at
        // step 0 when every layer refreshes at once.
        let pool = self.pool;
        let tcfg = self.task_cfg();
        let mut due: Vec<(usize, Vec<f32>)> = Vec::new();
        for (i, g) in grads.into_iter().enumerate() {
            let g = g.into_f32()?;
            if i < n_fp {
                match self.kind {
                    GaloreKind::Fp => {
                        run_adam_fp(ctx, &mut self.fp[i], &mut self.fp_states_fp[i], &g)?
                    }
                    _ => run_adam_8bit(ctx, &mut self.fp[i], &mut self.fp_states_8[i], &g)?,
                }
            } else {
                let idx = i - n_fp;
                if self.pre_refresh(ctx.step, idx, &g) {
                    due.push((idx, g));
                } else {
                    let sr = self.next_sr_seed();
                    run_layer_update(&mut self.layers[idx], tcfg, ctx, g, sr)?;
                }
            }
        }
        // Shape-batched refresh (`group_due_layers`): groups are consumed
        // in waves of at most `pool.threads` layers, which caps the wave's
        // live buffers (mean-gradient matrices, bases, iteration scratch)
        // even at step 0 when every layer refreshes at once.  Every wave
        // of a group re-derives the same omega from the group seed, so
        // splitting a group into waves cannot change the projections (the
        // `left_subspace_batched` contract).
        let rank = self.rank;
        let wave_size = pool.threads.max(1);
        let groups = self.group_due_layers(due);
        for (_shape, seed, mut members) in groups {
            while !members.is_empty() {
                let take = wave_size.min(members.len());
                let wave: Vec<(usize, Vec<f32>)> = members.drain(..take).collect();
                let gms: Vec<Mat> =
                    wave.iter().map(|(idx, g)| self.take_mean_grad(*idx, g)).collect();
                let grefs: Vec<&Mat> = gms.iter().collect();
                let mut rng = Pcg32::new(seed, 0x5eed);
                let new_ps = left_subspace_batched(&grefs, rank, SUBSPACE_ITERS, &mut rng, pool);
                drop(grefs);
                drop(gms);
                for ((idx, g), new_p) in wave.into_iter().zip(new_ps) {
                    let sim = overlap_with_old(&self.layers[idx], &new_p, pool);
                    if let Some(s) = sim {
                        self.sim_history[idx].push(s);
                    }
                    store_projection(&mut self.layers[idx], tcfg, new_p);
                    self.sched.record_refresh(idx, ctx.step, sim);
                    let sr = self.next_sr_seed();
                    run_layer_update(&mut self.layers[idx], tcfg, ctx, g, sr)?;
                }
            }
        }
        Ok(())
    }

    fn apply_update_dataflow(
        &mut self,
        ctx: &StepCtx,
        grads: Vec<HostTensor>,
        wpool: &WorkerPool,
    ) -> Result<()> {
        let n_fp = self.fp.len();
        ensure!(
            grads.len() == n_fp + self.layers.len(),
            "GaLore dataflow update: {} gradient tensors for {} fp params + {} layers",
            grads.len(),
            n_fp,
            self.layers.len()
        );
        let pool = self.pool;
        let tcfg = self.task_cfg();
        let rank = self.rank;
        let step = ctx.step;

        // ---- Plan phase (serial).  Replays every decision the sequential
        // walk makes against *shared* optimizer state — accumulator folds,
        // due membership (snapshotted up front via `plan_due` so nothing
        // mid-step can shift it), shape grouping, sketch seeds, SR noise
        // seeds — in the exact order the sequential path consumes them.
        // After this block, the racy graph below only ever touches state
        // owned by a single chain.
        let planned_due = self.sched.plan_due(step);
        let mut fp_grads: Vec<Vec<f32>> = Vec::with_capacity(n_fp);
        let mut now: Vec<(usize, Vec<f32>, Option<i32>)> = Vec::new();
        let mut due: Vec<(usize, Vec<f32>)> = Vec::new();
        for (i, g) in grads.into_iter().enumerate() {
            let g = g.into_f32()?;
            if i < n_fp {
                fp_grads.push(g);
            } else {
                let idx = i - n_fp;
                if self.pre_refresh(step, idx, &g) {
                    debug_assert!(
                        planned_due.contains(&idx),
                        "due() drifted from the plan_due snapshot"
                    );
                    due.push((idx, g));
                } else {
                    let sr = self.next_sr_seed();
                    now.push((idx, g, sr));
                }
            }
        }
        // Wave plans: mean gradients are folded out of the accumulators
        // here (serially — they are shared state), so unlike the
        // sequential path all due waves' mean matrices are resident at
        // once; that is the price of letting waves run concurrently, and
        // it is bounded by the same gradients the step already held.
        struct WavePlan {
            seed: u64,
            members: Vec<(usize, Mat, Vec<f32>, Option<i32>)>,
        }
        let wave_size = pool.threads.max(1);
        let groups = self.group_due_layers(due);
        let mut waves: Vec<WavePlan> = Vec::new();
        for (_shape, seed, mut members) in groups {
            while !members.is_empty() {
                let take = wave_size.min(members.len());
                let mut wm = Vec::with_capacity(take);
                for (idx, g) in members.drain(..take) {
                    let gm = self.take_mean_grad(idx, &g);
                    let sr = self.next_sr_seed();
                    wm.push((idx, gm, g, sr));
                }
                waves.push(WavePlan { seed, members: wm });
            }
        }

        // ---- Execute phase.  One independent node per fp tensor and per
        // non-due layer; per wave, one basis node fanning into its member
        // layers' update nodes.  Each node owns exactly one tensor/layer's
        // `&mut` state, so concurrent chains commute.
        let proj_slots: Vec<Vec<Mutex<Option<Mat>>>> = waves
            .iter()
            .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
            .collect();
        let sim_slots: Vec<Vec<Mutex<Option<f32>>>> = waves
            .iter()
            .map(|w| w.members.iter().map(|_| Mutex::new(None)).collect())
            .collect();
        let mut recordings: Vec<(usize, usize, usize)> = Vec::new();
        let cx = *ctx;
        let mut b = StepGraphBuilder::new();
        match self.kind {
            GaloreKind::Fp => {
                let states = self.fp_states_fp.iter_mut();
                for ((w, st), g) in self.fp.iter_mut().zip(states).zip(fp_grads) {
                    b.fallible(&[], move || run_adam_fp(&cx, w, st, &g));
                }
            }
            _ => {
                let states = self.fp_states_8.iter_mut();
                for ((w, st), g) in self.fp.iter_mut().zip(states).zip(fp_grads) {
                    b.fallible(&[], move || run_adam_8bit(&cx, w, st, &g));
                }
            }
        }
        let mut layer_slots: Vec<Option<&mut Layer>> = self.layers.iter_mut().map(Some).collect();
        for (idx, g, sr) in now {
            let layer = layer_slots[idx].take().expect("one chain per layer");
            b.fallible(&[], move || run_layer_update(layer, tcfg, &cx, g, sr));
        }
        for (wi, wave) in waves.into_iter().enumerate() {
            let seed = wave.seed;
            let mut gms: Vec<Mat> = Vec::with_capacity(wave.members.len());
            let mut rest: Vec<(usize, Vec<f32>, Option<i32>)> = Vec::new();
            for (idx, gm, g, sr) in wave.members {
                gms.push(gm);
                rest.push((idx, g, sr));
            }
            let wave_out = &proj_slots[wi];
            let basis = b.node(&[], move || {
                let grefs: Vec<&Mat> = gms.iter().collect();
                let mut rng = Pcg32::new(seed, 0x5eed);
                let new_ps = left_subspace_batched(&grefs, rank, SUBSPACE_ITERS, &mut rng, pool);
                for (slot, p) in wave_out.iter().zip(new_ps) {
                    *slot.lock().unwrap() = Some(p);
                }
            });
            for (mi, (idx, g, sr)) in rest.into_iter().enumerate() {
                let layer = layer_slots[idx].take().expect("one chain per layer");
                let pslot = &proj_slots[wi][mi];
                let sslot = &sim_slots[wi][mi];
                recordings.push((wi, mi, idx));
                b.fallible(&[basis], move || {
                    let new_p = pslot.lock().unwrap().take().expect("basis node filled slot");
                    *sslot.lock().unwrap() = overlap_with_old(layer, &new_p, pool);
                    store_projection(layer, tcfg, new_p);
                    run_layer_update(layer, tcfg, &cx, g, sr)
                });
            }
        }
        b.run(wpool)?;

        // ---- Join phase (serial, plan order).  The cross-layer reductions
        // the chains must not race on: similarity history and scheduler
        // recording happen once, here, in the order the sequential walk
        // would have recorded them.
        for (wi, mi, idx) in recordings {
            let sim = *sim_slots[wi][mi].lock().unwrap();
            if let Some(s) = sim {
                self.sim_history[idx].push(s);
            }
            self.sched.record_refresh(idx, step, sim);
        }
        Ok(())
    }

    fn live_bytes(&self) -> u64 {
        let mut b: u64 = self.fp.iter().map(|t| t.numel() as u64 * 4).sum();
        b += self.fp_states_fp.iter().map(|s| s.bytes()).sum::<u64>();
        b += self
            .fp_states_8
            .iter()
            .map(|s| s.storage_bytes() as u64)
            .sum::<u64>();
        for l in &self.layers {
            if let Some(w) = &l.w_fp {
                b += w.numel() as u64 * 4;
            }
            if let Some(w) = &l.w_q {
                b += w.storage_bytes() as u64;
            }
            if let Some(p) = &l.p_fp {
                b += p.data.len() as u64 * 4;
            }
            if let Some(p) = &l.p_q4 {
                b += p.storage_bytes() as u64;
            }
            if let Some(p) = &l.p_q2 {
                b += p.storage_bytes() as u64;
            }
            if let Some(p) = &l.p_q {
                b += p.storage_bytes() as u64;
            }
            // l.pack is deliberately NOT counted: the paper's memory
            // accounting measures what training *requires* resident;
            // the panel pack is an optional speed cache (off via
            // QGALORE_PACK_CACHE=0 with identical bits).
            if let Some(s) = &l.st_fp {
                b += s.bytes();
            }
            if let Some(s) = &l.st_8 {
                b += s.storage_bytes() as u64;
            }
        }
        b
    }

    fn svd_stats(&self, step: u64) -> Option<(u64, f64)> {
        Some((self.sched.total_svd_count(), self.sched.svd_fraction(step)))
    }

    fn similarity_history(&self) -> Option<Vec<(String, Vec<f32>)>> {
        Some(
            self.layers
                .iter()
                .zip(&self.sim_history)
                .map(|(l, h)| (l.name.clone(), h.clone()))
                .collect(),
        )
    }

    fn export_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for t in &self.fp {
            out.extend_from_slice(&t.data);
        }
        for l in &self.layers {
            if let Some(w) = &l.w_fp {
                out.extend_from_slice(&w.data);
            } else if let Some(w) = &l.w_q {
                out.extend(quant::dequantize(w));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{ConfigEntry, Manifest};
    use crate::model::ModelConfig;
    use crate::optim::StepCtx;

    fn galore(kind: GaloreKind) -> Galore {
        let entry = ConfigEntry {
            model: ModelConfig {
                name: "galore-test".into(),
                vocab_size: 8,
                dim: 4,
                n_layers: 1,
                n_heads: 2,
                ffn_dim: 8,
                max_seq_len: 4,
                rank: 2,
                tied_head: true,
            },
            fp_params: vec![("emb".into(), vec![8, 4])],
            linear_params: vec![("l0.w".into(), vec![4, 4])],
            artifacts: Default::default(),
            init_path: std::path::PathBuf::new(),
            init_numel: 8 * 4 + 4 * 4,
        };
        let init: Vec<f32> = (0..entry.init_numel).map(|i| i as f32 * 0.01).collect();
        Galore::new(kind, &entry, &init, SchedulerConfig::default(), 5, ParallelCtx::serial())
    }

    #[test]
    fn update_with_short_grad_list_is_error_not_panic() {
        // regression for the positional-consumption panics: a truncated
        // gradient list must surface as Err for every GaLore variant
        let man = Manifest {
            dir: std::path::PathBuf::new(),
            block: 256,
            galore_scale: 0.25,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            lora_alpha: 16.0,
            batch: 1,
            configs: Default::default(),
            updates: Default::default(),
        };
        let rt = crate::runtime::Runtime::new().unwrap();
        let ctx = StepCtx { rt: &rt, man: &man, step: 1, lr: 1e-3 };
        for kind in [GaloreKind::Fp, GaloreKind::Bit8, GaloreKind::Quantized] {
            let mut g = galore(kind);
            let err = g.apply_update(&ctx, Vec::new()).unwrap_err();
            assert!(err.to_string().contains("gradient tensors"), "{kind:?}: {err}");
            let pool = WorkerPool::with_steal_seed(2, 3);
            let mut g = galore(kind);
            let err = g.apply_update_dataflow(&ctx, Vec::new(), &pool).unwrap_err();
            assert!(err.to_string().contains("gradient tensors"), "{kind:?}: {err}");
        }
    }

    #[test]
    fn base_in_place_methods_refuse_delta_io() {
        let mut g = galore(GaloreKind::Quantized);
        assert!(g.export_delta().is_err(), "Q-GaLore has no base/delta split");
        assert!(g.import_delta(Vec::new()).is_err());
    }
}
