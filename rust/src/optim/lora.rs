//! LoRA / ReLoRA / QLoRA baselines: frozen base weights + trainable rank-r
//! adapter pairs (U (out,r), V (r,in)), optimized with fp Adam.
//!
//! * LoRA: base f32 (counted BF16 by the memory model).
//! * QLoRA: base in blockwise INT8 (paper: "we keep the base models in
//!   8bits for fair comparison").
//! * ReLoRA: LoRA plus a periodic merge: base += (alpha/r)·U·V, adapters
//!   re-initialized, adapter optimizer states reset (Lialin et al. 2023).

use anyhow::{ensure, Result};

use crate::linalg::{Mat, ParallelCtx};
use crate::manifest::ConfigEntry;
use crate::quant::{self, QuantTensor};
use crate::runtime::HostTensor;
use crate::util::Pcg32;

use super::{next_out, run_adam_fp, split_init, AdamFp, FpTensor, Method, Optimizer, StepCtx};

struct AdapterPair {
    name: String,
    out: usize,
    inn: usize,
    u: FpTensor, // (out, r)
    v: FpTensor, // (r, in)
    st_u: AdamFp,
    st_v: AdamFp,
}

pub struct Lora {
    method: Method,
    rank: usize,
    lora_alpha: f32,
    fp: Vec<FpTensor>, // frozen (embedding, norms)
    base_fp: Vec<FpTensor>,
    base_q: Vec<QuantTensor>,
    adapters: Vec<AdapterPair>,
    rng: Pcg32,
    /// ReLoRA merge period in steps (0 = never).
    pub merge_every: u64,
    merges_done: u64,
    pool: ParallelCtx,
}

impl Lora {
    pub fn new(
        method: Method,
        entry: &ConfigEntry,
        init: &[f32],
        lora_alpha: f32,
        seed: u64,
        pool: ParallelCtx,
    ) -> Self {
        assert!(matches!(method, Method::LoRa | Method::ReLoRa | Method::QLoRa));
        let (fp, lin) = split_init(init, &entry.fp_params, &entry.linear_params);
        let rank = entry.model.rank;
        let mut rng = Pcg32::new(seed, 0x10ad);
        let mut adapters = Vec::new();
        for t in &lin {
            let (out, inn) = (t.shape[0], t.shape[1]);
            adapters.push(Self::fresh_adapter(&t.name, out, inn, rank, &mut rng));
        }
        let (base_fp, base_q) = if method == Method::QLoRa {
            (Vec::new(), lin.iter().map(|t| quant::quantize(&t.data, 8)).collect())
        } else {
            (lin, Vec::new())
        };
        Lora {
            method,
            rank,
            lora_alpha,
            fp,
            base_fp,
            base_q,
            adapters,
            rng,
            merge_every: 0, // the factory sets the ReLoRA period
            merges_done: 0,
            pool,
        }
    }

    fn fresh_adapter(
        name: &str,
        out: usize,
        inn: usize,
        rank: usize,
        rng: &mut Pcg32,
    ) -> AdapterPair {
        // standard LoRA init (Hu et al.): A = V (r, in) kaiming-scaled
        // gaussian, B = U (out, r) zero — the adapter product starts at
        // zero and dU ∝ V is immediately well-scaled.
        let v_std = 1.0 / (inn as f32).sqrt();
        AdapterPair {
            name: name.to_string(),
            out,
            inn,
            u: FpTensor {
                name: format!("{name}.lora_u"),
                shape: vec![out, rank],
                data: vec![0.0; out * rank],
            },
            v: FpTensor {
                name: format!("{name}.lora_v"),
                shape: vec![rank, inn],
                data: rng.normal_vec(rank * inn, 0.0, v_std),
            },
            st_u: AdamFp::zeros(out * rank),
            st_v: AdamFp::zeros(rank * inn),
        }
    }

    /// ReLoRA merge: fold adapters into the base and restart them.
    pub fn merge_and_restart(&mut self) {
        assert_eq!(self.method, Method::ReLoRa);
        let scale = self.lora_alpha / self.rank as f32;
        for (base, ad) in self.base_fp.iter_mut().zip(&mut self.adapters) {
            let u = Mat::from_vec(ad.out, self.rank, ad.u.data.clone());
            let v = Mat::from_vec(self.rank, ad.inn, ad.v.data.clone());
            let prod = u.matmul_with(&v, self.pool);
            for (b, p) in base.data.iter_mut().zip(prod.data) {
                *b += scale * p;
            }
            *ad = Self::fresh_adapter(&ad.name.clone(), ad.out, ad.inn, self.rank, &mut self.rng);
        }
        self.merges_done += 1;
    }

    pub fn merges_done(&self) -> u64 {
        self.merges_done
    }
}

impl Optimizer for Lora {
    fn method(&self) -> Method {
        self.method
    }

    fn fwd_artifact(&self) -> &'static str {
        if self.method == Method::QLoRa {
            "qlora_fwd_bwd"
        } else {
            "lora_fwd_bwd"
        }
    }

    fn forward_operands(&self) -> Vec<HostTensor> {
        let mut ops: Vec<HostTensor> =
            self.fp.iter().map(|t| HostTensor::F32(t.data.clone())).collect();
        if self.method == Method::QLoRa {
            for q in &self.base_q {
                ops.push(HostTensor::I8(q.q.clone()));
                ops.push(HostTensor::F32(q.scale.clone()));
                ops.push(HostTensor::F32(q.zero.clone()));
            }
        } else {
            for t in &self.base_fp {
                ops.push(HostTensor::F32(t.data.clone()));
            }
        }
        for ad in &self.adapters {
            ops.push(HostTensor::F32(ad.u.data.clone()));
            ops.push(HostTensor::F32(ad.v.data.clone()));
        }
        ops
    }

    // NOTE: no `apply_update_dataflow` override — ReLoRA's merge couples
    // every adapter to the base weights, so the default sequential
    // fallback is the correct factoring for the LoRA family.
    fn apply_update(&mut self, ctx: &StepCtx, grads: Vec<HostTensor>) -> Result<()> {
        // grads: (dU, dV) per adapter, in layer order
        ensure!(
            grads.len() == 2 * self.adapters.len(),
            "LoRA update: {} gradient tensors for {} adapters (want 2 per adapter)",
            grads.len(),
            self.adapters.len()
        );
        let mut it = grads.into_iter();
        for ad in self.adapters.iter_mut() {
            let gu = next_out(&mut it, "adapter dU")?.into_f32()?;
            let gv = next_out(&mut it, "adapter dV")?.into_f32()?;
            run_adam_fp(ctx, &mut ad.u, &mut ad.st_u, &gu)?;
            run_adam_fp(ctx, &mut ad.v, &mut ad.st_v, &gv)?;
        }
        Ok(())
    }

    fn on_step_end(&mut self, ctx: &StepCtx) -> Result<()> {
        if self.method == Method::ReLoRa
            && self.merge_every > 0
            && ctx.step % self.merge_every == 0
        {
            self.merge_and_restart();
        }
        Ok(())
    }

    fn live_bytes(&self) -> u64 {
        let mut b: u64 = self.fp.iter().map(|t| t.numel() as u64 * 4).sum();
        b += self.base_fp.iter().map(|t| t.numel() as u64 * 4).sum::<u64>();
        b += self.base_q.iter().map(|q| q.storage_bytes() as u64).sum::<u64>();
        for ad in &self.adapters {
            b += (ad.u.numel() + ad.v.numel()) as u64 * 4;
            b += ad.st_u.bytes() + ad.st_v.bytes();
        }
        b
    }

    fn export_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for t in &self.fp {
            out.extend_from_slice(&t.data);
        }
        let scale = self.lora_alpha / self.rank as f32;
        for (i, ad) in self.adapters.iter().enumerate() {
            let base: Vec<f32> = if self.method == Method::QLoRa {
                quant::dequantize(&self.base_q[i])
            } else {
                self.base_fp[i].data.clone()
            };
            let u = Mat::from_vec(ad.out, self.rank, ad.u.data.clone());
            let v = Mat::from_vec(self.rank, ad.inn, ad.v.data.clone());
            let prod = u.matmul_with(&v, self.pool);
            out.extend(base.iter().zip(prod.data).map(|(b, p)| b + scale * p));
        }
        Ok(out)
    }

    /// LoRA's delta IS the adapter set: (U, V) per layer, base untouched.
    fn export_delta(&self) -> Result<Vec<FpTensor>> {
        let mut out = Vec::with_capacity(2 * self.adapters.len());
        for ad in &self.adapters {
            out.push(ad.u.clone());
            out.push(ad.v.clone());
        }
        Ok(out)
    }

    /// Install adapters from a delta export.  Adapter Adam moments reset
    /// (see the trait docs); ReLoRA's merge counter is untouched — the
    /// delta describes adapter state, not merge history.
    fn import_delta(&mut self, deltas: Vec<FpTensor>) -> Result<()> {
        ensure!(
            deltas.len() == 2 * self.adapters.len(),
            "LoRA delta import: {} tensors for {} adapters (want 2 per adapter)",
            deltas.len(),
            self.adapters.len()
        );
        let mut it = deltas.into_iter();
        for ad in self.adapters.iter_mut() {
            let u = it.next().expect("length checked above");
            let v = it.next().expect("length checked above");
            ensure!(
                u.name == ad.u.name && v.name == ad.v.name,
                "LoRA delta import: tensor names ({}, {}) do not match adapter ({}, {})",
                u.name,
                v.name,
                ad.u.name,
                ad.v.name
            );
            ensure!(
                u.shape == ad.u.shape && v.shape == ad.v.shape,
                "LoRA delta import: {} shapes {:?}/{:?} do not match {:?}/{:?}",
                ad.name,
                u.shape,
                v.shape,
                ad.u.shape,
                ad.v.shape
            );
            ad.u = u;
            ad.v = v;
            ad.st_u = AdamFp::zeros(ad.out * self.rank);
            ad.st_v = AdamFp::zeros(self.rank * ad.inn);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{ConfigEntry, Manifest};
    use crate::model::ModelConfig;

    fn entry() -> ConfigEntry {
        ConfigEntry {
            model: ModelConfig {
                name: "lora-test".into(),
                vocab_size: 8,
                dim: 4,
                n_layers: 1,
                n_heads: 2,
                ffn_dim: 8,
                max_seq_len: 4,
                rank: 2,
                tied_head: true,
            },
            fp_params: vec![("emb".into(), vec![8, 4])],
            linear_params: vec![("l0.w".into(), vec![4, 4])],
            artifacts: Default::default(),
            init_path: std::path::PathBuf::new(),
            init_numel: 8 * 4 + 4 * 4,
        }
    }

    fn manifest() -> Manifest {
        Manifest {
            dir: std::path::PathBuf::new(),
            block: 256,
            galore_scale: 0.25,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            lora_alpha: 16.0,
            batch: 1,
            configs: Default::default(),
            updates: Default::default(),
        }
    }

    fn lora() -> Lora {
        let e = entry();
        let n: usize = 8 * 4 + 4 * 4;
        let init: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        Lora::new(Method::LoRa, &e, &init, 16.0, 7, ParallelCtx::serial())
    }

    #[test]
    fn delta_roundtrip_restores_adapters() {
        let mut a = lora();
        // perturb the adapters so the roundtrip moves real state
        for ad in a.adapters.iter_mut() {
            for x in ad.u.data.iter_mut() {
                *x += 0.25;
            }
        }
        let delta = a.export_delta().unwrap();
        let mut b = lora();
        assert_ne!(a.adapters[0].u.data, b.adapters[0].u.data);
        b.import_delta(delta).unwrap();
        assert_eq!(a.adapters[0].u.data, b.adapters[0].u.data);
        assert_eq!(a.adapters[0].v.data, b.adapters[0].v.data);
    }

    #[test]
    fn import_rejects_short_list_and_wrong_names() {
        let mut l = lora();
        assert!(l.import_delta(Vec::new()).is_err(), "short list must be an error");
        let mut delta = l.export_delta().unwrap();
        delta[0].name = "someone.else.lora_u".into();
        assert!(l.import_delta(delta).is_err(), "wrong names must be an error");
    }

    #[test]
    fn update_with_short_grad_list_is_error_not_panic() {
        // regression for the `it.next().unwrap()` chain: a truncated
        // gradient list must surface as Err
        let man = manifest();
        let rt = crate::runtime::Runtime::new().unwrap();
        let ctx = StepCtx { rt: &rt, man: &man, step: 1, lr: 1e-3 };
        let mut l = lora();
        let err = l.apply_update(&ctx, Vec::new()).unwrap_err();
        assert!(err.to_string().contains("gradient tensors"), "{err}");
    }
}
