//! LoRA / ReLoRA / QLoRA baselines: frozen base weights + trainable rank-r
//! adapter pairs (U (out,r), V (r,in)), optimized with fp Adam.
//!
//! * LoRA: base f32 (counted BF16 by the memory model).
//! * QLoRA: base in blockwise INT8 (paper: "we keep the base models in
//!   8bits for fair comparison").
//! * ReLoRA: LoRA plus a periodic merge: base += (alpha/r)·U·V, adapters
//!   re-initialized, adapter optimizer states reset (Lialin et al. 2023).

use anyhow::Result;

use crate::linalg::{Mat, ParallelCtx};
use crate::manifest::ConfigEntry;
use crate::quant::{self, QuantTensor};
use crate::runtime::HostTensor;
use crate::util::Pcg32;

use super::{run_adam_fp, split_init, AdamFp, FpTensor, Method, Optimizer, StepCtx};

struct AdapterPair {
    name: String,
    out: usize,
    inn: usize,
    u: FpTensor, // (out, r)
    v: FpTensor, // (r, in)
    st_u: AdamFp,
    st_v: AdamFp,
}

pub struct Lora {
    method: Method,
    rank: usize,
    lora_alpha: f32,
    fp: Vec<FpTensor>, // frozen (embedding, norms)
    base_fp: Vec<FpTensor>,
    base_q: Vec<QuantTensor>,
    adapters: Vec<AdapterPair>,
    rng: Pcg32,
    /// ReLoRA merge period in steps (0 = never).
    pub merge_every: u64,
    merges_done: u64,
    pool: ParallelCtx,
}

impl Lora {
    pub fn new(
        method: Method,
        entry: &ConfigEntry,
        init: &[f32],
        lora_alpha: f32,
        seed: u64,
        pool: ParallelCtx,
    ) -> Self {
        assert!(matches!(method, Method::LoRa | Method::ReLoRa | Method::QLoRa));
        let (fp, lin) = split_init(init, &entry.fp_params, &entry.linear_params);
        let rank = entry.model.rank;
        let mut rng = Pcg32::new(seed, 0x10ad);
        let mut adapters = Vec::new();
        for t in &lin {
            let (out, inn) = (t.shape[0], t.shape[1]);
            adapters.push(Self::fresh_adapter(&t.name, out, inn, rank, &mut rng));
        }
        let (base_fp, base_q) = if method == Method::QLoRa {
            (Vec::new(), lin.iter().map(|t| quant::quantize(&t.data, 8)).collect())
        } else {
            (lin, Vec::new())
        };
        Lora {
            method,
            rank,
            lora_alpha,
            fp,
            base_fp,
            base_q,
            adapters,
            rng,
            merge_every: 0, // the factory sets the ReLoRA period
            merges_done: 0,
            pool,
        }
    }

    fn fresh_adapter(
        name: &str,
        out: usize,
        inn: usize,
        rank: usize,
        rng: &mut Pcg32,
    ) -> AdapterPair {
        // standard LoRA init (Hu et al.): A = V (r, in) kaiming-scaled
        // gaussian, B = U (out, r) zero — the adapter product starts at
        // zero and dU ∝ V is immediately well-scaled.
        let v_std = 1.0 / (inn as f32).sqrt();
        AdapterPair {
            name: name.to_string(),
            out,
            inn,
            u: FpTensor {
                name: format!("{name}.lora_u"),
                shape: vec![out, rank],
                data: vec![0.0; out * rank],
            },
            v: FpTensor {
                name: format!("{name}.lora_v"),
                shape: vec![rank, inn],
                data: rng.normal_vec(rank * inn, 0.0, v_std),
            },
            st_u: AdamFp::zeros(out * rank),
            st_v: AdamFp::zeros(rank * inn),
        }
    }

    /// ReLoRA merge: fold adapters into the base and restart them.
    pub fn merge_and_restart(&mut self) {
        assert_eq!(self.method, Method::ReLoRa);
        let scale = self.lora_alpha / self.rank as f32;
        for (base, ad) in self.base_fp.iter_mut().zip(&mut self.adapters) {
            let u = Mat::from_vec(ad.out, self.rank, ad.u.data.clone());
            let v = Mat::from_vec(self.rank, ad.inn, ad.v.data.clone());
            let prod = u.matmul_with(&v, self.pool);
            for (b, p) in base.data.iter_mut().zip(prod.data) {
                *b += scale * p;
            }
            *ad = Self::fresh_adapter(&ad.name.clone(), ad.out, ad.inn, self.rank, &mut self.rng);
        }
        self.merges_done += 1;
    }

    pub fn merges_done(&self) -> u64 {
        self.merges_done
    }
}

impl Optimizer for Lora {
    fn method(&self) -> Method {
        self.method
    }

    fn fwd_artifact(&self) -> &'static str {
        if self.method == Method::QLoRa {
            "qlora_fwd_bwd"
        } else {
            "lora_fwd_bwd"
        }
    }

    fn forward_operands(&self) -> Vec<HostTensor> {
        let mut ops: Vec<HostTensor> =
            self.fp.iter().map(|t| HostTensor::F32(t.data.clone())).collect();
        if self.method == Method::QLoRa {
            for q in &self.base_q {
                ops.push(HostTensor::I8(q.q.clone()));
                ops.push(HostTensor::F32(q.scale.clone()));
                ops.push(HostTensor::F32(q.zero.clone()));
            }
        } else {
            for t in &self.base_fp {
                ops.push(HostTensor::F32(t.data.clone()));
            }
        }
        for ad in &self.adapters {
            ops.push(HostTensor::F32(ad.u.data.clone()));
            ops.push(HostTensor::F32(ad.v.data.clone()));
        }
        ops
    }

    // NOTE: no `apply_update_dataflow` override — ReLoRA's merge couples
    // every adapter to the base weights, so the default sequential
    // fallback is the correct factoring for the LoRA family.
    fn apply_update(&mut self, ctx: &StepCtx, grads: Vec<HostTensor>) -> Result<()> {
        // grads: (dU, dV) per adapter, in layer order
        assert_eq!(grads.len(), 2 * self.adapters.len());
        let mut it = grads.into_iter();
        for ad in self.adapters.iter_mut() {
            let gu = it.next().unwrap().into_f32()?;
            let gv = it.next().unwrap().into_f32()?;
            run_adam_fp(ctx, &mut ad.u, &mut ad.st_u, &gu)?;
            run_adam_fp(ctx, &mut ad.v, &mut ad.st_v, &gv)?;
        }
        Ok(())
    }

    fn on_step_end(&mut self, ctx: &StepCtx) -> Result<()> {
        if self.method == Method::ReLoRa
            && self.merge_every > 0
            && ctx.step % self.merge_every == 0
        {
            self.merge_and_restart();
        }
        Ok(())
    }

    fn live_bytes(&self) -> u64 {
        let mut b: u64 = self.fp.iter().map(|t| t.numel() as u64 * 4).sum();
        b += self.base_fp.iter().map(|t| t.numel() as u64 * 4).sum::<u64>();
        b += self.base_q.iter().map(|q| q.storage_bytes() as u64).sum::<u64>();
        for ad in &self.adapters {
            b += (ad.u.numel() + ad.v.numel()) as u64 * 4;
            b += ad.st_u.bytes() + ad.st_v.bytes();
        }
        b
    }

    fn export_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for t in &self.fp {
            out.extend_from_slice(&t.data);
        }
        let scale = self.lora_alpha / self.rank as f32;
        for (i, ad) in self.adapters.iter().enumerate() {
            let base: Vec<f32> = if self.method == Method::QLoRa {
                quant::dequantize(&self.base_q[i])
            } else {
                self.base_fp[i].data.clone()
            };
            let u = Mat::from_vec(ad.out, self.rank, ad.u.data.clone());
            let v = Mat::from_vec(self.rank, ad.inn, ad.v.data.clone());
            let prod = u.matmul_with(&v, self.pool);
            out.extend(base.iter().zip(prod.data).map(|(b, p)| b + scale * p));
        }
        Ok(out)
    }
}
