//! Optimizer zoo: every training method of the paper's evaluation, driving
//! the AOT update-step artifacts.
//!
//! Each implementation owns its parameter and optimizer-state buffers in
//! their *storage* formats (f32, blockwise INT8, nibble-packed INT4) and
//! knows (a) which fwd/bwd artifact computes its gradients, (b) how to lay
//! its buffers out as artifact operands, and (c) which update artifacts to
//! execute per tensor.  All heavy math happens inside the artifacts (L1
//! Pallas kernels); this module is buffer management and scheduling.

pub mod dataflow;
pub mod factory;
pub mod full;
pub mod galore;
pub mod lora;
pub mod lowrank;
pub mod method;

pub use dataflow::StepGraphBuilder;
pub use factory::{build, build_with_init, BuildOptions};
pub use method::Method;

use anyhow::Result;

use crate::linalg::WorkerPool;
use crate::manifest::{ArtifactSpec, Manifest};
use crate::runtime::{HostTensor, Runtime};

/// Per-step context handed to `Optimizer::apply_update`.
///
/// Host-side parallelism is NOT part of this context: each optimizer owns
/// one `ParallelCtx` (set from `BuildOptions::pool` by the factory) so a
/// step cannot mix two different worker budgets.  The ctx is a *handle*
/// onto the persistent worker pool — copies share the same long-lived
/// workers, so per-call dispatch is a queue push, not a thread spawn.
///
/// `Copy` (a shared `&Runtime` plus plain scalars): the dataflow step
/// hands every per-layer update chain its own copy, and the runtime's
/// interior mutability lets the chains execute artifacts concurrently.
#[derive(Clone, Copy)]
pub struct StepCtx<'a> {
    pub rt: &'a Runtime,
    pub man: &'a Manifest,
    /// 1-based optimization step (Adam bias correction)
    pub step: u64,
    pub lr: f32,
}

impl<'a> StepCtx<'a> {
    /// `[1/(1-b1^t), 1/(1-b2^t)]` — the `c` operand of every update artifact.
    pub fn corrections(&self) -> HostTensor {
        let t = self.step as i32;
        let c1 = 1.0 / (1.0 - self.man.beta1.powi(t));
        let c2 = 1.0 / (1.0 - self.man.beta2.powi(t));
        HostTensor::F32(vec![c1, c2])
    }

    pub fn lr_operand(&self) -> HostTensor {
        HostTensor::F32(vec![self.lr])
    }
}

/// A named f32 parameter tensor.
#[derive(Clone, Debug)]
pub struct FpTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl FpTensor {
    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Full-precision Adam moments for one tensor.
#[derive(Clone, Debug)]
pub struct AdamFp {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl AdamFp {
    pub fn zeros(numel: usize) -> Self {
        AdamFp { m: vec![0.0; numel], v: vec![0.0; numel] }
    }

    pub fn bytes(&self) -> u64 {
        (self.m.len() + self.v.len()) as u64 * 4
    }
}

/// The interface the coordinator drives.
///
/// `Send` so the trainer can run the update phase as a pool task that
/// overlaps with next-batch preparation.
pub trait Optimizer: Send {
    fn method(&self) -> Method;

    /// Name of the model-level fwd/bwd artifact (key into
    /// `ConfigEntry::artifacts`).
    fn fwd_artifact(&self) -> &'static str;

    /// Name of the eval artifact (loss only).  Defaults to the fwd/bwd
    /// artifact — callers read result 0 and ignore gradients.
    fn eval_artifact(&self) -> &'static str {
        self.fwd_artifact()
    }

    /// Parameter operands in ABI order (everything before tokens/targets).
    fn forward_operands(&self) -> Vec<HostTensor>;

    /// Consume the gradient results (everything after the loss) and update
    /// parameters/states in place, walking tensors sequentially.
    fn apply_update(&mut self, ctx: &StepCtx, grads: Vec<HostTensor>) -> Result<()>;

    /// Dataflow variant of [`Optimizer::apply_update`]: factor the step
    /// into per-tensor/per-layer chains with disjoint state and run them
    /// as a dependency graph on `pool` ([`WorkerPool::run_graph`]), so
    /// independent layer updates overlap.
    ///
    /// Contract: bitwise-identical final state to the sequential walk for
    /// any worker count, steal seed, and slab setting (pinned by
    /// `tests/golden_trace.rs` / `tests/proptests.rs`).  The default falls
    /// back to the sequential walk — correct for any optimizer, used by
    /// methods whose updates have not been factored (e.g. LoRA's
    /// merge-coupled adapters).
    fn apply_update_dataflow(
        &mut self,
        ctx: &StepCtx,
        grads: Vec<HostTensor>,
        _pool: &WorkerPool,
    ) -> Result<()> {
        self.apply_update(ctx, grads)
    }

    /// Actually-allocated bytes of params + optimizer state + projections.
    fn live_bytes(&self) -> u64;

    /// (total subspace computations, fraction vs plain-GaLore schedule).
    fn svd_stats(&self, _step: u64) -> Option<(u64, f64)> {
        None
    }

    /// Per-layer subspace cosine-similarity history (Figure 2 probe).
    fn similarity_history(&self) -> Option<Vec<(String, Vec<f32>)>> {
        None
    }

    /// Method-specific periodic maintenance (e.g. ReLoRA merge).
    fn on_step_end(&mut self, _ctx: &StepCtx) -> Result<()> {
        Ok(())
    }

    /// Export all model params as flat f32 in the `fwd_bwd_fp` ABI order
    /// (fp params then full linear weights): INT8 weights dequantized,
    /// adapters merged into the base, factor pairs multiplied out.  This is
    /// the checkpoint format shared across methods (fine-tuning handoff).
    fn export_flat(&self) -> Result<Vec<f32>>;

    /// Export this method's *delta state* — the per-user personalization
    /// that rides on top of a shared base (LoRA adapters, low-rank
    /// factors) — as named, shaped f32 tensors for the `QGDC` delta
    /// checkpoint (`coordinator::checkpoint::save_delta`).  Methods that
    /// train the base weights in place have no base/delta split and
    /// return `Err` — callers fall back to [`Optimizer::export_flat`].
    fn export_delta(&self) -> Result<Vec<FpTensor>> {
        Err(anyhow::anyhow!(
            "{} trains the base in place; it has no delta state to export",
            self.method()
        ))
    }

    /// Import delta state previously produced by
    /// [`Optimizer::export_delta`] (tensor names, count, and shapes are
    /// validated; any mismatch is an `Err`, never a partial import).
    /// Optimizer moments reset to zero: the flat delta stores the
    /// personalization only — resumable moment state lives in the richer
    /// multijob delta sections (`coordinator::multijob`).
    fn import_delta(&mut self, _deltas: Vec<FpTensor>) -> Result<()> {
        Err(anyhow::anyhow!(
            "{} trains the base in place; it cannot import delta state",
            self.method()
        ))
    }
}

// ---------------------------------------------------------------------------
// Shared artifact-driving helpers.
// ---------------------------------------------------------------------------

/// Pull the next artifact output, or fail with a structured error naming
/// the missing tensor.  Update paths consume result lists positionally; a
/// truncated list (artifact/ABI drift, a stub backend returning partial
/// results) must surface as this step's `Err`, not a panic mid-update.
pub(crate) fn next_out(
    it: &mut impl Iterator<Item = HostTensor>,
    what: &str,
) -> Result<HostTensor> {
    it.next()
        .ok_or_else(|| anyhow::anyhow!("artifact returned too few outputs: missing {what}"))
}

pub(crate) fn adam_artifact<'m>(man: &'m Manifest, numel: usize) -> Result<&'m ArtifactSpec> {
    man.update(&format!("adam_step_{numel}"))
}

pub(crate) fn adam8_artifact<'m>(man: &'m Manifest, numel: usize) -> Result<&'m ArtifactSpec> {
    man.update(&format!("adam8bit_step_{numel}"))
}

/// Run one fp Adam step on a flat tensor through its artifact.
pub(crate) fn run_adam_fp(
    ctx: &StepCtx,
    w: &mut FpTensor,
    st: &mut AdamFp,
    g: &[f32],
) -> Result<()> {
    let spec = adam_artifact(ctx.man, w.numel())?;
    let outs = ctx.rt.execute(
        spec,
        &[
            HostTensor::F32(g.to_vec()),
            HostTensor::F32(std::mem::take(&mut st.m)),
            HostTensor::F32(std::mem::take(&mut st.v)),
            HostTensor::F32(std::mem::take(&mut w.data)),
            ctx.corrections(),
            ctx.lr_operand(),
        ],
    )?;
    let mut it = outs.into_iter();
    w.data = next_out(&mut it, "updated weights")?.into_f32()?;
    st.m = next_out(&mut it, "Adam m")?.into_f32()?;
    st.v = next_out(&mut it, "Adam v")?.into_f32()?;
    Ok(())
}

/// Run one blockwise 8-bit Adam step on a flat tensor through its artifact.
pub(crate) fn run_adam_8bit(
    ctx: &StepCtx,
    w: &mut FpTensor,
    st: &mut crate::quant::Adam8State,
    g: &[f32],
) -> Result<()> {
    let spec = adam8_artifact(ctx.man, w.numel())?;
    let outs = ctx.rt.execute(
        spec,
        &[
            HostTensor::F32(g.to_vec()),
            HostTensor::I8(std::mem::take(&mut st.mq)),
            HostTensor::F32(std::mem::take(&mut st.ms)),
            HostTensor::U8(std::mem::take(&mut st.vq)),
            HostTensor::F32(std::mem::take(&mut st.vs)),
            HostTensor::F32(std::mem::take(&mut w.data)),
            ctx.corrections(),
            ctx.lr_operand(),
        ],
    )?;
    let mut it = outs.into_iter();
    w.data = next_out(&mut it, "updated weights")?.into_f32()?;
    match next_out(&mut it, "Adam8 mq")? {
        HostTensor::I8(v) => st.mq = v,
        other => return Err(anyhow::anyhow!("mq dtype {:?}", other.dtype())),
    }
    st.ms = next_out(&mut it, "Adam8 ms")?.into_f32()?;
    match next_out(&mut it, "Adam8 vq")? {
        HostTensor::U8(v) => st.vq = v,
        other => return Err(anyhow::anyhow!("vq dtype {:?}", other.dtype())),
    }
    st.vs = next_out(&mut it, "Adam8 vs")?.into_f32()?;
    Ok(())
}

/// Split a flat init checkpoint into named tensors per the manifest's
/// parameter tables. Returns (fp_tensors, linear_tensors).
pub fn split_init(
    init: &[f32],
    fp_params: &[(String, Vec<usize>)],
    linear_params: &[(String, Vec<usize>)],
) -> (Vec<FpTensor>, Vec<FpTensor>) {
    let mut off = 0usize;
    let mut take = |name: &str, shape: &[usize]| {
        let n: usize = shape.iter().product();
        let t = FpTensor {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: init[off..off + n].to_vec(),
        };
        off += n;
        t
    };
    let fp = fp_params.iter().map(|(n, s)| take(n, s)).collect();
    let lin = linear_params.iter().map(|(n, s)| take(n, s)).collect();
    assert_eq!(off, init.len(), "init checkpoint size mismatch");
    (fp, lin)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_init_partitions_exactly() {
        let fp = vec![("a".to_string(), vec![2usize]), ("b".to_string(), vec![3])];
        let lin = vec![("c".to_string(), vec![2, 2])];
        let init: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let (f, l) = split_init(&init, &fp, &lin);
        assert_eq!(f[0].data, vec![0.0, 1.0]);
        assert_eq!(f[1].data, vec![2.0, 3.0, 4.0]);
        assert_eq!(l[0].data, vec![5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    #[should_panic]
    fn split_init_rejects_leftover() {
        let fp = vec![("a".to_string(), vec![2usize])];
        let init = vec![0.0; 3];
        split_init(&init, &fp, &[]);
    }

    #[test]
    fn next_out_short_list_is_error_not_panic() {
        let outs = vec![HostTensor::F32(vec![1.0])];
        let mut it = outs.into_iter();
        assert!(next_out(&mut it, "updated weights").is_ok());
        let err = next_out(&mut it, "Adam m").unwrap_err();
        assert!(
            err.to_string().contains("missing Adam m"),
            "error should name the missing tensor: {err}"
        );
    }
}
