//! Construct an optimizer for any [`Method`] from the manifest + init
//! checkpoint.

use anyhow::Result;

use crate::linalg::ParallelCtx;
use crate::manifest::Manifest;
use crate::scheduler::SchedulerConfig;

use super::full::{Adam8bit, FullAdam};
use super::galore::{Galore, GaloreKind};
use super::lora::Lora;
use super::lowrank::LowRank;
use super::{Method, Optimizer};

/// Knobs that vary per experiment (ablations).
#[derive(Clone, Copy, Debug)]
pub struct BuildOptions {
    pub seed: u64,
    /// subspace scheduler config for the galore family
    pub sched: SchedulerConfig,
    /// projection quantization bits for Q-GaLore (Figure 3 ablation)
    pub proj_bits: u32,
    /// stochastic rounding for Q-GaLore weight requantization (Figure 6
    /// ablation; false = round-to-nearest)
    pub use_sr: bool,
    /// ReLoRA merge period (steps); 0 disables merging
    pub relora_merge_every: u64,
    /// worker-pool handle + thread budget for host-side linalg (CLI
    /// `--threads` / env; the default handle is the process-global
    /// persistent pool, spun up once and shared by every optimizer)
    pub pool: ParallelCtx,
}

impl Default for BuildOptions {
    fn default() -> Self {
        BuildOptions {
            seed: 0,
            sched: SchedulerConfig::default(),
            proj_bits: 4,
            use_sr: true,
            relora_merge_every: 0,
            pool: ParallelCtx::global(),
        }
    }
}

/// Build from the manifest's init checkpoint (pre-training from scratch).
pub fn build(
    method: Method,
    man: &Manifest,
    cfg_name: &str,
    opts: BuildOptions,
) -> Result<Box<dyn Optimizer>> {
    let init = man.load_init(cfg_name)?;
    build_with_init(method, man, cfg_name, &init, opts)
}

/// Build from an explicit flat checkpoint (fine-tuning a pretrained model).
pub fn build_with_init(
    method: Method,
    man: &Manifest,
    cfg_name: &str,
    init: &[f32],
    opts: BuildOptions,
) -> Result<Box<dyn Optimizer>> {
    let entry = man.config(cfg_name)?;
    let init = init.to_vec();
    Ok(match method {
        Method::Full => Box::new(FullAdam::new(entry, &init, opts.pool)),
        Method::Adam8bit => Box::new(Adam8bit::new(entry, &init, opts.pool)),
        Method::LowRank => Box::new(LowRank::new(entry, &init, opts.seed, opts.pool)),
        Method::LoRa | Method::ReLoRa | Method::QLoRa => {
            let mut l = Lora::new(method, entry, &init, man.lora_alpha, opts.seed, opts.pool);
            if method == Method::ReLoRa {
                l.merge_every = opts.relora_merge_every;
            }
            Box::new(l)
        }
        Method::GaLore => Box::new(Galore::new(
            GaloreKind::Fp,
            entry,
            &init,
            // plain GaLore uses the fixed schedule unless the caller
            // explicitly enables adaptivity (Figure 7 ablation)
            SchedulerConfig { adaptive: false, ..opts.sched },
            opts.seed,
            opts.pool,
        )),
        Method::GaLore8bit => Box::new(Galore::new(
            GaloreKind::Bit8,
            entry,
            &init,
            SchedulerConfig { adaptive: false, ..opts.sched },
            opts.seed,
            opts.pool,
        )),
        Method::QGaLore => {
            let mut g = Galore::new(
                GaloreKind::Quantized,
                entry,
                &init,
                opts.sched,
                opts.seed,
                opts.pool,
            );
            g.proj_bits = opts.proj_bits;
            g.use_sr = opts.use_sr;
            Box::new(g)
        }
    })
}
