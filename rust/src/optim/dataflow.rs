//! Step-graph construction for the dataflow training step.
//!
//! [`StepGraphBuilder`] is a thin, fallibility-aware layer over
//! [`WorkerPool::run_graph`]: optimizer code describes one step as nodes
//! (per-tensor Adam calls, per-layer project→Adam8→update chains, refresh
//! waves) wired by [`NodeId`] dependencies, and [`StepGraphBuilder::run`]
//! executes the graph and converts any node failure — an artifact `Err` or
//! a panic — into the step's single `anyhow::Result`.  That conversion is
//! what lets a panic inside one layer's update chain resurface in
//! `Trainer::step`'s `Result` while the pool survives
//! (`tests/pool_stress.rs`).
//!
//! Determinism contract (shared by every `apply_update_dataflow`
//! implementation and pinned by `tests/golden_trace.rs` /
//! `tests/proptests.rs`): nodes may race, so everything a node touches
//! must be either (a) state owned by exactly one chain — per-layer
//! weights, moments, projections — so concurrent updates commute, or
//! (b) pre-assigned during serial planning — SR noise seeds, sketch
//! seeds — in the exact order the sequential walk would have consumed it.
//! Cross-layer reductions (loss, scheduler recording) happen once, after
//! the graph joins, in layer order.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};

use anyhow::{anyhow, Error, Result};

use crate::linalg::{GraphNode, WorkerPool};

/// Handle to a node added to a [`StepGraphBuilder`]; used to declare
/// dependencies of later nodes.  Only valid for the builder that issued it.
#[derive(Clone, Copy, Debug)]
pub struct NodeId(usize);

/// Builder for one training step's dependency graph.
#[derive(Default)]
pub struct StepGraphBuilder<'scope> {
    nodes: Vec<GraphNode<'scope>>,
}

impl<'scope> StepGraphBuilder<'scope> {
    pub fn new() -> Self {
        StepGraphBuilder { nodes: Vec::new() }
    }

    /// Add an infallible node that starts after every node in `deps`.
    pub fn node(&mut self, deps: &[NodeId], task: impl FnOnce() + Send + 'scope) -> NodeId {
        let id = NodeId(self.nodes.len());
        self.nodes.push(GraphNode::new(deps.iter().map(|d| d.0).collect(), task));
        id
    }

    /// Add a node whose task can fail.  An `Err` is carried to
    /// [`StepGraphBuilder::run`]'s return value (via a typed panic the
    /// graph executor's first-panic latch transports), aborting
    /// not-yet-started nodes.
    pub fn fallible(
        &mut self,
        deps: &[NodeId],
        task: impl FnOnce() -> Result<()> + Send + 'scope,
    ) -> NodeId {
        self.node(deps, move || {
            if let Err(e) = task() {
                std::panic::panic_any(e);
            }
        })
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Execute the graph on `pool`; block until every node has settled.
    /// The first node failure (Err or panic) becomes this call's `Err`.
    pub fn run(self, pool: &WorkerPool) -> Result<()> {
        let nodes = self.nodes;
        match catch_unwind(AssertUnwindSafe(|| pool.run_graph(nodes))) {
            Ok(()) => Ok(()),
            Err(payload) => Err(payload_to_error(payload)),
        }
    }
}

/// Downcast a graph panic payload back into the step error: a `fallible`
/// node's `anyhow::Error` passes through unchanged; genuine panics keep
/// their message.
fn payload_to_error(payload: Box<dyn Any + Send>) -> Error {
    match payload.downcast::<Error>() {
        Ok(e) => *e,
        Err(payload) => match payload.downcast::<String>() {
            Ok(s) => anyhow!("step task panicked: {s}"),
            Err(payload) => match payload.downcast::<&'static str>() {
                Ok(s) => anyhow!("step task panicked: {s}"),
                Err(_) => anyhow!("step task panicked"),
            },
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    #[test]
    fn builder_wires_dependencies() {
        let pool = WorkerPool::with_steal_seed(4, 1);
        let log = Mutex::new(Vec::new());
        let mut b = StepGraphBuilder::new();
        let a = b.node(&[], || log.lock().unwrap().push(1));
        let c = b.node(&[a], || log.lock().unwrap().push(2));
        b.node(&[c], || log.lock().unwrap().push(3));
        assert_eq!(b.len(), 3);
        b.run(&pool).unwrap();
        assert_eq!(*log.lock().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn fallible_err_becomes_run_err_and_skips_dependents() {
        let pool = WorkerPool::with_steal_seed(4, 2);
        let ran = AtomicUsize::new(0);
        let mut b = StepGraphBuilder::new();
        let bad = b.fallible(&[], || Err(anyhow!("layer 3 artifact rejected operand")));
        b.node(&[bad], || {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        let err = b.run(&pool).expect_err("node Err must surface");
        assert!(
            err.to_string().contains("layer 3 artifact rejected operand"),
            "error lost its message: {err}"
        );
        assert_eq!(ran.load(Ordering::Relaxed), 0, "dependent of failed node must not run");
    }

    #[test]
    fn panic_payload_becomes_run_err() {
        let pool = WorkerPool::with_steal_seed(2, 3);
        let mut b = StepGraphBuilder::new();
        b.node(&[], || panic!("chain blew up at step 7"));
        b.node(&[], || {});
        let err = b.run(&pool).expect_err("panic must surface as Err");
        assert!(err.to_string().contains("chain blew up at step 7"), "got: {err}");
        // the pool survives for the next step
        let mut b2 = StepGraphBuilder::new();
        let done = AtomicUsize::new(0);
        b2.node(&[], || {
            done.fetch_add(1, Ordering::Relaxed);
        });
        b2.run(&pool).unwrap();
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn empty_graph_is_ok() {
        let pool = WorkerPool::with_steal_seed(1, 4);
        let b = StepGraphBuilder::new();
        assert!(b.is_empty());
        b.run(&pool).unwrap();
    }
}
