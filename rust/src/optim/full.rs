//! Baselines "Full" (fp Adam) and "8-bit Adam": full-rank training, no
//! projection, weights in full precision.

use anyhow::Result;

use crate::linalg::{engine, par_map, ParallelCtx, WorkerPool};
use crate::manifest::ConfigEntry;
use crate::quant::Adam8State;
use crate::runtime::HostTensor;

use super::{
    run_adam_8bit, run_adam_fp, split_init, AdamFp, FpTensor, Method, Optimizer, StepCtx,
    StepGraphBuilder,
};

/// Marshal the fp param tensors as artifact operands, cloning buffers in
/// parallel on the persistent worker pool (memory-bound but scales with
/// core count). Tiny models stay serial — even pool dispatch would exceed
/// the memcpy.
fn clone_operands(pool: ParallelCtx, fp: &[FpTensor], lin: &[FpTensor]) -> Vec<HostTensor> {
    let refs: Vec<&FpTensor> = fp.iter().chain(lin.iter()).collect();
    let total: usize = refs.iter().map(|t| t.numel()).sum();
    let pool = engine::clone_pool(total, pool);
    par_map(pool, &refs, |t| HostTensor::F32(t.data.clone()))
}

pub struct FullAdam {
    pub fp: Vec<FpTensor>,
    pub lin: Vec<FpTensor>,
    states: Vec<AdamFp>, // fp tensors then linear tensors
    pub pool: ParallelCtx,
}

impl FullAdam {
    pub fn new(entry: &ConfigEntry, init: &[f32], pool: ParallelCtx) -> Self {
        let (fp, lin) = split_init(init, &entry.fp_params, &entry.linear_params);
        let states = fp
            .iter()
            .chain(lin.iter())
            .map(|t| AdamFp::zeros(t.numel()))
            .collect();
        FullAdam { fp, lin, states, pool }
    }
}

impl Optimizer for FullAdam {
    fn method(&self) -> Method {
        Method::Full
    }

    fn fwd_artifact(&self) -> &'static str {
        "fwd_bwd_fp"
    }

    fn eval_artifact(&self) -> &'static str {
        "eval_fwd_fp"
    }

    fn forward_operands(&self) -> Vec<HostTensor> {
        clone_operands(self.pool, &self.fp, &self.lin)
    }

    fn apply_update(&mut self, ctx: &StepCtx, grads: Vec<HostTensor>) -> Result<()> {
        let n_fp = self.fp.len();
        anyhow::ensure!(
            grads.len() == n_fp + self.lin.len(),
            "full-rank update: {} gradient tensors for {} params",
            grads.len(),
            n_fp + self.lin.len()
        );
        for (i, g) in grads.into_iter().enumerate() {
            let g = g.into_f32()?;
            let (w, st) = if i < n_fp {
                (&mut self.fp[i], &mut self.states[i])
            } else {
                (&mut self.lin[i - n_fp], &mut self.states[i])
            };
            run_adam_fp(ctx, w, st, &g)?;
        }
        Ok(())
    }

    fn apply_update_dataflow(
        &mut self,
        ctx: &StepCtx,
        grads: Vec<HostTensor>,
        pool: &WorkerPool,
    ) -> Result<()> {
        // Every tensor's Adam step owns disjoint (w, m, v) state, so the
        // whole update is one flat layer of independent graph nodes.
        let n_fp = self.fp.len();
        anyhow::ensure!(
            grads.len() == n_fp + self.lin.len(),
            "full-rank dataflow update: {} gradient tensors for {} params",
            grads.len(),
            n_fp + self.lin.len()
        );
        let mut flat = Vec::with_capacity(grads.len());
        for g in grads {
            flat.push(g.into_f32()?);
        }
        let cx = *ctx;
        let mut b = StepGraphBuilder::new();
        let tensors = self.fp.iter_mut().chain(self.lin.iter_mut());
        for ((w, st), g) in tensors.zip(self.states.iter_mut()).zip(flat) {
            b.fallible(&[], move || run_adam_fp(&cx, w, st, &g));
        }
        b.run(pool)
    }

    fn live_bytes(&self) -> u64 {
        let w: u64 = self
            .fp
            .iter()
            .chain(self.lin.iter())
            .map(|t| t.numel() as u64 * 4)
            .sum();
        let s: u64 = self.states.iter().map(|s| s.bytes()).sum();
        w + s
    }

    fn export_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for t in self.fp.iter().chain(self.lin.iter()) {
            out.extend_from_slice(&t.data);
        }
        Ok(out)
    }
}

pub struct Adam8bit {
    pub fp: Vec<FpTensor>,
    pub lin: Vec<FpTensor>,
    states: Vec<Adam8State>,
    pub pool: ParallelCtx,
}

impl Adam8bit {
    pub fn new(entry: &ConfigEntry, init: &[f32], pool: ParallelCtx) -> Self {
        let (fp, lin) = split_init(init, &entry.fp_params, &entry.linear_params);
        let states = fp
            .iter()
            .chain(lin.iter())
            .map(|t| Adam8State::zeros(t.numel()))
            .collect();
        Adam8bit { fp, lin, states, pool }
    }
}

impl Optimizer for Adam8bit {
    fn method(&self) -> Method {
        Method::Adam8bit
    }

    fn fwd_artifact(&self) -> &'static str {
        "fwd_bwd_fp"
    }

    fn eval_artifact(&self) -> &'static str {
        "eval_fwd_fp"
    }

    fn forward_operands(&self) -> Vec<HostTensor> {
        clone_operands(self.pool, &self.fp, &self.lin)
    }

    fn apply_update(&mut self, ctx: &StepCtx, grads: Vec<HostTensor>) -> Result<()> {
        let n_fp = self.fp.len();
        for (i, g) in grads.into_iter().enumerate() {
            let g = g.into_f32()?;
            let (w, st) = if i < n_fp {
                (&mut self.fp[i], &mut self.states[i])
            } else {
                (&mut self.lin[i - n_fp], &mut self.states[i])
            };
            run_adam_8bit(ctx, w, st, &g)?;
        }
        Ok(())
    }

    fn apply_update_dataflow(
        &mut self,
        ctx: &StepCtx,
        grads: Vec<HostTensor>,
        pool: &WorkerPool,
    ) -> Result<()> {
        // Same flat fan-out as `FullAdam`: disjoint per-tensor 8-bit state.
        let mut flat = Vec::with_capacity(grads.len());
        for g in grads {
            flat.push(g.into_f32()?);
        }
        let cx = *ctx;
        let mut b = StepGraphBuilder::new();
        let tensors = self.fp.iter_mut().chain(self.lin.iter_mut());
        for ((w, st), g) in tensors.zip(self.states.iter_mut()).zip(flat) {
            b.fallible(&[], move || run_adam_8bit(&cx, w, st, &g));
        }
        b.run(pool)
    }

    fn live_bytes(&self) -> u64 {
        let w: u64 = self
            .fp
            .iter()
            .chain(self.lin.iter())
            .map(|t| t.numel() as u64 * 4)
            .sum();
        let s: u64 = self.states.iter().map(|s| s.storage_bytes() as u64).sum();
        w + s
    }

    fn export_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for t in self.fp.iter().chain(self.lin.iter()) {
            out.extend_from_slice(&t.data);
        }
        Ok(out)
    }
}
