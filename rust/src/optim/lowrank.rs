//! "Low-Rank" baseline: the linear weights are *replaced* by a factorization
//! W = U V trained directly (Kamalakara et al. 2022).  Unlike LoRA there is
//! no frozen base, which is why the paper's Table 1 shows it degrading
//! sharply at scale — the model simply has no full-rank expressivity.

use anyhow::{ensure, Result};

use crate::linalg::{par_map, ParallelCtx, WorkerPool};
use crate::manifest::ConfigEntry;
use crate::runtime::HostTensor;
use crate::util::Pcg32;

use super::{
    next_out, run_adam_fp, split_init, AdamFp, FpTensor, Method, Optimizer, StepCtx,
    StepGraphBuilder,
};

struct FactorPair {
    u: FpTensor, // (out, r)
    v: FpTensor, // (r, in)
    st_u: AdamFp,
    st_v: AdamFp,
}

pub struct LowRank {
    fp: Vec<FpTensor>,
    fp_states: Vec<AdamFp>,
    factors: Vec<FactorPair>,
    pub pool: ParallelCtx,
}

impl LowRank {
    pub fn new(entry: &ConfigEntry, init: &[f32], seed: u64, pool: ParallelCtx) -> Self {
        let (fp, lin) = split_init(init, &entry.fp_params, &entry.linear_params);
        let rank = entry.model.rank;
        let mut rng = Pcg32::new(seed, 0x10f2);
        let mut factors = Vec::new();
        for t in &lin {
            let (out, inn) = (t.shape[0], t.shape[1]);
            // scale so that (U V) has roughly the init std of W
            let std = (0.02f32 / (rank as f32).sqrt()).sqrt();
            factors.push(FactorPair {
                u: FpTensor {
                    name: format!("{}.u", t.name),
                    shape: vec![out, rank],
                    data: rng.normal_vec(out * rank, 0.0, std),
                },
                v: FpTensor {
                    name: format!("{}.v", t.name),
                    shape: vec![rank, inn],
                    data: rng.normal_vec(rank * inn, 0.0, std),
                },
                st_u: AdamFp::zeros(out * rank),
                st_v: AdamFp::zeros(rank * inn),
            });
        }
        let fp_states = fp.iter().map(|t| AdamFp::zeros(t.numel())).collect();
        LowRank { fp, fp_states, factors, pool }
    }
}

impl Optimizer for LowRank {
    fn method(&self) -> Method {
        Method::LowRank
    }

    fn fwd_artifact(&self) -> &'static str {
        "lowrank_fwd_bwd"
    }

    fn forward_operands(&self) -> Vec<HostTensor> {
        // buffer cloning fans out over the persistent pool above the
        // PAR_MIN_CLONE_ELEMS gate (same policy as every optimizer)
        let total: usize = self.fp.iter().map(|t| t.numel()).sum::<usize>()
            + self.factors.iter().map(|f| f.u.numel() + f.v.numel()).sum::<usize>();
        let pool = crate::linalg::clone_pool(total, self.pool);
        let mut ops: Vec<HostTensor> =
            par_map(pool, &self.fp, |t| HostTensor::F32(t.data.clone()));
        let pairs: Vec<[HostTensor; 2]> = par_map(pool, &self.factors, |f| {
            [
                HostTensor::F32(f.u.data.clone()),
                HostTensor::F32(f.v.data.clone()),
            ]
        });
        ops.extend(pairs.into_iter().flatten());
        ops
    }

    fn apply_update(&mut self, ctx: &StepCtx, grads: Vec<HostTensor>) -> Result<()> {
        let n_fp = self.fp.len();
        ensure!(
            grads.len() == n_fp + 2 * self.factors.len(),
            "LowRank update: {} gradient tensors for {} fp params + {} factor pairs",
            grads.len(),
            n_fp,
            self.factors.len()
        );
        let mut it = grads.into_iter();
        for i in 0..n_fp {
            let g = next_out(&mut it, "fp param grad")?.into_f32()?;
            run_adam_fp(ctx, &mut self.fp[i], &mut self.fp_states[i], &g)?;
        }
        for f in self.factors.iter_mut() {
            let gu = next_out(&mut it, "factor dU")?.into_f32()?;
            let gv = next_out(&mut it, "factor dV")?.into_f32()?;
            run_adam_fp(ctx, &mut f.u, &mut f.st_u, &gu)?;
            run_adam_fp(ctx, &mut f.v, &mut f.st_v, &gv)?;
        }
        Ok(())
    }

    fn apply_update_dataflow(
        &mut self,
        ctx: &StepCtx,
        grads: Vec<HostTensor>,
        pool: &WorkerPool,
    ) -> Result<()> {
        // U and V of one factor pair are separate tensors with separate
        // Adam states (the bwd artifact emits g_u and g_v independently),
        // so every factor contributes TWO independent graph nodes.
        let n_fp = self.fp.len();
        ensure!(
            grads.len() == n_fp + 2 * self.factors.len(),
            "LowRank dataflow update: {} gradient tensors for {} fp params + {} factor pairs",
            grads.len(),
            n_fp,
            self.factors.len()
        );
        let mut flat = Vec::with_capacity(grads.len());
        for g in grads {
            flat.push(g.into_f32()?);
        }
        let mut it = flat.into_iter();
        let cx = *ctx;
        let mut b = StepGraphBuilder::new();
        for (w, st) in self.fp.iter_mut().zip(self.fp_states.iter_mut()) {
            let g = it.next().expect("length checked above");
            b.fallible(&[], move || run_adam_fp(&cx, w, st, &g));
        }
        for f in self.factors.iter_mut() {
            let FactorPair { u, v, st_u, st_v } = f;
            let gu = it.next().expect("length checked above");
            let gv = it.next().expect("length checked above");
            b.fallible(&[], move || run_adam_fp(&cx, u, st_u, &gu));
            b.fallible(&[], move || run_adam_fp(&cx, v, st_v, &gv));
        }
        b.run(pool)
    }

    fn live_bytes(&self) -> u64 {
        let mut b: u64 = self.fp.iter().map(|t| t.numel() as u64 * 4).sum();
        b += self.fp_states.iter().map(|s| s.bytes()).sum::<u64>();
        for f in &self.factors {
            b += (f.u.numel() + f.v.numel()) as u64 * 4;
            b += f.st_u.bytes() + f.st_v.bytes();
        }
        b
    }

    fn export_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for t in &self.fp {
            out.extend_from_slice(&t.data);
        }
        for f in &self.factors {
            let (out_dim, rank) = (f.u.shape[0], f.u.shape[1]);
            let inn = f.v.shape[1];
            let u = crate::linalg::Mat::from_vec(out_dim, rank, f.u.data.clone());
            let v = crate::linalg::Mat::from_vec(rank, inn, f.v.data.clone());
            out.extend(u.matmul_with(&v, self.pool).data);
        }
        Ok(out)
    }

    /// LowRank's trainable linear state is exactly the factor pairs.
    /// The fp params (embedding, norms) train too, so this delta is only
    /// the low-rank portion — documented asymmetry with LoRA's adapters.
    fn export_delta(&self) -> Result<Vec<FpTensor>> {
        let mut out = Vec::with_capacity(2 * self.factors.len());
        for f in &self.factors {
            out.push(f.u.clone());
            out.push(f.v.clone());
        }
        Ok(out)
    }

    /// Install factor pairs from a delta export; Adam moments reset (see
    /// the trait docs).
    fn import_delta(&mut self, deltas: Vec<FpTensor>) -> Result<()> {
        ensure!(
            deltas.len() == 2 * self.factors.len(),
            "LowRank delta import: {} tensors for {} factor pairs (want 2 per pair)",
            deltas.len(),
            self.factors.len()
        );
        let mut it = deltas.into_iter();
        for f in self.factors.iter_mut() {
            let u = it.next().expect("length checked above");
            let v = it.next().expect("length checked above");
            ensure!(
                u.name == f.u.name && v.name == f.v.name,
                "LowRank delta import: tensor names ({}, {}) do not match factors ({}, {})",
                u.name,
                v.name,
                f.u.name,
                f.v.name
            );
            ensure!(
                u.shape == f.u.shape && v.shape == f.v.shape,
                "LowRank delta import: shapes {:?}/{:?} do not match {:?}/{:?}",
                u.shape,
                v.shape,
                f.u.shape,
                f.v.shape
            );
            f.st_u = AdamFp::zeros(u.data.len());
            f.st_v = AdamFp::zeros(v.data.len());
            f.u = u;
            f.v = v;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{ConfigEntry, Manifest};
    use crate::model::ModelConfig;

    fn lowrank() -> LowRank {
        let entry = ConfigEntry {
            model: ModelConfig {
                name: "lowrank-test".into(),
                vocab_size: 8,
                dim: 4,
                n_layers: 1,
                n_heads: 2,
                ffn_dim: 8,
                max_seq_len: 4,
                rank: 2,
                tied_head: true,
            },
            fp_params: vec![("emb".into(), vec![8, 4])],
            linear_params: vec![("l0.w".into(), vec![4, 4])],
            artifacts: Default::default(),
            init_path: std::path::PathBuf::new(),
            init_numel: 8 * 4 + 4 * 4,
        };
        let init: Vec<f32> = (0..entry.init_numel).map(|i| i as f32 * 0.01).collect();
        LowRank::new(&entry, &init, 11, ParallelCtx::serial())
    }

    #[test]
    fn delta_roundtrip_restores_factors() {
        let mut a = lowrank();
        for f in a.factors.iter_mut() {
            for x in f.u.data.iter_mut() {
                *x += 0.5;
            }
        }
        let delta = a.export_delta().unwrap();
        let mut b = lowrank();
        assert_ne!(a.factors[0].u.data, b.factors[0].u.data);
        b.import_delta(delta).unwrap();
        assert_eq!(a.factors[0].u.data, b.factors[0].u.data);
        assert_eq!(a.factors[0].v.data, b.factors[0].v.data);
    }

    #[test]
    fn import_rejects_short_list() {
        let mut l = lowrank();
        let err = l.import_delta(Vec::new()).unwrap_err();
        assert!(err.to_string().contains("2 per pair"), "{err}");
    }

    #[test]
    fn update_with_short_grad_list_is_error_not_panic() {
        let man = Manifest {
            dir: std::path::PathBuf::new(),
            block: 256,
            galore_scale: 0.25,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            lora_alpha: 16.0,
            batch: 1,
            configs: Default::default(),
            updates: Default::default(),
        };
        let rt = crate::runtime::Runtime::new().unwrap();
        let ctx = StepCtx { rt: &rt, man: &man, step: 1, lr: 1e-3 };
        let mut l = lowrank();
        let err = l.apply_update(&ctx, Vec::new()).unwrap_err();
        assert!(err.to_string().contains("gradient tensors"), "{err}");
        let err = l
            .apply_update_dataflow(&ctx, Vec::new(), &WorkerPool::with_steal_seed(2, 3))
            .unwrap_err();
        assert!(err.to_string().contains("gradient tensors"), "{err}");
    }
}
