//! "Low-Rank" baseline: the linear weights are *replaced* by a factorization
//! W = U V trained directly (Kamalakara et al. 2022).  Unlike LoRA there is
//! no frozen base, which is why the paper's Table 1 shows it degrading
//! sharply at scale — the model simply has no full-rank expressivity.

use anyhow::Result;

use crate::linalg::{par_map, ParallelCtx, WorkerPool};
use crate::manifest::ConfigEntry;
use crate::runtime::HostTensor;
use crate::util::Pcg32;

use super::{
    run_adam_fp, split_init, AdamFp, FpTensor, Method, Optimizer, StepCtx, StepGraphBuilder,
};

struct FactorPair {
    u: FpTensor, // (out, r)
    v: FpTensor, // (r, in)
    st_u: AdamFp,
    st_v: AdamFp,
}

pub struct LowRank {
    fp: Vec<FpTensor>,
    fp_states: Vec<AdamFp>,
    factors: Vec<FactorPair>,
    pub pool: ParallelCtx,
}

impl LowRank {
    pub fn new(entry: &ConfigEntry, init: &[f32], seed: u64, pool: ParallelCtx) -> Self {
        let (fp, lin) = split_init(init, &entry.fp_params, &entry.linear_params);
        let rank = entry.model.rank;
        let mut rng = Pcg32::new(seed, 0x10f2);
        let mut factors = Vec::new();
        for t in &lin {
            let (out, inn) = (t.shape[0], t.shape[1]);
            // scale so that (U V) has roughly the init std of W
            let std = (0.02f32 / (rank as f32).sqrt()).sqrt();
            factors.push(FactorPair {
                u: FpTensor {
                    name: format!("{}.u", t.name),
                    shape: vec![out, rank],
                    data: rng.normal_vec(out * rank, 0.0, std),
                },
                v: FpTensor {
                    name: format!("{}.v", t.name),
                    shape: vec![rank, inn],
                    data: rng.normal_vec(rank * inn, 0.0, std),
                },
                st_u: AdamFp::zeros(out * rank),
                st_v: AdamFp::zeros(rank * inn),
            });
        }
        let fp_states = fp.iter().map(|t| AdamFp::zeros(t.numel())).collect();
        LowRank { fp, fp_states, factors, pool }
    }
}

impl Optimizer for LowRank {
    fn method(&self) -> Method {
        Method::LowRank
    }

    fn fwd_artifact(&self) -> &'static str {
        "lowrank_fwd_bwd"
    }

    fn forward_operands(&self) -> Vec<HostTensor> {
        // buffer cloning fans out over the persistent pool above the
        // PAR_MIN_CLONE_ELEMS gate (same policy as every optimizer)
        let total: usize = self.fp.iter().map(|t| t.numel()).sum::<usize>()
            + self.factors.iter().map(|f| f.u.numel() + f.v.numel()).sum::<usize>();
        let pool = crate::linalg::clone_pool(total, self.pool);
        let mut ops: Vec<HostTensor> =
            par_map(pool, &self.fp, |t| HostTensor::F32(t.data.clone()));
        let pairs: Vec<[HostTensor; 2]> = par_map(pool, &self.factors, |f| {
            [
                HostTensor::F32(f.u.data.clone()),
                HostTensor::F32(f.v.data.clone()),
            ]
        });
        ops.extend(pairs.into_iter().flatten());
        ops
    }

    fn apply_update(&mut self, ctx: &StepCtx, grads: Vec<HostTensor>) -> Result<()> {
        let n_fp = self.fp.len();
        assert_eq!(grads.len(), n_fp + 2 * self.factors.len());
        let mut it = grads.into_iter();
        for i in 0..n_fp {
            let g = it.next().unwrap().into_f32()?;
            run_adam_fp(ctx, &mut self.fp[i], &mut self.fp_states[i], &g)?;
        }
        for f in self.factors.iter_mut() {
            let gu = it.next().unwrap().into_f32()?;
            let gv = it.next().unwrap().into_f32()?;
            run_adam_fp(ctx, &mut f.u, &mut f.st_u, &gu)?;
            run_adam_fp(ctx, &mut f.v, &mut f.st_v, &gv)?;
        }
        Ok(())
    }

    fn apply_update_dataflow(
        &mut self,
        ctx: &StepCtx,
        grads: Vec<HostTensor>,
        pool: &WorkerPool,
    ) -> Result<()> {
        // U and V of one factor pair are separate tensors with separate
        // Adam states (the bwd artifact emits g_u and g_v independently),
        // so every factor contributes TWO independent graph nodes.
        let n_fp = self.fp.len();
        assert_eq!(grads.len(), n_fp + 2 * self.factors.len());
        let mut flat = Vec::with_capacity(grads.len());
        for g in grads {
            flat.push(g.into_f32()?);
        }
        let mut it = flat.into_iter();
        let cx = *ctx;
        let mut b = StepGraphBuilder::new();
        for (w, st) in self.fp.iter_mut().zip(self.fp_states.iter_mut()) {
            let g = it.next().unwrap();
            b.fallible(&[], move || run_adam_fp(&cx, w, st, &g));
        }
        for f in self.factors.iter_mut() {
            let FactorPair { u, v, st_u, st_v } = f;
            let gu = it.next().unwrap();
            let gv = it.next().unwrap();
            b.fallible(&[], move || run_adam_fp(&cx, u, st_u, &gu));
            b.fallible(&[], move || run_adam_fp(&cx, v, st_v, &gv));
        }
        b.run(pool)
    }

    fn live_bytes(&self) -> u64 {
        let mut b: u64 = self.fp.iter().map(|t| t.numel() as u64 * 4).sum();
        b += self.fp_states.iter().map(|s| s.bytes()).sum::<u64>();
        for f in &self.factors {
            b += (f.u.numel() + f.v.numel()) as u64 * 4;
            b += f.st_u.bytes() + f.st_v.bytes();
        }
        b
    }

    fn export_flat(&self) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        for t in &self.fp {
            out.extend_from_slice(&t.data);
        }
        for f in &self.factors {
            let (out_dim, rank) = (f.u.shape[0], f.u.shape[1]);
            let inn = f.v.shape[1];
            let u = crate::linalg::Mat::from_vec(out_dim, rank, f.u.data.clone());
            let v = crate::linalg::Mat::from_vec(rank, inn, f.v.data.clone());
            out.extend(u.matmul_with(&v, self.pool).data);
        }
        Ok(out)
    }
}
