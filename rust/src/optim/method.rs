//! The training-method taxonomy used across the crate (paper §4.1 baselines
//! plus Q-GaLore itself).

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Adam, full rank, full precision (paper "Full").
    Full,
    /// Adam with blockwise 8-bit optimizer states (paper "8-bit Adam").
    Adam8bit,
    /// W = U V factorization trained directly (paper "Low-Rank").
    LowRank,
    /// Frozen full-precision base + rank-r adapters (paper "LoRA").
    LoRa,
    /// LoRA with periodic merge-and-restart (paper "ReLoRA").
    ReLoRa,
    /// LoRA over an 8-bit quantized frozen base (paper "QLoRA").
    QLoRa,
    /// Gradient low-rank projection, fp weights + fp Adam (paper "GaLore").
    GaLore,
    /// GaLore with 8-bit Adam states (paper "8-bit GaLore").
    GaLore8bit,
    /// This paper: INT8 weights (stochastic rounding), INT4 projection,
    /// 8-bit Adam, lazy layer-adaptive subspace updates.
    QGaLore,
}

impl Method {
    pub const ALL: [Method; 9] = [
        Method::Full,
        Method::Adam8bit,
        Method::LowRank,
        Method::LoRa,
        Method::ReLoRa,
        Method::QLoRa,
        Method::GaLore,
        Method::GaLore8bit,
        Method::QGaLore,
    ];

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "full" => Method::Full,
            "adam8bit" | "8bit-adam" | "8-bit-adam" => Method::Adam8bit,
            "lowrank" | "low-rank" => Method::LowRank,
            "lora" => Method::LoRa,
            "relora" => Method::ReLoRa,
            "qlora" => Method::QLoRa,
            "galore" => Method::GaLore,
            "galore8bit" | "8bit-galore" | "8-bit-galore" => Method::GaLore8bit,
            "qgalore" | "q-galore" => Method::QGaLore,
            _ => return None,
        })
    }

    /// Does the method keep weights in INT8 storage?
    pub fn int8_weights(self) -> bool {
        matches!(self, Method::QGaLore)
    }

    /// Does the method project gradients through a low-rank subspace?
    pub fn galore_family(self) -> bool {
        matches!(self, Method::GaLore | Method::GaLore8bit | Method::QGaLore)
    }

    /// Does the method use adapter/factor pairs instead of full weights?
    pub fn adapter_family(self) -> bool {
        matches!(
            self,
            Method::LowRank | Method::LoRa | Method::ReLoRa | Method::QLoRa
        )
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Method::Full => "Full",
            Method::Adam8bit => "8-bit Adam",
            Method::LowRank => "Low-Rank",
            Method::LoRa => "LoRA",
            Method::ReLoRa => "ReLoRA",
            Method::QLoRa => "QLoRA",
            Method::GaLore => "GaLore",
            Method::GaLore8bit => "8-bit GaLore",
            Method::QGaLore => "Q-GaLore",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::ALL {
            let s = m.to_string().to_ascii_lowercase().replace(' ', "-");
            assert_eq!(Method::parse(&s), Some(m), "{s}");
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn families() {
        assert!(Method::QGaLore.galore_family());
        assert!(Method::QGaLore.int8_weights());
        assert!(!Method::GaLore.int8_weights());
        assert!(Method::QLoRa.adapter_family());
        assert!(!Method::Full.adapter_family());
    }
}
