//! Regenerates every table and figure of the paper's evaluation (the
//! experiment index lives in DESIGN.md §5).
//!
//! Perplexities are measured on the trainable tiny configs; memory columns
//! come from the analytic model evaluated at the paper's exact scales.  The
//! claim being reproduced is the *shape* of each result (method ordering,
//! saving ratios, crossovers), not the authors' absolute numbers — their
//! substrate was a GPU cluster, ours is a CPU PJRT simulator.
//!
//! Every harness prints a paper-style table to stdout and writes CSV series
//! under `results/` for figures.

use anyhow::Result;

use crate::coordinator::{finetune, pretrain, FinetuneConfig, TrainConfig};
use crate::manifest::Manifest;
use crate::memory;
use crate::model::paper_config;
use crate::optim::{BuildOptions, Method};
use crate::report::{f, f4, write_csv, Table};
use crate::scheduler::SchedulerConfig;
use crate::util::human_bytes;

#[derive(Clone, Debug)]
pub struct ReproOptions {
    /// training steps per run (tiny default keeps `repro all` minutes-scale)
    pub steps: u64,
    pub out_dir: String,
    pub cfg_name: String,
    pub seed: u64,
    pub quiet: bool,
}

impl Default for ReproOptions {
    fn default() -> Self {
        ReproOptions {
            steps: 150,
            out_dir: "results".into(),
            cfg_name: "llama-tiny".into(),
            seed: 0,
            quiet: true,
        }
    }
}

fn tc(o: &ReproOptions, method: Method) -> TrainConfig {
    TrainConfig {
        cfg_name: o.cfg_name.clone(),
        method,
        steps: o.steps,
        lr_max: 0.01,
        warmup: o.steps / 10,
        eval_every: 0,
        eval_batches: 8,
        n_documents: 512,
        seed: o.seed,
        opts: BuildOptions {
            seed: o.seed,
            // tiny runs need a proportionally tighter refresh interval than
            // the paper's 200/150k steps
            sched: SchedulerConfig { base_interval: o.steps / 10, ..Default::default() },
            ..Default::default()
        },
        log_every: (o.steps / 6).max(1),
        quiet: o.quiet,
        dataflow: crate::coordinator::dataflow_default(),
    }
}

/// Table 1: pre-training perplexity + memory across methods.
pub fn table1(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let methods = [
        Method::Full,
        Method::LowRank,
        Method::LoRa,
        Method::ReLoRa,
        Method::GaLore,
        Method::QGaLore,
    ];
    let mut t = Table::new(&[
        "Method",
        &format!("PPL ({})", o.cfg_name),
        "Live bytes (measured)",
        "60M",
        "130M",
        "350M",
        "1B",
    ]);
    let mut csv = Vec::new();
    for m in methods {
        let mut cfg = tc(o, m);
        if m == Method::ReLoRa {
            cfg.opts.relora_merge_every = (o.steps / 3).max(1);
        }
        let r = pretrain(man, cfg)?;
        let paper_cols: Vec<String> = ["llama-60m", "llama-130m", "llama-350m", "llama-1b"]
            .iter()
            .map(|n| memory::estimate_str(&paper_config(n).unwrap(), m))
            .collect();
        csv.push(vec![
            m.to_string(),
            f4(r.final_ppl),
            r.live_bytes.to_string(),
            paper_cols[0].clone(),
            paper_cols[1].clone(),
            paper_cols[2].clone(),
            paper_cols[3].clone(),
        ]);
        t.row(vec![
            m.to_string(),
            f(r.final_ppl),
            human_bytes(r.live_bytes),
            paper_cols[0].clone(),
            paper_cols[1].clone(),
            paper_cols[2].clone(),
            paper_cols[3].clone(),
        ]);
    }
    write_csv(
        format!("{}/table1.csv", o.out_dir),
        &["method", "ppl", "live_bytes", "mem60m", "mem130m", "mem350m", "mem1b"],
        &csv,
    )?;
    let out = format!("## Table 1 — pre-training (measured @ {})\n\n{}", o.cfg_name, t.render());
    println!("{out}");
    Ok(out)
}

/// Table 2: 7B-scale methods (8-bit Adam / 8-bit GaLore / Q-GaLore).
pub fn table2(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let methods = [Method::Adam8bit, Method::GaLore8bit, Method::QGaLore];
    let mut t = Table::new(&[
        "Method",
        &format!("PPL ({})", o.cfg_name),
        "Live bytes",
        "7B total (model)",
        "fits 16GB",
    ]);
    let mut csv = Vec::new();
    let seven_b = paper_config("llama-7b").unwrap();
    for m in methods {
        let r = pretrain(man, tc(o, m))?;
        let total = memory::breakdown(&seven_b, m, 2048).total();
        let fits = total < 16_000_000_000;
        csv.push(vec![
            m.to_string(),
            f4(r.final_ppl),
            r.live_bytes.to_string(),
            total.to_string(),
            fits.to_string(),
        ]);
        t.row(vec![
            m.to_string(),
            f(r.final_ppl),
            human_bytes(r.live_bytes),
            human_bytes(total),
            if fits { "yes".into() } else { "no".into() },
        ]);
    }
    write_csv(
        format!("{}/table2.csv", o.out_dir),
        &["method", "ppl", "live_bytes", "mem7b_total", "fits_16gb"],
        &csv,
    )?;
    let out = format!("## Table 2 — 7B pre-training proxy\n\n{}", t.render());
    println!("{out}");
    Ok(out)
}

fn finetune_methods() -> [Method; 5] {
    [Method::Full, Method::LoRa, Method::GaLore, Method::QLoRa, Method::QGaLore]
}

/// Shared fine-tuning flow: pretrain one base checkpoint, fine-tune each
/// method from it on `tasks`, return accuracy rows.
fn finetune_grid(
    man: &Manifest,
    o: &ReproOptions,
    tasks: &[(u64, usize)], // (salt, n_labels)
) -> Result<Vec<(Method, Vec<f32>, u64)>> {
    // base checkpoint: a short Full pretrain so fine-tuning starts from a
    // non-random LM (the "pretrained model" of Tables 3-4)
    let mut base_cfg = tc(o, Method::Full);
    base_cfg.steps = o.steps;
    let base = pretrain(man, base_cfg)?;
    let mut rows = Vec::new();
    for m in finetune_methods() {
        let mut accs = Vec::new();
        let mut live = 0u64;
        // per-method fine-tuning LR (swept once; see EXPERIMENTS.md):
        // full fine-tuning needs a small step, adapters a medium one, the
        // galore family tolerates the largest (projection regularizes).
        let lr = match m {
            Method::Full => 0.002,
            Method::LoRa | Method::ReLoRa | Method::QLoRa => 0.003,
            _ => 0.01,
        };
        for &(salt, n_labels) in tasks {
            let fr = finetune(
                man,
                FinetuneConfig {
                    cfg_name: o.cfg_name.clone(),
                    method: m,
                    n_labels,
                    steps: (o.steps * 2).max(200),
                    lr,
                    seed: o.seed,
                    task_salt: salt,
                    n_eval_examples: 40,
                    opts: BuildOptions {
                        seed: o.seed,
                        sched: SchedulerConfig {
                            base_interval: (o.steps / 10).max(5),
                            ..Default::default()
                        },
                        ..Default::default()
                    },
                    quiet: o.quiet,
                },
                &base.final_params,
            )?;
            accs.push(fr.accuracy * 100.0);
            live = fr.live_bytes;
        }
        rows.push((m, accs, live));
    }
    Ok(rows)
}

/// Table 3: MMLU-style fine-tuning (4 subjects) + 7B/8B memory columns.
pub fn table3(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let tasks = [(101u64, 4usize)];
    let rows = finetune_grid(man, o, &tasks)?;
    let mut t = Table::new(&[
        "Method",
        "Acc (4-subject)",
        "Live bytes",
        "LLaMA-3-8B",
        "Gemma-7B",
        "Mistral-7B",
    ]);
    let mut csv = Vec::new();
    for (m, accs, live) in &rows {
        let cols: Vec<String> = ["llama3-8b", "gemma-7b", "mistral-7b"]
            .iter()
            .map(|n| memory::estimate_str(&paper_config(n).unwrap(), *m))
            .collect();
        csv.push(vec![
            m.to_string(),
            f(accs[0]),
            live.to_string(),
            cols[0].clone(),
            cols[1].clone(),
            cols[2].clone(),
        ]);
        t.row(vec![
            m.to_string(),
            f(accs[0]),
            human_bytes(*live),
            cols[0].clone(),
            cols[1].clone(),
            cols[2].clone(),
        ]);
    }
    write_csv(
        format!("{}/table3.csv", o.out_dir),
        &["method", "accuracy", "live_bytes", "mem_llama3_8b", "mem_gemma_7b", "mem_mistral_7b"],
        &csv,
    )?;
    let out = format!("## Table 3 — MMLU-style fine-tuning\n\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Table 4: GLUE-style fine-tuning (8 tasks) + RoBERTa memory column.
pub fn table4(man: &Manifest, o: &ReproOptions) -> Result<String> {
    // 8 tasks: mix of binary and 4-way, distinct salts (like the GLUE suite)
    let tasks: Vec<(u64, usize)> =
        vec![(11, 2), (12, 2), (13, 2), (14, 2), (15, 4), (16, 4), (17, 2), (18, 4)];
    let rows = finetune_grid(man, o, &tasks)?;
    let mut header: Vec<String> = vec!["Method".into()];
    header.extend((1..=8).map(|i| format!("T{i}")));
    header.push("Avg".into());
    header.push("RoBERTa mem".into());
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    let mut csv = Vec::new();
    for (m, accs, _) in &rows {
        let avg = accs.iter().sum::<f32>() / accs.len() as f32;
        let mem = memory::estimate_str(&paper_config("roberta-base").unwrap(), *m);
        let mut row = vec![m.to_string()];
        row.extend(accs.iter().map(|a| f(*a)));
        row.push(f(avg));
        row.push(mem.clone());
        csv.push(row.clone());
        t.row(row);
    }
    let mut csv_hdr: Vec<&str> = vec!["method"];
    let tcols: Vec<String> = (1..=8).map(|i| format!("t{i}")).collect();
    csv_hdr.extend(tcols.iter().map(|s| s.as_str()));
    csv_hdr.push("avg");
    csv_hdr.push("roberta_mem");
    write_csv(format!("{}/table4.csv", o.out_dir), &csv_hdr, &csv)?;
    let out = format!("## Table 4 — GLUE-style fine-tuning\n\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Figure 2: per-layer cosine similarity of adjacent projections.
pub fn fig2(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let mut cfg = tc(o, Method::GaLore);
    // frequent fixed refresh to get a dense similarity series
    cfg.opts.sched = SchedulerConfig {
        base_interval: (o.steps / 15).max(2),
        adaptive: false,
        ..Default::default()
    };
    let r = pretrain(man, cfg)?;
    let mut rows = Vec::new();
    for (layer, sims) in &r.sim_history {
        for (i, s) in sims.iter().enumerate() {
            rows.push(vec![layer.clone(), i.to_string(), f4(*s)]);
        }
    }
    write_csv(
        format!("{}/fig2_cosine_similarity.csv", o.out_dir),
        &["layer", "refresh_idx", "cosine_similarity"],
        &rows,
    )?;
    // summarize: early/mid/late mean similarity per layer
    let mut t = Table::new(&["Layer", "first sim", "last sim", "mean sim"]);
    for (layer, sims) in &r.sim_history {
        if sims.is_empty() {
            continue;
        }
        let mean = sims.iter().sum::<f32>() / sims.len() as f32;
        t.row(vec![
            layer.clone(),
            f4(sims[0]),
            f4(*sims.last().unwrap()),
            f4(mean),
        ]);
    }
    let out = format!(
        "## Figure 2 — projection-similarity dynamics (series in {}/fig2_cosine_similarity.csv)\n\n{}",
        o.out_dir,
        t.render()
    );
    println!("{out}");
    Ok(out)
}

/// Figure 3: perplexity vs projection quantization bits.
pub fn fig3(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let mut t = Table::new(&["Projection bits", "PPL"]);
    let mut csv = Vec::new();
    for bits in [16u32, 8, 4, 2] {
        let mut cfg = tc(o, Method::QGaLore);
        cfg.opts.proj_bits = bits;
        let r = pretrain(man, cfg)?;
        t.row(vec![bits.to_string(), f(r.final_ppl)]);
        csv.push(vec![bits.to_string(), f4(r.final_ppl)]);
    }
    write_csv(format!("{}/fig3_proj_bits.csv", o.out_dir), &["bits", "ppl"], &csv)?;
    let out = format!("## Figure 3 — projection quantization tolerance\n\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Figure 5: end-to-end memory breakdown for 7B training.
pub fn fig5(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let _ = man;
    let cfg = paper_config("llama-7b").unwrap();
    let methods = [
        Method::Full,
        Method::Adam8bit,
        Method::GaLore8bit,
        Method::QGaLore,
    ];
    let mut t = Table::new(&[
        "Method", "Weights", "Optim m", "Optim v", "Projection", "Gradients",
        "Activations", "Total", "fits 16GB",
    ]);
    let mut csv = Vec::new();
    for m in methods {
        let b = memory::breakdown(&cfg, m, 2048);
        t.row(vec![
            m.to_string(),
            human_bytes(b.weights + b.adapters),
            human_bytes(b.optim_m),
            human_bytes(b.optim_v),
            human_bytes(b.projection),
            human_bytes(b.gradients),
            human_bytes(b.activations),
            human_bytes(b.total()),
            if b.total() < 16_000_000_000 { "yes".into() } else { "no".into() },
        ]);
        csv.push(vec![
            m.to_string(),
            b.weights.to_string(),
            b.optim_m.to_string(),
            b.optim_v.to_string(),
            b.projection.to_string(),
            b.gradients.to_string(),
            b.activations.to_string(),
            b.total().to_string(),
        ]);
    }
    write_csv(
        format!("{}/fig5_memory_breakdown.csv", o.out_dir),
        &["method", "weights", "optim_m", "optim_v", "projection", "gradients", "activations", "total"],
        &csv,
    )?;
    let out = format!("## Figure 5 — LLaMA-7B memory breakdown (analytic)\n\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Figure 6: stochastic rounding vs round-to-nearest.
pub fn fig6(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let mut t = Table::new(&["Variant", "PPL", "ΔPPL vs SR"]);
    let mut csv = Vec::new();
    let mut ppl_sr = 0f32;
    for (name, sr) in [("Q-GaLore (SR)", true), ("Q-GaLore w/o SR", false)] {
        let mut cfg = tc(o, Method::QGaLore);
        // probe the small-update regime where rounding policy matters: with
        // large steps both schemes see the gradient; when updates sit below
        // the INT8 quantization step, round-to-nearest swallows them and SR
        // keeps the trajectory (paper §4.4: the gap concentrates in warmup,
        // where updates are small)
        cfg.lr_max = 0.002;
        cfg.opts.use_sr = sr;
        let r = pretrain(man, cfg)?;
        if sr {
            ppl_sr = r.final_ppl;
        }
        t.row(vec![
            name.into(),
            f(r.final_ppl),
            if sr { "-".into() } else { format!("+{:.2}", r.final_ppl - ppl_sr) },
        ]);
        csv.push(vec![name.into(), f4(r.final_ppl)]);
        // also dump the loss curve for the figure
        let curve: Vec<Vec<String>> = r
            .train_losses
            .iter()
            .map(|(s, l)| vec![s.to_string(), f4(*l)])
            .collect();
        write_csv(
            format!(
                "{}/fig6_curve_{}.csv",
                o.out_dir,
                if sr { "sr" } else { "rtn" }
            ),
            &["step", "loss"],
            &curve,
        )?;
    }
    write_csv(format!("{}/fig6_sr_ablation.csv", o.out_dir), &["variant", "ppl"], &csv)?;
    let out = format!("## Figure 6 — stochastic rounding ablation\n\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Figure 7: perplexity vs SVD-call fraction (threshold sweep).
pub fn fig7(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let mut t = Table::new(&["cos threshold", "SVD fraction vs GaLore", "SVD calls", "PPL"]);
    let mut csv = Vec::new();
    for thr in [1.01f32, 0.8, 0.6, 0.4, 0.2, 0.0] {
        let mut cfg = tc(o, Method::QGaLore);
        cfg.opts.sched = SchedulerConfig {
            base_interval: (o.steps / 15).max(2),
            threshold: thr,
            window: 2,
            adaptive: true,
            max_interval: 0,
        };
        let r = pretrain(man, cfg)?;
        t.row(vec![
            format!("{thr:.2}"),
            format!("{:.1}%", r.svd_fraction * 100.0),
            r.svd_count.to_string(),
            f(r.final_ppl),
        ]);
        csv.push(vec![
            format!("{thr:.2}"),
            format!("{:.4}", r.svd_fraction),
            r.svd_count.to_string(),
            f4(r.final_ppl),
        ]);
    }
    write_csv(
        format!("{}/fig7_svd_tradeoff.csv", o.out_dir),
        &["threshold", "svd_fraction", "svd_calls", "ppl"],
        &csv,
    )?;
    let out = format!("## Figure 7 — performance vs SVD count\n\n{}", t.render());
    println!("{out}");
    Ok(out)
}

/// Run everything, return the concatenated report.
pub fn all(man: &Manifest, o: &ReproOptions) -> Result<String> {
    let mut out = String::new();
    for part in [
        table1(man, o)?,
        table2(man, o)?,
        table3(man, o)?,
        table4(man, o)?,
        fig2(man, o)?,
        fig3(man, o)?,
        fig5(man, o)?,
        fig6(man, o)?,
        fig7(man, o)?,
    ] {
        out.push_str(&part);
        out.push('\n');
    }
    Ok(out)
}
