//! Dense linear algebra substrate.
//!
//! GaLore's subspace refresh needs the top-r *left singular subspace* of the
//! gradient matrix.  The paper uses cuSOLVER SVD; we build the equivalent
//! from scratch: Householder QR + randomized subspace iteration (Halko,
//! Martinsson & Tropp 2011).  Subspace iteration converges to the dominant
//! invariant subspace, which is all GaLore consumes — the singular values
//! themselves are discarded.
//!
//! The matmul substrate itself ([`engine`]) is parallel and cache-blocked,
//! and executes on the persistent work-stealing worker pool ([`pool`]):
//! decomposition into disjoint row panels happens in the engine (over-
//! decomposed to ~4 slabs per budgeted worker so stragglers get stolen),
//! execution on long-lived workers with per-worker Chase-Lev deques
//! (wait-free LIFO own-pop, CAS-only PCG-ordered stealing on empty), so
//! per-call dispatch is a lock-free deque push instead of a thread spawn
//! and the dispatch path holds no mutex at any worker count.  Inside each
//! panel a register-blocked SIMD microkernel
//! ([`engine::KernelPath`]: AVX-512 / AVX2 / portable, dispatched at
//! runtime) does the accumulation in the naive reference's exact
//! per-element order.  Frozen quantized projections are additionally
//! packed once per quantization epoch into microkernel-native panels
//! ([`packing`]), so the steady-state projection matmuls skip per-call
//! decode entirely.  Same-shape subspace refreshes batch into one stacked
//! range-finder product ([`left_subspace_batched`]); the naive `*_naive`
//! kernels remain as the bitwise reference the parity tests (and benches)
//! compare against.

pub mod engine;
pub mod packing;
pub mod pool;
pub(crate) mod sync;

pub use engine::{
    clone_pool, global_slabs_per_worker, global_threads, kernel_override, par_map, par_rows,
    set_global_slabs_per_worker, set_global_threads, set_kernel_override,
    simd512_kernel_available, simd_kernel_available, KernelPath, ParallelCtx,
    DEFAULT_SLABS_PER_WORKER, KERNEL_ENV, MAX_SLABS_PER_WORKER, SLABS_ENV, THREADS_ENV,
};
pub use packing::{pack_cache_enabled, set_pack_cache, PanelCache, PanelPack, PACK_CACHE_ENV};
pub use pool::{global_pool, GraphNode, PoolStats, WorkerPool, STEAL_SEED_ENV};

use crate::util::Pcg32;

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg32) -> Self {
        Mat { rows, cols, data: rng.normal_vec(rows * cols, 0.0, 1.0) }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *t.at_mut(c, r) = self.at(r, c);
            }
        }
        t
    }

    /// self (m,k) @ other (k,n) -> (m,n) through the blocked/parallel
    /// engine at the process-global thread count.
    pub fn matmul(&self, other: &Mat) -> Mat {
        engine::matmul(self, other, ParallelCtx::global())
    }

    /// [`Mat::matmul`] with an explicit parallelism context.
    pub fn matmul_with(&self, other: &Mat, ctx: ParallelCtx) -> Mat {
        engine::matmul(self, other, ctx)
    }

    /// Single-threaded ikj reference kernel (parity baseline for the
    /// engine; also what the benches call "old").
    pub fn matmul_naive(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            for kk in 0..k {
                let a = self.at(i, kk);
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// self^T (k,m)^T @ other (k,n) -> (m,n) without materializing the
    /// transpose (the projection step R = P^T G), via the engine.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        engine::t_matmul(self, other, ParallelCtx::global())
    }

    /// [`Mat::t_matmul`] with an explicit parallelism context.
    pub fn t_matmul_with(&self, other: &Mat, ctx: ParallelCtx) -> Mat {
        engine::t_matmul(self, other, ctx)
    }

    /// Single-threaded reference for `t_matmul` (parity baseline).
    pub fn t_matmul_naive(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for kk in 0..k {
            let arow = &self.data[kk * m..(kk + 1) * m];
            let brow = &other.data[kk * n..(kk + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// ||self - other||_F / ||other||_F — the parity metric shared by the
    /// engine tests, parity suite, and benches.
    pub fn rel_frobenius(&self, other: &Mat) -> f32 {
        self.sub(other).frobenius() / other.frobenius().max(1e-12)
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }
}

/// Thin QR via modified Gram–Schmidt with re-orthogonalization.
///
/// For the (m, r) panels of subspace iteration (r << m) MGS with a second
/// pass is numerically adequate and ~2x cheaper than Householder on panels;
/// re-orthogonalization keeps `Q^T Q - I` at f32 roundoff even for highly
/// correlated columns ("twice is enough", Giraud et al. 2005).
pub fn qr_orthonormal(a: &Mat) -> Mat {
    let (m, r) = (a.rows, a.cols);
    let mut q = a.clone();
    for j in 0..r {
        // two orthogonalization passes against previous columns
        for _pass in 0..2 {
            for p in 0..j {
                let mut dot = 0f32;
                for i in 0..m {
                    dot += q.at(i, p) * q.at(i, j);
                }
                for i in 0..m {
                    let v = q.at(i, p);
                    *q.at_mut(i, j) -= dot * v;
                }
            }
        }
        let mut norm = 0f32;
        for i in 0..m {
            norm += q.at(i, j) * q.at(i, j);
        }
        let norm = norm.sqrt();
        if norm > 1e-12 {
            for i in 0..m {
                *q.at_mut(i, j) /= norm;
            }
        } else {
            // degenerate column: replace with a fresh deterministic direction
            for i in 0..m {
                *q.at_mut(i, j) = if i % (j + 2) == 0 { 1.0 } else { 0.0 };
            }
            let mut n2 = 0f32;
            for i in 0..m {
                n2 += q.at(i, j) * q.at(i, j);
            }
            let n2 = n2.sqrt();
            for i in 0..m {
                *q.at_mut(i, j) /= n2;
            }
        }
    }
    q
}

/// Eigendecomposition of a small symmetric matrix via cyclic Jacobi
/// rotations.  Returns (eigenvalues desc, eigenvector columns, same order).
/// Used to canonicalize the randomized subspace (r <= a few hundred).
pub fn symmetric_eig(a: &Mat) -> (Vec<f32>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::zeros(n, n);
    for i in 0..n {
        *v.at_mut(i, i) = 1.0;
    }
    let max_sweeps = 30;
    for _ in 0..max_sweeps {
        let mut off = 0f32;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m.at(p, q) * m.at(p, q);
            }
        }
        if off < 1e-12 * (1.0 + m.frobenius()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let mkp = m.at(k, p);
                    let mkq = m.at(k, q);
                    *m.at_mut(k, p) = c * mkp - s * mkq;
                    *m.at_mut(k, q) = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m.at(p, k);
                    let mqk = m.at(q, k);
                    *m.at_mut(p, k) = c * mpk - s * mqk;
                    *m.at_mut(q, k) = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    *v.at_mut(k, p) = c * vkp - s * vkq;
                    *v.at_mut(k, q) = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort descending by eigenvalue
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| m.at(j, j).partial_cmp(&m.at(i, i)).unwrap());
    let vals: Vec<f32> = idx.iter().map(|&i| m.at(i, i)).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_c, &old_c) in idx.iter().enumerate() {
        for k in 0..n {
            *vecs.at_mut(k, new_c) = v.at(k, old_c);
        }
    }
    (vals, vecs)
}

/// Top-r left singular subspace of `g` (m, n) via randomized subspace
/// iteration (Y = (G G^T)^q G Omega, Q = qr(Y); 2 power steps suffice for
/// GaLore), *canonicalized* to the singular-vector basis: the columns of the
/// result are ordered by singular value, like a truncated SVD — required so
/// that the paper's Figure-2 column-cosine similarity between successive
/// projections is well defined (a raw randomized basis is arbitrarily
/// rotated within the subspace).
pub fn left_subspace(g: &Mat, r: usize, iters: usize, rng: &mut Pcg32) -> Mat {
    left_subspace_with(g, r, iters, rng, ParallelCtx::global())
}

/// [`left_subspace`] with an explicit parallelism context — callers that
/// refresh several layers concurrently split their worker budget with
/// [`ParallelCtx::with_threads`] to avoid nested oversubscription.
pub fn left_subspace_with(
    g: &Mat,
    r: usize,
    iters: usize,
    rng: &mut Pcg32,
    ctx: ParallelCtx,
) -> Mat {
    let r = r.min(g.rows).min(g.cols);
    let omega = Mat::randn(g.cols, r, rng);
    let y = g.matmul_with(&omega, ctx); // (m, r)
    finish_left_subspace(g, &y, iters, ctx)
}

/// Everything after the range-finder product `Y = G Omega`: QR, power
/// iterations, and canonicalization.  Shared between the per-layer and
/// batched refresh paths so the two are bitwise identical by construction.
fn finish_left_subspace(g: &Mat, y: &Mat, iters: usize, ctx: ParallelCtx) -> Mat {
    let mut q = qr_orthonormal(y);
    for _ in 0..iters {
        // Z = G^T Q (n, r); Y = G Z (m, r)
        let z = g.t_matmul_with(&q, ctx);
        let y2 = g.matmul_with(&z, ctx);
        q = qr_orthonormal(&y2);
    }
    // canonicalize: Z = Q^T G; C = Z Z^T; Q <- Q * eigvecs(C)
    let z = q.t_matmul_with(g, ctx); // (r, n)
    let c = z.matmul_with(&z.transpose(), ctx); // (r, r)
    let (_vals, vecs) = symmetric_eig(&c);
    q.matmul_with(&vecs, ctx)
}

/// Shape-batched subspace refresh: [`left_subspace_with`] for several
/// same-shape gradient matrices at once, sharing one range sketch.
///
/// The sketch `Omega` is drawn ONCE from `rng` for the whole group, and the
/// range-finder products are presented to the worker pool as a single
/// stacked `(L*m, n) @ (n, r)` matmul — row panels of the stacked output
/// map straight onto per-layer row blocks, so each layer's slice is bitwise
/// identical to `g.matmul(&omega)` computed on its own.  The per-layer
/// power iterations and canonicalization (whose operands differ per layer
/// and therefore cannot stack) then fan out across `pool`, each with a
/// proportional share of the worker budget.
///
/// Equivalence contract (asserted by `tests/parity.rs`): the result is
/// bitwise identical to calling [`left_subspace_with`] on each `g` with a
/// clone of `rng` — i.e. batching changes dispatch, never projections.
pub fn left_subspace_batched(
    gs: &[&Mat],
    r: usize,
    iters: usize,
    rng: &mut Pcg32,
    pool: ParallelCtx,
) -> Vec<Mat> {
    if gs.is_empty() {
        return Vec::new();
    }
    let (m, n) = (gs[0].rows, gs[0].cols);
    for g in gs {
        assert_eq!((g.rows, g.cols), (m, n), "batched refresh needs one shape");
    }
    let r = r.min(m).min(n);
    let omega = Mat::randn(n, r, rng);
    // one stacked (L*m, n) @ (n, r) range-finder product over all layers:
    // the pool sees a single large matmul instead of L small dispatches,
    // without materializing the stacked gradient (each panel indexes into
    // its owning layer's buffer directly)
    let l = gs.len();
    let lrows = l * m;
    let ctx = engine::effective(pool, lrows, n, r);
    let ydata = engine::par_rows(ctx, lrows, r, |r0, r1, out| {
        let mut row = r0;
        while row < r1 {
            let li = row / m;
            let l0 = row % m;
            let lw = (m - l0).min(r1 - row);
            engine::panel_matmul(
                &gs[li].data[l0 * n..(l0 + lw) * n],
                lw,
                n,
                &omega,
                &mut out[(row - r0) * r..(row - r0 + lw) * r],
            );
            row += lw;
        }
    });
    // per-layer finish, fanned out on the pool with a split worker budget
    // (same outer/inner policy as the optimizer's wave scheduler)
    let ys: Vec<(usize, Mat)> = (0..l)
        .map(|li| (li, Mat::from_vec(m, r, ydata[li * m * r..(li + 1) * m * r].to_vec())))
        .collect();
    let inner = pool.with_threads(pool.threads.div_ceil(l));
    let outer = pool.with_threads(pool.threads.min(l));
    par_map(outer, &ys, |(li, y)| finish_left_subspace(gs[*li], y, iters, inner))
}

/// Cosine similarity between two orthonormal bases of the same shape, as the
/// paper's Figure 2 uses it: mean |cos| between corresponding columns.
pub fn subspace_cosine(a: &Mat, b: &Mat) -> f32 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut acc = 0f32;
    for j in 0..a.cols {
        let mut dot = 0f32;
        let mut na = 0f32;
        let mut nb = 0f32;
        for i in 0..a.rows {
            dot += a.at(i, j) * b.at(i, j);
            na += a.at(i, j) * a.at(i, j);
            nb += b.at(i, j) * b.at(i, j);
        }
        acc += dot.abs() / (na.sqrt() * nb.sqrt()).max(1e-12);
    }
    acc / a.cols as f32
}

/// Projection-invariant similarity: ||A^T B||_F^2 / r in [0, 1].  Robust to
/// column permutation/sign — used by tests to check subspace *recovery*.
pub fn subspace_overlap(a: &Mat, b: &Mat) -> f32 {
    subspace_overlap_with(a, b, ParallelCtx::global())
}

/// [`subspace_overlap`] with an explicit parallelism context.
pub fn subspace_overlap_with(a: &Mat, b: &Mat, ctx: ParallelCtx) -> f32 {
    let prod = a.t_matmul_with(b, ctx); // (ra, rb)
    let f = prod.frobenius();
    f * f / a.cols.min(b.cols).max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn t_matmul_matches_transpose_matmul() {
        let mut rng = Pcg32::seeded(1);
        let a = Mat::randn(17, 5, &mut rng);
        let b = Mat::randn(17, 9, &mut rng);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn qr_produces_orthonormal_columns() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::randn(64, 16, &mut rng);
        let q = qr_orthonormal(&a);
        let gram = q.t_matmul(&q);
        for i in 0..16 {
            for j in 0..16 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (gram.at(i, j) - want).abs() < 1e-4,
                    "gram[{i},{j}] = {}",
                    gram.at(i, j)
                );
            }
        }
    }

    #[test]
    fn qr_spans_input() {
        // span(Q) == span(A): projecting A onto Q reproduces A.
        let mut rng = Pcg32::seeded(3);
        let a = Mat::randn(32, 8, &mut rng);
        let q = qr_orthonormal(&a);
        let proj = q.matmul(&q.t_matmul(&a));
        let diff = proj.sub(&a).frobenius() / a.frobenius();
        assert!(diff < 1e-4, "residual {diff}");
    }

    #[test]
    fn qr_handles_rank_deficient() {
        let mut rng = Pcg32::seeded(4);
        let mut a = Mat::randn(16, 4, &mut rng);
        // duplicate column 0 into column 1
        for i in 0..16 {
            let v = a.at(i, 0);
            *a.at_mut(i, 1) = v;
        }
        let q = qr_orthonormal(&a);
        let gram = q.t_matmul(&q);
        for i in 0..4 {
            assert!((gram.at(i, i) - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn subspace_recovers_low_rank() {
        // G = U_true @ V with rank 4 -> left_subspace must recover span(U_true).
        let mut rng = Pcg32::seeded(5);
        let u_true = qr_orthonormal(&Mat::randn(48, 4, &mut rng));
        let v = Mat::randn(4, 96, &mut rng);
        let g = u_true.matmul(&v);
        let q = left_subspace(&g, 4, 2, &mut rng);
        let overlap = subspace_overlap(&u_true, &q);
        assert!(overlap > 0.999, "overlap {overlap}");
    }

    #[test]
    fn subspace_dominant_directions_with_noise() {
        let mut rng = Pcg32::seeded(6);
        let u_true = qr_orthonormal(&Mat::randn(64, 4, &mut rng));
        let v = Mat::randn(4, 80, &mut rng);
        let strong = u_true.matmul(&v);
        let mut g = strong.clone();
        for x in g.data.iter_mut() {
            *x = *x * 5.0 + rng.next_normal() * 0.1;
        }
        let q = left_subspace(&g, 4, 3, &mut rng);
        let overlap = subspace_overlap(&u_true, &q);
        assert!(overlap > 0.98, "overlap {overlap}");
    }

    #[test]
    fn cosine_identical_is_one() {
        let mut rng = Pcg32::seeded(7);
        let q = qr_orthonormal(&Mat::randn(32, 8, &mut rng));
        assert!((subspace_cosine(&q, &q) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn cosine_sign_invariant() {
        let mut rng = Pcg32::seeded(8);
        let q = qr_orthonormal(&Mat::randn(32, 8, &mut rng));
        let mut neg = q.clone();
        for x in neg.data.iter_mut() {
            *x = -*x;
        }
        assert!((subspace_cosine(&q, &neg) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn cosine_random_is_small() {
        let mut rng = Pcg32::seeded(9);
        let a = qr_orthonormal(&Mat::randn(256, 8, &mut rng));
        let b = qr_orthonormal(&Mat::randn(256, 8, &mut rng));
        assert!(subspace_cosine(&a, &b) < 0.3);
    }

    #[test]
    fn jacobi_eig_diagonalizes() {
        let mut rng = Pcg32::seeded(21);
        let b = Mat::randn(12, 12, &mut rng);
        let a = b.matmul(&b.transpose()); // SPD
        let (vals, vecs) = symmetric_eig(&a);
        // descending, non-negative
        for w in vals.windows(2) {
            assert!(w[0] >= w[1] - 1e-3);
        }
        assert!(vals.iter().all(|&v| v > -1e-3));
        // A v_i = lambda_i v_i
        for i in 0..12 {
            let vi = Mat::from_vec(12, 1, vecs.col(i));
            let av = a.matmul(&vi);
            for k in 0..12 {
                assert!(
                    (av.at(k, 0) - vals[i] * vi.at(k, 0)).abs()
                        < 1e-2 * (1.0 + vals[0]),
                    "eigpair {i}"
                );
            }
        }
    }

    #[test]
    fn canonical_subspace_is_stable_across_rng() {
        // two randomized runs over the same matrix must return (nearly) the
        // same canonical basis — the property Figure 2 depends on.
        let mut rng = Pcg32::seeded(22);
        let u_true = qr_orthonormal(&Mat::randn(48, 6, &mut rng));
        // distinct singular values so the canonical order is unambiguous
        let mut v = Mat::randn(6, 96, &mut rng);
        for j in 0..6 {
            for k in 0..96 {
                *v.at_mut(j, k) *= (6 - j) as f32;
            }
        }
        let g = u_true.matmul(&v);
        let mut r1 = Pcg32::seeded(100);
        let mut r2 = Pcg32::seeded(200);
        let q1 = left_subspace(&g, 4, 3, &mut r1);
        let q2 = left_subspace(&g, 4, 3, &mut r2);
        let sim = subspace_cosine(&q1, &q2);
        assert!(sim > 0.99, "canonical bases disagree: {sim}");
    }

    #[test]
    fn rank_clamped_to_dims() {
        let mut rng = Pcg32::seeded(10);
        let g = Mat::randn(8, 6, &mut rng);
        let q = left_subspace(&g, 32, 2, &mut rng);
        assert_eq!(q.cols, 6);
        assert_eq!(q.rows, 8);
    }

    #[test]
    fn batched_refresh_recovers_each_layer() {
        // three layers with distinct planted subspaces through ONE batched
        // call: each recovered basis must match its own layer, not a blend
        let mut rng = Pcg32::seeded(30);
        let mut gs = Vec::new();
        let mut trues = Vec::new();
        for _ in 0..3 {
            let u_true = qr_orthonormal(&Mat::randn(48, 4, &mut rng));
            let v = Mat::randn(4, 96, &mut rng);
            gs.push(u_true.matmul(&v));
            trues.push(u_true);
        }
        let grefs: Vec<&Mat> = gs.iter().collect();
        let mut brng = Pcg32::seeded(31);
        let qs = left_subspace_batched(&grefs, 4, 2, &mut brng, ParallelCtx::new(4));
        assert_eq!(qs.len(), 3);
        for (u_true, q) in trues.iter().zip(&qs) {
            let overlap = subspace_overlap(u_true, q);
            assert!(overlap > 0.999, "batched refresh lost a layer: {overlap}");
        }
    }

    #[test]
    fn batched_refresh_empty_and_single() {
        let mut rng = Pcg32::seeded(32);
        assert!(left_subspace_batched(&[], 4, 2, &mut rng, ParallelCtx::new(2)).is_empty());
        let g = Mat::randn(24, 36, &mut rng);
        let mut r1 = Pcg32::seeded(33);
        let mut r2 = Pcg32::seeded(33);
        let batched = left_subspace_batched(&[&g], 6, 2, &mut r1, ParallelCtx::new(2));
        let solo = left_subspace_with(&g, 6, 2, &mut r2, ParallelCtx::serial());
        assert_eq!(batched[0].data, solo.data, "L=1 batched must equal solo");
    }
}
