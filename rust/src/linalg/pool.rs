//! Persistent worker pool — the execution substrate under `par_rows` /
//! `par_map` and every fused dequant kernel.
//!
//! PR-1's engine spawned fresh `std::thread::scope` workers per call, which
//! costs ~100us of dispatch per matmul.  That tax is invisible on big dense
//! products but caps speedup exactly where Q-GaLore lives: many small
//! per-layer products (`P^T g`, `P u`, rank-r refreshes) each individually
//! below a millisecond.  This module replaces per-call spawning with a
//! long-lived pool:
//!
//! * Workers are spun up **once** (from `--threads` / `QGALORE_THREADS` via
//!   [`global_pool`], or explicitly via [`WorkerPool::new`]) and block on a
//!   condvar-guarded FIFO job queue between calls.
//! * [`WorkerPool::run_scoped`] submits one call's task set and returns only
//!   after every task has executed, which is what makes handing the pool
//!   closures that borrow the caller's stack sound (see SAFETY below).
//! * While waiting, the submitting thread **helps**: it drains tasks from
//!   the shared queue instead of sleeping.  Helping is not just a latency
//!   optimization — it is the deadlock-freedom argument for *nested*
//!   submission (the galore wave scheduler fans layers out with `par_map`
//!   and each layer's refresh submits its own matmul tasks): a worker
//!   blocked on an inner submission keeps executing queued tasks, so the
//!   queue always drains and every latch eventually opens.
//! * A task that panics is caught, its payload parked on the submission's
//!   latch, and the panic **resumed in the submitting thread** (original
//!   message intact) after the call settles — the pool itself survives,
//!   matching `std::thread::scope` semantics.
//!
//! The pool does not decide decomposition — `par_rows`/`par_map` still split
//! work into the same disjoint slabs keyed by `ParallelCtx::threads`, so
//! results are bitwise identical to the scoped-thread engine and to a
//! 1-thread run regardless of how many pool workers actually execute the
//! slabs (asserted by `tests/parity.rs`).

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A queued unit of work.  Tasks are erased to `'static` at submission; the
/// latch protocol in [`WorkerPool::run_scoped`] is what keeps that sound.
type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    /// signalled when tasks are pushed (and at shutdown)
    available: Condvar,
    shutdown: AtomicBool,
}

/// Completion latch for one `run_scoped` submission.  Carries the first
/// caught panic payload so the submitter can resume it verbatim — the
/// original assert/index message survives, like `std::thread::scope`.
struct Latch {
    left: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            left: Mutex::new(n),
            done: Condvar::new(),
            panic: Mutex::new(None),
        }
    }

    fn complete(&self) {
        let mut left = self.left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.left.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut left = self.left.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// A long-lived pool of worker threads with a shared FIFO job queue.
///
/// One process-global instance ([`global_pool`]) backs `ParallelCtx::new` /
/// `::global`; tests and benches construct private instances (usually via
/// [`WorkerPool::leaked`], since `ParallelCtx` carries a `&'static` handle
/// so it can stay `Copy`).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers` (clamped to 1+) threads, parked on the job queue.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("qgalore-pool-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, workers }
    }

    /// A leaked (process-lifetime) pool: the `&'static` handle form that
    /// [`super::ParallelCtx::with_pool`] takes.  Used by tests and benches
    /// that need explicit pool sizes; the workers are never joined.
    pub fn leaked(workers: usize) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::new(workers)))
    }

    /// Number of worker threads (excluding helping submitters).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute every task and return once all have completed.
    ///
    /// The submitting thread helps drain the queue while it waits, so
    /// calling this from *inside* a pool task (nested submission) cannot
    /// deadlock.  If any task panicked, the panic is re-thrown here after
    /// the whole submission has settled.
    ///
    /// SAFETY invariant: tasks may borrow data with lifetime `'scope`
    /// (shorter than `'static`).  They are transmuted to `'static` to sit
    /// in the shared queue, which is sound because this function does not
    /// return until the latch confirms every submitted task has finished
    /// running — no task can outlive the borrows it captures.
    pub fn run_scoped<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        if tasks.len() == 1 {
            // nothing to fan out; run inline (panics propagate naturally)
            (tasks.into_iter().next().unwrap())();
            return;
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for task in tasks {
                let l = Arc::clone(&latch);
                let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                    if let Err(payload) =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(task))
                    {
                        let mut slot = l.panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                    }
                    l.complete();
                });
                // SAFETY: see the invariant above — we block on `latch`
                // below until every wrapped task has run to completion, so
                // the 'scope borrows stay live for every execution.
                let wrapped: Task = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(wrapped)
                };
                q.push_back(wrapped);
            }
            self.shared.available.notify_all();
        }
        // Help while waiting: run queued tasks (ours or another
        // submission's) until the queue is momentarily empty, then block on
        // the latch for whatever is still in flight on the workers.
        loop {
            if latch.is_done() {
                break;
            }
            let task = self.shared.queue.lock().unwrap().pop_front();
            match task {
                Some(t) => t(),
                None => {
                    latch.wait();
                    break;
                }
            }
        }
        let payload = latch.panic.lock().unwrap().take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool").field("workers", &self.workers).finish_non_exhaustive()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            // signal under the queue lock: a worker is either holding the
            // lock (and will see the flag on its next check) or already
            // waiting (and will be woken) — no lost-wakeup window between
            // its shutdown check and its wait
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.available.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match task {
            // panics are caught inside the run_scoped wrapper, so a bad
            // task cannot take the worker (or the queue mutex) down
            Some(t) => t(),
            None => return,
        }
    }
}

/// The process-global pool: sized from [`super::engine::global_threads`]
/// (CLI `--threads` / `QGALORE_THREADS` env / detected cores) on first use.
/// `main` touches this right after parsing `--threads` so the workers spin
/// up once, before any timed work.
pub fn global_pool() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(super::engine::global_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn reuse_across_many_submissions() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn tasks_can_borrow_caller_stack() {
        let pool = WorkerPool::new(2);
        let mut out = vec![0usize; 8];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(2)
            .enumerate()
            .map(|(i, slab)| {
                Box::new(move || {
                    for (j, s) in slab.iter_mut().enumerate() {
                        *s = i * 2 + j;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(out, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_task_propagates_but_pool_survives() {
        let pool = WorkerPool::new(2);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("task boom")),
                Box::new(|| {}),
            ];
            pool.run_scoped(tasks);
        }));
        let payload = boom.expect_err("panic must reach the submitter");
        assert_eq!(
            payload.downcast_ref::<&str>().copied().unwrap_or(""),
            "task boom",
            "original panic payload must be preserved"
        );
        // the pool keeps working after a task panic
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(4);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        drop(pool); // must not hang
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = global_pool() as *const WorkerPool;
        let b = global_pool() as *const WorkerPool;
        assert!(std::ptr::eq(a, b));
        assert!(global_pool().workers() >= 1);
    }
}
