//! Lock-free work-stealing worker pool — the execution substrate under
//! `par_rows` / `par_map` and every fused dequant kernel.
//!
//! Q-GaLore's steady state is thousands of sub-millisecond per-layer
//! projected-gradient products (`P^T g`, `P u`, rank-r refreshes), so
//! per-task dispatch cost is a first-order throughput term.  PR 2 moved
//! from per-call thread spawns to a persistent pool (one mutex-guarded
//! FIFO); PR 4 split that into per-worker deques so contention became
//! per-deque instead of process-wide — but every push, pop, and steal
//! still took a mutex.  This PR makes the per-worker deque a **Chase-Lev
//! owner/thief deque** (Chase & Lev 2005, with the C11 memory orderings of
//! Lê, Pop & Cohen 2013): own-side operations are wait-free, steals are a
//! single CAS, and the only lock left on the dispatch path is the injector
//! (below), touched once per *external* batch rather than once per task.
//!
//! # The deque ([`ChaseLev`])
//!
//! A growable power-of-two ring indexed by two monotone counters:
//! `top` (the steal end) and `bottom` (the owner end).
//!
//! * **Owner `push`/`pop` are wait-free**: the owner is the only thread
//!   that writes `bottom`, so pushing is "store element, bump `bottom`" —
//!   no CAS, no retry loop, not even in the grow path (the owner copies
//!   into a fresh ring and republishes the buffer pointer; retired rings
//!   are kept until the deque drops, so a thief still reading an old ring
//!   dereferences valid memory).  Popping CASes `top` only in the
//!   single-element case, where the owner must race thieves for the last
//!   task.
//! * **Steals are CAS-only FIFO**: a thief reads `top`, fences, reads
//!   `bottom`, and claims slot `top` with one `compare_exchange`.  Losing
//!   the race means another thread took a task — global progress — so the
//!   retry loop is lock-free.
//! * **Memory-ordering invariants** (the part `cargo miri` checks in CI):
//!   the owner's element store is published by a `Release` *fence* before
//!   its relaxed store of `bottom` (a fence, not a release store, because
//!   a thief may learn the index from `pop`'s later *relaxed* speculative
//!   decrement — the fence makes every subsequent owner store of `bottom`
//!   a publication point), which the thief's `Acquire` load of `bottom`
//!   pairs with — so a thief that observes `top < bottom` also observes
//!   the element.
//!   The `SeqCst` fence in `pop` (after the speculative `bottom`
//!   decrement) and in `steal` (between the `top` and `bottom` loads)
//!   order the two sides' speculative reads into a single total order, so
//!   owner and thief cannot both conclude they own the last element; the
//!   `SeqCst` CAS on `top` then arbitrates who actually takes it.  ABA on
//!   ring wraparound cannot occur because `top`/`bottom` are monotone
//!   64-bit counters masked only at slot-index time — a recycled slot
//!   always has a fresh (greater) logical index.
//!
//! # The pool around it
//!
//! * **One Chase-Lev deque per worker, plus one mutex-guarded injector.**
//!   Chase-Lev is single-producer: only the owner may push.  A pool worker
//!   submitting a *nested* batch therefore pushes onto its **own** deque
//!   (wait-free, and LIFO means it pops back exactly the tasks it just
//!   submitted while thieves drain the far end).  External submitters
//!   can't own a deque, so their batch lands in the injector under one
//!   lock acquisition per batch — not one per task like the PR-4
//!   round-robin placement.  A worker that finds the injector non-empty
//!   takes one task and migrates a bounded share of the rest onto its own
//!   deque, where siblings steal it lock-free; the injector mutex is the
//!   only lock left, and it is touched O(batches), not O(tasks).
//! * **Victim choice is a per-worker PCG stream** seeded from
//!   [`STEAL_SEED_ENV`] (`QGALORE_STEAL_SEED`) or
//!   [`WorkerPool::with_steal_seed`]: each failed own-pop starts a sweep
//!   at a PCG-chosen victim and walks the ring, skipping the worker's own
//!   deque.  Seeding the stream lets the determinism tests force a
//!   *hostile* steal order and prove result bits cannot depend on
//!   interleaving (`tests/golden_trace.rs`).
//! * **Parking is a last resort, and wakeups are targeted.**  A worker
//!   blocks on the condvar only after a full failed sweep (own deque,
//!   every victim, the injector), and re-checks the pending-task count
//!   under the sleep lock so a submission cannot slip between its sweep
//!   and its wait.  Submitters wake `min(tasks, sleepers)` workers via
//!   `notify_one` — NOT `notify_all`, which would stampede every parked
//!   worker at a 2-task submission (the thundering herd the unit tests pin
//!   down via [`WorkerPool::stats`]).
//! * **Helping submitters are kept from PR 2** — they are the
//!   deadlock-freedom argument for *nested* submission (the galore wave
//!   scheduler fans layers out with `par_map` and each layer's refresh
//!   submits its own matmul tasks).  A blocked submitter first pops its
//!   own deque (if it is a pool worker), then steals, then drains the
//!   injector; a worker blocked on an inner submission therefore keeps
//!   executing queued tasks, so every deque drains and every latch
//!   eventually opens.
//! * A task that panics is caught, its payload parked on the submission's
//!   latch, and the panic **resumed in the submitting thread** (original
//!   message intact) after the call settles — the pool itself survives,
//!   matching `std::thread::scope` semantics.  A helper that happens to
//!   run another submission's panicking task never unwinds itself: the
//!   payload always travels to the latch it belongs to
//!   (`tests/pool_stress.rs`).
//!
//! # What the mutex versions are kept for
//!
//! Two older disciplines survive as explicitly non-production baselines:
//! [`WorkerPool::new_fifo`] (PR 2: one shared mutex FIFO) is the
//! scheduler-equivalence anchor for `tests/proptests.rs`, and
//! [`WorkerPool::new_mutex_steal`] (PR 4: per-worker mutex deques,
//! round-robin placement) is the like-for-like foil the
//! `benches/throughput.rs` contention section measures the Chase-Lev
//! rewrite against.  Keeping them callable keeps the "lock-free is
//! faster" claim falsifiable on every machine the bench runs on.
//!
//! The pool still does not decide decomposition — `par_rows`/`par_map`
//! split work into disjoint slabs keyed by `ParallelCtx` alone (since this
//! PR: ~[`super::engine::global_slabs_per_worker`] slabs per budgeted
//! worker, so one straggler slab no longer serializes a wave's tail), and
//! every task writes a disjoint output slice, so results are bitwise
//! identical to the scoped engine and to a 1-thread run for ANY worker
//! count, ANY slab count, and ANY steal interleaving (asserted by
//! `tests/parity.rs`, `tests/proptests.rs`, and `tests/golden_trace.rs`).

use std::cell::Cell;
use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// All atomics in this file go through the `linalg::sync` shim: a verbatim
// re-export of `std::sync::atomic` in production, the instrumented shadow
// atomics under `--cfg qgalore_modelcheck` so `modelcheck` explores the
// REAL deque and release-protocol code below (see `modelcheck/checks.rs`).
use crate::linalg::sync::{
    fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicU64, AtomicUsize, Ordering,
};
use crate::util::{env_parse, Pcg32};

/// A queued unit of work.  Tasks are erased to `'static` at submission; the
/// latch protocol in [`WorkerPool::run_scoped`] is what keeps that sound.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// A not-yet-erased scoped task: the `transmute` sites below cast this to
/// [`Task`], erasing only the lifetime.
type ScopedTask<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// Env var forcing the victim-choice PCG seed (u64).  The determinism
/// suites use it to drive whole-process runs under a hostile steal order;
/// result bits must not move.
pub const STEAL_SEED_ENV: &str = "QGALORE_STEAL_SEED";

/// Default victim-choice seed when neither the env var nor
/// [`WorkerPool::with_steal_seed`] supplies one (an arbitrary odd constant;
/// ANY value is correct, which is the whole point).
const DEFAULT_STEAL_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

/// Initial Chase-Lev ring capacity (a power of two; the ring doubles on
/// demand and never shrinks).  Sized so a default over-decomposed batch
/// (`threads * slabs_per_worker` tasks) usually fits without growing.
const INITIAL_DEQUE_CAP: usize = 64;

/// Most tasks a worker migrates from the injector onto its own deque per
/// injector visit (beyond the one it returns to run).  Bounds the time the
/// injector lock is held and keeps one worker from hoarding a huge batch
/// its siblings could have grabbed directly.
const INJECTOR_GRAB_MAX: usize = 16;

// ---------------------------------------------------------------------------
// Chase-Lev deque
// ---------------------------------------------------------------------------

/// One ring generation.  `slots` hold thin pointers to heap-boxed tasks
/// (`Task` itself is a fat `Box<dyn FnOnce>`, so it is boxed once more to
/// fit a single atomic word).  Slots are atomics so concurrent owner
/// stores and thief loads of the same slot are data-race-free under the
/// C11 model — the algorithm's fences and the `top` CAS decide which
/// values are actually *used*.
struct ClBuffer<T> {
    mask: usize,
    slots: Box<[AtomicPtr<T>]>,
}

impl<T> ClBuffer<T> {
    fn alloc(cap: usize) -> *mut ClBuffer<T> {
        debug_assert!(cap.is_power_of_two());
        let slots: Box<[AtomicPtr<T>]> =
            (0..cap).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect();
        Box::into_raw(Box::new(ClBuffer { mask: cap - 1, slots }))
    }

    fn cap(&self) -> usize {
        self.mask + 1
    }

    /// Slot for logical index `i`.  Indices are monotone counters; only
    /// the slot address wraps, which is why wraparound cannot ABA.
    fn slot(&self, i: isize) -> &AtomicPtr<T> {
        &self.slots[(i as usize) & self.mask]
    }
}

/// Growable Chase-Lev work-stealing deque: wait-free LIFO `push`/`pop` for
/// the single owning thread, lock-free CAS-claimed FIFO [`ChaseLev::steal`]
/// for any number of thieves.  See the module docs for the memory-ordering
/// invariants; the operation bodies follow Lê, Pop & Cohen (2013) line for
/// line so the orderings can be audited against the paper.
///
/// Generic over the element type so the model checker can explore the real
/// operation bodies over plain `usize` markers (`T = Task` in the pool).
pub(crate) struct ChaseLev<T: Send> {
    /// Steal end: index of the oldest task.  Only ever advanced, only by
    /// winning a `SeqCst` CAS (thieves and the owner's last-element pop).
    top: AtomicIsize,
    /// Owner end: index one past the newest task.  Written only by the
    /// owner (no CAS needed — single-producer is the whole design).
    bottom: AtomicIsize,
    /// Current ring.  Replaced (never mutated in place) by the owner on
    /// growth; old rings stay allocated in `retired` until drop so thieves
    /// holding a stale pointer still read valid memory.
    buf: AtomicPtr<ClBuffer<T>>,
    /// Rings replaced by growth.  Pushed only by the owner (inside `grow`)
    /// and drained only by `Drop`; the mutex is uncontended and exists so
    /// the type stays `Sync` without a second unsafe cell.
    retired: Mutex<Vec<*mut ClBuffer<T>>>,
}

// SAFETY: the ring stores thin pointers to boxed `T: Send` elements, all
// cross-thread slot/index accesses are atomics ordered per Chase-Lev, and
// buffer reclamation is deferred to `Drop` (exclusive access by &mut).
unsafe impl<T: Send> Send for ChaseLev<T> {}
unsafe impl<T: Send> Sync for ChaseLev<T> {}

impl<T: Send> ChaseLev<T> {
    pub(crate) fn with_capacity(cap: usize) -> Self {
        let cap = cap.next_power_of_two().max(2);
        ChaseLev {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(ClBuffer::alloc(cap)),
            retired: Mutex::new(Vec::new()),
        }
    }

    fn new() -> Self {
        Self::with_capacity(INITIAL_DEQUE_CAP)
    }

    /// Approximate occupancy (exact when no operation is in flight).
    /// Observability/test hook — the scheduling path never needs a length,
    /// only pop/steal outcomes.
    #[allow(dead_code)]
    pub(crate) fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Owner-only: append at the bottom (LIFO end).  Wait-free — no CAS,
    /// no retry; growth is a bounded copy by the owner alone.
    pub(crate) fn push(&self, task: T) {
        let elem = Box::into_raw(Box::new(task));
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut a = self.buf.load(Ordering::Relaxed);
        // SAFETY: `a` is the live ring (owner-only load; only the owner
        // replaces it, and it does so inside `grow` below), and slot `b` is
        // unclaimed: thieves only touch indices < `bottom`, which still
        // reads `b`.
        unsafe {
            if b - t >= (*a).cap() as isize {
                a = self.grow(a, t, b);
            }
            (*a).slot(b).store(elem, Ordering::Relaxed);
        }
        // Release FENCE + relaxed store, per the paper — NOT a release
        // store.  A thief may observe `bottom` through pop()'s speculative
        // relaxed decrement rather than through this store, and a release
        // store's publication does not extend to that later relaxed store
        // (C++20 release sequences exclude same-thread relaxed stores).
        // The fence does: every subsequent `bottom` store by this thread —
        // including pop's — synchronizes the element (and grow's ring)
        // publication to any thief that acquires the value it wrote.
        fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Relaxed);
    }

    /// Owner-only: take the newest task (LIFO end).  Wait-free; the single
    /// CAS in the last-element case either wins immediately or reports the
    /// task already stolen — no loop.
    pub(crate) fn pop(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let a = self.buf.load(Ordering::Relaxed);
        // Speculatively claim slot b, then fence before reading `top`: the
        // SeqCst fence globally orders this decrement against a concurrent
        // thief's top/bottom reads, so both sides agree on who must CAS.
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            // SAFETY: `a` is the live ring (owner-only), and t <= b means
            // slot `b` was filled by a prior push of this same owner.
            let elem = unsafe { (*a).slot(b).load(Ordering::Relaxed) };
            if t == b {
                // exactly one task left: race any thief for it via `top`
                if self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_err()
                {
                    // a thief won; restore bottom past the (gone) slot
                    self.bottom.store(b + 1, Ordering::Relaxed);
                    return None;
                }
                self.bottom.store(b + 1, Ordering::Relaxed);
            }
            // SAFETY: `elem` came from `Box::into_raw` in push, and this
            // thread owns it exclusively — plain path: thieves can no
            // longer see index b; last-element path: this CAS won `top`.
            Some(unsafe { *Box::from_raw(elem) })
        } else {
            // empty: undo the speculative decrement
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Thief: take the oldest task (FIFO end) with a single CAS.  Returns
    /// `None` only when the deque was observed empty; a lost CAS means
    /// another thread took a task (global progress), so retrying here
    /// keeps the operation lock-free without ever spinning on a lock.
    pub(crate) fn steal(&self) -> Option<T> {
        loop {
            let t = self.top.load(Ordering::Acquire);
            // SeqCst: order this thief's `top` read before its `bottom`
            // read in the same global order the owner's pop fence uses.
            fence(Ordering::SeqCst);
            let b = self.bottom.load(Ordering::Acquire);
            if t >= b {
                return None;
            }
            // Acquire on the buffer pointer: a grow published before the
            // `bottom` we just read is fully visible (and if the owner
            // grows after this load, the retired ring we read from stays
            // allocated and still holds the same element at index t).
            let a = self.buf.load(Ordering::Acquire);
            // SAFETY: `a` is either the live ring or a retired one (kept
            // allocated until Drop); t < b means slot t holds a pointer
            // published by the owner's push before the `bottom` we read.
            let elem = unsafe { (*a).slot(t).load(Ordering::Relaxed) };
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                // SAFETY: winning the `top` CAS grants exclusive ownership
                // of the element at index t (owner and other thieves lose).
                return Some(unsafe { *Box::from_raw(elem) });
            }
        }
    }

    /// Owner-only (called from `push` when full): double the ring, copy
    /// the live range, publish the new ring, retire the old one.  Thieves
    /// that loaded the old pointer keep reading valid memory — indices
    /// they can legitimately claim hold identical element pointers in both
    /// rings, and the `top` CAS still arbitrates ownership.
    unsafe fn grow(&self, old: *mut ClBuffer<T>, t: isize, b: isize) -> *mut ClBuffer<T> {
        // SAFETY: caller (push) passes the live ring it just loaded; the
        // owner is the only thread that allocates, copies into, or
        // publishes rings, and `old` stays allocated in `retired` for any
        // thief still holding it.
        unsafe {
            let new = ClBuffer::alloc((*old).cap() * 2);
            for i in t..b {
                (*new)
                    .slot(i)
                    .store((*old).slot(i).load(Ordering::Relaxed), Ordering::Relaxed);
            }
            self.buf.store(new, Ordering::Release);
            self.retired.lock().unwrap().push(old);
            new
        }
    }
}

impl<T: Send> Drop for ChaseLev<T> {
    fn drop(&mut self) {
        // &mut self: no concurrent owners or thieves remain.  Free any
        // undelivered tasks (their captured state included), the live
        // ring, and every retired generation.
        while self.pop().is_some() {}
        // SAFETY: exclusive access — every ring pointer (live + retired)
        // came from `ClBuffer::alloc`'s Box::into_raw and is freed exactly
        // once here.
        unsafe {
            drop(Box::from_raw(*self.buf.get_mut()));
            for p in self.retired.get_mut().unwrap().drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pool scheduling
// ---------------------------------------------------------------------------

/// Queue discipline of a pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Sched {
    /// Chase-Lev per-worker deques + a mutex injector for external
    /// batches: wait-free own-pops, CAS-only steals.  The production path.
    Steal,
    /// The PR-4 baseline: per-worker mutex deques, round-robin placement,
    /// mutex-guarded LIFO own-pop / FIFO steal.  Kept ONLY so
    /// `benches/throughput.rs` can report mutex-deque vs Chase-Lev rows
    /// side by side on live hardware.
    MutexSteal,
    /// The PR-2 baseline: one shared mutex deque, strict FIFO pop, no
    /// stealing.  The scheduler-equivalence anchor for the proptests.
    Fifo,
}

struct Shared {
    /// One Chase-Lev deque per worker (`Steal` only; empty otherwise).
    deques: Vec<ChaseLev<Task>>,
    /// Mutex queues: `[injector]` for `Steal`, one per worker for
    /// `MutexSteal`, `[the queue]` for `Fifo`.
    queues: Vec<Mutex<VecDeque<Task>>>,
    /// Tasks currently sitting in deques/queues (NOT in-flight on a
    /// thread).  Conservative during submission (incremented before the
    /// pushes), so a worker can never park while a sibling task is still
    /// being enqueued.
    pending: AtomicUsize,
    /// Count of workers blocked on `available` — read by submitters to
    /// wake exactly as many workers as there are new tasks.
    sleep: Mutex<usize>,
    /// Parked workers wait here; signalled task-count-many times per
    /// submission (and broadcast at shutdown).
    available: Condvar,
    shutdown: AtomicBool,
    /// Round-robin submission cursor across queues (`MutexSteal` only).
    rr: AtomicUsize,
    /// Victim-choice PCG seed; worker `i` draws from stream `i`.
    steal_seed: u64,
    sched: Sched,
    /// Worker-thread count (denominator of the injector grab share).
    workers: usize,
    /// Times any worker returned from a condvar wait (observability; the
    /// thundering-herd regression test bounds its growth).
    park_wakeups: AtomicUsize,
    /// Tasks taken from a deque/queue the taker did not own.
    steals: AtomicUsize,
}

/// Pool observability counters (monotonic since construction).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Condvar wakeups across all workers — a 2-task submission into a
    /// fully parked pool should cost ~2, not one per worker.
    pub park_wakeups: usize,
    /// Tasks executed by a thread that did not own the deque they sat in.
    pub steals: usize,
}

thread_local! {
    /// (owning pool's `Shared` address, worker index) for pool worker
    /// threads; `(0, MAX)` elsewhere.  Lets a nested submitter find its own
    /// deque (wait-free help-LIFO) and lets the steal sweep exclude it.
    static HOME: Cell<(usize, usize)> = const { Cell::new((0, usize::MAX)) };
}

impl Shared {
    fn new(workers: usize, sched: Sched, steal_seed: u64) -> Self {
        let (ncl, nq) = match sched {
            Sched::Steal => (workers, 1),
            Sched::MutexSteal => (0, workers),
            Sched::Fifo => (0, 1),
        };
        Shared {
            deques: (0..ncl).map(|_| ChaseLev::new()).collect(),
            queues: (0..nq).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            sleep: Mutex::new(0),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            rr: AtomicUsize::new(0),
            steal_seed,
            sched,
            workers,
            park_wakeups: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
        }
    }

    /// Enqueue wrapped tasks.  `home` is the submitting thread's own deque
    /// index when it is a worker of THIS pool (nested submission), else
    /// `None`.  `pending` is bumped BEFORE any push so no worker can
    /// observe an enqueued task while believing the pool is idle (the park
    /// guard reads `pending` under the sleep lock).
    ///
    /// Placement by discipline: a nested stealing-pool batch goes onto the
    /// submitter's own Chase-Lev deque (wait-free; Chase-Lev is
    /// single-producer, and the submitter IS the producer), an external
    /// stealing-pool batch takes the injector lock once for the whole
    /// batch, FIFO takes its one lock once, and the mutex-deque baseline
    /// keeps the PR-4 per-task round-robin.
    fn enqueue(&self, tasks: Vec<Task>, home: Option<usize>) {
        let n_tasks = tasks.len();
        self.pending.fetch_add(n_tasks, Ordering::Relaxed);
        match (self.sched, home) {
            (Sched::Steal, Some(h)) => {
                for t in tasks {
                    self.deques[h].push(t);
                }
            }
            (Sched::Steal, None) | (Sched::Fifo, _) => {
                let mut q = self.queues[0].lock().unwrap();
                for t in tasks {
                    q.push_back(t);
                }
            }
            (Sched::MutexSteal, _) => {
                let nd = self.queues.len();
                let start = self.rr.fetch_add(n_tasks, Ordering::Relaxed);
                for (i, t) in tasks.into_iter().enumerate() {
                    self.queues[(start + i) % nd].lock().unwrap().push_back(t);
                }
            }
        }
        // Targeted wakeup: exactly as many workers as there are new tasks
        // (capped at the parked count).  notify_all here would stampede a
        // 32-worker pool for a 2-task submission — the thundering herd the
        // park_wakeups stat exists to catch.
        let sleepers = self.sleep.lock().unwrap();
        for _ in 0..n_tasks.min(*sleepers) {
            self.available.notify_one();
        }
    }
}

/// Completion latch for one `run_scoped` submission.  Carries the first
/// caught panic payload so the submitter can resume it verbatim — the
/// original assert/index message survives, like `std::thread::scope`.
struct Latch {
    left: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            left: Mutex::new(n),
            done: Condvar::new(),
            panic: Mutex::new(None),
        }
    }

    fn complete(&self) {
        let mut left = self.left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.left.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut left = self.left.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// One node of a [`WorkerPool::run_graph`] dependency graph: a task plus
/// the indices of the nodes that must complete before it may start.
///
/// Indices refer to positions in the `Vec<GraphNode>` handed to
/// `run_graph`; forward references (depending on a node declared later)
/// are allowed — only cycles are rejected.
pub struct GraphNode<'scope> {
    deps: Vec<usize>,
    task: Box<dyn FnOnce() + Send + 'scope>,
}

impl<'scope> GraphNode<'scope> {
    pub fn new(deps: Vec<usize>, task: impl FnOnce() + Send + 'scope) -> Self {
        GraphNode { deps, task: Box::new(task) }
    }
}

/// The dependency-release / abort-skip protocol of one in-flight graph,
/// factored out of [`GraphRun`] so the model checker can drive the *real*
/// release code over plain markers (`T = Task` in the pool, `T = usize` in
/// `modelcheck::checks`).
///
/// Invariant (explored exhaustively by `modelcheck`, sampled by the stress
/// suites): each node's parked payload leaves its slot exactly once — taken
/// by the unique dependency whose `fetch_sub` observes 1 — and an abort
/// skips payloads but never releases, so the latch always settles.
pub(crate) struct GraphProtocol<T> {
    /// First-panic fail-fast flag: once set, nodes that have not started
    /// yet skip their payload (but still complete and still release their
    /// successors, so the latch always opens and nothing leaks).
    abort: AtomicBool,
    /// Unmet-dependency counts, one per node.
    remaining: Vec<AtomicUsize>,
    /// Successor adjacency, one list per node.
    succs: Vec<Vec<usize>>,
    /// Parked payloads awaiting their last dependency.
    slots: Vec<Mutex<Option<T>>>,
    /// Nodes with no dependencies, ascending (submitted directly).
    roots: Vec<usize>,
}

impl<T> GraphProtocol<T> {
    /// Validate `deps` (index bounds + acyclicity via a Kahn pass) and
    /// build the release state.  Panics on a malformed graph BEFORE the
    /// caller submits anything.
    pub(crate) fn build(deps: &[Vec<usize>]) -> Self {
        let n = deps.len();
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for (i, ds) in deps.iter().enumerate() {
            for &d in ds {
                assert!(d < n, "graph node {i} depends on node {d}, but there are only {n} nodes");
                succs[d].push(i);
                indeg[i] += 1;
            }
        }
        {
            // Kahn pass: every node must be schedulable
            let mut left = indeg.clone();
            let mut ready: Vec<usize> = (0..n).filter(|&i| left[i] == 0).collect();
            let mut seen = 0usize;
            while let Some(i) = ready.pop() {
                seen += 1;
                for &s in &succs[i] {
                    left[s] -= 1;
                    if left[s] == 0 {
                        ready.push(s);
                    }
                }
            }
            assert_eq!(
                seen, n,
                "dependency graph has a cycle (only {seen} of {n} nodes schedulable)"
            );
        }
        GraphProtocol {
            abort: AtomicBool::new(false),
            remaining: indeg.iter().map(|&d| AtomicUsize::new(d)).collect(),
            succs,
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            roots: (0..n).filter(|&i| indeg[i] == 0).collect(),
        }
    }

    /// Park node `i`'s payload until its last dependency releases it.
    pub(crate) fn park(&self, i: usize, payload: T) {
        *self.slots[i].lock().unwrap() = Some(payload);
    }

    /// Take node `i`'s parked payload, if any (roots at submission time).
    pub(crate) fn take(&self, i: usize) -> Option<T> {
        self.slots[i].lock().unwrap().take()
    }

    /// Nodes with zero dependencies, ascending.
    pub(crate) fn roots(&self) -> &[usize] {
        &self.roots
    }

    /// Fail-fast check a node runs before starting its payload.
    pub(crate) fn abort_requested(&self) -> bool {
        self.abort.load(Ordering::Acquire)
    }

    /// First panic wins: nodes that have not started will skip payloads.
    pub(crate) fn request_abort(&self) {
        self.abort.store(true, Ordering::Release);
    }

    /// Node `i` finished: decrement each successor's unmet count.  The
    /// unique decrement observing 1 takes the parked payload, so a node is
    /// released exactly once; the returned payloads are the caller's to
    /// enqueue.
    pub(crate) fn release_successors(&self, i: usize) -> Vec<T> {
        let mut unlocked = Vec::new();
        for &s in &self.succs[i] {
            if self.remaining[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                if let Some(t) = self.slots[s].lock().unwrap().take() {
                    unlocked.push(t);
                }
            }
        }
        unlocked
    }
}

/// Shared state of one in-flight `run_graph` submission.  Nodes whose
/// dependencies are not yet met park their (wrapped, `'static`-erased)
/// task in the protocol's slots; the LAST finishing dependency takes it
/// out and enqueues it, so a node enters the deques exactly once and only
/// when runnable.
struct GraphRun {
    shared: Arc<Shared>,
    latch: Latch,
    proto: GraphProtocol<Task>,
}

/// Pop one task from the stealing pool's injector.  A pool worker
/// (`home = Some`) additionally migrates a bounded share of what remains
/// onto its own deque — owner pushes, wait-free — so siblings pick the
/// batch up via lock-free steals instead of queueing on this mutex.
/// Migrated tasks stay counted in `pending` (they are still queued).
fn injector_pop(shared: &Shared, home: Option<usize>) -> Option<Task> {
    let mut q = shared.queues[0].lock().unwrap();
    let first = q.pop_front()?;
    if let Some(h) = home {
        let grab = (q.len() / shared.workers.max(1)).min(INJECTOR_GRAB_MAX);
        for _ in 0..grab {
            match q.pop_front() {
                Some(t) => shared.deques[h].push(t),
                None => break,
            }
        }
    }
    drop(q);
    shared.pending.fetch_sub(1, Ordering::Relaxed);
    Some(first)
}

/// Take one task under the pool's discipline.  `home` is the caller's own
/// deque index (pool workers and nested-submitting workers), or `None` for
/// an external helping submitter.  Returns `None` only after a FULL failed
/// sweep — the precondition for parking.
///
/// Stealing order: own deque (wait-free LIFO), then a PCG-ordered CAS
/// steal sweep over the other deques, then the injector (which an external
/// helper instead visits FIRST — the injector is where its own submission
/// landed, the moral equivalent of "own deque first").
fn find_task(shared: &Shared, home: Option<usize>, rng: &mut Pcg32) -> Option<Task> {
    match shared.sched {
        Sched::Fifo => {
            // the PR-2 discipline: everyone pops the one shared queue in order
            let t = shared.queues[0].lock().unwrap().pop_front();
            if t.is_some() {
                shared.pending.fetch_sub(1, Ordering::Relaxed);
            }
            t
        }
        Sched::MutexSteal => {
            // the PR-4 discipline: mutex-guarded LIFO own-pop, FIFO steals
            if let Some(h) = home {
                if let Some(t) = shared.queues[h].lock().unwrap().pop_back() {
                    shared.pending.fetch_sub(1, Ordering::Relaxed);
                    return Some(t);
                }
            }
            let n = shared.queues.len();
            let start = rng.below(n);
            for i in 0..n {
                let v = (start + i) % n;
                if Some(v) == home {
                    continue; // steal-from-self exclusion (own queue already tried)
                }
                if let Some(t) = shared.queues[v].lock().unwrap().pop_front() {
                    shared.pending.fetch_sub(1, Ordering::Relaxed);
                    shared.steals.fetch_add(1, Ordering::Relaxed);
                    return Some(t);
                }
            }
            None
        }
        Sched::Steal => {
            if let Some(h) = home {
                if let Some(t) = shared.deques[h].pop() {
                    shared.pending.fetch_sub(1, Ordering::Relaxed);
                    return Some(t);
                }
            } else if let Some(t) = injector_pop(shared, None) {
                return Some(t);
            }
            let n = shared.deques.len();
            if n > 0 {
                let start = rng.below(n);
                for i in 0..n {
                    let v = (start + i) % n;
                    if Some(v) == home {
                        continue; // steal-from-self exclusion (own deque already tried)
                    }
                    if let Some(t) = shared.deques[v].steal() {
                        shared.pending.fetch_sub(1, Ordering::Relaxed);
                        shared.steals.fetch_add(1, Ordering::Relaxed);
                        return Some(t);
                    }
                }
            }
            if home.is_some() {
                injector_pop(shared, home)
            } else {
                None
            }
        }
    }
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    HOME.with(|h| h.set((Arc::as_ptr(&shared) as usize, id)));
    let mut rng = Pcg32::new(shared.steal_seed, id as u64);
    loop {
        if let Some(t) = find_task(&shared, Some(id), &mut rng) {
            // panics are caught inside the run_scoped wrapper, so a bad
            // task cannot take the worker (or the injector mutex) down
            t();
            continue;
        }
        // Full sweep failed: park.  The pending re-check happens under the
        // sleep lock, and submitters bump `pending` BEFORE taking that lock
        // to notify — so either this worker sees the new tasks here and
        // re-sweeps, or it is already counted a sleeper and gets notified.
        let mut sleepers = shared.sleep.lock().unwrap();
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            if shared.pending.load(Ordering::Relaxed) > 0 {
                break; // re-sweep
            }
            *sleepers += 1;
            sleepers = shared.available.wait(sleepers).unwrap();
            *sleepers -= 1;
            shared.park_wakeups.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// A long-lived pool of worker threads with per-worker Chase-Lev stealing
/// deques (or one of the two mutex baselines: [`WorkerPool::new_fifo`],
/// [`WorkerPool::new_mutex_steal`]).
///
/// One process-global instance ([`global_pool`]) backs `ParallelCtx::new` /
/// `::global`; tests and benches construct private instances (usually via
/// [`WorkerPool::leaked`], since `ParallelCtx` carries a `&'static` handle
/// so it can stay `Copy`).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

/// `QGALORE_STEAL_SEED` -> seed, via the shared warn-on-malformed env
/// parser (a typo must not silently fall back while claiming to force a
/// steal order).
fn steal_seed_from_env() -> u64 {
    env_parse(STEAL_SEED_ENV, "a u64 victim-choice seed", |s| s.parse::<u64>().ok())
        .unwrap_or(DEFAULT_STEAL_SEED)
}

impl WorkerPool {
    /// Spawn `workers` (clamped to 1+) Chase-Lev stealing workers, parked
    /// on their deques.  The victim-choice seed comes from
    /// [`STEAL_SEED_ENV`] when set (the determinism suites' hostile-order
    /// hook), else a default.
    pub fn new(workers: usize) -> Self {
        Self::build(workers, Sched::Steal, steal_seed_from_env())
    }

    /// [`WorkerPool::new`] with an explicit victim-choice seed — the
    /// in-process form of [`STEAL_SEED_ENV`] for tests that pin a steal
    /// order without touching process env.
    pub fn with_steal_seed(workers: usize, seed: u64) -> Self {
        Self::build(workers, Sched::Steal, seed)
    }

    /// The PR-2 execution layer: one shared mutex-guarded FIFO, no
    /// stealing.  Kept as the scheduler-equivalence baseline for
    /// `tests/proptests.rs` and the contention benchmark — NOT for
    /// production dispatch.
    pub fn new_fifo(workers: usize) -> Self {
        Self::build(workers, Sched::Fifo, DEFAULT_STEAL_SEED)
    }

    /// The PR-4 execution layer: per-worker mutex-guarded deques with
    /// round-robin placement.  Kept so the contention benchmark can report
    /// mutex-deque vs Chase-Lev side by side — NOT for production
    /// dispatch.
    pub fn new_mutex_steal(workers: usize) -> Self {
        Self::build(workers, Sched::MutexSteal, steal_seed_from_env())
    }

    fn build(workers: usize, sched: Sched, steal_seed: u64) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(Shared::new(workers, sched, steal_seed));
        let handles = (0..workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("qgalore-pool-{i}"))
                    .spawn(move || worker_loop(s, i))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, workers }
    }

    /// A leaked (process-lifetime) stealing pool: the `&'static` handle
    /// form that [`super::ParallelCtx::with_pool`] takes.  Used by tests
    /// and benches that need explicit pool sizes; never joined.
    pub fn leaked(workers: usize) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::new(workers)))
    }

    /// Leaked [`WorkerPool::new_fifo`] baseline pool.
    pub fn leaked_fifo(workers: usize) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::new_fifo(workers)))
    }

    /// Leaked [`WorkerPool::new_mutex_steal`] baseline pool.
    pub fn leaked_mutex_steal(workers: usize) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::new_mutex_steal(workers)))
    }

    /// Leaked [`WorkerPool::with_steal_seed`] pool (hostile-order tests).
    pub fn leaked_with_steal_seed(workers: usize, seed: u64) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::with_steal_seed(workers, seed)))
    }

    /// Number of worker threads (excluding helping submitters).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Whether this pool runs a stealing discipline (Chase-Lev or the
    /// mutex-deque baseline; false: the FIFO baseline).
    pub fn is_stealing(&self) -> bool {
        matches!(self.shared.sched, Sched::Steal | Sched::MutexSteal)
    }

    /// Human-readable queue-discipline label (bench/debug output).
    pub fn kind(&self) -> &'static str {
        match self.shared.sched {
            Sched::Steal => "chase-lev",
            Sched::MutexSteal => "mutex-deque",
            Sched::Fifo => "fifo",
        }
    }

    /// Workers currently parked on the condvar (instantaneous).
    pub fn sleepers(&self) -> usize {
        *self.shared.sleep.lock().unwrap()
    }

    /// Monotonic observability counters; see [`PoolStats`].
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            park_wakeups: self.shared.park_wakeups.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
        }
    }

    /// Execute every task and return once all have completed.
    ///
    /// The submitting thread helps while it waits — own deque first (when
    /// the submitter IS a pool worker doing a nested submission), then
    /// stealing, then the injector — so calling this from *inside* a pool
    /// task cannot deadlock.  If any task panicked, the panic is re-thrown
    /// here after the whole submission has settled.
    ///
    /// SAFETY invariant: tasks may borrow data with lifetime `'scope`
    /// (shorter than `'static`).  They are transmuted to `'static` to sit
    /// in the deques, which is sound because this function does not return
    /// until the latch confirms every submitted task has finished running —
    /// no task can outlive the borrows it captures.
    pub fn run_scoped<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        if tasks.len() == 1 {
            // nothing to fan out; run inline (panics propagate naturally)
            (tasks.into_iter().next().unwrap())();
            return;
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        let wrapped: Vec<Task> = tasks
            .into_iter()
            .map(|task| {
                let l = Arc::clone(&latch);
                let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                    if let Err(payload) =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(task))
                    {
                        let mut slot = l.panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                    }
                    l.complete();
                });
                // SAFETY: see the invariant above — we block on `latch`
                // below until every wrapped task has run to completion, so
                // the 'scope borrows stay live for every execution.
                unsafe { std::mem::transmute::<ScopedTask<'scope>, Task>(wrapped) }
            })
            .collect();
        // A nested submission (this thread is a worker of THIS pool) owns a
        // Chase-Lev deque and pushes there wait-free; external submissions
        // go through the injector.  Computed before enqueue: placement
        // depends on it.
        let home = HOME.with(|h| {
            let (pool, id) = h.get();
            (pool == Arc::as_ptr(&self.shared) as usize).then_some(id)
        });
        self.shared.enqueue(wrapped, home);

        // Help while waiting: a pool worker submitting a nested batch pops
        // its own deque first (LIFO — the tasks it just pushed), then
        // steals; an external submitter drains the injector and steals.
        // Tasks of OTHER submissions get helped too — that is what keeps
        // nested latches opening.  Block on the latch only after a full
        // failed sweep, for whatever is still in flight elsewhere.
        static HELPER_STREAM: AtomicU64 = AtomicU64::new(1 << 32);
        let mut rng = Pcg32::new(
            self.shared.steal_seed,
            HELPER_STREAM.fetch_add(1, Ordering::Relaxed),
        );
        loop {
            if latch.is_done() {
                break;
            }
            match find_task(&self.shared, home, &mut rng) {
                Some(t) => t(),
                None => {
                    latch.wait();
                    break;
                }
            }
        }
        let payload = latch.panic.lock().unwrap().take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// Execute a dependency graph of tasks and return once every node has
    /// completed.  A node starts only after all of its `deps` have
    /// finished; independent nodes run concurrently under the pool's
    /// normal stealing discipline, and a finishing node enqueues each
    /// successor it was the last unmet dependency of (onto its own deque
    /// when the finisher is a pool worker — the chain stays hot).
    ///
    /// This is the execution substrate of the dataflow training step: each
    /// layer's project→Adam→update chain is a path in the graph, refresh
    /// waves are nodes that fan into their member layers' chains, and the
    /// submitter's return is the step's single join point.
    ///
    /// Semantics mirror [`WorkerPool::run_scoped`]:
    ///
    /// * The submitting thread helps while it waits (nested submission
    ///   from inside a pool task cannot deadlock), and node tasks may
    ///   themselves submit nested `run_scoped`/`par_map` batches.
    /// * The first panicking node's payload is re-thrown here after the
    ///   whole graph has settled.  Nodes that have not started when the
    ///   panic lands skip their payload (fail-fast) but still complete and
    ///   release their successors, so the latch opens, the pool survives,
    ///   and no parked task leaks.  Nodes already running elsewhere are
    ///   unaffected.
    ///
    /// Cycles and out-of-range dependency indices panic BEFORE anything is
    /// submitted (the graph is validated with a Kahn pass up front).
    ///
    /// SAFETY invariant: same as `run_scoped` — tasks may borrow `'scope`
    /// data because this function blocks until the latch confirms every
    /// node (including parked ones, which always drain) has completed.
    pub fn run_graph<'scope>(&self, nodes: Vec<GraphNode<'scope>>) {
        let n = nodes.len();
        if n == 0 {
            return;
        }
        let mut deps = Vec::with_capacity(n);
        let mut tasks = Vec::with_capacity(n);
        for node in nodes {
            deps.push(node.deps);
            tasks.push(node.task);
        }
        // Validate + build the release protocol before any submission, so a
        // malformed graph cannot strand half-submitted work in the deques.
        let proto: GraphProtocol<Task> = GraphProtocol::build(&deps);
        if n == 1 {
            // a single node has nothing to overlap with; run inline
            // (panics propagate naturally, like run_scoped's fast path)
            (tasks.into_iter().next().unwrap())();
            return;
        }
        let run =
            Arc::new(GraphRun { shared: Arc::clone(&self.shared), latch: Latch::new(n), proto });
        for (i, task) in tasks.into_iter().enumerate() {
            let r = Arc::clone(&run);
            let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                if !r.proto.abort_requested() {
                    if let Err(payload) =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(task))
                    {
                        r.proto.request_abort();
                        let mut slot = r.latch.panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                    }
                }
                // Release successors: the fetch_sub observing 1 is the
                // unique last dependency, so exactly one finisher takes
                // each parked task out of its slot.
                let home = HOME.with(|h| {
                    let (pool, id) = h.get();
                    (pool == Arc::as_ptr(&r.shared) as usize).then_some(id)
                });
                let unlocked = r.proto.release_successors(i);
                if !unlocked.is_empty() {
                    r.shared.enqueue(unlocked, home);
                }
                r.latch.complete();
            });
            // SAFETY: see the invariant above — the latch below holds this
            // call until every node (parked or enqueued) has run, so the
            // 'scope borrows stay live for every execution.
            let wrapped = unsafe { std::mem::transmute::<ScopedTask<'scope>, Task>(wrapped) };
            run.proto.park(i, wrapped);
        }
        // Submit the roots (nodes with no dependencies) as one batch; every
        // other node is released by its last finishing dependency.
        let home = HOME.with(|h| {
            let (pool, id) = h.get();
            (pool == Arc::as_ptr(&self.shared) as usize).then_some(id)
        });
        let mut roots: Vec<Task> = Vec::new();
        for &i in run.proto.roots() {
            if let Some(t) = run.proto.take(i) {
                roots.push(t);
            }
        }
        self.shared.enqueue(roots, home);
        // Help while waiting, exactly like run_scoped (distinct helper
        // stream range so graph submitters never collide with scoped ones).
        static GRAPH_HELPER_STREAM: AtomicU64 = AtomicU64::new(1 << 33);
        let mut rng = Pcg32::new(
            self.shared.steal_seed,
            GRAPH_HELPER_STREAM.fetch_add(1, Ordering::Relaxed),
        );
        loop {
            if run.latch.is_done() {
                break;
            }
            match find_task(&self.shared, home, &mut rng) {
                Some(t) => t(),
                None => {
                    run.latch.wait();
                    break;
                }
            }
        }
        let payload = run.latch.panic.lock().unwrap().take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("kind", &self.kind())
            .finish_non_exhaustive()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            // signal under the sleep lock: a worker is either holding it
            // (and will see the flag on its park-guard check) or already
            // waiting (and will be woken) — no lost-wakeup window between
            // its shutdown check and its wait
            let _sleepers = self.shared.sleep.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.available.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-global pool: sized from [`super::engine::global_threads`]
/// (CLI `--threads` / `QGALORE_THREADS` env / detected cores) on first use.
/// `main` touches this right after parsing `--threads` so the workers spin
/// up once, before any timed work.
pub fn global_pool() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(super::engine::global_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::{Duration, Instant};

    // -----------------------------------------------------------------------
    // Chase-Lev deque unit tests (single-owner / multi-thief, ring growth,
    // last-element races, wraparound) — the core of the lock-free rewrite.
    // Thread counts and iteration budgets shrink under miri, which runs
    // these under its weak-memory model in the CI best-effort leg.
    // -----------------------------------------------------------------------

    /// A counting task: `cl_task(log, id)` pushes `id` into `log` when run.
    fn cl_task(log: &Arc<Mutex<Vec<usize>>>, id: usize) -> Task {
        let log = Arc::clone(log);
        Box::new(move || log.lock().unwrap().push(id))
    }

    #[test]
    fn chase_lev_own_pop_is_lifo() {
        let d = ChaseLev::with_capacity(8);
        let log = Arc::new(Mutex::new(Vec::new()));
        for id in 0..5 {
            d.push(cl_task(&log, id));
        }
        assert_eq!(d.len(), 5);
        while let Some(t) = d.pop() {
            t();
        }
        assert_eq!(*log.lock().unwrap(), vec![4, 3, 2, 1, 0], "own pop must be LIFO");
        assert!(d.pop().is_none(), "empty deque must pop None");
    }

    #[test]
    fn chase_lev_steal_is_fifo() {
        let d = ChaseLev::with_capacity(8);
        let log = Arc::new(Mutex::new(Vec::new()));
        for id in 0..5 {
            d.push(cl_task(&log, id));
        }
        while let Some(t) = d.steal() {
            t();
        }
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3, 4], "steals must be FIFO");
        assert!(d.steal().is_none(), "empty deque must steal None");
    }

    #[test]
    fn chase_lev_ring_grows_past_initial_capacity() {
        // capacity 2: pushing 100 forces several doublings; every element
        // must survive the copies, in order, and retired rings must be
        // kept (freed only at drop — no use-after-free for thieves)
        let d = ChaseLev::with_capacity(2);
        let log = Arc::new(Mutex::new(Vec::new()));
        let n = 100;
        for id in 0..n {
            d.push(cl_task(&log, id));
        }
        assert!(d.retired.lock().unwrap().len() >= 5, "growth did not retire rings");
        // drain half from the steal end, half from the owner end
        for _ in 0..n / 2 {
            d.steal().expect("steal during growth test")();
        }
        while let Some(t) = d.pop() {
            t();
        }
        let got = log.lock().unwrap().clone();
        assert_eq!(got.len(), n, "grow lost or duplicated tasks");
        assert_eq!(&got[..n / 2], &(0..n / 2).collect::<Vec<_>>()[..], "steal end order");
        let mut tail: Vec<usize> = got[n / 2..].to_vec();
        tail.reverse();
        assert_eq!(tail, (n / 2..n).collect::<Vec<_>>(), "owner end order");
    }

    #[test]
    fn chase_lev_last_element_owner_vs_thief_sequential() {
        // the single-element edge both sides CAS for, exercised from each
        // side deterministically (the racing version is below)
        let d = ChaseLev::with_capacity(4);
        let log = Arc::new(Mutex::new(Vec::new()));
        d.push(cl_task(&log, 1));
        assert!(d.pop().is_some(), "owner must win an uncontested last element");
        assert!(d.pop().is_none());
        assert!(d.steal().is_none());
        d.push(cl_task(&log, 2));
        assert!(d.steal().is_some(), "thief must win an uncontested last element");
        assert!(d.steal().is_none());
        assert!(d.pop().is_none());
    }

    #[test]
    fn chase_lev_empty_and_last_element_steal_race_exactly_once() {
        // 1 owner and several thieves hammer a deque that is almost always
        // empty or holding exactly one task — the pop/steal CAS window.
        // Every task must run exactly once: an execution counter that
        // over/undershoots means a double-take or a lost task.
        let thieves = if cfg!(miri) { 2 } else { 4 };
        let rounds = if cfg!(miri) { 50 } else { 5_000 };
        let d = ChaseLev::with_capacity(4);
        let executed = Arc::new(AtomicUsize::new(0));
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..thieves {
                s.spawn(|| {
                    while !stop.load(Ordering::Acquire) {
                        if let Some(t) = d.steal() {
                            t();
                        }
                    }
                    // final drain so nothing is stranded
                    while let Some(t) = d.steal() {
                        t();
                    }
                });
            }
            // the owner: push one, maybe pop it back, repeat
            for i in 0..rounds {
                let ex = Arc::clone(&executed);
                d.push(Box::new(move || {
                    ex.fetch_add(1, Ordering::Relaxed);
                }) as Task);
                if i % 2 == 0 {
                    if let Some(t) = d.pop() {
                        t();
                    }
                }
            }
            while let Some(t) = d.pop() {
                t();
            }
            stop.store(true, Ordering::Release);
        });
        assert_eq!(
            executed.load(Ordering::Relaxed),
            rounds,
            "last-element race lost or duplicated tasks"
        );
    }

    #[test]
    fn chase_lev_wraparound_indices_stay_sound() {
        // a fixed-capacity ring cycled many times over: the monotone
        // top/bottom counters wrap the slot mask thousands of times while
        // thieves race — the classic ABA shape.  Exactly-once execution
        // proves a recycled slot is never claimed under a stale index.
        let rounds = if cfg!(miri) { 60 } else { 20_000 };
        let batch = 3; // stays below capacity 4: the ring never grows
        let d = ChaseLev::with_capacity(4);
        let executed = Arc::new(AtomicUsize::new(0));
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    while !stop.load(Ordering::Acquire) {
                        if let Some(t) = d.steal() {
                            t();
                        }
                    }
                    while let Some(t) = d.steal() {
                        t();
                    }
                });
            }
            for _ in 0..rounds {
                for _ in 0..batch {
                    let ex = Arc::clone(&executed);
                    d.push(Box::new(move || {
                        ex.fetch_add(1, Ordering::Relaxed);
                    }) as Task);
                }
                while let Some(t) = d.pop() {
                    t();
                }
            }
            stop.store(true, Ordering::Release);
        });
        assert_eq!(d.retired.lock().unwrap().len(), 0, "capacity-4 ring must not grow");
        assert_eq!(
            executed.load(Ordering::Relaxed),
            rounds * batch,
            "wraparound lost or duplicated tasks"
        );
    }

    #[test]
    fn chase_lev_drop_frees_undelivered_tasks() {
        // tasks still queued at drop must have their captured state freed
        // (the Arc strong count is the observable)
        let log = Arc::new(Mutex::new(Vec::new()));
        {
            let d = ChaseLev::with_capacity(2);
            for id in 0..10 {
                d.push(cl_task(&log, id));
            }
        }
        assert_eq!(Arc::strong_count(&log), 1, "dropped deque leaked task captures");
        assert!(log.lock().unwrap().is_empty(), "drop must not RUN undelivered tasks");
    }

    // -----------------------------------------------------------------------
    // scheduling-logic tests on a worker-less Shared (deterministic: no
    // threads racing for the tasks staged by hand)
    // -----------------------------------------------------------------------

    fn bare_shared(workers: usize, sched: Sched) -> Shared {
        Shared::new(workers, sched, 0)
    }

    /// Stage a marker task on one of a stealing `Shared`'s deques.  Safe
    /// here because the test thread is the only "owner" in sight.
    fn push_marker(shared: &Shared, deque: usize, log: &Arc<Mutex<Vec<usize>>>, id: usize) {
        let log = Arc::clone(log);
        shared.deques[deque].push(Box::new(move || log.lock().unwrap().push(id)) as Task);
        shared.pending.fetch_add(1, Ordering::Relaxed);
    }

    #[test]
    fn own_pop_is_lifo_steal_is_fifo() {
        let shared = bare_shared(2, Sched::Steal);
        let log = Arc::new(Mutex::new(Vec::new()));
        for id in [10usize, 11, 12] {
            push_marker(&shared, 0, &log, id);
        }
        let mut rng = Pcg32::new(0, 0);
        // owner of deque 0 pops newest-first
        for _ in 0..3 {
            find_task(&shared, Some(0), &mut rng).expect("own pop")();
        }
        assert_eq!(*log.lock().unwrap(), vec![12, 11, 10], "own pop must be LIFO");

        log.lock().unwrap().clear();
        for id in [20usize, 21, 22] {
            push_marker(&shared, 0, &log, id);
        }
        // worker 1 steals from deque 0 oldest-first
        for _ in 0..3 {
            find_task(&shared, Some(1), &mut rng).expect("steal")();
        }
        assert_eq!(*log.lock().unwrap(), vec![20, 21, 22], "steals must be FIFO");
        assert_eq!(shared.steals.load(Ordering::Relaxed), 3);
        assert_eq!(shared.pending.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn steal_sweep_excludes_own_deque() {
        // a single-deque stealing pool shape: with the own deque empty, the
        // sweep has only "self" to visit and must come back empty-handed
        let shared = bare_shared(1, Sched::Steal);
        let mut rng = Pcg32::new(7, 0);
        assert!(find_task(&shared, Some(0), &mut rng).is_none());
        assert_eq!(shared.steals.load(Ordering::Relaxed), 0, "self-steal counted");

        // and in a 3-deque pool, a sweep from worker 1 with work ONLY in
        // deque 1 finds nothing: its own deque was tried (and emptied by the
        // LIFO pop below), the others and the injector are empty
        let shared = bare_shared(3, Sched::Steal);
        let log = Arc::new(Mutex::new(Vec::new()));
        push_marker(&shared, 1, &log, 1);
        find_task(&shared, Some(1), &mut rng).expect("own pop")();
        assert_eq!(shared.steals.load(Ordering::Relaxed), 0, "own pop counted as steal");
        assert!(find_task(&shared, Some(1), &mut rng).is_none());
    }

    #[test]
    fn external_helper_reaches_deques_and_injector() {
        // home = None (a non-worker submitter): the sweep must be able to
        // reach work wherever it sits — any worker's deque or the injector
        let shared = bare_shared(4, Sched::Steal);
        let log = Arc::new(Mutex::new(Vec::new()));
        for d in 0..4 {
            push_marker(&shared, d, &log, d);
        }
        // one more staged in the injector (an external batch's home)
        {
            let log = Arc::clone(&log);
            shared.queues[0]
                .lock()
                .unwrap()
                .push_back(Box::new(move || log.lock().unwrap().push(99)) as Task);
            shared.pending.fetch_add(1, Ordering::Relaxed);
        }
        let mut rng = Pcg32::new(3, 99);
        for _ in 0..5 {
            find_task(&shared, None, &mut rng).expect("helper sweep")();
        }
        assert!(find_task(&shared, None, &mut rng).is_none());
        let mut seen = log.lock().unwrap().clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 99], "helper missed a deque or the injector");
        assert_eq!(shared.pending.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn nested_enqueue_lands_on_own_deque_external_on_injector() {
        let shared = bare_shared(3, Sched::Steal);
        let tasks: Vec<Task> = (0..4).map(|_| Box::new(|| {}) as Task).collect();
        shared.enqueue(tasks, Some(1));
        assert_eq!(shared.deques[1].len(), 4, "nested batch must sit on the own deque");
        assert_eq!(shared.queues[0].lock().unwrap().len(), 0);
        let tasks: Vec<Task> = (0..5).map(|_| Box::new(|| {}) as Task).collect();
        shared.enqueue(tasks, None);
        assert_eq!(
            shared.queues[0].lock().unwrap().len(),
            5,
            "external batch must sit in the injector"
        );
        assert_eq!(shared.pending.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn injector_visit_migrates_a_bounded_share_to_the_own_deque() {
        // 3 workers, 13 injected tasks: the first visiting worker takes 1
        // and migrates floor(12 / 3) = 4 onto its own deque, leaving 8
        let shared = bare_shared(3, Sched::Steal);
        let tasks: Vec<Task> = (0..13).map(|_| Box::new(|| {}) as Task).collect();
        shared.enqueue(tasks, None);
        let mut rng = Pcg32::new(5, 0);
        let t = find_task(&shared, Some(2), &mut rng).expect("injector pop");
        t();
        assert_eq!(shared.deques[2].len(), 4, "grab share mis-sized");
        assert_eq!(shared.queues[0].lock().unwrap().len(), 8);
        assert_eq!(shared.pending.load(Ordering::Relaxed), 12, "migrated tasks left pending");
        // a worker-side visit with a huge backlog is capped at the grab max
        let shared = bare_shared(1, Sched::Steal);
        let tasks: Vec<Task> = (0..100).map(|_| Box::new(|| {}) as Task).collect();
        shared.enqueue(tasks, None);
        find_task(&shared, Some(0), &mut rng).expect("injector pop")();
        assert_eq!(shared.deques[0].len(), INJECTOR_GRAB_MAX, "grab must cap");
    }

    #[test]
    fn mutex_steal_baseline_keeps_round_robin_placement() {
        // the PR-4 discipline survives for the bench: 10 tasks over 4
        // queues from a fresh cursor land 3/3/2/2, and the next batch
        // CONTINUES at the cursor instead of restarting at 0
        let shared = bare_shared(4, Sched::MutexSteal);
        let tasks: Vec<Task> = (0..10).map(|_| Box::new(|| {}) as Task).collect();
        shared.enqueue(tasks, None);
        let lens = |shared: &Shared| -> Vec<usize> {
            shared.queues.iter().map(|d| d.lock().unwrap().len()).collect()
        };
        assert_eq!(lens(&shared), vec![3, 3, 2, 2], "batch not spread round-robin");
        let tasks: Vec<Task> = (0..2).map(|_| Box::new(|| {}) as Task).collect();
        shared.enqueue(tasks, Some(0));
        assert_eq!(lens(&shared), vec![3, 3, 3, 3], "cursor reset between batches");
        assert_eq!(shared.pending.load(Ordering::Relaxed), 12);
        // and its find_task still does mutex LIFO-own / FIFO-steal
        let log = Arc::new(Mutex::new(Vec::new()));
        let shared = bare_shared(2, Sched::MutexSteal);
        for id in [1usize, 2, 3] {
            let log = Arc::clone(&log);
            shared.queues[0]
                .lock()
                .unwrap()
                .push_back(Box::new(move || log.lock().unwrap().push(id)) as Task);
            shared.pending.fetch_add(1, Ordering::Relaxed);
        }
        let mut rng = Pcg32::new(0, 0);
        find_task(&shared, Some(0), &mut rng).expect("own pop")();
        find_task(&shared, Some(1), &mut rng).expect("steal")();
        assert_eq!(*log.lock().unwrap(), vec![3, 1], "mutex baseline order drifted");
    }

    // -----------------------------------------------------------------------
    // whole-pool behavior
    // -----------------------------------------------------------------------

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn baseline_pools_run_every_task_exactly_once() {
        for pool in [WorkerPool::new_fifo(3), WorkerPool::new_mutex_steal(3)] {
            let counter = AtomicUsize::new(0);
            for _ in 0..20 {
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                    .map(|_| {
                        Box::new(|| {
                            counter.fetch_add(1, Ordering::Relaxed);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_scoped(tasks);
            }
            assert_eq!(counter.load(Ordering::Relaxed), 160, "{}", pool.kind());
        }
    }

    #[test]
    fn pool_kinds_and_stealing_flags() {
        assert!(WorkerPool::new(1).is_stealing());
        assert_eq!(WorkerPool::new(1).kind(), "chase-lev");
        assert!(WorkerPool::new_mutex_steal(1).is_stealing());
        assert_eq!(WorkerPool::new_mutex_steal(1).kind(), "mutex-deque");
        assert!(!WorkerPool::new_fifo(1).is_stealing());
        assert_eq!(WorkerPool::new_fifo(1).kind(), "fifo");
    }

    #[test]
    fn reuse_across_many_submissions() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn tasks_can_borrow_caller_stack() {
        let pool = WorkerPool::new(2);
        let mut out = vec![0usize; 8];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(2)
            .enumerate()
            .map(|(i, slab)| {
                Box::new(move || {
                    for (j, s) in slab.iter_mut().enumerate() {
                        *s = i * 2 + j;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(out, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_task_propagates_but_pool_survives() {
        let pool = WorkerPool::new(2);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("task boom")),
                Box::new(|| {}),
            ];
            pool.run_scoped(tasks);
        }));
        let payload = boom.expect_err("panic must reach the submitter");
        assert_eq!(
            payload.downcast_ref::<&str>().copied().unwrap_or(""),
            "task boom",
            "original panic payload must be preserved"
        );
        // the pool keeps working after a task panic
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(4);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        drop(pool); // must not hang
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }

    #[test]
    #[cfg_attr(
        miri,
        ignore = "the global pool's workers outlive the test process; miri flags them as leaked threads"
    )]
    fn global_pool_is_a_singleton() {
        let a = global_pool() as *const WorkerPool;
        let b = global_pool() as *const WorkerPool;
        assert!(std::ptr::eq(a, b));
        assert!(global_pool().workers() >= 1);
        assert!(global_pool().is_stealing());
    }

    /// Spin until `cond` holds or ~2s elapse (parking is asynchronous).
    fn wait_for(cond: impl Fn() -> bool) -> bool {
        let deadline = Instant::now() + Duration::from_secs(2);
        while Instant::now() < deadline {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        cond()
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock park timing, too slow under the interpreter")]
    fn all_parked_workers_wake_on_submit_without_thundering_herd() {
        let pool = WorkerPool::with_steal_seed(8, 42);
        assert!(wait_for(|| pool.sleepers() == 8), "workers failed to park");
        let before = pool.stats();
        // a 2-task submission into a fully parked 8-worker pool must wake
        // ~2 workers, not all 8 (the submitter may even help one of the
        // tasks itself).  Generous slack for OS-level spurious wakeups; the
        // pre-fix notify_all behavior woke all 8 deterministically.
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 2);
        assert!(wait_for(|| pool.sleepers() == 8), "workers failed to re-park");
        let woke = pool.stats().park_wakeups - before.park_wakeups;
        assert!(woke <= 4, "thundering herd: {woke} wakeups for a 2-task submission");
        // and a fully parked pool still wakes for the NEXT submission (the
        // park/unpark handshake cannot strand tasks)
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 18);
    }

    #[test]
    #[cfg_attr(miri, ignore = "1200-batch timing stress, too slow under the interpreter")]
    fn park_unpark_race_under_rapid_small_batches() {
        // hammer the exact window the park guard protects: workers finish a
        // sweep and head for the condvar while submitters push fresh tiny
        // batches.  A lost wakeup deadlocks this test; a miscounted sleeper
        // loses tasks.  4 submitters x 300 batches x 2 tasks on 2 workers.
        let pool = WorkerPool::with_steal_seed(2, 5);
        let counter = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..300 {
                        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                            .map(|_| {
                                Box::new(|| {
                                    counter.fetch_add(1, Ordering::Relaxed);
                                })
                                    as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        pool.run_scoped(tasks);
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4 * 300 * 2);
        assert!(wait_for(|| pool.sleepers() == 2), "workers failed to quiesce");
    }

    #[test]
    #[cfg_attr(miri, ignore = "whole-pool throughput loop, too slow under the interpreter")]
    fn hostile_steal_seeds_do_not_change_results() {
        // same staged work, three victim-choice seeds: totals must agree
        // (bit-for-bit output equality lives in the integration suites;
        // here we pin the cheap invariant that scheduling is the ONLY
        // thing the seed touches)
        for seed in [0u64, 1, u64::MAX] {
            let pool = WorkerPool::with_steal_seed(4, seed);
            let counter = AtomicUsize::new(0);
            for _ in 0..25 {
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..7)
                    .map(|_| {
                        Box::new(|| {
                            counter.fetch_add(1, Ordering::Relaxed);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_scoped(tasks);
            }
            assert_eq!(counter.load(Ordering::Relaxed), 175, "seed {seed:#x}");
        }
    }

    // -----------------------------------------------------------------------
    // run_graph: the dependency-graph executor under the dataflow step.
    // -----------------------------------------------------------------------

    /// The three pool disciplines at a given size — graph execution must
    /// behave identically on all of them.
    fn graph_pools(workers: usize) -> Vec<WorkerPool> {
        vec![
            WorkerPool::with_steal_seed(workers, 42),
            WorkerPool::new_fifo(workers),
            WorkerPool::new_mutex_steal(workers),
        ]
    }

    #[test]
    fn graph_chain_runs_in_dependency_order() {
        for workers in [1usize, 4, 16] {
            for pool in graph_pools(workers) {
                let log = Mutex::new(Vec::new());
                let nodes = vec![
                    GraphNode::new(vec![], || log.lock().unwrap().push('a')),
                    GraphNode::new(vec![0], || log.lock().unwrap().push('b')),
                    GraphNode::new(vec![1], || log.lock().unwrap().push('c')),
                ];
                pool.run_graph(nodes);
                assert_eq!(
                    *log.lock().unwrap(),
                    vec!['a', 'b', 'c'],
                    "chain order violated ({} workers, {})",
                    workers,
                    pool.kind()
                );
            }
        }
    }

    #[test]
    fn graph_diamond_joins_after_both_branches() {
        // a -> (b, c) -> d, with d also a *forward* reference target:
        // declaration order is deliberately not topological order
        for workers in [1usize, 4, 16] {
            let pool = WorkerPool::with_steal_seed(workers, 7);
            for _ in 0..20 {
                let flags: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
                let join_saw = AtomicUsize::new(0);
                let nodes = vec![
                    // node 0 = the JOIN, depending on nodes declared later
                    GraphNode::new(vec![2, 3], || {
                        join_saw.store(
                            flags[1].load(Ordering::SeqCst)
                                + flags[2].load(Ordering::SeqCst)
                                + flags[3].load(Ordering::SeqCst),
                            Ordering::SeqCst,
                        );
                        flags[0].store(1, Ordering::SeqCst);
                    }),
                    // node 1 = the root
                    GraphNode::new(vec![], || {
                        flags[1].store(1, Ordering::SeqCst);
                    }),
                    // nodes 2, 3 = the parallel branches
                    GraphNode::new(vec![1], || {
                        assert_eq!(flags[1].load(Ordering::SeqCst), 1, "branch ran before root");
                        flags[2].store(1, Ordering::SeqCst);
                    }),
                    GraphNode::new(vec![1], || {
                        assert_eq!(flags[1].load(Ordering::SeqCst), 1, "branch ran before root");
                        flags[3].store(1, Ordering::SeqCst);
                    }),
                ];
                pool.run_graph(nodes);
                assert_eq!(join_saw.load(Ordering::SeqCst), 3, "join ran before both branches");
                assert!(flags.iter().all(|f| f.load(Ordering::SeqCst) == 1), "a node was lost");
            }
        }
    }

    #[test]
    fn graph_wide_fanout_runs_every_node() {
        let pool = WorkerPool::with_steal_seed(8, 3);
        let counter = AtomicUsize::new(0);
        // 64 roots, each with a dependent, plus one join over all dependents
        let mut nodes: Vec<GraphNode<'_>> = Vec::new();
        for _ in 0..64 {
            nodes.push(GraphNode::new(vec![], || {
                counter.fetch_add(1, Ordering::Relaxed);
            }));
        }
        for i in 0..64 {
            nodes.push(GraphNode::new(vec![i], || {
                counter.fetch_add(1, Ordering::Relaxed);
            }));
        }
        nodes.push(GraphNode::new((64..128).collect(), || {
            counter.fetch_add(1000, Ordering::Relaxed);
        }));
        pool.run_graph(nodes);
        assert_eq!(counter.load(Ordering::Relaxed), 128 + 1000);
    }

    #[test]
    fn graph_panic_resurfaces_skips_descendants_and_pool_survives() {
        let pool = WorkerPool::with_steal_seed(4, 11);
        let ran_after = AtomicUsize::new(0);
        let sibling_ran = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let nodes = vec![
                GraphNode::new(vec![], || panic!("graph boom")),
                // descendant of the panicking node: must be skipped
                GraphNode::new(vec![0], || {
                    ran_after.fetch_add(1, Ordering::Relaxed);
                }),
                GraphNode::new(vec![1], || {
                    ran_after.fetch_add(1, Ordering::Relaxed);
                }),
                // independent root: may or may not run its payload before
                // the abort flag lands; either way it must not wedge
                GraphNode::new(vec![], || {
                    sibling_ran.fetch_add(1, Ordering::Relaxed);
                }),
            ];
            pool.run_graph(nodes);
        }));
        let payload = result.expect_err("graph panic must resurface in the submitter");
        assert_eq!(
            payload.downcast_ref::<&str>().copied().unwrap_or(""),
            "graph boom",
            "panic payload mangled"
        );
        assert_eq!(
            ran_after.load(Ordering::Relaxed),
            0,
            "descendants of a panicked node must be skipped"
        );
        // the pool survives: a fresh graph on the same pool runs clean
        let counter = AtomicUsize::new(0);
        let nodes = vec![
            GraphNode::new(vec![], || {
                counter.fetch_add(1, Ordering::Relaxed);
            }),
            GraphNode::new(vec![0], || {
                counter.fetch_add(10, Ordering::Relaxed);
            }),
        ];
        pool.run_graph(nodes);
        assert_eq!(counter.load(Ordering::Relaxed), 11, "pool wedged after a graph panic");
        assert!(wait_for(|| pool.sleepers() == 4), "workers failed to quiesce after panic");
    }

    #[test]
    fn graph_cycle_is_rejected_before_submission() {
        let pool = WorkerPool::with_steal_seed(2, 9);
        let ran = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let nodes = vec![
                GraphNode::new(vec![1], || {
                    ran.fetch_add(1, Ordering::Relaxed);
                }),
                GraphNode::new(vec![0], || {
                    ran.fetch_add(1, Ordering::Relaxed);
                }),
            ];
            pool.run_graph(nodes);
        }));
        let payload = result.expect_err("cyclic graph must be rejected");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("cycle"), "wrong rejection message: {msg}");
        assert_eq!(ran.load(Ordering::Relaxed), 0, "cycle rejection must precede submission");
        // nothing was stranded in the deques
        let counter = AtomicUsize::new(0);
        pool.run_graph(vec![
            GraphNode::new(vec![], || {
                counter.fetch_add(1, Ordering::Relaxed);
            }),
            GraphNode::new(vec![0], || {
                counter.fetch_add(1, Ordering::Relaxed);
            }),
        ]);
        assert_eq!(counter.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn graph_nodes_may_submit_nested_scoped_batches() {
        // a graph node fanning out its own run_scoped batch on the SAME
        // pool — the shape of a refresh-wave node submitting its matmuls
        let pool = WorkerPool::with_steal_seed(2, 13);
        let counter = AtomicUsize::new(0);
        let nodes = vec![
            GraphNode::new(vec![], || {
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..6)
                    .map(|_| {
                        Box::new(|| {
                            counter.fetch_add(1, Ordering::Relaxed);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_scoped(tasks);
            }),
            GraphNode::new(vec![0], || {
                counter.fetch_add(100, Ordering::Relaxed);
            }),
        ];
        pool.run_graph(nodes);
        assert_eq!(counter.load(Ordering::Relaxed), 106);
    }

    #[test]
    fn graph_from_inside_a_pool_task_does_not_deadlock() {
        // nested graph submission: a run_scoped task on the pool submits a
        // run_graph to the same pool (the trainer overlaps the update graph
        // with batch prefetch exactly this way)
        let pool = WorkerPool::with_steal_seed(2, 17);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                Box::new(|| {
                    let nodes = vec![
                        GraphNode::new(vec![], || {
                            counter.fetch_add(1, Ordering::Relaxed);
                        }),
                        GraphNode::new(vec![0], || {
                            counter.fetch_add(10, Ordering::Relaxed);
                        }),
                    ];
                    pool.run_graph(nodes);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 22);
    }
}
