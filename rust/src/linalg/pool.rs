//! Persistent work-stealing worker pool — the execution substrate under
//! `par_rows` / `par_map` and every fused dequant kernel.
//!
//! PR-2 replaced per-call thread spawns with a long-lived pool, but funneled
//! every task through ONE mutex-guarded FIFO.  That is fine at laptop core
//! counts and guaranteed contention at 16-32+ workers: every push, every
//! pop, and every park/unpark serialized on a single lock — exactly the
//! regime Q-GaLore's throughput story lives in (many small per-layer
//! products: `P^T g`, `P u`, rank-r refreshes, each individually below a
//! millisecond).  This module replaces the shared queue with per-worker
//! deques plus work stealing:
//!
//! * **One deque per worker.**  A worker pushes and pops its *own* deque
//!   from the back (LIFO — the task it just produced is the one whose
//!   operands are still cache-hot) and only touches another worker's deque
//!   to steal from the front (FIFO — the oldest task is the one its owner
//!   is least likely to want next).  Submitters distribute a batch
//!   round-robin across all deques (a process-wide cursor, so consecutive
//!   submissions interleave instead of piling onto worker 0).
//! * **Victim choice is a per-worker PCG stream** seeded from
//!   [`STEAL_SEED_ENV`] (`QGALORE_STEAL_SEED`) or [`WorkerPool::with_steal_seed`]:
//!   each failed own-pop starts a sweep at a PCG-chosen victim and walks
//!   the ring, skipping the worker's own deque.  Seeding the stream lets
//!   the determinism tests force a *hostile* steal order and prove result
//!   bits cannot depend on interleaving (`tests/golden_trace.rs`).
//! * **Parking is a last resort, and wakeups are targeted.**  A worker
//!   blocks on the condvar only after a full failed steal sweep, and
//!   re-checks the pending-task count under the sleep lock so a submission
//!   cannot slip between its sweep and its wait.  Submitters wake
//!   `min(tasks, sleepers)` workers via `notify_one` — NOT `notify_all`,
//!   which would stampede every parked worker at a 2-task submission only
//!   for most of them to find nothing and re-park (the thundering herd the
//!   unit tests pin down via [`WorkerPool::stats`]).
//! * **Helping submitters are kept from PR 2** — they are the
//!   deadlock-freedom argument for *nested* submission (the galore wave
//!   scheduler fans layers out with `par_map` and each layer's refresh
//!   submits its own matmul tasks).  A blocked submitter first pops its own
//!   deque (if it is a pool worker), then steals from the others; a worker
//!   blocked on an inner submission therefore keeps executing queued tasks,
//!   so every deque drains and every latch eventually opens.
//! * A task that panics is caught, its payload parked on the submission's
//!   latch, and the panic **resumed in the submitting thread** (original
//!   message intact) after the call settles — the pool itself survives,
//!   matching `std::thread::scope` semantics.  A helper that happens to run
//!   another submission's panicking task never unwinds itself: the payload
//!   always travels to the latch it belongs to (`tests/pool_stress.rs`).
//! * The PR-2 single-shared-FIFO pool survives as [`WorkerPool::new_fifo`]
//!   — the scheduler-equivalence baseline for the proptests and the
//!   contention benchmark in `benches/throughput.rs`, exactly like
//!   `ParallelCtx::scoped` is for pooled execution.
//!
//! The pool still does not decide decomposition — `par_rows`/`par_map`
//! split work into the same disjoint slabs keyed by `ParallelCtx::threads`,
//! and every task writes a disjoint output slice, so results are bitwise
//! identical to the scoped engine and to a 1-thread run for ANY worker
//! count and ANY steal interleaving (asserted by `tests/parity.rs`,
//! `tests/proptests.rs`, and `tests/golden_trace.rs`).

use std::cell::Cell;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::util::Pcg32;

/// A queued unit of work.  Tasks are erased to `'static` at submission; the
/// latch protocol in [`WorkerPool::run_scoped`] is what keeps that sound.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Env var forcing the victim-choice PCG seed (u64).  The determinism
/// suites use it to drive whole-process runs under a hostile steal order;
/// result bits must not move.
pub const STEAL_SEED_ENV: &str = "QGALORE_STEAL_SEED";

/// Default victim-choice seed when neither the env var nor
/// [`WorkerPool::with_steal_seed`] supplies one (an arbitrary odd constant;
/// ANY value is correct, which is the whole point).
const DEFAULT_STEAL_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

/// Queue discipline of a pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Sched {
    /// Per-worker deques, LIFO own-pop, PCG-ordered FIFO stealing.
    Steal,
    /// The PR-2 baseline: one shared deque, strict FIFO pop, no stealing.
    Fifo,
}

struct Shared {
    /// One deque per worker (`Steal`) or exactly one (`Fifo`).  Each has
    /// its own mutex: dispatch contention is per-deque, not process-wide.
    /// Constructed via [`Shared::new`] (also the test-fixture constructor).
    deques: Vec<Mutex<VecDeque<Task>>>,
    /// Tasks currently sitting in deques (NOT in-flight on a thread).
    /// Conservative during submission (incremented before the pushes), so a
    /// worker can never park while a sibling task is still being enqueued.
    pending: AtomicUsize,
    /// Count of workers blocked on `available` — read by submitters to
    /// wake exactly as many workers as there are new tasks.
    sleep: Mutex<usize>,
    /// Parked workers wait here; signalled task-count-many times per
    /// submission (and broadcast at shutdown).
    available: Condvar,
    shutdown: AtomicBool,
    /// Round-robin submission cursor across deques.
    rr: AtomicUsize,
    /// Victim-choice PCG seed; worker `i` draws from stream `i`.
    steal_seed: u64,
    sched: Sched,
    /// Times any worker returned from a condvar wait (observability; the
    /// thundering-herd regression test bounds its growth).
    park_wakeups: AtomicUsize,
    /// Tasks taken from a deque the taker did not own.
    steals: AtomicUsize,
}

/// Pool observability counters (monotonic since construction).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Condvar wakeups across all workers — a 2-task submission into a
    /// fully parked pool should cost ~2, not one per worker.
    pub park_wakeups: usize,
    /// Tasks executed by a thread that did not own the deque they sat in.
    pub steals: usize,
}

thread_local! {
    /// (owning pool's `Shared` address, worker index) for pool worker
    /// threads; `(0, MAX)` elsewhere.  Lets a nested submitter find its own
    /// deque (help-LIFO) and lets the steal sweep exclude it.
    static HOME: Cell<(usize, usize)> = const { Cell::new((0, usize::MAX)) };
}

impl Shared {
    fn new(ndeques: usize, sched: Sched, steal_seed: u64) -> Self {
        Shared {
            deques: (0..ndeques).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            sleep: Mutex::new(0),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            rr: AtomicUsize::new(0),
            steal_seed,
            sched,
            park_wakeups: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
        }
    }

    /// Enqueue wrapped tasks: round-robin across deques (stealing) or into
    /// the single shared deque (FIFO).  `pending` is bumped BEFORE any push
    /// so no worker can observe an enqueued task while believing the pool
    /// is idle (the park guard reads `pending` under the sleep lock).
    fn enqueue(&self, tasks: Vec<Task>) {
        let n_tasks = tasks.len();
        self.pending.fetch_add(n_tasks, Ordering::Relaxed);
        match self.sched {
            Sched::Fifo => {
                let mut q = self.deques[0].lock().unwrap();
                for t in tasks {
                    q.push_back(t);
                }
            }
            Sched::Steal => {
                let nd = self.deques.len();
                let start = self.rr.fetch_add(n_tasks, Ordering::Relaxed);
                for (i, t) in tasks.into_iter().enumerate() {
                    self.deques[(start + i) % nd].lock().unwrap().push_back(t);
                }
            }
        }
        // Targeted wakeup: exactly as many workers as there are new tasks
        // (capped at the parked count).  notify_all here would stampede a
        // 32-worker pool for a 2-task submission — the thundering herd the
        // park_wakeups stat exists to catch.
        let sleepers = self.sleep.lock().unwrap();
        for _ in 0..n_tasks.min(*sleepers) {
            self.available.notify_one();
        }
    }
}

/// Completion latch for one `run_scoped` submission.  Carries the first
/// caught panic payload so the submitter can resume it verbatim — the
/// original assert/index message survives, like `std::thread::scope`.
struct Latch {
    left: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            left: Mutex::new(n),
            done: Condvar::new(),
            panic: Mutex::new(None),
        }
    }

    fn complete(&self) {
        let mut left = self.left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.left.lock().unwrap() == 0
    }

    fn wait(&self) {
        let mut left = self.left.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// Take one task: own deque first (LIFO), then a PCG-ordered FIFO steal
/// sweep over the other deques.  `home` is the caller's own deque index
/// (pool workers and nested-submitting workers), or `None` for an external
/// helping submitter, which sweeps every deque.  Returns `None` only after
/// a FULL failed sweep — the precondition for parking.
fn find_task(shared: &Shared, home: Option<usize>, rng: &mut Pcg32) -> Option<Task> {
    if shared.sched == Sched::Fifo {
        // the PR-2 discipline: everyone pops the one shared deque in order
        let t = shared.deques[0].lock().unwrap().pop_front();
        if t.is_some() {
            shared.pending.fetch_sub(1, Ordering::Relaxed);
        }
        return t;
    }
    if let Some(h) = home {
        if let Some(t) = shared.deques[h].lock().unwrap().pop_back() {
            shared.pending.fetch_sub(1, Ordering::Relaxed);
            return Some(t);
        }
    }
    let n = shared.deques.len();
    let start = rng.below(n);
    for i in 0..n {
        let v = (start + i) % n;
        if Some(v) == home {
            continue; // steal-from-self exclusion (own deque already tried)
        }
        if let Some(t) = shared.deques[v].lock().unwrap().pop_front() {
            shared.pending.fetch_sub(1, Ordering::Relaxed);
            shared.steals.fetch_add(1, Ordering::Relaxed);
            return Some(t);
        }
    }
    None
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    HOME.with(|h| h.set((Arc::as_ptr(&shared) as usize, id)));
    let mut rng = Pcg32::new(shared.steal_seed, id as u64);
    loop {
        if let Some(t) = find_task(&shared, Some(id), &mut rng) {
            // panics are caught inside the run_scoped wrapper, so a bad
            // task cannot take the worker (or any deque mutex) down
            t();
            continue;
        }
        // Full sweep failed: park.  The pending re-check happens under the
        // sleep lock, and submitters bump `pending` BEFORE taking that lock
        // to notify — so either this worker sees the new tasks here and
        // re-sweeps, or it is already counted a sleeper and gets notified.
        let mut sleepers = shared.sleep.lock().unwrap();
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            if shared.pending.load(Ordering::Relaxed) > 0 {
                break; // re-sweep
            }
            *sleepers += 1;
            sleepers = shared.available.wait(sleepers).unwrap();
            *sleepers -= 1;
            shared.park_wakeups.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// A long-lived pool of worker threads with per-worker stealing deques
/// (or, for the [`WorkerPool::new_fifo`] baseline, one shared FIFO).
///
/// One process-global instance ([`global_pool`]) backs `ParallelCtx::new` /
/// `::global`; tests and benches construct private instances (usually via
/// [`WorkerPool::leaked`], since `ParallelCtx` carries a `&'static` handle
/// so it can stay `Copy`).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
}

/// `QGALORE_STEAL_SEED`-style value -> seed, warning (not silently
/// defaulting a typo) like the `QGALORE_KERNEL` parser does.
fn steal_seed_from_env() -> u64 {
    match std::env::var(STEAL_SEED_ENV) {
        Ok(s) => match s.trim().parse::<u64>() {
            Ok(v) => v,
            Err(_) => {
                eprintln!(
                    "warning: unrecognized {STEAL_SEED_ENV}={s:?} (want a u64); \
                     using the default steal seed"
                );
                DEFAULT_STEAL_SEED
            }
        },
        Err(_) => DEFAULT_STEAL_SEED,
    }
}

impl WorkerPool {
    /// Spawn `workers` (clamped to 1+) stealing workers, parked on their
    /// deques.  The victim-choice seed comes from [`STEAL_SEED_ENV`] when
    /// set (the determinism suites' hostile-order hook), else a default.
    pub fn new(workers: usize) -> Self {
        Self::build(workers, Sched::Steal, steal_seed_from_env())
    }

    /// [`WorkerPool::new`] with an explicit victim-choice seed — the
    /// in-process form of [`STEAL_SEED_ENV`] for tests that pin a steal
    /// order without touching process env.
    pub fn with_steal_seed(workers: usize, seed: u64) -> Self {
        Self::build(workers, Sched::Steal, seed)
    }

    /// The PR-2 execution layer: one shared mutex-guarded FIFO, no
    /// stealing.  Kept as the scheduler-equivalence baseline for
    /// `tests/proptests.rs` and the contention benchmark — NOT for
    /// production dispatch.
    pub fn new_fifo(workers: usize) -> Self {
        Self::build(workers, Sched::Fifo, DEFAULT_STEAL_SEED)
    }

    fn build(workers: usize, sched: Sched, steal_seed: u64) -> Self {
        let workers = workers.max(1);
        let ndeques = match sched {
            Sched::Steal => workers,
            Sched::Fifo => 1,
        };
        let shared = Arc::new(Shared::new(ndeques, sched, steal_seed));
        let handles = (0..workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("qgalore-pool-{i}"))
                    .spawn(move || worker_loop(s, i))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles, workers }
    }

    /// A leaked (process-lifetime) stealing pool: the `&'static` handle
    /// form that [`super::ParallelCtx::with_pool`] takes.  Used by tests
    /// and benches that need explicit pool sizes; never joined.
    pub fn leaked(workers: usize) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::new(workers)))
    }

    /// Leaked [`WorkerPool::new_fifo`] baseline pool.
    pub fn leaked_fifo(workers: usize) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::new_fifo(workers)))
    }

    /// Leaked [`WorkerPool::with_steal_seed`] pool (hostile-order tests).
    pub fn leaked_with_steal_seed(workers: usize, seed: u64) -> &'static WorkerPool {
        Box::leak(Box::new(WorkerPool::with_steal_seed(workers, seed)))
    }

    /// Number of worker threads (excluding helping submitters).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Whether this pool runs the stealing discipline (false: FIFO baseline).
    pub fn is_stealing(&self) -> bool {
        self.shared.sched == Sched::Steal
    }

    /// Workers currently parked on the condvar (instantaneous).
    pub fn sleepers(&self) -> usize {
        *self.shared.sleep.lock().unwrap()
    }

    /// Monotonic observability counters; see [`PoolStats`].
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            park_wakeups: self.shared.park_wakeups.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
        }
    }

    /// Execute every task and return once all have completed.
    ///
    /// The submitting thread helps while it waits — own deque first (when
    /// the submitter IS a pool worker doing a nested submission), then
    /// stealing — so calling this from *inside* a pool task cannot
    /// deadlock.  If any task panicked, the panic is re-thrown here after
    /// the whole submission has settled.
    ///
    /// SAFETY invariant: tasks may borrow data with lifetime `'scope`
    /// (shorter than `'static`).  They are transmuted to `'static` to sit
    /// in the deques, which is sound because this function does not return
    /// until the latch confirms every submitted task has finished running —
    /// no task can outlive the borrows it captures.
    pub fn run_scoped<'scope>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if tasks.is_empty() {
            return;
        }
        if tasks.len() == 1 {
            // nothing to fan out; run inline (panics propagate naturally)
            (tasks.into_iter().next().unwrap())();
            return;
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        let wrapped: Vec<Task> = tasks
            .into_iter()
            .map(|task| {
                let l = Arc::clone(&latch);
                let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                    if let Err(payload) =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(task))
                    {
                        let mut slot = l.panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(payload);
                        }
                    }
                    l.complete();
                });
                // SAFETY: see the invariant above — we block on `latch`
                // below until every wrapped task has run to completion, so
                // the 'scope borrows stay live for every execution.
                unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Task>(wrapped)
                }
            })
            .collect();
        self.shared.enqueue(wrapped);

        // Help while waiting: a pool worker submitting a nested batch pops
        // its own deque first, then steals; an external submitter sweeps
        // every deque.  Tasks of OTHER submissions get helped too — that is
        // what keeps nested latches opening.  Block on the latch only after
        // a full failed sweep, for whatever is still in flight elsewhere.
        let home = HOME.with(|h| {
            let (pool, id) = h.get();
            (pool == Arc::as_ptr(&self.shared) as usize).then_some(id)
        });
        static HELPER_STREAM: AtomicU64 = AtomicU64::new(1 << 32);
        let mut rng = Pcg32::new(
            self.shared.steal_seed,
            HELPER_STREAM.fetch_add(1, Ordering::Relaxed),
        );
        loop {
            if latch.is_done() {
                break;
            }
            match find_task(&self.shared, home, &mut rng) {
                Some(t) => t(),
                None => {
                    latch.wait();
                    break;
                }
            }
        }
        let payload = latch.panic.lock().unwrap().take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }
}

impl fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("stealing", &self.is_stealing())
            .finish_non_exhaustive()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            // signal under the sleep lock: a worker is either holding it
            // (and will see the flag on its park-guard check) or already
            // waiting (and will be woken) — no lost-wakeup window between
            // its shutdown check and its wait
            let _sleepers = self.shared.sleep.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.available.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-global pool: sized from [`super::engine::global_threads`]
/// (CLI `--threads` / `QGALORE_THREADS` env / detected cores) on first use.
/// `main` touches this right after parsing `--threads` so the workers spin
/// up once, before any timed work.
pub fn global_pool() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(super::engine::global_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::{Duration, Instant};

    /// A worker-less `Shared` for deterministic scheduling-logic tests
    /// (no threads racing for the tasks we stage by hand).
    fn bare_shared(ndeques: usize, sched: Sched) -> Shared {
        Shared::new(ndeques, sched, 0)
    }

    fn push_marker(shared: &Shared, deque: usize, log: &Arc<Mutex<Vec<usize>>>, id: usize) {
        let log = Arc::clone(log);
        shared.deques[deque]
            .lock()
            .unwrap()
            .push_back(Box::new(move || log.lock().unwrap().push(id)) as Task);
        shared.pending.fetch_add(1, Ordering::Relaxed);
    }

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..64)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn fifo_baseline_runs_every_task_exactly_once() {
        let pool = WorkerPool::new_fifo(3);
        assert!(!pool.is_stealing());
        let counter = AtomicUsize::new(0);
        for _ in 0..20 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 160);
    }

    #[test]
    fn reuse_across_many_submissions() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn tasks_can_borrow_caller_stack() {
        let pool = WorkerPool::new(2);
        let mut out = vec![0usize; 8];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .chunks_mut(2)
            .enumerate()
            .map(|(i, slab)| {
                Box::new(move || {
                    for (j, s) in slab.iter_mut().enumerate() {
                        *s = i * 2 + j;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(out, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_task_propagates_but_pool_survives() {
        let pool = WorkerPool::new(2);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("task boom")),
                Box::new(|| {}),
            ];
            pool.run_scoped(tasks);
        }));
        let payload = boom.expect_err("panic must reach the submitter");
        assert_eq!(
            payload.downcast_ref::<&str>().copied().unwrap_or(""),
            "task boom",
            "original panic payload must be preserved"
        );
        // the pool keeps working after a task panic
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(4);
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        drop(pool); // must not hang
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = global_pool() as *const WorkerPool;
        let b = global_pool() as *const WorkerPool;
        assert!(std::ptr::eq(a, b));
        assert!(global_pool().workers() >= 1);
        assert!(global_pool().is_stealing());
    }

    // -----------------------------------------------------------------------
    // steal-aware scheduling tests (the ISSUE-4 satellite block)
    // -----------------------------------------------------------------------

    #[test]
    fn own_pop_is_lifo_steal_is_fifo() {
        // worker-less Shared: we stage tasks by hand and drive find_task
        // directly, so the order observations are deterministic
        let shared = bare_shared(2, Sched::Steal);
        let log = Arc::new(Mutex::new(Vec::new()));
        for id in [10usize, 11, 12] {
            push_marker(&shared, 0, &log, id);
        }
        let mut rng = Pcg32::new(0, 0);
        // owner of deque 0 pops newest-first
        for _ in 0..3 {
            find_task(&shared, Some(0), &mut rng).expect("own pop")();
        }
        assert_eq!(*log.lock().unwrap(), vec![12, 11, 10], "own pop must be LIFO");

        log.lock().unwrap().clear();
        for id in [20usize, 21, 22] {
            push_marker(&shared, 0, &log, id);
        }
        // worker 1 steals from deque 0 oldest-first
        for _ in 0..3 {
            find_task(&shared, Some(1), &mut rng).expect("steal")();
        }
        assert_eq!(*log.lock().unwrap(), vec![20, 21, 22], "steals must be FIFO");
        assert_eq!(shared.steals.load(Ordering::Relaxed), 3);
        assert_eq!(shared.pending.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn steal_sweep_excludes_own_deque() {
        // a single-deque stealing pool shape: with the own deque empty, the
        // sweep has only "self" to visit and must come back empty-handed
        // instead of double-polling (or deadlocking on) its own mutex
        let shared = bare_shared(1, Sched::Steal);
        let mut rng = Pcg32::new(7, 0);
        assert!(find_task(&shared, Some(0), &mut rng).is_none());
        assert_eq!(shared.steals.load(Ordering::Relaxed), 0, "self-steal counted");

        // and in a 3-deque pool, a sweep from worker 1 with work ONLY in
        // deque 1 finds nothing: its own deque was tried (and emptied by the
        // LIFO pop below), the others are empty
        let shared = bare_shared(3, Sched::Steal);
        let log = Arc::new(Mutex::new(Vec::new()));
        push_marker(&shared, 1, &log, 1);
        find_task(&shared, Some(1), &mut rng).expect("own pop")();
        assert_eq!(shared.steals.load(Ordering::Relaxed), 0, "own pop counted as steal");
        assert!(find_task(&shared, Some(1), &mut rng).is_none());
    }

    #[test]
    fn external_helper_sweeps_every_deque() {
        // home = None (a non-worker submitter): the sweep must be able to
        // reach work wherever round-robin placed it
        let shared = bare_shared(4, Sched::Steal);
        let log = Arc::new(Mutex::new(Vec::new()));
        for d in 0..4 {
            push_marker(&shared, d, &log, d);
        }
        let mut rng = Pcg32::new(3, 99);
        for _ in 0..4 {
            find_task(&shared, None, &mut rng).expect("helper sweep")();
        }
        assert!(find_task(&shared, None, &mut rng).is_none());
        let mut seen = log.lock().unwrap().clone();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3], "helper missed a deque");
    }

    #[test]
    fn round_robin_spreads_a_batch_across_deques() {
        // worker-less Shared, so the placement survives to be observed:
        // 10 tasks over 4 deques from a fresh cursor land 3/3/2/2, and the
        // next batch CONTINUES at the cursor instead of restarting at 0
        let shared = bare_shared(4, Sched::Steal);
        let tasks: Vec<Task> = (0..10).map(|_| Box::new(|| {}) as Task).collect();
        shared.enqueue(tasks);
        let lens = |shared: &Shared| -> Vec<usize> {
            shared.deques.iter().map(|d| d.lock().unwrap().len()).collect()
        };
        assert_eq!(lens(&shared), vec![3, 3, 2, 2], "batch not spread round-robin");
        let tasks: Vec<Task> = (0..2).map(|_| Box::new(|| {}) as Task).collect();
        shared.enqueue(tasks);
        assert_eq!(lens(&shared), vec![3, 3, 3, 3], "cursor reset between batches");
        assert_eq!(shared.pending.load(Ordering::Relaxed), 12);
    }

    /// Spin until `cond` holds or ~2s elapse (parking is asynchronous).
    fn wait_for(cond: impl Fn() -> bool) -> bool {
        let deadline = Instant::now() + Duration::from_secs(2);
        while Instant::now() < deadline {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        cond()
    }

    #[test]
    fn all_parked_workers_wake_on_submit_without_thundering_herd() {
        let pool = WorkerPool::with_steal_seed(8, 42);
        assert!(wait_for(|| pool.sleepers() == 8), "workers failed to park");
        let before = pool.stats();
        // a 2-task submission into a fully parked 8-worker pool must wake
        // ~2 workers, not all 8 (the submitter may even help one of the
        // tasks itself).  Generous slack for OS-level spurious wakeups; the
        // pre-fix notify_all behavior woke all 8 deterministically.
        let counter = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 2);
        assert!(wait_for(|| pool.sleepers() == 8), "workers failed to re-park");
        let woke = pool.stats().park_wakeups - before.park_wakeups;
        assert!(woke <= 4, "thundering herd: {woke} wakeups for a 2-task submission");
        // and a fully parked pool still wakes for the NEXT submission (the
        // park/unpark handshake cannot strand tasks)
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(tasks);
        assert_eq!(counter.load(Ordering::Relaxed), 18);
    }

    #[test]
    fn park_unpark_race_under_rapid_small_batches() {
        // hammer the exact window the park guard protects: workers finish a
        // sweep and head for the condvar while submitters push fresh tiny
        // batches.  A lost wakeup deadlocks this test; a miscounted sleeper
        // loses tasks.  4 submitters x 300 batches x 2 tasks on 2 workers.
        let pool = WorkerPool::with_steal_seed(2, 5);
        let counter = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..300 {
                        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                            .map(|_| {
                                Box::new(|| {
                                    counter.fetch_add(1, Ordering::Relaxed);
                                })
                                    as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        pool.run_scoped(tasks);
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4 * 300 * 2);
        assert!(wait_for(|| pool.sleepers() == 2), "workers failed to quiesce");
    }

    #[test]
    fn hostile_steal_seeds_do_not_change_results() {
        // same staged work, three victim-choice seeds: totals must agree
        // (bit-for-bit output equality lives in the integration suites;
        // here we pin the cheap invariant that scheduling is the ONLY
        // thing the seed touches)
        for seed in [0u64, 1, u64::MAX] {
            let pool = WorkerPool::with_steal_seed(4, seed);
            let counter = AtomicUsize::new(0);
            for _ in 0..25 {
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..7)
                    .map(|_| {
                        Box::new(|| {
                            counter.fetch_add(1, Ordering::Relaxed);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_scoped(tasks);
            }
            assert_eq!(counter.load(Ordering::Relaxed), 175, "seed {seed:#x}");
        }
    }
}
