//! Parallel cache-blocked matmul engine — the hot path under every
//! Q-GaLore projection (`P^T g`, `P u`) and subspace refresh.
//!
//! Architecture (no external deps; std threads only):
//!
//! * **Decomposition** lives here: work splits over disjoint row panels of
//!   the output keyed by [`ParallelCtx::threads`]; each task owns a
//!   `&mut` slab, so the parallelism is safe-Rust with zero synchronization
//!   on the accumulation path.
//! * **Execution** lives in the persistent [`pool`](super::pool): a
//!   [`ParallelCtx`] is a *handle* — a thread budget plus the
//!   [`WorkerPool`] that will run the tasks.  The pool is spun up once
//!   (from CLI `--threads` / `QGALORE_THREADS` env / detected cores) and
//!   reused for every call, replacing PR-1's per-call
//!   `std::thread::scope` spawns and their ~100us dispatch tax.  The old
//!   scoped-spawn path survives as a fallback ([`ParallelCtx::scoped`]) and
//!   as the baseline the dispatch-overhead bench measures against.
//! * Because the pool executes the *same* disjoint-slab decomposition, its
//!   results are **bitwise identical** to the scoped-thread engine and to a
//!   1-thread run, for any pool size (asserted by `tests/parity.rs`).
//! * Within a panel the kernel is k-blocked (`KC`-sized stripes of B stay
//!   hot in cache) with the same ascending-k accumulation order as the
//!   naive reference, so blocked and naive results also match bitwise —
//!   parity tests assert a 1e-5 rel-Frobenius bound but the engine in fact
//!   meets 0.
//! * `t_matmul` transposes bounded per-worker column sub-panels into a
//!   dense row-major scratch and reuses the same kernel: the strided column
//!   walk happens once per panel instead of once per fma.
//!
//! Small problems (< [`PAR_MIN_FLOPS`] fma) run serially on the calling
//! thread — even pool dispatch costs more than the arithmetic there.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::pool::{global_pool, WorkerPool};
use super::Mat;

/// k-stripe width: `KC` rows of B (KC * n * 4 bytes) form the resident
/// cache block each panel row streams against.
const KC: usize = 256;

/// Problems below this many fma ops (m*k*n) stay on the calling thread.
pub const PAR_MIN_FLOPS: usize = 1 << 20;

/// Buffer-cloning fan-outs (operand marshalling) below this many total
/// elements stay serial — dispatch cost would exceed the memcpy.
pub const PAR_MIN_CLONE_ELEMS: usize = 1 << 20;

/// Resolve-once container for a worker-count default: 0 = unresolved, an
/// explicit [`ThreadCount::set`] always wins over the detected value.
/// Factored out of the process-global so tests exercise the override
/// semantics on a *private* instance instead of mutating (and racing) the
/// global that concurrent parity tests read through `ParallelCtx::global`.
pub(crate) struct ThreadCount(AtomicUsize);

impl ThreadCount {
    pub(crate) const fn unresolved() -> Self {
        ThreadCount(AtomicUsize::new(0))
    }

    /// Explicit override (CLI `--threads`). Clamped to 1+.
    pub(crate) fn set(&self, n: usize) {
        self.0.store(n.max(1), Ordering::Relaxed);
    }

    /// Current value, resolving via `detect` on first use.
    pub(crate) fn get(&self, detect: impl FnOnce() -> usize) -> usize {
        match self.0.load(Ordering::Relaxed) {
            0 => {
                let n = detect().max(1);
                // racing first-callers agree on detect()'s value; an
                // explicit set() always wins afterwards
                let _ = self.0.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed);
                n
            }
            n => n,
        }
    }
}

/// Process-global default thread count.
static GLOBAL_THREADS: ThreadCount = ThreadCount::unresolved();

/// Override the global default (CLI `--threads`). Values are clamped to 1+.
/// Call before the first parallel work: the global pool sizes itself from
/// this value once, on first use.
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.set(n);
}

/// `QGALORE_THREADS`-style value -> worker count (>= 1), if well-formed.
fn parse_threads(s: &str) -> Option<usize> {
    match s.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => None,
    }
}

fn detect_threads() -> usize {
    std::env::var("QGALORE_THREADS")
        .ok()
        .and_then(|s| parse_threads(&s))
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The global default thread count (resolving it on first use).
pub fn global_threads() -> usize {
    GLOBAL_THREADS.get(detect_threads)
}

/// Parallelism handle threaded through the optimizer stack: a thread budget
/// (how many disjoint slabs the decomposition produces) plus the worker
/// pool that executes them.  `Copy`, so it flows by value everywhere; the
/// pool reference is `&'static` (the global pool, or a leaked explicit one).
///
/// The budget controls *decomposition only* — results are bitwise identical
/// whatever pool (or the scoped fallback) runs the slabs.
#[derive(Clone, Copy, Debug)]
pub struct ParallelCtx {
    pub threads: usize,
    pool: Option<&'static WorkerPool>,
}

impl ParallelCtx {
    /// Exactly one thread (reference semantics, no dispatch at all).
    pub fn serial() -> Self {
        ParallelCtx { threads: 1, pool: None }
    }

    /// A budget of `threads` executed on the process-global pool.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        ParallelCtx { threads, pool: if threads > 1 { Some(global_pool()) } else { None } }
    }

    /// A budget of `threads` executed by per-call scoped spawns (the PR-1
    /// engine).  Kept as a fallback and as the dispatch-overhead baseline
    /// for `benches/throughput.rs`.
    pub fn scoped(threads: usize) -> Self {
        ParallelCtx { threads: threads.max(1), pool: None }
    }

    /// A budget of `threads` executed on an explicit pool (tests/benches;
    /// leak the pool via [`WorkerPool::leaked`] to get the `'static` handle).
    pub fn with_pool(threads: usize, pool: &'static WorkerPool) -> Self {
        ParallelCtx { threads: threads.max(1), pool: Some(pool) }
    }

    /// The process-global default (CLI/env/hardware) on the global pool.
    pub fn global() -> Self {
        ParallelCtx::new(global_threads())
    }

    /// Same pool, different thread budget — for callers splitting one
    /// worker budget between an outer fan-out and inner linalg calls.
    pub fn with_threads(self, threads: usize) -> Self {
        ParallelCtx { threads: threads.max(1), pool: self.pool }
    }

    /// The pool that should execute a parallel call, if any.
    fn pool(&self) -> Option<&'static WorkerPool> {
        if self.threads <= 1 {
            None
        } else {
            self.pool
        }
    }
}

impl PartialEq for ParallelCtx {
    fn eq(&self, other: &Self) -> bool {
        self.threads == other.threads
            && match (self.pool, other.pool) {
                (None, None) => true,
                (Some(a), Some(b)) => std::ptr::eq(a, b),
                _ => false,
            }
    }
}

impl Eq for ParallelCtx {}

impl Default for ParallelCtx {
    fn default() -> Self {
        ParallelCtx::global()
    }
}

/// Gate a buffer-cloning fan-out: serial below [`PAR_MIN_CLONE_ELEMS`]
/// total elements (dispatch cost would exceed the memcpy), else `pool`.
pub fn clone_pool(total_elems: usize, pool: ParallelCtx) -> ParallelCtx {
    if total_elems < PAR_MIN_CLONE_ELEMS {
        ParallelCtx::serial()
    } else {
        pool
    }
}

/// Run `body(r0, r1, slab)` over disjoint row panels of a freshly zeroed
/// (rows, cols) row-major buffer, splitting panels across `ctx.threads`
/// tasks.  Tasks execute on the ctx's pool (or per-call scoped workers for
/// a pool-less ctx); either way the decomposition — and therefore the
/// result, bit for bit — is identical.  `slab` covers exactly rows
/// `r0..r1`.
pub fn par_rows<F>(ctx: ParallelCtx, rows: usize, cols: usize, body: F) -> Vec<f32>
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let mut out = vec![0f32; rows * cols];
    if rows == 0 || cols == 0 {
        return out;
    }
    let t = ctx.threads.clamp(1, rows);
    if t <= 1 {
        body(0, rows, &mut out);
        return out;
    }
    let chunk = rows.div_ceil(t);
    let body = &body;
    match ctx.pool() {
        Some(pool) => {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(chunk * cols)
                .enumerate()
                .map(|(ti, slab)| {
                    let r0 = ti * chunk;
                    let r1 = (r0 + chunk).min(rows);
                    Box::new(move || body(r0, r1, slab)) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        None => {
            std::thread::scope(|s| {
                for (ti, slab) in out.chunks_mut(chunk * cols).enumerate() {
                    let r0 = ti * chunk;
                    let r1 = (r0 + chunk).min(rows);
                    s.spawn(move || body(r0, r1, slab));
                }
            });
        }
    }
    out
}

/// Map `f` over `items` with up to `ctx.threads` tasks, preserving order.
/// Used to step independent layers / tensors concurrently; executes on the
/// ctx's pool like [`par_rows`].
pub fn par_map<T, U, F>(ctx: ParallelCtx, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    if ctx.threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let t = ctx.threads.min(items.len());
    let chunk = items.len().div_ceil(t);
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    let f = &f;
    match ctx.pool() {
        Some(pool) => {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = items
                .chunks(chunk)
                .zip(out.chunks_mut(chunk))
                .map(|(islab, oslab)| {
                    Box::new(move || {
                        for (i, o) in islab.iter().zip(oslab.iter_mut()) {
                            *o = Some(f(i));
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        None => {
            std::thread::scope(|s| {
                for (islab, oslab) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
                    s.spawn(move || {
                        for (i, o) in islab.iter().zip(oslab.iter_mut()) {
                            *o = Some(f(i));
                        }
                    });
                }
            });
        }
    }
    out.into_iter().map(|o| o.expect("par_map worker filled every slot")).collect()
}

/// Inner kernel: `out (rows, n) += panel (rows, k) @ b (k, n)`, k-blocked.
/// Accumulation over k is strictly ascending per output element — the same
/// order as the naive reference, so results match it bitwise.
pub(crate) fn panel_matmul(panel: &[f32], rows: usize, k: usize, b: &Mat, out: &mut [f32]) {
    let n = b.cols;
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for i in 0..rows {
            let arow = &panel[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        kb = kend;
    }
}

/// Clamp `ctx` to serial when the m*k*n fma count is below
/// [`PAR_MIN_FLOPS`] (shared policy for the dense and fused-dequant paths).
pub(crate) fn effective(ctx: ParallelCtx, m: usize, k: usize, n: usize) -> ParallelCtx {
    if m.saturating_mul(k).saturating_mul(n) < PAR_MIN_FLOPS {
        ParallelCtx::serial()
    } else {
        ctx
    }
}

/// `a (m, k) @ b (k, n) -> (m, n)`, parallel over row panels of the output.
pub fn matmul(a: &Mat, b: &Mat, ctx: ParallelCtx) -> Mat {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    matmul_ungated(a, b, effective(ctx, m, k, n))
}

/// [`matmul`] without the [`PAR_MIN_FLOPS`] serial gate.  Bench/test hook:
/// the dispatch-overhead benchmark drives deliberately small products
/// through the parallel path to measure per-call scoped-spawn vs pool
/// latency.  Results are identical to [`matmul`] for any ctx.
pub fn matmul_ungated(a: &Mat, b: &Mat, ctx: ParallelCtx) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let data = par_rows(ctx, m, n, |r0, r1, out| {
        panel_matmul(&a.data[r0 * k..r1 * k], r1 - r0, k, b, out);
    });
    Mat { rows: m, cols: n, data }
}

/// Max rows of transposed scratch a `t_matmul` worker holds at once: the
/// strided column walk is amortized per sub-panel while scratch stays at
/// `TRANSPOSE_PANEL_ROWS * k` floats regardless of the worker's row range
/// (a serial call would otherwise materialize the whole transpose).
const TRANSPOSE_PANEL_ROWS: usize = 64;

/// `a^T @ b` for `a (k, m)`, `b (k, n) -> (m, n)` without materializing the
/// full transpose: each worker transposes bounded sub-panels of its column
/// range of `a` into a reused dense scratch, then runs the shared blocked
/// kernel on each.
pub fn t_matmul(a: &Mat, b: &Mat, ctx: ParallelCtx) -> Mat {
    assert_eq!(a.rows, b.rows, "t_matmul shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let ctx = effective(ctx, m, k, n);
    let data = par_rows(ctx, m, n, |r0, r1, out| {
        let mut panel = vec![0f32; TRANSPOSE_PANEL_ROWS.min(r1 - r0) * k];
        let mut rs = r0;
        while rs < r1 {
            let re = (rs + TRANSPOSE_PANEL_ROWS).min(r1);
            let pw = re - rs;
            for kk in 0..k {
                let arow = &a.data[kk * m..(kk + 1) * m];
                for i in 0..pw {
                    panel[i * k + kk] = arow[rs + i];
                }
            }
            panel_matmul(
                &panel[..pw * k],
                pw,
                k,
                b,
                &mut out[(rs - r0) * n..(re - r0) * n],
            );
            rs = re;
        }
    });
    Mat { rows: m, cols: n, data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn matmul_matches_naive_across_threads() {
        let mut rng = Pcg32::seeded(11);
        for (m, k, n) in [(1, 1, 1), (7, 13, 5), (64, 64, 64), (129, 257, 65)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = a.matmul_naive(&b);
            for t in [1usize, 2, 8] {
                let got = matmul(&a, &b, ParallelCtx::new(t));
                assert!(
                    got.rel_frobenius(&want) <= 1e-5,
                    "matmul {m}x{k}x{n} t={t} diverged"
                );
            }
        }
    }

    #[test]
    fn t_matmul_matches_naive_across_threads() {
        let mut rng = Pcg32::seeded(12);
        for (k, m, n) in [(1, 1, 1), (13, 7, 5), (64, 64, 64), (257, 129, 65)] {
            let a = Mat::randn(k, m, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = a.t_matmul_naive(&b);
            for t in [1usize, 2, 8] {
                let got = t_matmul(&a, &b, ParallelCtx::new(t));
                assert!(
                    got.rel_frobenius(&want) <= 1e-5,
                    "t_matmul {k}x{m}x{n} t={t} diverged"
                );
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        let c = matmul(&a, &b, ParallelCtx::new(4));
        assert_eq!((c.rows, c.cols), (0, 3));
        let a = Mat::zeros(4, 0);
        let b = Mat::zeros(0, 3);
        let c = matmul(&a, &b, ParallelCtx::new(4));
        assert_eq!(c.data, vec![0.0; 12]);
    }

    #[test]
    fn scoped_fallback_matches_pool_bitwise() {
        // ungated so the small shape actually exercises both dispatch paths
        let mut rng = Pcg32::seeded(13);
        let a = Mat::randn(65, 33, &mut rng);
        let b = Mat::randn(33, 17, &mut rng);
        let want = matmul_ungated(&a, &b, ParallelCtx::serial());
        for t in [2usize, 8] {
            assert_eq!(
                matmul_ungated(&a, &b, ParallelCtx::scoped(t)).data,
                want.data,
                "scoped t={t}"
            );
            assert_eq!(
                matmul_ungated(&a, &b, ParallelCtx::new(t)).data,
                want.data,
                "pool t={t}"
            );
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        let ys = par_map(ParallelCtx::new(8), &xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
        let empty: Vec<usize> = Vec::new();
        assert!(par_map(ParallelCtx::new(8), &empty, |&x: &usize| x).is_empty());
    }

    #[test]
    fn thread_count_override_and_resolution() {
        // a PRIVATE instance: the former version of this test mutated the
        // process-global count, racing parity tests that concurrently read
        // ParallelCtx::global() under cargo's parallel test runner
        let tc = ThreadCount::unresolved();
        assert_eq!(tc.get(|| 5), 5);
        assert_eq!(tc.get(|| 99), 5, "detection resolves exactly once");
        tc.set(3);
        assert_eq!(tc.get(|| 99), 3, "explicit override wins");
        tc.set(0);
        assert_eq!(tc.get(|| 99), 1, "override clamps to 1+");
    }

    #[test]
    fn thread_env_parsing() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 16\n"), Some(16));
        assert_eq!(parse_threads("0"), None, "0 falls back to detection");
        assert_eq!(parse_threads("-2"), None);
        assert_eq!(parse_threads("lots"), None);
        assert_eq!(parse_threads(""), None);
    }

    #[test]
    fn global_threads_resolves_to_at_least_one() {
        // read-only on the process global: safe under the parallel runner
        assert!(global_threads() >= 1);
        assert_eq!(ParallelCtx::global().threads, global_threads());
    }

    #[test]
    fn ctx_constructors_and_budget_split() {
        assert_eq!(ParallelCtx::serial().threads, 1);
        assert_eq!(ParallelCtx::new(0).threads, 1);
        assert_eq!(ParallelCtx::scoped(0).threads, 1);
        let ctx = ParallelCtx::new(8);
        assert_eq!(ctx.with_threads(3).threads, 3);
        assert_eq!(ctx.with_threads(0).threads, 1);
        // serial never dispatches, whatever handle it carries
        assert!(ParallelCtx::new(1).pool().is_none());
        assert!(ctx.with_threads(1).pool().is_none());
    }
}
