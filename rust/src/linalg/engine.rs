//! Parallel cache-blocked matmul engine — the hot path under every
//! Q-GaLore projection (`P^T g`, `P u`) and subspace refresh.
//!
//! Architecture (no external deps; std threads only):
//!
//! * **Decomposition** lives here: work splits over disjoint row panels of
//!   the output keyed by [`ParallelCtx::threads`]; each task owns a
//!   `&mut` slab, so the parallelism is safe-Rust with zero synchronization
//!   on the accumulation path.
//! * **Execution** lives in the persistent [`pool`](super::pool): a
//!   [`ParallelCtx`] is a *handle* — a thread budget plus the
//!   [`WorkerPool`] that will run the tasks.  The pool is spun up once
//!   (from CLI `--threads` / `QGALORE_THREADS` env / detected cores) and
//!   reused for every call; it schedules over per-worker **Chase-Lev**
//!   deques (wait-free LIFO own-pop, CAS-only FIFO steals, a once-per-
//!   batch injector for external submitters) instead of mutex queues, so
//!   the many small projection products Q-GaLore issues stop serializing
//!   on locks at high worker counts.  Which thread runs a slab — and in
//!   what steal order — never affects the bits: tasks own disjoint output
//!   slices and the decomposition below is keyed by the ctx alone.  The
//!   old scoped-spawn path survives as a fallback
//!   ([`ParallelCtx::scoped`]) and as the baseline the dispatch-overhead
//!   bench measures against; the PR-2 single-FIFO pool
//!   ([`WorkerPool::new_fifo`]) and the PR-4 mutex-deque pool
//!   ([`WorkerPool::new_mutex_steal`]) survive for the same reason.
//! * **Over-decomposition with a shape-aware cost model**: pool-dispatched
//!   `par_rows` / `par_map` calls cut finer-grained slabs than one per
//!   budgeted worker, so a straggler slab no longer serializes a wave's
//!   tail — idle workers steal the leftovers, which the Chase-Lev rewrite
//!   makes nearly free.  The slab count comes from a small cost model
//!   (`ParallelCtx::cost_slabs`): tall-skinny outputs split finer (their
//!   row panels are cheap, so straggler variance dominates), while shapes
//!   approaching [`PAR_MIN_FLOPS`] coarsen toward one slab per worker —
//!   no slab holds fewer than [`MIN_SLAB_ELEMS`] output elements, where
//!   push/steal overhead would rival the arithmetic.  An explicit
//!   multiplier (env [`SLABS_ENV`] /
//!   [`ParallelCtx::with_slabs_per_worker`] /
//!   [`set_global_slabs_per_worker`]) pins the fixed
//!   `threads * slabs_per_worker` decomposition instead, so tuned CI legs
//!   keep their exact historical slab counts.  Slab bounds affect only who
//!   computes which rows, never any element's accumulation order, so
//!   results stay bitwise identical at every slab count — model-chosen or
//!   pinned (asserted by `tests/parity.rs` and `tests/proptests.rs`).  The
//!   scoped fallback keeps one slab per thread: over-decomposing it would
//!   multiply OS thread spawns with no stealing to profit from.
//! * **The kernel body** is a register-blocked microkernel (PR 3): an
//!   [`MR`]×[`NR`] tile of output accumulators stays live in registers
//!   across each `KC`-wide k stripe, vectorized across the *independent*
//!   j (output-column) dimension, so each output element's k-accumulation
//!   order is exactly the naive reference's ascending walk — results stay
//!   **bitwise identical** to `Mat::matmul_naive` while B-row loads and
//!   out-row traffic drop by the tile factors.  Three bodies sit behind
//!   [`KernelPath`] runtime dispatch:
//!   - [`KernelPath::Simd`]: explicit AVX2 intrinsics (x86_64, selected at
//!     runtime when `is_x86_feature_detected!` reports both `avx2` and
//!     `fma`), 8-lane f32 column vectors with 4 row accumulators.
//!   - [`KernelPath::Simd512`]: the MR=4 × [`NR512`]=16 AVX-512 widening
//!     of the same tile — zmm column vectors, runtime-detected `avx512f`.
//!     The intrinsics body compiles only when the building rustc has the
//!     stabilized `_mm512_*` f32 intrinsics (sniffed by `build.rs`, cfg
//!     `qgalore_avx512_intrinsics`); everywhere else — old toolchain, no
//!     avx512f, non-x86 — the request runs a portable body with the SAME
//!     NR=16 tiling, so `QGALORE_KERNEL=avx512` is safe on any runner and
//!     the bits never move.
//!   - [`KernelPath::Portable`]: the same tiling and op order in plain
//!     unrolled Rust (autovectorizes well on any target).
//!   - [`KernelPath::Autovec`]: the PR-1/2 row-streaming kernel, kept
//!     callable as the regression baseline `benches/throughput.rs` compares
//!     against (like `ParallelCtx::scoped` is for the pool).
//!   m/n/k tails fall to a scalar edge kernel with the same per-element
//!   order.  Why mul+add and not `fmadd`: a fused multiply-add rounds once
//!   where the reference (`o += a * b`) rounds twice, so real FMA would
//!   silently break the bitwise contract every parity test pins down.  The
//!   kernel is memory-bound, and register blocking — not fusion — carries
//!   the speedup; the `fma` target feature is still enabled so the dispatch
//!   contract matches the detection gate.
//! * Because the pool executes the *same* disjoint-slab decomposition, its
//!   results are bitwise identical to the scoped-thread engine and to a
//!   1-thread run, for any pool size, any kernel path (asserted by
//!   `tests/parity.rs` and `tests/golden_trace.rs`).
//! * `t_matmul` transposes bounded per-worker column sub-panels into a
//!   dense row-major scratch and reuses the same kernel: the strided column
//!   walk happens once per panel instead of once per fma.
//! * **Prepacked panels** live in [`packing`](super::packing): Q-GaLore
//!   reuses each frozen INT4 projection for hundreds of steps between
//!   subspace refreshes, so the fused dequant kernels' per-call nibble
//!   decode is pure repeated work.  A `PanelPack` decodes a quantized
//!   tensor ONCE (both orientations) into the dense row-major panel layout
//!   this engine's `panel_matmul` consumes, keyed by the tensor's
//!   quantization epoch; the `*_prepacked` entry points in [`crate::quant`]
//!   then skip decode entirely.  Decode timing never touches accumulation
//!   order, so prepacked results are bitwise identical to the fused path.
//!
//! Small problems (< [`PAR_MIN_FLOPS`] fma) run serially on the calling
//! thread — even pool dispatch costs more than the arithmetic there.

use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};

use super::pool::{global_pool, WorkerPool};
use super::Mat;
use crate::util::env_parse;

/// k-stripe width: `KC` rows of B (KC * n * 4 bytes) form the resident
/// cache block each register tile streams against.
const KC: usize = 256;

/// Microkernel register-tile rows: output rows accumulated simultaneously,
/// amortizing each B-row load across `MR` fma rows.
pub const MR: usize = 4;

/// Microkernel register-tile columns: one 8-lane f32 vector of *independent*
/// output columns, so vectorizing across them cannot reorder any single
/// element's k accumulation.
pub const NR: usize = 8;

/// Register-tile columns for the AVX-512 body ([`KernelPath::Simd512`]):
/// one 16-lane f32 zmm vector of independent output columns.  Same
/// argument as [`NR`] — widening across j cannot reorder any element's k
/// accumulation.
pub const NR512: usize = 16;

/// Problems below this many fma ops (m*k*n) stay on the calling thread.
pub const PAR_MIN_FLOPS: usize = 1 << 20;

/// Buffer-cloning fan-outs (operand marshalling) below this many total
/// elements stay serial — dispatch cost would exceed the memcpy.
pub const PAR_MIN_CLONE_ELEMS: usize = 1 << 20;

/// Resolve-once container for a worker-count default: 0 = unresolved, an
/// explicit [`ThreadCount::set`] always wins over the detected value.
/// Factored out of the process-global so tests exercise the override
/// semantics on a *private* instance instead of mutating (and racing) the
/// global that concurrent parity tests read through `ParallelCtx::global`.
pub(crate) struct ThreadCount(AtomicUsize);

impl ThreadCount {
    pub(crate) const fn unresolved() -> Self {
        ThreadCount(AtomicUsize::new(0))
    }

    /// Explicit override (CLI `--threads`). Clamped to 1+.
    pub(crate) fn set(&self, n: usize) {
        self.0.store(n.max(1), Ordering::Relaxed);
    }

    /// Current value, resolving via `detect` on first use.
    pub(crate) fn get(&self, detect: impl FnOnce() -> usize) -> usize {
        match self.0.load(Ordering::Relaxed) {
            0 => {
                let n = detect().max(1);
                // racing first-callers agree on detect()'s value; an
                // explicit set() always wins afterwards
                let _ = self.0.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed);
                n
            }
            n => n,
        }
    }
}

/// Process-global default thread count.
static GLOBAL_THREADS: ThreadCount = ThreadCount::unresolved();

/// Override the global default (CLI `--threads`). Values are clamped to 1+.
/// Call before the first parallel work: the global pool sizes itself from
/// this value once, on first use.
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.set(n);
}

/// Env var overriding the worker count (CLI `--threads` wins over it).
pub const THREADS_ENV: &str = "QGALORE_THREADS";

/// `QGALORE_THREADS`-style value -> worker count (>= 1), if well-formed.
fn parse_threads(s: &str) -> Option<usize> {
    match s.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => None,
    }
}

fn detect_threads() -> usize {
    // warn-on-malformed like every QGALORE_* knob: a typo'd QGALORE_THREADS
    // used to be silently ignored while QGALORE_KERNEL typos warned —
    // a CI job pinning the thread count must not quietly run on all cores
    env_parse(THREADS_ENV, "a worker count >= 1", parse_threads)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// The global default thread count (resolving it on first use).
pub fn global_threads() -> usize {
    GLOBAL_THREADS.get(detect_threads)
}

// ---------------------------------------------------------------------------
// Over-decomposition (slabs per worker).
// ---------------------------------------------------------------------------

/// Env var overriding the default slab multiplier for pool dispatch.
pub const SLABS_ENV: &str = "QGALORE_SLABS_PER_WORKER";

/// Default slabs cut per budgeted worker when dispatching to a pool.
/// ~4 smooths stragglers (an idle worker steals the tail instead of
/// waiting on the slowest slab) without making tasks so small that even a
/// Chase-Lev push/steal dominates the arithmetic.
pub const DEFAULT_SLABS_PER_WORKER: usize = 4;

/// Upper bound on the slab multiplier — beyond this, per-task overhead
/// provably dominates any straggler win for the shapes this engine sees.
pub const MAX_SLABS_PER_WORKER: usize = 64;

/// `QGALORE_SLABS_PER_WORKER`-style value -> multiplier, if well-formed.
fn parse_slabs(s: &str) -> Option<usize> {
    match s.trim().parse::<usize>() {
        Ok(n) if (1..=MAX_SLABS_PER_WORKER).contains(&n) => Some(n),
        _ => None,
    }
}

/// Process-global default slab multiplier (resolve-once like the thread
/// count; [`ThreadCount`] is just a resolve-once positive usize).
static GLOBAL_SLABS: ThreadCount = ThreadCount::unresolved();

/// Minimum output elements a cost-model slab may hold.  Below this, a
/// Chase-Lev push + steal costs about as much as the slab's arithmetic,
/// so the model coarsens toward one slab per budgeted worker as shapes
/// approach [`PAR_MIN_FLOPS`].  Explicitly pinned multipliers ignore it.
pub const MIN_SLAB_ELEMS: usize = 1 << 12;

/// Whether an explicit slab multiplier (env [`SLABS_ENV`] or
/// [`set_global_slabs_per_worker`]) pinned the fixed decomposition
/// process-wide.  Newly built ctxs capture this flag; the cost model only
/// runs when nothing pinned it, so tuned CI legs keep their exact
/// historical slab counts.
static SLABS_PINNED: AtomicBool = AtomicBool::new(false);

/// Override the global default slab multiplier (clamped to
/// `1..=`[`MAX_SLABS_PER_WORKER`]).  Newly constructed [`ParallelCtx`]
/// values pick it up; in-flight ctxs keep the value they captured.  An
/// explicit override also pins the fixed decomposition (disables the
/// shape-aware cost model) for ctxs built afterwards.
pub fn set_global_slabs_per_worker(n: usize) {
    SLABS_PINNED.store(true, Ordering::Relaxed);
    GLOBAL_SLABS.set(n.clamp(1, MAX_SLABS_PER_WORKER));
}

/// The global default slab multiplier (resolving [`SLABS_ENV`] on first
/// use, falling back to [`DEFAULT_SLABS_PER_WORKER`]).  A well-formed env
/// value counts as an explicit override: it pins the fixed decomposition
/// just like [`set_global_slabs_per_worker`].
pub fn global_slabs_per_worker() -> usize {
    GLOBAL_SLABS.get(|| {
        match env_parse(SLABS_ENV, "a slab multiplier in 1..=64", parse_slabs) {
            Some(n) => {
                SLABS_PINNED.store(true, Ordering::Relaxed);
                n
            }
            None => DEFAULT_SLABS_PER_WORKER,
        }
    })
}

/// Whether the process-wide slab multiplier was explicitly pinned (env or
/// [`set_global_slabs_per_worker`]), resolving the env on first use.
pub fn global_slabs_pinned() -> bool {
    let _ = global_slabs_per_worker();
    SLABS_PINNED.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Kernel-path selection.
// ---------------------------------------------------------------------------

/// Which `panel_matmul` body executes the accumulation.  All paths are
/// bitwise identical for finite inputs (same per-element ascending-k
/// mul+add order), so the choice is purely a throughput knob — which is
/// what makes a process-global override safe to flip even mid-run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Respect the process override (`QGALORE_KERNEL` env /
    /// [`set_kernel_override`]), else the widest body the CPU supports:
    /// [`KernelPath::Simd512`], then [`KernelPath::Simd`], then
    /// [`KernelPath::Portable`].
    Auto,
    /// Explicit AVX2 microkernel (x86_64 with avx2+fma only; silently
    /// falls back to `Portable` elsewhere).
    Simd,
    /// AVX-512 microkernel: the same MR=4 tile widened to [`NR512`]=16
    /// zmm columns.  Runs the intrinsics body when the toolchain compiled
    /// it and the CPU reports `avx512f`; everywhere else it runs a
    /// portable body with the identical NR=16 tiling, so forcing it
    /// (`QGALORE_KERNEL=avx512`) is safe on any runner.
    Simd512,
    /// Register-blocked microkernel in plain Rust — same tiling, same op
    /// order as `Simd`, on every target.
    Portable,
    /// The PR-1/2 autovectorized row-streaming kernel: the baseline the
    /// microkernel benches compare against.
    Autovec,
}

const K_UNSET: u8 = 0;
const K_AUTO: u8 = 1;
const K_SIMD: u8 = 2;
const K_PORTABLE: u8 = 3;
const K_AUTOVEC: u8 = 4;
const K_SIMD512: u8 = 5;

fn kernel_code(p: KernelPath) -> u8 {
    match p {
        KernelPath::Auto => K_AUTO,
        KernelPath::Simd => K_SIMD,
        KernelPath::Simd512 => K_SIMD512,
        KernelPath::Portable => K_PORTABLE,
        KernelPath::Autovec => K_AUTOVEC,
    }
}

fn kernel_from_code(c: u8) -> KernelPath {
    match c {
        K_SIMD => KernelPath::Simd,
        K_SIMD512 => KernelPath::Simd512,
        K_PORTABLE => KernelPath::Portable,
        K_AUTOVEC => KernelPath::Autovec,
        _ => KernelPath::Auto,
    }
}

/// Env var forcing a kernel body process-wide (CI matrix runs).
pub const KERNEL_ENV: &str = "QGALORE_KERNEL";

/// `QGALORE_KERNEL`-style value -> kernel path, if well-formed.
fn parse_kernel(s: &str) -> Option<KernelPath> {
    match s.trim().to_ascii_lowercase().as_str() {
        "auto" => Some(KernelPath::Auto),
        "simd" | "avx2" => Some(KernelPath::Simd),
        "simd512" | "avx512" => Some(KernelPath::Simd512),
        "portable" => Some(KernelPath::Portable),
        "autovec" | "baseline" => Some(KernelPath::Autovec),
        _ => None,
    }
}

/// Process-global kernel override; `K_UNSET` until first resolution (which
/// consults the `QGALORE_KERNEL` env var, for CI matrix runs).
static KERNEL_OVERRIDE: AtomicU8 = AtomicU8::new(K_UNSET);

/// Force every [`KernelPath::Auto`] caller (i.e. the whole engine) onto one
/// kernel body.  Results are bitwise identical across paths, so flipping
/// this — even concurrently with in-flight matmuls — changes throughput,
/// never values; `tests/golden_trace.rs` drives whole training traces
/// through each path via this hook.
pub fn set_kernel_override(path: KernelPath) {
    KERNEL_OVERRIDE.store(kernel_code(path), Ordering::Relaxed);
}

/// The current process-wide kernel selection (resolving the `QGALORE_KERNEL`
/// env var on first use; [`KernelPath::Auto`] when neither env nor
/// [`set_kernel_override`] chose one).
pub fn kernel_override() -> KernelPath {
    match KERNEL_OVERRIDE.load(Ordering::Relaxed) {
        K_UNSET => {
            // the shared warn-on-malformed env parser: a typo here must not
            // let a CI job that exists to force one body quietly test another
            let p = env_parse(KERNEL_ENV, "auto|simd|avx512|portable|autovec", parse_kernel)
                .unwrap_or(KernelPath::Auto);
            // racing first-callers agree on the env value; an explicit
            // set_kernel_override always wins afterwards
            let _ = KERNEL_OVERRIDE.compare_exchange(
                K_UNSET,
                kernel_code(p),
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            kernel_from_code(KERNEL_OVERRIDE.load(Ordering::Relaxed))
        }
        c => kernel_from_code(c),
    }
}

/// Whether this machine can run the explicit-intrinsics AVX2 SIMD body.
pub fn simd_kernel_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Whether this machine can run the explicit-intrinsics AVX-512 body:
/// requires both a toolchain new enough to have compiled it (`build.rs`
/// sets `qgalore_avx512_intrinsics` on rustc >= 1.89, where the
/// `_mm512_*` f32 intrinsics stabilized) and runtime `avx512f`.  When
/// false, [`KernelPath::Simd512`] still runs — on the portable NR=16
/// body — so this gates only which body computes the (identical) bits.
pub fn simd512_kernel_available() -> bool {
    #[cfg(all(target_arch = "x86_64", qgalore_avx512_intrinsics))]
    {
        std::arch::is_x86_feature_detected!("avx512f")
    }
    #[cfg(not(all(target_arch = "x86_64", qgalore_avx512_intrinsics)))]
    {
        false
    }
}

/// Collapse a requested path to the body that will actually run: `Auto`
/// defers to the process override then to the widest available SIMD
/// body, and `Simd` degrades to `Portable` when the CPU (or target)
/// lacks avx2+fma.  `Simd512` resolves to itself — its dispatch arm
/// degrades internally to the portable NR=16 body when the intrinsics
/// are unavailable, so a forced `QGALORE_KERNEL=avx512` run exercises
/// the wide tiling on every machine.
fn resolved_kernel(path: KernelPath) -> KernelPath {
    let p = match path {
        KernelPath::Auto => kernel_override(),
        p => p,
    };
    match p {
        KernelPath::Auto => {
            if simd512_kernel_available() {
                KernelPath::Simd512
            } else if simd_kernel_available() {
                KernelPath::Simd
            } else {
                KernelPath::Portable
            }
        }
        KernelPath::Simd => {
            if simd_kernel_available() {
                KernelPath::Simd
            } else {
                KernelPath::Portable
            }
        }
        p => p,
    }
}

/// Parallelism handle threaded through the optimizer stack: a thread budget
/// (how many workers' worth of slabs the decomposition produces) plus the
/// worker pool that executes them.  `Copy`, so it flows by value
/// everywhere; the pool reference is `&'static` (the global pool, or a
/// leaked explicit one).
///
/// The budget and slab multiplier control *decomposition only* — results
/// are bitwise identical whatever pool (or the scoped fallback) runs the
/// slabs, and at any slab count.
#[derive(Clone, Copy, Debug)]
pub struct ParallelCtx {
    pub threads: usize,
    /// Slabs cut per budgeted worker on pool dispatch (over-decomposition;
    /// see the module docs).  Ignored by the serial and scoped paths.
    pub slabs_per_worker: usize,
    /// Whether the multiplier was explicitly chosen (builder, env, or
    /// global override).  Explicit ⇒ the fixed `threads * slabs_per_worker`
    /// decomposition; otherwise the shape-aware cost model picks the slab
    /// count per call.  Either way the bits are identical — only wall
    /// clock moves.
    slabs_explicit: bool,
    pool: Option<&'static WorkerPool>,
}

impl ParallelCtx {
    /// Exactly one thread (reference semantics, no dispatch at all).
    pub fn serial() -> Self {
        ParallelCtx { threads: 1, slabs_per_worker: 1, slabs_explicit: true, pool: None }
    }

    /// A budget of `threads` executed on the process-global pool.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        ParallelCtx {
            threads,
            slabs_per_worker: global_slabs_per_worker(),
            slabs_explicit: global_slabs_pinned(),
            pool: if threads > 1 { Some(global_pool()) } else { None },
        }
    }

    /// A budget of `threads` executed by per-call scoped spawns (the PR-1
    /// engine).  Kept as a fallback and as the dispatch-overhead baseline
    /// for `benches/throughput.rs`.
    pub fn scoped(threads: usize) -> Self {
        ParallelCtx {
            threads: threads.max(1),
            slabs_per_worker: global_slabs_per_worker(),
            slabs_explicit: global_slabs_pinned(),
            pool: None,
        }
    }

    /// A budget of `threads` executed on an explicit pool (tests/benches;
    /// leak the pool via [`WorkerPool::leaked`] to get the `'static` handle).
    pub fn with_pool(threads: usize, pool: &'static WorkerPool) -> Self {
        ParallelCtx {
            threads: threads.max(1),
            slabs_per_worker: global_slabs_per_worker(),
            slabs_explicit: global_slabs_pinned(),
            pool: Some(pool),
        }
    }

    /// The process-global default (CLI/env/hardware) on the global pool.
    pub fn global() -> Self {
        ParallelCtx::new(global_threads())
    }

    /// Same pool, different thread budget — for callers splitting one
    /// worker budget between an outer fan-out and inner linalg calls.
    pub fn with_threads(self, threads: usize) -> Self {
        ParallelCtx { threads: threads.max(1), ..self }
    }

    /// Same pool and budget, explicit slab multiplier (clamped to
    /// `1..=`[`MAX_SLABS_PER_WORKER`]) — the in-process form of
    /// [`SLABS_ENV`] for tests and tuning.  Pins the fixed decomposition
    /// for this ctx (the cost model steps aside, like the env override).
    pub fn with_slabs_per_worker(self, slabs: usize) -> Self {
        ParallelCtx {
            slabs_per_worker: slabs.clamp(1, MAX_SLABS_PER_WORKER),
            slabs_explicit: true,
            ..self
        }
    }

    /// The underlying pool handle regardless of thread budget — the
    /// dataflow trainer schedules its step graph here even when the
    /// linalg budget is serial (ungated, unlike the private `pool()`).
    pub fn worker_pool(&self) -> Option<&'static WorkerPool> {
        self.pool
    }

    /// The pool that should execute a parallel call, if any.
    fn pool(&self) -> Option<&'static WorkerPool> {
        if self.threads <= 1 {
            None
        } else {
            self.pool
        }
    }

    /// Slab count for a pool-dispatched decomposition over `items` units:
    /// `threads * slabs_per_worker`, clamped to the work available.  The
    /// fixed (pre-cost-model) decomposition; [`Self::cost_slabs`] defers
    /// to it whenever the multiplier was explicitly pinned.
    fn slabs(&self, items: usize) -> usize {
        self.threads
            .saturating_mul(self.slabs_per_worker.max(1))
            .clamp(1, items)
    }

    /// Shape-aware slab count for a `(rows, cols)` row decomposition.
    /// Explicitly pinned multipliers get the exact fixed decomposition;
    /// otherwise a small cost model adjusts granularity:
    ///
    /// * tall-skinny outputs (rows ≫ cols) split 2–4× finer — each row
    ///   panel is cheap, so straggler variance, not per-task overhead,
    ///   dominates the tail;
    /// * shapes near [`PAR_MIN_FLOPS`] coarsen: no slab smaller than
    ///   [`MIN_SLAB_ELEMS`] output elements (but every budgeted worker
    ///   still gets work);
    /// * the [`MAX_SLABS_PER_WORKER`] overhead ceiling always applies.
    ///
    /// Slab counts never affect accumulation order, so this is purely a
    /// wall-clock knob — asserted bitwise by the over-decomposition tests.
    fn cost_slabs(&self, rows: usize, cols: usize) -> usize {
        if self.slabs_explicit {
            return self.slabs(rows);
        }
        let base = self.threads.saturating_mul(self.slabs_per_worker.max(1));
        let aspect = rows / cols.max(1);
        let boosted = if aspect >= 64 {
            base.saturating_mul(4)
        } else if aspect >= 16 {
            base.saturating_mul(2)
        } else {
            base
        };
        let grain = rows
            .saturating_mul(cols)
            .div_euclid(MIN_SLAB_ELEMS)
            .max(self.threads);
        boosted
            .min(grain)
            .min(self.threads.saturating_mul(MAX_SLABS_PER_WORKER))
            .clamp(1, rows)
    }

    /// Cost-model slab count for a [`par_map`] item decomposition.  Item
    /// cost is opaque (a whole layer update or a single cheap closure), so
    /// no element grain applies; the model splits finer only when there
    /// are plenty of items to absorb the extra per-task overhead.
    fn cost_slabs_items(&self, items: usize) -> usize {
        if self.slabs_explicit {
            return self.slabs(items);
        }
        let base = self.threads.saturating_mul(self.slabs_per_worker.max(1));
        let slabs = if items >= base.saturating_mul(8) {
            base.saturating_mul(2)
        } else {
            base
        };
        slabs
            .min(self.threads.saturating_mul(MAX_SLABS_PER_WORKER))
            .clamp(1, items)
    }
}

impl PartialEq for ParallelCtx {
    fn eq(&self, other: &Self) -> bool {
        self.threads == other.threads
            && self.slabs_per_worker == other.slabs_per_worker
            && self.slabs_explicit == other.slabs_explicit
            && match (self.pool, other.pool) {
                (None, None) => true,
                (Some(a), Some(b)) => std::ptr::eq(a, b),
                _ => false,
            }
    }
}

impl Eq for ParallelCtx {}

impl Default for ParallelCtx {
    fn default() -> Self {
        ParallelCtx::global()
    }
}

/// Gate a buffer-cloning fan-out: serial below [`PAR_MIN_CLONE_ELEMS`]
/// total elements (dispatch cost would exceed the memcpy), else `pool`.
pub fn clone_pool(total_elems: usize, pool: ParallelCtx) -> ParallelCtx {
    if total_elems < PAR_MIN_CLONE_ELEMS {
        ParallelCtx::serial()
    } else {
        pool
    }
}

/// Run `body(r0, r1, slab)` over disjoint row panels of a freshly zeroed
/// (rows, cols) row-major buffer.  Pool dispatch over-decomposes via the
/// shape-aware cost model (`cost_slabs`; the fixed
/// `ctx.threads * ctx.slabs_per_worker` count when the multiplier is
/// explicitly pinned), so stragglers get stolen instead of serializing
/// the tail; the scoped fallback keeps one slab per spawned thread.  Slab bounds never change what any output element
/// contains — the body is keyed by absolute row — so the result is
/// bitwise identical for every scheduler AND every slab count.  `slab`
/// covers exactly rows `r0..r1`.
pub fn par_rows<F>(ctx: ParallelCtx, rows: usize, cols: usize, body: F) -> Vec<f32>
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let mut out = vec![0f32; rows * cols];
    if rows == 0 || cols == 0 {
        return out;
    }
    let t = ctx.threads.clamp(1, rows);
    if t <= 1 {
        body(0, rows, &mut out);
        return out;
    }
    let body = &body;
    match ctx.pool() {
        Some(pool) => {
            let chunk = rows.div_ceil(ctx.cost_slabs(rows, cols));
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
                .chunks_mut(chunk * cols)
                .enumerate()
                .map(|(ti, slab)| {
                    let r0 = ti * chunk;
                    let r1 = (r0 + chunk).min(rows);
                    Box::new(move || body(r0, r1, slab)) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        None => {
            let chunk = rows.div_ceil(t);
            std::thread::scope(|s| {
                for (ti, slab) in out.chunks_mut(chunk * cols).enumerate() {
                    let r0 = ti * chunk;
                    let r1 = (r0 + chunk).min(rows);
                    s.spawn(move || body(r0, r1, slab));
                }
            });
        }
    }
    out
}

/// Map `f` over `items`, preserving order.  Used to step independent
/// layers / tensors concurrently; pool dispatch over-decomposes like
/// [`par_rows`] (per-item results depend only on the item, so chunking is
/// invisible in the output).
pub fn par_map<T, U, F>(ctx: ParallelCtx, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    if ctx.threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    let f = &f;
    match ctx.pool() {
        Some(pool) => {
            let chunk = items.len().div_ceil(ctx.cost_slabs_items(items.len()));
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = items
                .chunks(chunk)
                .zip(out.chunks_mut(chunk))
                .map(|(islab, oslab)| {
                    Box::new(move || {
                        for (i, o) in islab.iter().zip(oslab.iter_mut()) {
                            *o = Some(f(i));
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(tasks);
        }
        None => {
            let t = ctx.threads.min(items.len());
            let chunk = items.len().div_ceil(t);
            std::thread::scope(|s| {
                for (islab, oslab) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
                    s.spawn(move || {
                        for (i, o) in islab.iter().zip(oslab.iter_mut()) {
                            *o = Some(f(i));
                        }
                    });
                }
            });
        }
    }
    out.into_iter().map(|o| o.expect("par_map worker filled every slot")).collect()
}

// ---------------------------------------------------------------------------
// Kernel bodies.
//
// Contract shared by every body: `out (rows, n) += panel (rows, k) @ b`,
// with each output element's k accumulation strictly ascending — the naive
// reference's order — for FINITE inputs and an `out` buffer containing no
// -0.0 entries (par_rows always supplies fresh +0.0 slabs, and f32
// addition only yields -0.0 from two -0.0 operands, so accumulators never
// become -0.0 either).  Under that contract all bodies, the naive
// reference, and the autovec baseline are bitwise identical.
//
// One deliberate divergence inside the contract: the reference (and the
// autovec baseline) skip `a == 0.0` terms as a perf heuristic; the
// microkernel — main tiles AND scalar edge tiles, uniformly, so tile
// placement and therefore the thread-count-driven panel split can never
// matter — does not.  Adding `0.0 * b` (b finite) to a never--0.0
// accumulator is a bitwise no-op, so results still match bit for bit.
// ---------------------------------------------------------------------------

/// Scalar edge kernel for tile tails: rows `i0..i1` x cols `j0..j1` over the
/// k stripe `kb..kend`, in the same per-element ascending-k order (and the
/// same no-skip term handling) as the main register tiles.
#[allow(clippy::too_many_arguments)]
fn edge_tile(
    panel: &[f32],
    k: usize,
    b: &Mat,
    out: &mut [f32],
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    kb: usize,
    kend: usize,
) {
    let n = b.cols;
    for i in i0..i1 {
        let arow = &panel[i * k..(i + 1) * k];
        let orow = &mut out[i * n + j0..i * n + j1];
        for kk in kb..kend {
            let av = arow[kk];
            let brow = &b.data[kk * n + j0..kk * n + j1];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// The PR-1/2 row-streaming kernel (j-loop left to the autovectorizer; the
/// out row round-trips through memory on every k step).  Kept callable as
/// the microkernel's bench baseline and regression reference.
fn panel_matmul_autovec(panel: &[f32], rows: usize, k: usize, b: &Mat, out: &mut [f32]) {
    let n = b.cols;
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for i in 0..rows {
            let arow = &panel[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        kb = kend;
    }
}

/// Register-blocked microkernel in portable Rust: [`MR`]x[`NR`] accumulator
/// tiles live across each `KC` stripe, the [`NR`] lane loop autovectorizes.
/// Identical tiling and op order to the AVX2 body, so the two are bitwise
/// interchangeable.
fn panel_matmul_portable(panel: &[f32], rows: usize, k: usize, b: &Mat, out: &mut [f32]) {
    let n = b.cols;
    let r_main = rows - rows % MR;
    let n_main = n - n % NR;
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        let mut i = 0;
        while i < r_main {
            let mut j = 0;
            while j < n_main {
                // load the MRxNR out tile, accumulate the stripe, store
                let mut acc = [[0f32; NR]; MR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    accr.copy_from_slice(&out[(i + r) * n + j..(i + r) * n + j + NR]);
                }
                for kk in kb..kend {
                    let brow = &b.data[kk * n + j..kk * n + j + NR];
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let av = panel[(i + r) * k + kk];
                        for (o, &bv) in accr.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    out[(i + r) * n + j..(i + r) * n + j + NR].copy_from_slice(accr);
                }
                j += NR;
            }
            if j < n {
                edge_tile(panel, k, b, out, i, i + MR, j, n, kb, kend);
            }
            i += MR;
        }
        if i < rows {
            edge_tile(panel, k, b, out, i, rows, 0, n, kb, kend);
        }
        kb = kend;
    }
}

/// The [`KernelPath::Simd512`] tiling in plain Rust: identical to
/// [`panel_matmul_portable`] except the register tile is [`MR`]×[`NR512`].
/// Tile membership moves some (i, j) elements between main and edge tiles
/// relative to the NR=8 bodies, but every element's k accumulation stays
/// the strictly ascending reference walk, so this body is bitwise
/// interchangeable with all the others.  It is both the CI fallback for
/// forced `QGALORE_KERNEL=avx512` runs on non-avx512 hardware and the
/// only Simd512 body on toolchains predating the `_mm512_*` intrinsics.
fn panel_matmul_portable512(panel: &[f32], rows: usize, k: usize, b: &Mat, out: &mut [f32]) {
    let n = b.cols;
    let r_main = rows - rows % MR;
    let n_main = n - n % NR512;
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        let mut i = 0;
        while i < r_main {
            let mut j = 0;
            while j < n_main {
                // load the MRxNR512 out tile, accumulate the stripe, store
                let mut acc = [[0f32; NR512]; MR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    accr.copy_from_slice(&out[(i + r) * n + j..(i + r) * n + j + NR512]);
                }
                for kk in kb..kend {
                    let brow = &b.data[kk * n + j..kk * n + j + NR512];
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let av = panel[(i + r) * k + kk];
                        for (o, &bv) in accr.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    out[(i + r) * n + j..(i + r) * n + j + NR512].copy_from_slice(accr);
                }
                j += NR512;
            }
            if j < n {
                edge_tile(panel, k, b, out, i, i + MR, j, n, kb, kend);
            }
            i += MR;
        }
        if i < rows {
            edge_tile(panel, k, b, out, i, rows, 0, n, kb, kend);
        }
        kb = kend;
    }
}

#[cfg(target_arch = "x86_64")]
mod simd {
    //! Explicit AVX2 body of the register-blocked microkernel.
    //!
    //! Accumulation is `add(mul(a, b))`, NOT `fmadd`: the reference kernel
    //! rounds the product and the sum separately, and the bitwise contract
    //! is with the reference — see the module docs.  The speedup comes from
    //! the tile structure (4 out rows x 8 columns resident in ymm
    //! registers for a whole k stripe), not from fusing the arithmetic.

    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };

    use super::{edge_tile, Mat, KC, MR, NR};

    /// AVX2 `panel_matmul` body.
    ///
    /// # Safety
    /// The CPU must support `avx2` and `fma`; callers route through
    /// [`super::resolved_kernel`], which gates on
    /// [`super::simd_kernel_available`].  All pointer arithmetic stays
    /// inside the slices by the loop bounds (`j + NR <= n`, `i + MR <=
    /// rows`, `kk < k`).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn panel_matmul(
        panel: &[f32],
        rows: usize,
        k: usize,
        b: &Mat,
        out: &mut [f32],
    ) {
        // SAFETY: the contract above (target features verified by the
        // caller, pointer arithmetic bounded by the loop limits) covers
        // every intrinsic and raw-pointer dereference below.
        unsafe {
            let n = b.cols;
            let r_main = rows - rows % MR;
            let n_main = n - n % NR;
            let mut kb = 0;
            while kb < k {
                let kend = (kb + KC).min(k);
                let mut i = 0;
                while i < r_main {
                    let mut j = 0;
                    while j < n_main {
                        let o = out.as_mut_ptr();
                        let mut acc0 = _mm256_loadu_ps(o.add(i * n + j));
                        let mut acc1 = _mm256_loadu_ps(o.add((i + 1) * n + j));
                        let mut acc2 = _mm256_loadu_ps(o.add((i + 2) * n + j));
                        let mut acc3 = _mm256_loadu_ps(o.add((i + 3) * n + j));
                        let bp = b.data.as_ptr();
                        let ap = panel.as_ptr();
                        for kk in kb..kend {
                            let bv = _mm256_loadu_ps(bp.add(kk * n + j));
                            let a0 = _mm256_set1_ps(*ap.add(i * k + kk));
                            let a1 = _mm256_set1_ps(*ap.add((i + 1) * k + kk));
                            let a2 = _mm256_set1_ps(*ap.add((i + 2) * k + kk));
                            let a3 = _mm256_set1_ps(*ap.add((i + 3) * k + kk));
                            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(a0, bv));
                            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(a1, bv));
                            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(a2, bv));
                            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(a3, bv));
                        }
                        _mm256_storeu_ps(o.add(i * n + j), acc0);
                        _mm256_storeu_ps(o.add((i + 1) * n + j), acc1);
                        _mm256_storeu_ps(o.add((i + 2) * n + j), acc2);
                        _mm256_storeu_ps(o.add((i + 3) * n + j), acc3);
                        j += NR;
                    }
                    if j < n {
                        edge_tile(panel, k, b, out, i, i + MR, j, n, kb, kend);
                    }
                    i += MR;
                }
                if i < rows {
                    edge_tile(panel, k, b, out, i, rows, 0, n, kb, kend);
                }
                kb = kend;
            }
        }
    }
}

#[cfg(all(target_arch = "x86_64", qgalore_avx512_intrinsics))]
mod simd512 {
    //! Explicit AVX-512 body of the register-blocked microkernel: the AVX2
    //! tile widened to one 16-lane zmm vector of output columns per row
    //! accumulator.  Same contract as `mod simd`: `add(mul(a, b))`, never
    //! `fmadd` — fused rounding would break the bitwise contract with the
    //! naive reference.  Compiled only when `build.rs` reports a rustc new
    //! enough (>= 1.89) to have the stabilized `_mm512_*` f32 intrinsics.

    use std::arch::x86_64::{
        _mm512_add_ps, _mm512_loadu_ps, _mm512_mul_ps, _mm512_set1_ps, _mm512_storeu_ps,
    };

    use super::{edge_tile, Mat, KC, MR, NR512};

    /// AVX-512 `panel_matmul` body.
    ///
    /// # Safety
    /// The CPU must support `avx512f`; callers gate on
    /// [`super::simd512_kernel_available`].  All pointer arithmetic stays
    /// inside the slices by the loop bounds (`j + NR512 <= n`,
    /// `i + MR <= rows`, `kk < k`).
    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn panel_matmul(
        panel: &[f32],
        rows: usize,
        k: usize,
        b: &Mat,
        out: &mut [f32],
    ) {
        // SAFETY: the contract above (target features verified by the
        // caller, pointer arithmetic bounded by the loop limits) covers
        // every intrinsic and raw-pointer dereference below.
        unsafe {
            let n = b.cols;
            let r_main = rows - rows % MR;
            let n_main = n - n % NR512;
            let mut kb = 0;
            while kb < k {
                let kend = (kb + KC).min(k);
                let mut i = 0;
                while i < r_main {
                    let mut j = 0;
                    while j < n_main {
                        let o = out.as_mut_ptr();
                        let mut acc0 = _mm512_loadu_ps(o.add(i * n + j));
                        let mut acc1 = _mm512_loadu_ps(o.add((i + 1) * n + j));
                        let mut acc2 = _mm512_loadu_ps(o.add((i + 2) * n + j));
                        let mut acc3 = _mm512_loadu_ps(o.add((i + 3) * n + j));
                        let bp = b.data.as_ptr();
                        let ap = panel.as_ptr();
                        for kk in kb..kend {
                            let bv = _mm512_loadu_ps(bp.add(kk * n + j));
                            let a0 = _mm512_set1_ps(*ap.add(i * k + kk));
                            let a1 = _mm512_set1_ps(*ap.add((i + 1) * k + kk));
                            let a2 = _mm512_set1_ps(*ap.add((i + 2) * k + kk));
                            let a3 = _mm512_set1_ps(*ap.add((i + 3) * k + kk));
                            acc0 = _mm512_add_ps(acc0, _mm512_mul_ps(a0, bv));
                            acc1 = _mm512_add_ps(acc1, _mm512_mul_ps(a1, bv));
                            acc2 = _mm512_add_ps(acc2, _mm512_mul_ps(a2, bv));
                            acc3 = _mm512_add_ps(acc3, _mm512_mul_ps(a3, bv));
                        }
                        _mm512_storeu_ps(o.add(i * n + j), acc0);
                        _mm512_storeu_ps(o.add((i + 1) * n + j), acc1);
                        _mm512_storeu_ps(o.add((i + 2) * n + j), acc2);
                        _mm512_storeu_ps(o.add((i + 3) * n + j), acc3);
                        j += NR512;
                    }
                    if j < n {
                        edge_tile(panel, k, b, out, i, i + MR, j, n, kb, kend);
                    }
                    i += MR;
                }
                if i < rows {
                    edge_tile(panel, k, b, out, i, rows, 0, n, kb, kend);
                }
                kb = kend;
            }
        }
    }
}

/// Inner kernel: `out (rows, n) += panel (rows, k) @ b (k, n)` through the
/// process-selected kernel body.  Accumulation over k is strictly ascending
/// per output element — the same order as the naive reference, so results
/// match it bitwise.
pub(crate) fn panel_matmul(panel: &[f32], rows: usize, k: usize, b: &Mat, out: &mut [f32]) {
    panel_matmul_with(panel, rows, k, b, out, KernelPath::Auto);
}

/// [`panel_matmul`] with an explicit kernel body (tests/benches).
pub(crate) fn panel_matmul_with(
    panel: &[f32],
    rows: usize,
    k: usize,
    b: &Mat,
    out: &mut [f32],
    path: KernelPath,
) {
    match resolved_kernel(path) {
        KernelPath::Simd => {
            // SAFETY: resolved_kernel only returns Simd when avx2+fma were
            // detected at runtime on this CPU.
            #[cfg(target_arch = "x86_64")]
            unsafe {
                simd::panel_matmul(panel, rows, k, b, out);
            }
            #[cfg(not(target_arch = "x86_64"))]
            panel_matmul_portable(panel, rows, k, b, out);
        }
        KernelPath::Simd512 => {
            // graceful degrade: forced avx512 on hardware (or a toolchain)
            // without it runs the portable body with the same NR=16
            // tiling — same op order, same bits
            #[cfg(all(target_arch = "x86_64", qgalore_avx512_intrinsics))]
            {
                if simd512_kernel_available() {
                    // SAFETY: avx512f detected at runtime on this CPU.
                    unsafe {
                        simd512::panel_matmul(panel, rows, k, b, out);
                    }
                    return;
                }
            }
            panel_matmul_portable512(panel, rows, k, b, out);
        }
        KernelPath::Autovec => panel_matmul_autovec(panel, rows, k, b, out),
        _ => panel_matmul_portable(panel, rows, k, b, out),
    }
}

/// Clamp `ctx` to serial when the m*k*n fma count is below
/// [`PAR_MIN_FLOPS`] (shared policy for the dense and fused-dequant paths).
pub(crate) fn effective(ctx: ParallelCtx, m: usize, k: usize, n: usize) -> ParallelCtx {
    if m.saturating_mul(k).saturating_mul(n) < PAR_MIN_FLOPS {
        ParallelCtx::serial()
    } else {
        ctx
    }
}

/// `a (m, k) @ b (k, n) -> (m, n)`, parallel over row panels of the output.
pub fn matmul(a: &Mat, b: &Mat, ctx: ParallelCtx) -> Mat {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    matmul_with_kernel(a, b, effective(ctx, m, k, n), KernelPath::Auto)
}

/// [`matmul`] without the [`PAR_MIN_FLOPS`] serial gate.  Bench/test hook:
/// the dispatch-overhead benchmark drives deliberately small products
/// through the parallel path to measure per-call scoped-spawn vs pool
/// latency.  Results are identical to [`matmul`] for any ctx.
pub fn matmul_ungated(a: &Mat, b: &Mat, ctx: ParallelCtx) -> Mat {
    matmul_with_kernel(a, b, ctx, KernelPath::Auto)
}

/// [`matmul`] with an explicit kernel body and no serial gate — the hook
/// the microkernel parity sweep and the kernel benches drive each path
/// through directly.  Results are bitwise identical to [`matmul`] for any
/// (ctx, path).
pub fn matmul_with_kernel(a: &Mat, b: &Mat, ctx: ParallelCtx, path: KernelPath) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let data = par_rows(ctx, m, n, |r0, r1, out| {
        panel_matmul_with(&a.data[r0 * k..r1 * k], r1 - r0, k, b, out, path);
    });
    Mat { rows: m, cols: n, data }
}

/// Max rows of transposed scratch a `t_matmul` worker holds at once: the
/// strided column walk is amortized per sub-panel while scratch stays at
/// `TRANSPOSE_PANEL_ROWS * k` floats regardless of the worker's row range
/// (a serial call would otherwise materialize the whole transpose).
const TRANSPOSE_PANEL_ROWS: usize = 64;

/// `a^T @ b` for `a (k, m)`, `b (k, n) -> (m, n)` without materializing the
/// full transpose: each worker transposes bounded sub-panels of its column
/// range of `a` into a reused dense scratch, then runs the shared
/// microkernel on each.
pub fn t_matmul(a: &Mat, b: &Mat, ctx: ParallelCtx) -> Mat {
    let (k, m, n) = (a.rows, a.cols, b.cols);
    t_matmul_with_kernel(a, b, effective(ctx, m, k, n), KernelPath::Auto)
}

/// [`t_matmul`] with an explicit kernel body and no serial gate (the
/// microkernel parity sweep's transposed-panel hook).
pub fn t_matmul_with_kernel(a: &Mat, b: &Mat, ctx: ParallelCtx, path: KernelPath) -> Mat {
    assert_eq!(a.rows, b.rows, "t_matmul shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let data = par_rows(ctx, m, n, |r0, r1, out| {
        let mut panel = vec![0f32; TRANSPOSE_PANEL_ROWS.min(r1 - r0) * k];
        let mut rs = r0;
        while rs < r1 {
            let re = (rs + TRANSPOSE_PANEL_ROWS).min(r1);
            let pw = re - rs;
            for kk in 0..k {
                let arow = &a.data[kk * m..(kk + 1) * m];
                for i in 0..pw {
                    panel[i * k + kk] = arow[rs + i];
                }
            }
            panel_matmul_with(
                &panel[..pw * k],
                pw,
                k,
                b,
                &mut out[(rs - r0) * n..(re - r0) * n],
                path,
            );
            rs = re;
        }
    });
    Mat { rows: m, cols: n, data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn matmul_matches_naive_across_threads() {
        let mut rng = Pcg32::seeded(11);
        for (m, k, n) in [(1, 1, 1), (7, 13, 5), (64, 64, 64), (129, 257, 65)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = a.matmul_naive(&b);
            for t in [1usize, 2, 8] {
                let got = matmul(&a, &b, ParallelCtx::new(t));
                assert!(
                    got.rel_frobenius(&want) <= 1e-5,
                    "matmul {m}x{k}x{n} t={t} diverged"
                );
            }
        }
    }

    #[test]
    fn t_matmul_matches_naive_across_threads() {
        let mut rng = Pcg32::seeded(12);
        for (k, m, n) in [(1, 1, 1), (13, 7, 5), (64, 64, 64), (257, 129, 65)] {
            let a = Mat::randn(k, m, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = a.t_matmul_naive(&b);
            for t in [1usize, 2, 8] {
                let got = t_matmul(&a, &b, ParallelCtx::new(t));
                assert!(
                    got.rel_frobenius(&want) <= 1e-5,
                    "t_matmul {k}x{m}x{n} t={t} diverged"
                );
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        let c = matmul(&a, &b, ParallelCtx::new(4));
        assert_eq!((c.rows, c.cols), (0, 3));
        let a = Mat::zeros(4, 0);
        let b = Mat::zeros(0, 3);
        let c = matmul(&a, &b, ParallelCtx::new(4));
        assert_eq!(c.data, vec![0.0; 12]);
    }

    #[test]
    fn scoped_fallback_matches_pool_bitwise() {
        // ungated so the small shape actually exercises both dispatch paths
        let mut rng = Pcg32::seeded(13);
        let a = Mat::randn(65, 33, &mut rng);
        let b = Mat::randn(33, 17, &mut rng);
        let want = matmul_ungated(&a, &b, ParallelCtx::serial());
        for t in [2usize, 8] {
            assert_eq!(
                matmul_ungated(&a, &b, ParallelCtx::scoped(t)).data,
                want.data,
                "scoped t={t}"
            );
            assert_eq!(
                matmul_ungated(&a, &b, ParallelCtx::new(t)).data,
                want.data,
                "pool t={t}"
            );
        }
    }

    #[test]
    fn kernel_paths_are_bitwise_interchangeable() {
        // every explicit body must agree with the naive reference bit for
        // bit, on shapes hitting all of the m/n tail classes at once
        let mut rng = Pcg32::seeded(14);
        for (m, k, n) in [(4, 16, 8), (5, 7, 9), (13, 300, 23), (64, 257, 65)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = a.matmul_naive(&b);
            // Simd512 is unconditional: it degrades to the portable NR=16
            // body wherever the intrinsics are unavailable, so the wide
            // tiling is exercised on every machine
            let mut paths = vec![
                KernelPath::Auto,
                KernelPath::Portable,
                KernelPath::Autovec,
                KernelPath::Simd512,
            ];
            if simd_kernel_available() {
                paths.push(KernelPath::Simd);
            }
            for path in paths {
                let got = matmul_with_kernel(&a, &b, ParallelCtx::serial(), path);
                assert_eq!(got.data, want.data, "{path:?} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn microkernel_respects_accumulate_contract() {
        // panel_matmul is +=: a pre-filled out buffer must accumulate in
        // the reference's order (out entry first, then ascending k)
        let mut rng = Pcg32::seeded(15);
        let (m, k, n) = (6usize, 10usize, 11usize);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let seed_out = Mat::randn(m, n, &mut rng);
        let mut paths = vec![KernelPath::Portable, KernelPath::Autovec, KernelPath::Simd512];
        if simd_kernel_available() {
            paths.push(KernelPath::Simd);
        }
        let mut want = seed_out.data.clone();
        panel_matmul_with(&a.data, m, k, &b, &mut want, KernelPath::Autovec);
        for path in paths {
            let mut got = seed_out.data.clone();
            panel_matmul_with(&a.data, m, k, &b, &mut got, path);
            assert_eq!(got, want, "{path:?} accumulate-into-out diverged");
        }
    }

    #[test]
    fn kernel_env_parsing() {
        assert_eq!(parse_kernel("auto"), Some(KernelPath::Auto));
        assert_eq!(parse_kernel(" SIMD\n"), Some(KernelPath::Simd));
        assert_eq!(parse_kernel("avx2"), Some(KernelPath::Simd));
        assert_eq!(parse_kernel("avx512"), Some(KernelPath::Simd512));
        assert_eq!(parse_kernel(" Simd512\n"), Some(KernelPath::Simd512));
        assert_eq!(parse_kernel("portable"), Some(KernelPath::Portable));
        assert_eq!(parse_kernel("autovec"), Some(KernelPath::Autovec));
        assert_eq!(parse_kernel("baseline"), Some(KernelPath::Autovec));
        assert_eq!(parse_kernel("cuda"), None);
        assert_eq!(parse_kernel(""), None);
    }

    #[test]
    fn kernel_resolution_never_yields_auto() {
        let all = [
            KernelPath::Auto,
            KernelPath::Simd,
            KernelPath::Simd512,
            KernelPath::Portable,
            KernelPath::Autovec,
        ];
        for p in all {
            let r = resolved_kernel(p);
            assert_ne!(r, KernelPath::Auto, "{p:?} resolved to Auto");
            if r == KernelPath::Simd {
                assert!(simd_kernel_available(), "Simd resolved without CPU support");
            }
        }
        // an explicit Simd512 request always resolves to Simd512 (the
        // dispatch arm degrades internally), but Auto must only pick it
        // when the intrinsics body can actually run
        assert_eq!(resolved_kernel(KernelPath::Simd512), KernelPath::Simd512);
        if resolved_kernel(KernelPath::Auto) == KernelPath::Simd512 {
            assert!(simd512_kernel_available(), "Auto chose Simd512 without support");
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        let ys = par_map(ParallelCtx::new(8), &xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
        let empty: Vec<usize> = Vec::new();
        assert!(par_map(ParallelCtx::new(8), &empty, |&x: &usize| x).is_empty());
    }

    #[test]
    fn thread_count_override_and_resolution() {
        // a PRIVATE instance: the former version of this test mutated the
        // process-global count, racing parity tests that concurrently read
        // ParallelCtx::global() under cargo's parallel test runner
        let tc = ThreadCount::unresolved();
        assert_eq!(tc.get(|| 5), 5);
        assert_eq!(tc.get(|| 99), 5, "detection resolves exactly once");
        tc.set(3);
        assert_eq!(tc.get(|| 99), 3, "explicit override wins");
        tc.set(0);
        assert_eq!(tc.get(|| 99), 1, "override clamps to 1+");
    }

    #[test]
    fn thread_env_parsing() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 16\n"), Some(16));
        assert_eq!(parse_threads("0"), None, "0 falls back to detection");
        assert_eq!(parse_threads("-2"), None);
        assert_eq!(parse_threads("lots"), None);
        assert_eq!(parse_threads(""), None);
    }

    #[test]
    fn global_threads_resolves_to_at_least_one() {
        // read-only on the process global: safe under the parallel runner
        assert!(global_threads() >= 1);
        assert_eq!(ParallelCtx::global().threads, global_threads());
    }

    #[test]
    fn ctx_constructors_and_budget_split() {
        assert_eq!(ParallelCtx::serial().threads, 1);
        assert_eq!(ParallelCtx::new(0).threads, 1);
        assert_eq!(ParallelCtx::scoped(0).threads, 1);
        let ctx = ParallelCtx::new(8);
        assert_eq!(ctx.with_threads(3).threads, 3);
        assert_eq!(ctx.with_threads(0).threads, 1);
        // serial never dispatches, whatever handle it carries
        assert!(ParallelCtx::new(1).pool().is_none());
        assert!(ctx.with_threads(1).pool().is_none());
    }

    #[test]
    fn slabs_env_parsing() {
        assert_eq!(parse_slabs("1"), Some(1));
        assert_eq!(parse_slabs(" 4\n"), Some(4));
        assert_eq!(parse_slabs("64"), Some(64));
        assert_eq!(parse_slabs("0"), None, "0 slabs is malformed, not serial");
        assert_eq!(parse_slabs("65"), None, "beyond the cap is malformed");
        assert_eq!(parse_slabs("-2"), None);
        assert_eq!(parse_slabs("many"), None);
        assert_eq!(parse_slabs(""), None);
    }

    #[test]
    fn slabs_builder_and_decomposition_math() {
        let ctx = ParallelCtx::new(4).with_slabs_per_worker(3);
        assert_eq!(ctx.slabs_per_worker, 3);
        // threads * slabs_per_worker, clamped to the available rows
        assert_eq!(ctx.slabs(1000), 12);
        assert_eq!(ctx.slabs(5), 5);
        assert_eq!(ctx.slabs(1), 1);
        // builder clamps to the legal range
        assert_eq!(ParallelCtx::new(2).with_slabs_per_worker(0).slabs_per_worker, 1);
        assert_eq!(
            ParallelCtx::new(2).with_slabs_per_worker(1_000).slabs_per_worker,
            MAX_SLABS_PER_WORKER
        );
        // with_threads preserves the multiplier; serial pins it to 1
        assert_eq!(ctx.with_threads(2).slabs_per_worker, 3);
        assert_eq!(ParallelCtx::serial().slabs_per_worker, 1);
        assert!(global_slabs_per_worker() >= 1);
    }

    #[test]
    fn cost_model_slab_math() {
        // a model-driven ctx built as a private literal, so this test is
        // immune to QGALORE_SLABS_PER_WORKER pinning in the environment
        // (the CI stress legs set it process-wide)
        let m = ParallelCtx { threads: 4, slabs_per_worker: 4, slabs_explicit: false, pool: None };
        // balanced output with plenty of elements: the fixed base holds
        assert_eq!(m.cost_slabs(1024, 1024), 16);
        // tall-skinny (aspect >= 64): 4x finer slabs
        assert_eq!(m.cost_slabs(8192, 128), 64);
        // moderately tall (aspect >= 16): 2x finer
        assert_eq!(m.cost_slabs(2048, 128), 32);
        // near the serial gate the grain floor coarsens to one slab per
        // budgeted worker — even though the aspect boost applies
        assert_eq!(m.cost_slabs(128, 8), 4);
        // the grain floor also caps a tall-skinny boost: no slab below
        // MIN_SLAB_ELEMS output elements
        assert_eq!(m.cost_slabs(4096, 16), 16);
        // never more slabs than rows
        assert_eq!(m.cost_slabs(3, 1024), 3);
        // an explicit multiplier pins the exact fixed decomposition
        let pinned = m.with_slabs_per_worker(3);
        assert_eq!(pinned.cost_slabs(8192, 128), pinned.slabs(8192));
        assert_eq!(pinned.cost_slabs(8192, 128), 12);
        assert_eq!(ParallelCtx::serial().with_slabs_per_worker(5).cost_slabs(500, 1), 5);
        // item decomposition: fixed base for few items, finer with plenty
        assert_eq!(m.cost_slabs_items(1000), 32);
        assert_eq!(m.cost_slabs_items(40), 16);
        assert_eq!(m.cost_slabs_items(5), 5);
        assert_eq!(pinned.cost_slabs_items(1000), 12);
    }

    #[test]
    fn cost_model_is_bitwise_invariant() {
        // the model only ever changes slab boundaries, which the over-
        // decomposition contract already proves harmless — but pin it
        // anyway: model-driven and explicitly pinned ctxs must agree
        // bitwise on a shape where the model actually deviates (tall-
        // skinny boost AND grain coarsening both engage across these)
        let mut rng = Pcg32::seeded(17);
        for (m, k, n) in [(257, 9, 3), (96, 40, 7)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = matmul_ungated(&a, &b, ParallelCtx::serial());
            let model = ParallelCtx {
                threads: 4,
                slabs_per_worker: 4,
                slabs_explicit: false,
                ..ParallelCtx::new(4)
            };
            assert_eq!(matmul_ungated(&a, &b, model).data, want.data, "model {m}x{k}x{n}");
            for spw in [1usize, 8] {
                let pinned = ParallelCtx::new(4).with_slabs_per_worker(spw);
                assert_eq!(
                    matmul_ungated(&a, &b, pinned).data,
                    want.data,
                    "pinned spw={spw} {m}x{k}x{n}"
                );
            }
        }
    }

    #[test]
    fn over_decomposition_is_bitwise_invariant() {
        // the over-decomposition contract: slab count changes who computes
        // which rows, never any element's bits.  matmul + par_rows with a
        // row-keyed body, across slab multipliers straddling the row count.
        let mut rng = Pcg32::seeded(16);
        let a = Mat::randn(37, 45, &mut rng);
        let b = Mat::randn(45, 21, &mut rng);
        let want = matmul_ungated(&a, &b, ParallelCtx::serial());
        for spw in [1usize, 2, 4, 8, 64] {
            for t in [2usize, 8] {
                let ctx = ParallelCtx::new(t).with_slabs_per_worker(spw);
                assert_eq!(
                    matmul_ungated(&a, &b, ctx).data,
                    want.data,
                    "matmul t={t} spw={spw} diverged"
                );
            }
        }
        let fill = |r0: usize, _r1: usize, slab: &mut [f32]| {
            for (ri, row) in slab.chunks_mut(3).enumerate() {
                for (ci, v) in row.iter_mut().enumerate() {
                    *v = ((r0 + ri) * 10 + ci) as f32;
                }
            }
        };
        let want_rows = par_rows(ParallelCtx::serial(), 29, 3, fill);
        for spw in [1usize, 4, 64] {
            let got = par_rows(ParallelCtx::new(4).with_slabs_per_worker(spw), 29, 3, fill);
            assert_eq!(got, want_rows, "par_rows spw={spw} diverged");
        }
    }

    #[test]
    fn par_map_over_decomposed_preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        for spw in [1usize, 4, 64] {
            let ctx = ParallelCtx::new(8).with_slabs_per_worker(spw);
            let ys = par_map(ctx, &xs, |&x| x * 2);
            assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>(), "spw={spw}");
        }
    }
}
