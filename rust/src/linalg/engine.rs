//! Parallel cache-blocked matmul engine — the hot path under every
//! Q-GaLore projection (`P^T g`, `P u`) and subspace refresh.
//!
//! Design (no external deps, std scoped threads only):
//!
//! * Work splits over **row panels** of the output; each worker owns a
//!   disjoint `&mut` slab, so the parallelism is safe-Rust with zero
//!   synchronization on the accumulation path.
//! * Within a panel the kernel is k-blocked (`KC`-sized stripes of B stay
//!   hot in cache while the panel's rows stream over them) with the same
//!   ascending-k accumulation order as the naive reference, so blocked and
//!   naive results are **bitwise identical** — parity tests assert a
//!   1e-5 rel-Frobenius bound but the engine in fact meets 0.
//! * `t_matmul` first transposes its per-worker column panel into a dense
//!   row-major scratch (a few KB) and then reuses the same kernel: the
//!   strided column walk happens once per panel instead of once per fma.
//!
//! Thread count comes from [`ParallelCtx`]: explicit per-call, or the
//! process-global default (CLI `--threads` / `QGALORE_THREADS` env /
//! `available_parallelism`). Small problems (< [`PAR_MIN_FLOPS`] fma) run
//! serially — spawn cost would dominate.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::Mat;

/// k-stripe width: `KC` rows of B (KC * n * 4 bytes) form the resident
/// cache block each panel row streams against.
const KC: usize = 256;

/// Problems below this many fma ops (m*k*n) stay on the calling thread.
pub const PAR_MIN_FLOPS: usize = 1 << 20;

/// Buffer-cloning fan-outs (operand marshalling) below this many total
/// elements stay serial — spawn cost would exceed the memcpy.
pub const PAR_MIN_CLONE_ELEMS: usize = 1 << 20;

/// Process-global default thread count (0 = not yet resolved).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Override the global default (CLI `--threads`). Values are clamped to 1+.
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::Relaxed);
}

fn detect_threads() -> usize {
    if let Ok(s) = std::env::var("QGALORE_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The global default thread count (resolving it on first use).
pub fn global_threads() -> usize {
    match GLOBAL_THREADS.load(Ordering::Relaxed) {
        0 => {
            let n = detect_threads();
            // racing first-callers agree on detect()'s value; an explicit
            // set_global_threads always wins afterwards
            let _ = GLOBAL_THREADS.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed);
            n
        }
        n => n,
    }
}

/// Parallelism context threaded through the optimizer stack: how many
/// worker threads a linalg call may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelCtx {
    pub threads: usize,
}

impl ParallelCtx {
    /// Exactly one thread (reference semantics, no spawns).
    pub fn serial() -> Self {
        ParallelCtx { threads: 1 }
    }

    pub fn new(threads: usize) -> Self {
        ParallelCtx { threads: threads.max(1) }
    }

    /// The process-global default (CLI/env/hardware).
    pub fn global() -> Self {
        ParallelCtx { threads: global_threads() }
    }
}

impl Default for ParallelCtx {
    fn default() -> Self {
        ParallelCtx::global()
    }
}

/// Gate a buffer-cloning fan-out: serial below [`PAR_MIN_CLONE_ELEMS`]
/// total elements (spawn cost would exceed the memcpy), else `pool`.
pub fn clone_pool(total_elems: usize, pool: ParallelCtx) -> ParallelCtx {
    if total_elems < PAR_MIN_CLONE_ELEMS {
        ParallelCtx::serial()
    } else {
        pool
    }
}

/// Run `body(r0, r1, slab)` over disjoint row panels of a freshly zeroed
/// (rows, cols) row-major buffer, splitting panels across `ctx.threads`
/// scoped workers. `slab` covers exactly rows `r0..r1`.
pub fn par_rows<F>(ctx: ParallelCtx, rows: usize, cols: usize, body: F) -> Vec<f32>
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let mut out = vec![0f32; rows * cols];
    if rows == 0 || cols == 0 {
        return out;
    }
    let t = ctx.threads.clamp(1, rows);
    if t <= 1 {
        body(0, rows, &mut out);
        return out;
    }
    let chunk = rows.div_ceil(t);
    std::thread::scope(|s| {
        for (ti, slab) in out.chunks_mut(chunk * cols).enumerate() {
            let body = &body;
            let r0 = ti * chunk;
            let r1 = (r0 + chunk).min(rows);
            s.spawn(move || body(r0, r1, slab));
        }
    });
    out
}

/// Map `f` over `items` with up to `ctx.threads` scoped workers, preserving
/// order. Used to step independent layers / tensors concurrently.
pub fn par_map<T, U, F>(ctx: ParallelCtx, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    if ctx.threads <= 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let t = ctx.threads.min(items.len());
    let chunk = items.len().div_ceil(t);
    let mut out: Vec<Option<U>> = (0..items.len()).map(|_| None).collect();
    std::thread::scope(|s| {
        for (islab, oslab) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            let f = &f;
            s.spawn(move || {
                for (i, o) in islab.iter().zip(oslab.iter_mut()) {
                    *o = Some(f(i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map worker filled every slot")).collect()
}

/// Inner kernel: `out (rows, n) += panel (rows, k) @ b (k, n)`, k-blocked.
/// Accumulation over k is strictly ascending per output element — the same
/// order as the naive reference, so results match it bitwise.
pub(crate) fn panel_matmul(panel: &[f32], rows: usize, k: usize, b: &Mat, out: &mut [f32]) {
    let n = b.cols;
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for i in 0..rows {
            let arow = &panel[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for kk in kb..kend {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        kb = kend;
    }
}

/// Clamp `ctx` to serial when the m*k*n fma count is below
/// [`PAR_MIN_FLOPS`] (shared policy for the dense and fused-dequant paths).
pub(crate) fn effective(ctx: ParallelCtx, m: usize, k: usize, n: usize) -> ParallelCtx {
    if m.saturating_mul(k).saturating_mul(n) < PAR_MIN_FLOPS {
        ParallelCtx::serial()
    } else {
        ctx
    }
}

/// `a (m, k) @ b (k, n) -> (m, n)`, parallel over row panels of the output.
pub fn matmul(a: &Mat, b: &Mat, ctx: ParallelCtx) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let ctx = effective(ctx, m, k, n);
    let data = par_rows(ctx, m, n, |r0, r1, out| {
        panel_matmul(&a.data[r0 * k..r1 * k], r1 - r0, k, b, out);
    });
    Mat { rows: m, cols: n, data }
}

/// Max rows of transposed scratch a `t_matmul` worker holds at once: the
/// strided column walk is amortized per sub-panel while scratch stays at
/// `TRANSPOSE_PANEL_ROWS * k` floats regardless of the worker's row range
/// (a serial call would otherwise materialize the whole transpose).
const TRANSPOSE_PANEL_ROWS: usize = 64;

/// `a^T @ b` for `a (k, m)`, `b (k, n) -> (m, n)` without materializing the
/// full transpose: each worker transposes bounded sub-panels of its column
/// range of `a` into a reused dense scratch, then runs the shared blocked
/// kernel on each.
pub fn t_matmul(a: &Mat, b: &Mat, ctx: ParallelCtx) -> Mat {
    assert_eq!(a.rows, b.rows, "t_matmul shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let ctx = effective(ctx, m, k, n);
    let data = par_rows(ctx, m, n, |r0, r1, out| {
        let mut panel = vec![0f32; TRANSPOSE_PANEL_ROWS.min(r1 - r0) * k];
        let mut rs = r0;
        while rs < r1 {
            let re = (rs + TRANSPOSE_PANEL_ROWS).min(r1);
            let pw = re - rs;
            for kk in 0..k {
                let arow = &a.data[kk * m..(kk + 1) * m];
                for i in 0..pw {
                    panel[i * k + kk] = arow[rs + i];
                }
            }
            panel_matmul(
                &panel[..pw * k],
                pw,
                k,
                b,
                &mut out[(rs - r0) * n..(re - r0) * n],
            );
            rs = re;
        }
    });
    Mat { rows: m, cols: n, data }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn matmul_matches_naive_across_threads() {
        let mut rng = Pcg32::seeded(11);
        for (m, k, n) in [(1, 1, 1), (7, 13, 5), (64, 64, 64), (129, 257, 65)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = a.matmul_naive(&b);
            for t in [1usize, 2, 8] {
                let got = matmul(&a, &b, ParallelCtx::new(t));
                assert!(
                    got.rel_frobenius(&want) <= 1e-5,
                    "matmul {m}x{k}x{n} t={t} diverged"
                );
            }
        }
    }

    #[test]
    fn t_matmul_matches_naive_across_threads() {
        let mut rng = Pcg32::seeded(12);
        for (k, m, n) in [(1, 1, 1), (13, 7, 5), (64, 64, 64), (257, 129, 65)] {
            let a = Mat::randn(k, m, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let want = a.t_matmul_naive(&b);
            for t in [1usize, 2, 8] {
                let got = t_matmul(&a, &b, ParallelCtx::new(t));
                assert!(
                    got.rel_frobenius(&want) <= 1e-5,
                    "t_matmul {k}x{m}x{n} t={t} diverged"
                );
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        let c = matmul(&a, &b, ParallelCtx::new(4));
        assert_eq!((c.rows, c.cols), (0, 3));
        let a = Mat::zeros(4, 0);
        let b = Mat::zeros(0, 3);
        let c = matmul(&a, &b, ParallelCtx::new(4));
        assert_eq!(c.data, vec![0.0; 12]);
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        let ys = par_map(ParallelCtx::new(8), &xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
        let empty: Vec<usize> = Vec::new();
        assert!(par_map(ParallelCtx::new(8), &empty, |&x: &usize| x).is_empty());
    }

    #[test]
    fn global_threads_env_and_override() {
        // whatever the resolved default, an explicit override must win
        let before = global_threads();
        assert!(before >= 1);
        set_global_threads(3);
        assert_eq!(global_threads(), 3);
        set_global_threads(before);
        assert_eq!(global_threads(), before);
    }
}
