//! Prepacked dequantized panels for the steady-state projection matmuls.
//!
//! Q-GaLore's training loop multiplies the *same* frozen quantized
//! projection matrix hundreds of steps in a row — subspaces converge, so
//! refreshes are rare — yet the fused kernels in [`crate::quant`] re-decode
//! every code and re-lay out every panel on every call.  This module packs
//! the dequantized matrix **once per quantization epoch** into the exact
//! slice layouts the microkernel consumes, so the hot path degenerates to
//! plain dense panel matmuls over cached `f32` rows.
//!
//! # Panel layouts
//!
//! A [`PanelPack`] holds both orientations the trainer needs:
//!
//! * `fwd` — the dequantized matrix itself, `(rows, cols)` row-major.  The
//!   prepacked forward path hands `fwd[r0*cols .. r1*cols]` straight to
//!   `engine::panel_matmul` for each row slab, exactly where the fused path
//!   hands its per-call scratch tile.
//! * `tpose` — the transpose, `(cols, rows)` row-major.  The prepacked
//!   `Pᵀ·x` path slices `tpose[j0*rows .. j1*rows]` per column slab, the
//!   same layout the fused transpose path decodes per call.
//!
//! # Why bits are preserved
//!
//! Packing uses the tensors' own `dequant_at` — literally the same
//! `(code − zero) × scale` expression the fused closures evaluate — and the
//! fused bodies' row-group loops only *partition* rows, never reorder the
//! ascending-k accumulation inside the microkernel.  Handing the microkernel
//! a cached panel instead of a freshly decoded one therefore yields
//! bit-identical outputs by construction; `tests/parity.rs` and the golden
//! trace pin this across the tail-class sweep and whole training runs.
//!
//! # The epoch protocol
//!
//! Every quantized tensor is stamped with a process-unique epoch at
//! creation ([`crate::quant`]'s `fresh_epoch`), and a [`PanelPack`] records
//! the epoch it was built from.  `matches*` compares epoch **and** shape,
//! so a refreshed projection (new tensor, new epoch) can never be served a
//! stale pack — even if its values happen to coincide.  [`PanelCache`] is
//! the one-slot memo built on that check: `get_or_pack*` repacks exactly
//! when the epoch or shape moved, and is a cache hit otherwise.
//!
//! The cache is a pure speed artifact: [`pack_cache_enabled`] (env
//! [`PACK_CACHE_ENV`], default on) lets CI and benches force the per-call
//! decode path, and the golden trace runs both settings to prove the bits
//! don't care.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::quant::{Quant2Tensor, Quant4Tensor, QuantTensor};
use crate::util::env_parse;

/// Env var disabling the projection panel cache process-wide (`0`/`off`/
/// `false`); default is enabled.  A malformed value warns and keeps the
/// default, via the shared warn-on-malformed env parser.
pub const PACK_CACHE_ENV: &str = "QGALORE_PACK_CACHE";

const CACHE_UNSET: u8 = 0;
const CACHE_ON: u8 = 1;
const CACHE_OFF: u8 = 2;

/// Process-global cache switch; `CACHE_UNSET` until first resolution
/// (which consults [`PACK_CACHE_ENV`]), mirroring the engine's
/// `KERNEL_OVERRIDE` resolve-once protocol.
static PACK_CACHE: AtomicU8 = AtomicU8::new(CACHE_UNSET);

/// `QGALORE_PACK_CACHE`-style value -> enabled flag, if well-formed.
fn parse_pack_cache(s: &str) -> Option<bool> {
    match s.trim().to_ascii_lowercase().as_str() {
        "1" | "on" | "true" | "yes" => Some(true),
        "0" | "off" | "false" | "no" => Some(false),
        _ => None,
    }
}

/// Force the panel cache on or off process-wide (overrides the env var;
/// the golden trace uses this to pin cache-on == cache-off bitwise).
pub fn set_pack_cache(enabled: bool) {
    PACK_CACHE.store(if enabled { CACHE_ON } else { CACHE_OFF }, Ordering::Relaxed);
}

/// Whether projection consumers should build/use [`PanelCache`] packs
/// (resolving [`PACK_CACHE_ENV`] on first use; default `true`).  Bits are
/// identical either way — this only trades pack memory for decode time.
pub fn pack_cache_enabled() -> bool {
    match PACK_CACHE.load(Ordering::Relaxed) {
        CACHE_UNSET => {
            let on = env_parse(PACK_CACHE_ENV, "on|off|1|0|true|false", parse_pack_cache)
                .unwrap_or(true);
            let code = if on { CACHE_ON } else { CACHE_OFF };
            // racing first-callers agree on the env value; an explicit
            // set_pack_cache always wins afterwards
            let _ =
                PACK_CACHE.compare_exchange(CACHE_UNSET, code, Ordering::Relaxed, Ordering::Relaxed);
            PACK_CACHE.load(Ordering::Relaxed) == CACHE_ON
        }
        c => c == CACHE_ON,
    }
}

/// A dequantized projection packed into the microkernel's slice layouts,
/// in both orientations, stamped with the source tensor's epoch.
#[derive(Clone)]
pub struct PanelPack {
    rows: usize,
    cols: usize,
    epoch: u64,
    /// `(rows, cols)` row-major — the dequantized matrix itself.
    fwd: Vec<f32>,
    /// `(cols, rows)` row-major — the dequantized transpose.
    tpose: Vec<f32>,
}

impl std::fmt::Debug for PanelPack {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PanelPack")
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .field("epoch", &self.epoch)
            .finish_non_exhaustive()
    }
}

impl PanelPack {
    /// Decode-once shared body: `deq(idx)` over the row-major index space.
    fn build(rows: usize, cols: usize, epoch: u64, deq: impl Fn(usize) -> f32) -> Self {
        let mut fwd = vec![0f32; rows * cols];
        let mut tpose = vec![0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let v = deq(r * cols + c);
                fwd[r * cols + c] = v;
                tpose[c * rows + r] = v;
            }
        }
        PanelPack { rows, cols, epoch, fwd, tpose }
    }

    /// Pack an INT4 tensor viewed as a `(rows, cols)` row-major matrix.
    pub fn pack4(w: &Quant4Tensor, rows: usize, cols: usize) -> Self {
        assert_eq!(w.numel(), rows * cols, "pack4 shape mismatch");
        Self::build(rows, cols, w.epoch(), |idx| w.dequant_at(idx))
    }

    /// Pack an INT8/INT2-coded [`QuantTensor`] (unpacked i8 codes).
    pub fn pack8(w: &QuantTensor, rows: usize, cols: usize) -> Self {
        assert_eq!(w.q.len(), rows * cols, "pack8 shape mismatch");
        Self::build(rows, cols, w.epoch(), |idx| w.dequant_at(idx))
    }

    /// Pack a sub-byte 2-bit tensor viewed as `(rows, cols)` row-major.
    pub fn pack2(w: &Quant2Tensor, rows: usize, cols: usize) -> Self {
        assert_eq!(w.numel(), rows * cols, "pack2 shape mismatch");
        Self::build(rows, cols, w.epoch(), |idx| w.dequant_at(idx))
    }

    /// The dequantized matrix, `(rows, cols)` row-major.
    pub fn fwd(&self) -> &[f32] {
        &self.fwd
    }

    /// The dequantized transpose, `(cols, rows)` row-major.
    pub fn tpose(&self) -> &[f32] {
        &self.tpose
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Epoch of the tensor this pack was decoded from.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Whether this pack is current for `w` viewed as `(rows, cols)`.
    pub fn matches4(&self, w: &Quant4Tensor, rows: usize, cols: usize) -> bool {
        self.epoch == w.epoch() && self.rows == rows && self.cols == cols
    }

    /// Whether this pack is current for `w` viewed as `(rows, cols)`.
    pub fn matches8(&self, w: &QuantTensor, rows: usize, cols: usize) -> bool {
        self.epoch == w.epoch() && self.rows == rows && self.cols == cols
    }

    /// Whether this pack is current for `w` viewed as `(rows, cols)`.
    pub fn matches2(&self, w: &Quant2Tensor, rows: usize, cols: usize) -> bool {
        self.epoch == w.epoch() && self.rows == rows && self.cols == cols
    }

    /// Heap bytes held by the pack (both orientations).
    pub fn pack_bytes(&self) -> usize {
        (self.fwd.len() + self.tpose.len()) * std::mem::size_of::<f32>()
    }
}

/// One-slot epoch-keyed memo of the current [`PanelPack`] for a layer's
/// projection.  Repacks exactly when the source tensor's epoch or shape
/// moved (i.e. at subspace refreshes); every other step is a cache hit.
#[derive(Clone, Debug, Default)]
pub struct PanelCache {
    slot: Option<PanelPack>,
}

impl PanelCache {
    /// An empty cache (packs on first use).
    pub const fn empty() -> Self {
        PanelCache { slot: None }
    }

    /// The cached pack for `w`, repacking if stale or absent.
    pub fn get_or_pack4(&mut self, w: &Quant4Tensor, rows: usize, cols: usize) -> &PanelPack {
        if !self.slot.as_ref().is_some_and(|p| p.matches4(w, rows, cols)) {
            self.slot = Some(PanelPack::pack4(w, rows, cols));
        }
        self.slot.as_ref().unwrap()
    }

    /// The cached pack for `w`, repacking if stale or absent.
    pub fn get_or_pack8(&mut self, w: &QuantTensor, rows: usize, cols: usize) -> &PanelPack {
        if !self.slot.as_ref().is_some_and(|p| p.matches8(w, rows, cols)) {
            self.slot = Some(PanelPack::pack8(w, rows, cols));
        }
        self.slot.as_ref().unwrap()
    }

    /// The cached pack for `w`, repacking if stale or absent.
    pub fn get_or_pack2(&mut self, w: &Quant2Tensor, rows: usize, cols: usize) -> &PanelPack {
        if !self.slot.as_ref().is_some_and(|p| p.matches2(w, rows, cols)) {
            self.slot = Some(PanelPack::pack2(w, rows, cols));
        }
        self.slot.as_ref().unwrap()
    }

    /// The current pack, if any (no staleness check — pair with `matches*`).
    pub fn get(&self) -> Option<&PanelPack> {
        self.slot.as_ref()
    }

    /// Drop the cached pack (next `get_or_pack*` rebuilds).
    pub fn invalidate(&mut self) {
        self.slot = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{dequantize, dequantize2, dequantize4, quantize, quantize2, quantize4};
    use crate::util::Pcg32;

    fn vals(n: usize, seed: u64) -> Vec<f32> {
        Pcg32::seeded(seed).normal_vec(n, 0.0, 0.5)
    }

    #[test]
    fn pack_matches_dequantize_reference() {
        let (rows, cols) = (16, 16);
        let x = vals(rows * cols, 1);
        let q4 = quantize4(&x);
        let p = PanelPack::pack4(&q4, rows, cols);
        let ref4 = dequantize4(&q4);
        assert_eq!(p.fwd(), &ref4[..], "fwd is the dequantized matrix, bitwise");
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(p.tpose()[c * rows + r], ref4[r * cols + c]);
            }
        }
        let q8 = quantize(&x, 8);
        assert_eq!(PanelPack::pack8(&q8, rows, cols).fwd(), &dequantize(&q8)[..]);
        let q2 = quantize2(&x);
        assert_eq!(PanelPack::pack2(&q2, rows, cols).fwd(), &dequantize2(&q2)[..]);
        assert_eq!(p.rows(), rows);
        assert_eq!(p.cols(), cols);
        assert_eq!(p.epoch(), q4.epoch());
        assert_eq!(p.pack_bytes(), 2 * rows * cols * 4);
    }

    #[test]
    fn cache_hits_on_same_epoch_and_repacks_on_refresh() {
        let (rows, cols) = (16, 16);
        let x = vals(rows * cols, 2);
        let q = quantize4(&x);
        let mut cache = PanelCache::empty();
        assert!(cache.get().is_none());
        let ptr = cache.get_or_pack4(&q, rows, cols).fwd().as_ptr();
        // same tensor, same epoch: a hit — the allocation must not move
        assert_eq!(cache.get_or_pack4(&q, rows, cols).fwd().as_ptr(), ptr);
        assert!(cache.get().unwrap().matches4(&q, rows, cols));
        // a refresh re-quantizes: new tensor, new epoch, even for the SAME
        // values — the stale pack must be replaced
        let refreshed = quantize4(&x);
        assert!(!cache.get().unwrap().matches4(&refreshed, rows, cols));
        let repacked = cache.get_or_pack4(&refreshed, rows, cols);
        assert_eq!(repacked.epoch(), refreshed.epoch());
        // in-place mutation protocol: bump_epoch invalidates too
        let mut q = quantize4(&x);
        let mut cache = PanelCache::empty();
        cache.get_or_pack4(&q, rows, cols);
        q.bump_epoch();
        assert!(!cache.get().unwrap().matches4(&q, rows, cols));
    }

    #[test]
    fn cache_repacks_on_shape_change() {
        let q = quantize4(&vals(256, 3));
        let mut cache = PanelCache::empty();
        cache.get_or_pack4(&q, 16, 16);
        assert!(!cache.get().unwrap().matches4(&q, 32, 8), "same tensor, new view");
        let p = cache.get_or_pack4(&q, 32, 8);
        assert_eq!((p.rows(), p.cols()), (32, 8));
        cache.invalidate();
        assert!(cache.get().is_none());
    }

    #[test]
    fn pack_cache_env_parsing() {
        for on in ["1", "on", "true", "yes", " ON\n"] {
            assert_eq!(parse_pack_cache(on), Some(true), "{on:?}");
        }
        for off in ["0", "off", "false", "no", " Off\n"] {
            assert_eq!(parse_pack_cache(off), Some(false), "{off:?}");
        }
        assert_eq!(parse_pack_cache("maybe"), None);
    }
}
