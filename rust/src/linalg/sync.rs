//! Atomics shim for the concurrency core.
//!
//! Production builds re-export `std::sync::atomic` verbatim — the shim is
//! zero-cost and `pool.rs` compiles to exactly the code it had before the
//! shim existed.  Under `--cfg qgalore_modelcheck` the same names resolve
//! to the instrumented shadow atomics in [`crate::modelcheck::shadow`], so
//! the schedule explorer runs the *real* Chase-Lev / `run_graph` release
//! code rather than a transliteration.
//!
//! `Ordering` always comes from std: the shadow types take the real enum
//! and classify it themselves.

pub(crate) use std::sync::atomic::Ordering;

#[cfg(not(qgalore_modelcheck))]
pub(crate) use std::sync::atomic::{
    fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicU64, AtomicUsize,
};

#[cfg(qgalore_modelcheck)]
pub(crate) use crate::modelcheck::shadow::{
    fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicU64, AtomicUsize,
};
