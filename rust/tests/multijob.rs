//! Multi-tenant coordinator golden fencing.
//!
//! The serving contract of `coordinator::multijob` is PR-6-style bitwise
//! determinism, extended to co-tenancy: a job's training trajectory is a
//! pure function of its own seed and the shared base arena.  Concretely,
//! the per-round loss bits and final factor bits of a job must be
//! IDENTICAL whether it runs alone or among 15 co-tenants, on any worker
//! count, under hostile steal seeds — and a job checkpointed to a delta
//! file, reloaded into a *different* coordinator, and resumed must emit
//! the same bits as its uninterrupted twin.
//!
//! Shapes mix one group above `PAR_MIN_FLOPS` (128x128: gradient products
//! genuinely fan out inside graph nodes) with serial-gated groups, so the
//! invariance covers both engine paths, as in tests/golden_trace.rs.

use qgalore::coordinator::{checkpoint, MultiJobConfig, MultiJobCoordinator};
use qgalore::linalg::{ParallelCtx, WorkerPool};
use qgalore::scheduler::SchedulerConfig;
use qgalore::util::unique_temp_dir;

const ROUNDS: usize = 6;

fn shapes() -> Vec<(usize, usize)> {
    // every quantized buffer (m*n base, m*r projection, r*n moments at
    // rank 8) is <= 256 elems or a multiple of 256
    vec![(128, 128), (64, 64), (32, 96), (96, 32)]
}

fn cfg() -> MultiJobConfig {
    MultiJobConfig {
        rank: 8,
        // interval 3 so subspace refreshes land mid-trace, not just at
        // round 0
        sched: SchedulerConfig { base_interval: 3, ..SchedulerConfig::default() },
        ..MultiJobConfig::default()
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// (loss-trace bits, exported-factor bits) of job `ji`.
fn job_bits(co: &MultiJobCoordinator, ji: usize) -> (Vec<u32>, Vec<u32>) {
    (bits(&co.job(ji).loss_trace), bits(&co.export_factors(ji)))
}

#[test]
fn cotenant_trace_is_bitwise_invariant() {
    // reference: the job alone, sequential rounds, serial compute
    let mut rf = MultiJobCoordinator::new(&shapes(), cfg(), ParallelCtx::serial());
    rf.add_job(42);
    for _ in 0..ROUNDS {
        rf.round_sequential();
    }
    let want = job_bits(&rf, 0);
    assert_eq!(want.0.len(), ROUNDS);

    for &(workers, steal_seed) in &[(1usize, 13u64), (4, 999_331), (16, u64::MAX)] {
        let pool = WorkerPool::leaked_with_steal_seed(workers, steal_seed);
        // thread budget >= 4 so a 1-worker pool still gets real dispatch
        let ctx = ParallelCtx::with_pool(workers.max(4), pool);

        // the same job alone, on the stealing pool
        let mut solo = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
        solo.add_job(42);
        for _ in 0..ROUNDS {
            solo.round(pool).unwrap();
        }
        assert_eq!(
            job_bits(&solo, 0),
            want,
            "solo trace diverged at {workers} workers (steal seed {steal_seed:#x})"
        );

        // the same job among 15 co-tenants with unrelated seeds
        let mut co = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
        let mut target = usize::MAX;
        for j in 0..16u64 {
            let ji = co.add_job(if j == 5 { 42 } else { 1_000 + 7 * j });
            if j == 5 {
                target = ji;
            }
        }
        for _ in 0..ROUNDS {
            co.round(pool).unwrap();
        }
        assert_eq!(
            job_bits(&co, target),
            want,
            "co-tenant trace diverged at {workers} workers (steal seed {steal_seed:#x})"
        );
    }

    // the trace is a real training signal, not a fixed point
    let first = f32::from_bits(want.0[0]);
    let last = f32::from_bits(want.0[ROUNDS - 1]);
    assert!(first.is_finite() && last.is_finite(), "non-finite loss in trace");
    assert!(last < first, "job did not learn over {ROUNDS} rounds ({first} -> {last})");
}

#[test]
fn delta_resume_matches_uninterrupted_bitwise() {
    let dir = unique_temp_dir("multijob");
    let path = dir.join("job42.delta");
    let pool = WorkerPool::leaked_with_steal_seed(4, 11);
    let ctx = ParallelCtx::with_pool(4, pool);

    // uninterrupted twin: 4 + 4 rounds straight through
    let mut full = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
    full.add_job(42);
    for _ in 0..4 {
        full.round(pool).unwrap();
    }

    // interrupted run: identical first half, checkpointed and dropped
    {
        let mut half = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
        half.add_job(42);
        for _ in 0..4 {
            half.round(pool).unwrap();
        }
        checkpoint::save_delta(&path, &half.export_delta(0, "itest").unwrap()).unwrap();
    }

    // resume into a coordinator already serving an unrelated tenant
    let mut resumed = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
    resumed.add_job(7);
    let ck = checkpoint::load_delta(&path).unwrap();
    let ji = resumed.import_job(&ck).unwrap();
    assert_eq!(
        resumed.job(ji).current_step(),
        full.job(0).current_step(),
        "imported job resumed at the wrong step"
    );

    let mut tail_full = Vec::new();
    let mut tail_res = Vec::new();
    for _ in 0..4 {
        tail_full.push(full.round(pool).unwrap()[0]);
        tail_res.push(resumed.round(pool).unwrap()[ji]);
    }
    assert_eq!(bits(&tail_full), bits(&tail_res), "post-resume losses diverged");
    assert_eq!(
        bits(&full.export_factors(0)),
        bits(&resumed.export_factors(ji)),
        "post-resume factors diverged"
    );
}

/// The CI stress shape: full tenancy on a 16-worker pool with a hostile
/// steal seed.  Every job must stay finite and the fleet must learn.
#[test]
fn sixteen_tenants_learn_under_hostile_stealing() {
    let pool = WorkerPool::leaked_with_steal_seed(16, 999_331);
    let ctx = ParallelCtx::with_pool(16, pool);
    let mut co = MultiJobCoordinator::new(&shapes(), cfg(), ctx);
    for j in 0..16u64 {
        co.add_job(2_000 + j);
    }
    let first = co.round(pool).unwrap();
    let mut last = first.clone();
    for _ in 0..9 {
        last = co.round(pool).unwrap();
    }
    for (ji, (&f, &l)) in first.iter().zip(&last).enumerate() {
        assert!(f.is_finite() && l.is_finite(), "job {ji} went non-finite: {f} -> {l}");
    }
    let mean_first = first.iter().sum::<f32>() / first.len() as f32;
    let mean_last = last.iter().sum::<f32>() / last.len() as f32;
    assert!(
        mean_last < mean_first,
        "fleet mean loss did not improve over 10 rounds: {mean_first} -> {mean_last}"
    );
    let improved = first.iter().zip(&last).filter(|(f, l)| l < f).count();
    assert!(improved >= 12, "only {improved}/16 jobs improved over 10 rounds");
}
