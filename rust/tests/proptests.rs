//! Property-based tests over the substrates.
//!
//! The offline dependency budget has no proptest crate, so this file uses a
//! small in-tree harness: `cases(n, seed, f)` runs `f` over n seeded random
//! cases and reports the failing case's seed on panic — the shrinking is
//! manual (re-run the printed case seed) but the coverage is the same idea:
//! each property is checked across hundreds of randomized inputs.

use qgalore::coordinator::{HostDataflowTrainer, HostMethod, HostStepConfig};
use qgalore::data::{Batcher, Tokenizer};
use qgalore::jsonx::Json;
use qgalore::linalg::{
    engine, left_subspace, par_map, par_rows, qr_orthonormal, subspace_cosine,
    subspace_overlap, KernelPath, Mat, ParallelCtx, WorkerPool,
};
use qgalore::quant;
use qgalore::scheduler::{SchedulerConfig, SubspaceScheduler};
use qgalore::util::Pcg32;

/// Run `f` over `n` seeded cases; panics identify the case seed.
fn cases(n: u64, seed: u64, f: impl Fn(&mut Pcg32, u64)) {
    for i in 0..n {
        let case_seed = seed.wrapping_mul(1_000_003).wrapping_add(i);
        let mut rng = Pcg32::seeded(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, case_seed)
        }));
        if let Err(e) = result {
            panic!("property failed on case seed {case_seed}: {e:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// quantization properties
// ---------------------------------------------------------------------------

#[test]
fn prop_quant_roundtrip_error_bounded() {
    cases(200, 1, |rng, _| {
        let bits = [8u32, 4, 2][rng.below(3)];
        let nblocks = 1 + rng.below(6);
        let scale = 10f32.powf(rng.next_f32() * 6.0 - 3.0); // 1e-3 .. 1e3
        let x = rng.normal_vec(nblocks * 256, 0.0, scale);
        let t = quant::quantize(&x, bits);
        let xh = quant::dequantize(&t);
        for (bi, (xb, hb)) in x.chunks(256).zip(xh.chunks(256)).enumerate() {
            let bound = t.scale[bi] * 0.5 + t.scale[bi] * 1e-3;
            for (a, b) in xb.iter().zip(hb) {
                assert!((a - b).abs() <= bound);
            }
        }
    });
}

#[test]
fn prop_int4_pack_unpack_identity() {
    // both parities: even lengths round-trip exactly; odd lengths round-trip
    // through the padded high nibble (unpack yields the padded even count)
    cases(200, 2, |rng, _| {
        let n = 1 + rng.below(1024);
        let codes: Vec<i8> = (0..n).map(|_| (rng.below(16) as i8) - 8).collect();
        let packed = quant::pack_int4(&codes);
        assert_eq!(packed.len(), n.div_ceil(2));
        let unpacked = quant::unpack_int4(&packed);
        assert_eq!(unpacked.len(), packed.len() * 2);
        assert_eq!(&unpacked[..n], &codes[..]);
        if n % 2 == 1 {
            assert_eq!(unpacked[n], 0, "odd-length pad nibble must decode to 0");
        }
    });
}

#[test]
fn prop_int2_pack_unpack_identity() {
    // 4 codes per byte, LSB-first, offset-binary +2; tail positions of the
    // last byte stay 0 and decode to the offset's floor (-2) — dequantize2
    // truncates to numel, so pads never surface in values
    cases(200, 14, |rng, _| {
        let n = 1 + rng.below(1024);
        let codes: Vec<i8> = (0..n).map(|_| (rng.below(4) as i8) - 2).collect();
        let packed = quant::pack_int2(&codes);
        assert_eq!(packed.len(), n.div_ceil(4));
        let unpacked = quant::unpack_int2(&packed);
        assert_eq!(unpacked.len(), packed.len() * 4);
        assert_eq!(&unpacked[..n], &codes[..]);
        for (i, &pad) in unpacked[n..].iter().enumerate() {
            assert_eq!(pad, -2, "pad position {i} must decode to the offset floor");
        }
    });
}

#[test]
fn prop_quant2_roundtrip_matches_unpacked_path() {
    // quantize2 must be exactly quantize(x, 2) in sub-byte storage: same
    // codes, same scales/zeros, same dequantized values, both parities
    cases(120, 15, |rng, _| {
        let n = if rng.below(2) == 0 {
            1 + rng.below(255)
        } else {
            256 * (1 + rng.below(4))
        };
        let x = rng.normal_vec(n, 0.0, 1.0);
        let t2 = quant::quantize2(&x);
        let t = quant::quantize(&x, 2);
        assert_eq!(t2.numel(), n);
        assert_eq!(t2.packed, quant::pack_int2(&t.q));
        assert_eq!(t2.scale, t.scale);
        assert_eq!(t2.zero, t.zero);
        assert_eq!(quant::dequantize2(&t2), quant::dequantize(&t));
    });
}

#[test]
fn prop_quant4_roundtrip_tracks_numel() {
    cases(120, 13, |rng, _| {
        // single-block (possibly odd) and multi-block sizes
        let n = if rng.below(2) == 0 {
            1 + rng.below(255)
        } else {
            256 * (1 + rng.below(4))
        };
        let x = rng.normal_vec(n, 0.0, 1.0);
        let t = quant::quantize4(&x);
        assert_eq!(t.numel(), n);
        let xh = quant::dequantize4(&t);
        assert_eq!(xh.len(), n);
        for (bi, (xb, hb)) in x.chunks(t.block).zip(xh.chunks(t.block)).enumerate() {
            let bound = t.scale[bi] * 0.5 + t.scale[bi] * 1e-3;
            for (a, b) in xb.iter().zip(hb) {
                assert!((a - b).abs() <= bound);
            }
        }
    });
}

#[test]
fn prop_sr_expectation_unbiased() {
    cases(20, 3, |rng, _| {
        let x = rng.normal_vec(256, 0.0, 1.0);
        let mut acc = vec![0f64; 256];
        let trials = 300;
        let mut scale0 = 0.0f32;
        for _ in 0..trials {
            let t = quant::sr_quantize(&x, 8, rng);
            scale0 = t.scale[0];
            for (a, v) in acc.iter_mut().zip(quant::dequantize(&t)) {
                *a += v as f64;
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let mean = (*a / trials as f64) as f32;
            assert!((mean - x[i]).abs() < scale0 * 0.6, "i={i}");
        }
    });
}

#[test]
fn prop_quant_codes_within_bit_range() {
    cases(200, 4, |rng, _| {
        let bits = [8u32, 4, 2][rng.below(3)];
        let nb = 1 + rng.below(4);
        let x = rng.normal_vec(256 * nb, 0.0, 5.0);
        let t = quant::quantize(&x, bits);
        let lim = 1i16 << (bits - 1);
        assert!(t.q.iter().all(|&c| (c as i16) >= -lim && (c as i16) < lim));
    });
}

// ---------------------------------------------------------------------------
// linalg properties
// ---------------------------------------------------------------------------

#[test]
fn prop_qr_orthonormal_and_span_preserving() {
    cases(60, 5, |rng, _| {
        let m = 8 + rng.below(56);
        let r = 1 + rng.below(8.min(m));
        let a = Mat::randn(m, r, rng);
        let q = qr_orthonormal(&a);
        let gram = q.t_matmul(&q);
        for i in 0..r {
            for j in 0..r {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((gram.at(i, j) - want).abs() < 2e-3);
            }
        }
        let proj = q.matmul(&q.t_matmul(&a));
        assert!(proj.sub(&a).frobenius() / a.frobenius().max(1e-6) < 1e-3);
    });
}

#[test]
fn prop_subspace_iteration_recovers_planted_rank() {
    cases(40, 6, |rng, _| {
        let m = 16 + rng.below(48);
        let n = 16 + rng.below(48);
        let r = 1 + rng.below(4);
        let u_true = qr_orthonormal(&Mat::randn(m, r, rng));
        let v = Mat::randn(r, n, rng);
        let g = u_true.matmul(&v);
        let q = left_subspace(&g, r, 2, rng);
        assert!(subspace_overlap(&u_true, &q) > 0.99);
    });
}

#[test]
fn prop_cosine_bounded_and_reflexive() {
    cases(60, 7, |rng, _| {
        let m = 8 + rng.below(56);
        let r = 1 + rng.below(8.min(m));
        let a = qr_orthonormal(&Mat::randn(m, r, rng));
        let b = qr_orthonormal(&Mat::randn(m, r, rng));
        let s = subspace_cosine(&a, &b);
        assert!((0.0..=1.0 + 1e-5).contains(&s));
        assert!((subspace_cosine(&a, &a) - 1.0).abs() < 1e-4);
    });
}

// ---------------------------------------------------------------------------
// scheduler-equivalence properties
//
// The execution-layer contract: par_rows / par_map / the dense engine / the
// fused dequant kernels produce BITWISE-identical output under every
// scheduler — serial, per-call scoped spawns, the PR-2 single-FIFO pool,
// and the Chase-Lev work-stealing pool — for arbitrary job counts, chunk
// sizes (ctx.threads and the per-case-random slabs_per_worker multiplier
// drive the decomposition), and worker counts.  Scheduling decides WHO
// runs a slab and WHEN; never what the slab contains.
// ---------------------------------------------------------------------------

/// Pools shared by every case: leaking one per case would leak hundreds of
/// worker threads across a 20-case property run.  Worker counts straddle
/// the decomposition widths the cases draw (1 under, 4 at, 16 over).
fn equivalence_pools() -> &'static [(&'static WorkerPool, &'static WorkerPool)] {
    use std::sync::OnceLock;
    static POOLS: OnceLock<Vec<(&'static WorkerPool, &'static WorkerPool)>> = OnceLock::new();
    POOLS.get_or_init(|| {
        [1usize, 4, 16]
            .iter()
            .map(|&w| (WorkerPool::leaked_fifo(w), WorkerPool::leaked(w)))
            .collect()
    })
}

/// Every execution scheduler for one thread budget against one pool pair:
/// serial is the caller's reference, the rest must match it bit for bit.
/// `spw` is the over-decomposition multiplier (randomized per case — slab
/// counts must be as invisible in the bits as worker counts are).  The
/// pool-independent scoped scheduler is checked once per case by the
/// callers (not per pool pair — it would re-run identical work).
fn schedulers(
    threads: usize,
    spw: usize,
    fifo: &'static WorkerPool,
    steal: &'static WorkerPool,
) -> [(&'static str, ParallelCtx); 2] {
    [
        ("fifo-pool", ParallelCtx::with_pool(threads, fifo).with_slabs_per_worker(spw)),
        ("steal-pool", ParallelCtx::with_pool(threads, steal).with_slabs_per_worker(spw)),
    ]
}

#[test]
fn prop_scheduler_equivalence_bitwise() {
    let pools = equivalence_pools();
    cases(20, 40, |rng, seed| {
        let m = 1 + rng.below(96);
        let k = 1 + rng.below(64);
        let n = 1 + rng.below(48);
        let threads = 1 + rng.below(9); // chunk width = ceil(rows / slabs)
        let spw = 1 + rng.below(8); // over-decomposition multiplier
        let a = Mat::randn(m, k, rng);
        let b = Mat::randn(k, n, rng);
        let at = a.transpose(); // (k, m): a t_matmul operand with shared k
        let want_mm = engine::matmul_ungated(&a, &b, ParallelCtx::serial());
        let want_tm =
            engine::t_matmul_with_kernel(&b, &at, ParallelCtx::serial(), KernelPath::Auto);

        // par_rows body keyed by ABSOLUTE row only, so any chunking must
        // reproduce it; per-row PCG streams like the SR/noise fills use
        let cols = 1 + rng.below(32);
        let rows = 1 + rng.below(64);
        let fill = move |r0: usize, _r1: usize, slab: &mut [f32]| {
            for (ri, row) in slab.chunks_mut(cols).enumerate() {
                let mut prng = Pcg32::new(seed, (r0 + ri) as u64);
                for v in row {
                    *v = prng.next_f32();
                }
            }
        };
        let want_rows = par_rows(ParallelCtx::serial(), rows, cols, fill);

        // par_map over a random job count, result keyed by item value only
        let items: Vec<u64> = (0..1 + rng.below(33) as u64).collect();
        let want_map: Vec<u32> =
            items.iter().map(|&i| Pcg32::new(seed, i).next_u32()).collect();

        // the scoped scheduler is pool-independent: check it once per case
        let scoped = std::iter::once(("scoped", ParallelCtx::scoped(threads)));
        let pooled = pools
            .iter()
            .flat_map(|&(fifo, steal)| schedulers(threads, spw, fifo, steal));
        for (label, ctx) in scoped.chain(pooled) {
            assert_eq!(
                engine::matmul_ungated(&a, &b, ctx).data,
                want_mm.data,
                "matmul {m}x{k}x{n} t={threads} spw={spw} diverged under {label}"
            );
            assert_eq!(
                engine::t_matmul_with_kernel(&b, &at, ctx, KernelPath::Auto).data,
                want_tm.data,
                "t_matmul t={threads} diverged under {label}"
            );
            assert_eq!(
                par_rows(ctx, rows, cols, fill),
                want_rows,
                "par_rows {rows}x{cols} t={threads} diverged under {label}"
            );
            assert_eq!(
                par_map(ctx, &items, |&i| Pcg32::new(seed, i).next_u32()),
                want_map,
                "par_map jobs={} t={threads} diverged under {label}",
                items.len()
            );
        }
    });
}

#[test]
fn prop_fused_dequant_scheduler_equivalence_bitwise() {
    // fused dequant paths gate to serial below PAR_MIN_FLOPS, so this
    // property mixes sub-gate shapes (the gate itself must be
    // scheduler-independent) with above-gate shapes where the pools
    // genuinely fan out dequant scratch tiles across workers
    let pools = equivalence_pools();
    cases(8, 41, |rng, _seed| {
        // blockwise quantization needs numel <= 256 or numel % 256 == 0:
        // above-gate shapes fix m = 256 (any k divides out), sub-gate
        // shapes keep m*k within one block
        let above_gate = rng.below(2) == 0;
        let (m, k) = if above_gate {
            (256, 64 + rng.below(64))
        } else {
            (1 + rng.below(16), 1 + rng.below(16))
        };
        let n = if above_gate { 64 } else { 1 + rng.below(24) };
        assert!(!above_gate || m * k * n >= engine::PAR_MIN_FLOPS);
        let threads = 2 + rng.below(7);
        let spw = 1 + rng.below(8); // over-decomposition multiplier
        let p4 = quant::quantize4(&rng.normal_vec(m * k, 0.0, 0.3));
        let w8 = quant::quantize(&rng.normal_vec(m * k, 0.0, 0.3), 8);
        let x = Mat::randn(k, n, rng);
        let xt = Mat::randn(m, n, rng);
        let serial = ParallelCtx::serial();
        let want4 = quant::dequant4_matmul(&p4, m, k, &x, serial);
        let want8 = quant::dequant8_matmul(&w8, m, k, &x, serial);
        let want4t = quant::dequant4_t_matmul(&p4, m, k, &xt, serial);
        let want8t = quant::dequant8_t_matmul(&w8, m, k, &xt, serial);
        // scoped once per case (pool-independent), then each pool pair
        let scoped = std::iter::once(("scoped", ParallelCtx::scoped(threads)));
        let pooled = pools
            .iter()
            .flat_map(|&(fifo, steal)| schedulers(threads, spw, fifo, steal));
        for (label, ctx) in scoped.chain(pooled) {
            assert_eq!(
                quant::dequant4_matmul(&p4, m, k, &x, ctx).data,
                want4.data,
                "dequant4_matmul {m}x{k}x{n} t={threads} diverged under {label}"
            );
            assert_eq!(
                quant::dequant8_matmul(&w8, m, k, &x, ctx).data,
                want8.data,
                "dequant8_matmul {m}x{k}x{n} t={threads} diverged under {label}"
            );
            assert_eq!(
                quant::dequant4_t_matmul(&p4, m, k, &xt, ctx).data,
                want4t.data,
                "dequant4_t_matmul {m}x{k}x{n} t={threads} diverged under {label}"
            );
            assert_eq!(
                quant::dequant8_t_matmul(&w8, m, k, &xt, ctx).data,
                want8t.data,
                "dequant8_t_matmul {m}x{k}x{n} t={threads} diverged under {label}"
            );
        }
    });
}

#[test]
fn prop_prepacked_scheduler_equivalence_bitwise() {
    // the prepacked paths under every scheduler, against the SERIAL FUSED
    // reference: one PanelPack built at "refresh time" must reproduce the
    // per-call-decode bits for any pool discipline, thread budget, and
    // slab multiplier — the panel cache cannot be observable in values
    let pools = equivalence_pools();
    cases(8, 43, |rng, _seed| {
        let above_gate = rng.below(2) == 0;
        let (m, k) = if above_gate {
            (256, 64 + rng.below(64))
        } else {
            (1 + rng.below(16), 1 + rng.below(16))
        };
        let n = if above_gate { 64 } else { 1 + rng.below(24) };
        let threads = 2 + rng.below(7);
        let spw = 1 + rng.below(8);
        let p4 = quant::quantize4(&rng.normal_vec(m * k, 0.0, 0.3));
        let p2 = quant::quantize2(&rng.normal_vec(m * k, 0.0, 0.3));
        let pk4 = qgalore::linalg::PanelPack::pack4(&p4, m, k);
        let pk2 = qgalore::linalg::PanelPack::pack2(&p2, m, k);
        let x = Mat::randn(k, n, rng);
        let xt = Mat::randn(m, n, rng);
        let serial = ParallelCtx::serial();
        let want4 = quant::dequant4_matmul(&p4, m, k, &x, serial);
        let want4t = quant::dequant4_t_matmul(&p4, m, k, &xt, serial);
        let want2 = quant::dequant2_matmul(&p2, m, k, &x, serial);
        let want2t = quant::dequant2_t_matmul(&p2, m, k, &xt, serial);
        let scoped = std::iter::once(("scoped", ParallelCtx::scoped(threads)));
        let pooled = pools
            .iter()
            .flat_map(|&(fifo, steal)| schedulers(threads, spw, fifo, steal));
        for (label, ctx) in scoped.chain(pooled) {
            assert_eq!(
                quant::dequant4_matmul_prepacked(&p4, &pk4, m, k, &x, ctx).data,
                want4.data,
                "dequant4_matmul_prepacked {m}x{k}x{n} t={threads} diverged under {label}"
            );
            assert_eq!(
                quant::dequant4_t_matmul_prepacked(&p4, &pk4, m, k, &xt, ctx).data,
                want4t.data,
                "dequant4_t_matmul_prepacked {m}x{k}x{n} t={threads} diverged under {label}"
            );
            assert_eq!(
                quant::dequant2_matmul_prepacked(&p2, &pk2, m, k, &x, ctx).data,
                want2.data,
                "dequant2_matmul_prepacked {m}x{k}x{n} t={threads} diverged under {label}"
            );
            assert_eq!(
                quant::dequant2_t_matmul_prepacked(&p2, &pk2, m, k, &xt, ctx).data,
                want2t.data,
                "dequant2_t_matmul_prepacked {m}x{k}x{n} t={threads} diverged under {label}"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// dataflow step-graph equivalence properties
//
// The trainer-layer extension of the scheduler-equivalence contract: an
// ENTIRE training step — per-layer grad/update chains racing as graph
// nodes, shape-batched refresh waves, adaptive scheduler recording —
// must be bitwise identical to the sequential walk, for every update
// method, random layer/shape mix, random refresh cadence (so waves
// interleave with non-due chains mid-run), and every pool discipline.
// ---------------------------------------------------------------------------

#[test]
fn prop_dataflow_step_matches_sequential_bitwise() {
    let pools = equivalence_pools();
    cases(12, 42, |rng, seed| {
        // layers drawn from 1..3 shape classes so refresh waves batch
        // some layers together and split others across waves
        let n_shapes = 1 + rng.below(3);
        let shape_pool: Vec<(usize, usize)> =
            (0..n_shapes).map(|_| (8 + rng.below(17), 8 + rng.below(17))).collect();
        let n_layers = 1 + rng.below(6);
        let shapes: Vec<(usize, usize)> =
            (0..n_layers).map(|_| shape_pool[rng.below(n_shapes)]).collect();
        let method = [HostMethod::Full, HostMethod::LowRank, HostMethod::Galore][rng.below(3)];
        let cfg = HostStepConfig {
            method,
            rank: 2 + rng.below(3),
            lr: 0.05,
            noise_eps: 1e-3,
            sched: SchedulerConfig {
                base_interval: 1 + rng.below(4) as u64,
                threshold: rng.next_f32(),
                window: 1 + rng.below(2),
                adaptive: rng.below(2) == 0,
                max_interval: 0,
            },
            seed,
        };
        let steps = 3 + rng.below(4);
        // reference: the sequential walk on the serial ctx
        let mut want_tr = HostDataflowTrainer::new(&shapes, cfg);
        let want_losses: Vec<u32> = (0..steps)
            .map(|_| want_tr.step_sequential(ParallelCtx::serial()).to_bits())
            .collect();
        let want_w: Vec<u32> = want_tr.export_weights().iter().map(|x| x.to_bits()).collect();
        let threads = 1 + rng.below(9);
        let spw = 1 + rng.below(8);
        for &(fifo, steal) in pools {
            for (label, pool) in [("fifo-pool", fifo), ("steal-pool", steal)] {
                let ctx = ParallelCtx::with_pool(threads, pool).with_slabs_per_worker(spw);
                let mut tr = HostDataflowTrainer::new(&shapes, cfg);
                let losses: Vec<u32> = (0..steps)
                    .map(|_| tr.step_dataflow(ctx, pool).unwrap().to_bits())
                    .collect();
                assert_eq!(
                    losses, want_losses,
                    "{method:?} loss trace diverged under {label} t={threads} spw={spw}"
                );
                let w: Vec<u32> = tr.export_weights().iter().map(|x| x.to_bits()).collect();
                assert_eq!(
                    w, want_w,
                    "{method:?} final weights diverged under {label} t={threads} spw={spw}"
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// json properties
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
    // string palette exercises escapes (quote, backslash, control bytes)
    // and multi-byte UTF-8 up to astral-plane emoji — the surrogate-pair
    // regression surface
    const CHARS: [char; 16] = [
        'a', 'z', 'Q', '7', ' ', '"', '\\', '\n', '\t', '\u{8}', '\u{1}', 'é', '—', '∞', '😀',
        '🦀',
    ];
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        // mix of integers, round floats, and awkward fractions so both
        // the integer and decimal printers feed the strict number grammar
        2 => Json::Num(match rng.below(4) {
            0 => (rng.below(2001) as f64) - 1000.0,
            1 => (rng.next_f32() * 2000.0 - 1000.0) as f64,
            2 => ((rng.next_f32() - 0.5) / 1000.0) as f64,
            _ => 0.0,
        }),
        3 => Json::Str(
            (0..rng.below(12))
                .map(|_| CHARS[rng.below(CHARS.len())])
                .collect(),
        ),
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    cases(300, 8, |rng, _| {
        let v = random_json(rng, 3);
        let parsed = Json::parse(&v.dump()).expect("roundtrip parse");
        // floats survive via shortest-representation printing
        assert_eq!(parsed.dump(), v.dump());
    });
}

#[test]
fn prop_json_unicode_escape_forms() {
    // every scalar value round-trips through the \uXXXX escape form,
    // including surrogate pairs for astral-plane chars
    cases(400, 81, |rng, _| {
        let c = loop {
            if let Some(c) = char::from_u32(rng.next_u32() % 0x11_0000) {
                break c;
            }
        };
        let mut buf = [0u16; 2];
        let escaped: String = c
            .encode_utf16(&mut buf)
            .iter()
            .map(|u| format!("\\u{u:04x}"))
            .collect();
        let parsed = Json::parse(&format!("\"{escaped}\"")).expect("escape form must parse");
        assert_eq!(
            parsed,
            Json::Str(c.to_string()),
            "\\u form of {c:?} (U+{:04X}) decoded wrong",
            c as u32
        );
    });
}

// ---------------------------------------------------------------------------
// data pipeline properties
// ---------------------------------------------------------------------------

#[test]
fn prop_tokenizer_roundtrip_lossless() {
    cases(100, 9, |rng, _| {
        let words = ["alpha", "beta", "gamma", "zz9", "Qx", "longish-token"];
        let text: String = (0..1 + rng.below(20))
            .map(|_| words[rng.below(words.len())])
            .collect::<Vec<_>>()
            .join(" ");
        let docs = vec!["alpha beta alpha gamma".to_string()];
        let tok = Tokenizer::train(&docs, 400);
        assert_eq!(tok.decode(&tok.encode(&text)), text);
    });
}

#[test]
fn prop_batcher_every_epoch_is_a_permutation() {
    cases(60, 10, |rng, _| {
        let seq = 4 + rng.below(12);
        let n_windows = 4 + rng.below(20);
        let ids: Vec<u32> = (0..(seq * n_windows + 1) as u32).collect();
        let batch = 1 + rng.below(n_windows.min(4));
        let mut b = Batcher::new(ids, batch, seq, rng.next_u64());
        let per_epoch = b.n_windows() / batch;
        for _epoch in 0..3 {
            let mut starts = Vec::new();
            for _ in 0..per_epoch {
                let bt = b.next();
                for row in 0..batch {
                    starts.push(bt.tokens[row * seq] as usize);
                }
            }
            starts.sort_unstable();
            starts.dedup();
            assert_eq!(starts.len(), per_epoch * batch, "windows repeated in epoch");
        }
    });
}

// ---------------------------------------------------------------------------
// scheduler properties
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_intervals_monotone_and_count_bounded() {
    cases(120, 11, |rng, _| {
        let layers: Vec<String> = (0..1 + rng.below(6)).map(|i| format!("l{i}")).collect();
        let base = 1 + rng.below(20) as u64;
        let mut s = SubspaceScheduler::new(
            &layers,
            SchedulerConfig {
                base_interval: base,
                threshold: rng.next_f32(),
                window: 1 + rng.below(3),
                adaptive: true,
                max_interval: 0,
            },
        );
        let horizon = base * 40;
        let mut prev: Vec<u64> = vec![0; layers.len()];
        for step in 0..horizon {
            for idx in 0..layers.len() {
                if s.due(idx, step) {
                    let iv = s.record_refresh(idx, step, Some(rng.next_f32()));
                    assert!(iv >= prev[idx], "interval shrank");
                    prev[idx] = iv;
                }
            }
        }
        // the adaptive scheduler can never do MORE svds than fixed GaLore
        assert!(s.total_svd_count() <= s.galore_equivalent_count(horizon));
    });
}

#[test]
fn prop_scheduler_non_adaptive_matches_fixed_schedule() {
    cases(60, 12, |rng, _| {
        let base = 1 + rng.below(15) as u64;
        let layers = vec!["a".to_string(), "b".to_string()];
        let mut s = SubspaceScheduler::new(
            &layers,
            SchedulerConfig {
                base_interval: base,
                threshold: 0.4,
                window: 2,
                adaptive: false,
                max_interval: 0,
            },
        );
        let horizon = base * (5 + rng.below(20) as u64);
        for step in 0..=horizon {
            for idx in 0..2 {
                if s.due(idx, step) {
                    s.record_refresh(idx, step, Some(rng.next_f32()));
                }
            }
        }
        assert_eq!(s.total_svd_count(), s.galore_equivalent_count(horizon));
    });
}
