//! Serving determinism fencing.
//!
//! The serving extension of the repo's determinism contract: a request's
//! scores/tokens are BITWISE identical served alone vs batched among N
//! strangers, at any worker count, under hostile steal seeds.  Batching
//! only widens the activation matrices with more columns, and every
//! kernel on the forward path computes each output element from its own
//! row/column in a fixed ascending-k order — these tests pin that the
//! implementation actually keeps the promise, on both the base-only and
//! the delta-applied paths.

use qgalore::coordinator::serve::{self, ServeConfig, ServeEngine, ServeModel, ServeResponse};
use qgalore::coordinator::{MultiJobConfig, MultiJobCoordinator};
use qgalore::linalg::{ParallelCtx, WorkerPool};
use qgalore::scheduler::SchedulerConfig;

/// Bitwise comparison key: f32 NLLs by bit pattern, tokens/pred verbatim.
fn resp_key(r: &ServeResponse) -> (Vec<u32>, Vec<u32>, Option<usize>) {
    match r {
        ServeResponse::Score { nll, pred } => {
            (nll.iter().map(|x| x.to_bits()).collect(), Vec::new(), *pred)
        }
        ServeResponse::Generate { tokens } => (Vec::new(), tokens.clone(), None),
    }
}

fn keys(rs: &[ServeResponse]) -> Vec<(Vec<u32>, Vec<u32>, Option<usize>)> {
    rs.iter().map(resp_key).collect()
}

fn serve_cfg() -> ServeConfig {
    // vocab*dim = 20480 and dim*dim = 4096: both multiples of 256, so the
    // blockwise quantizer accepts them; vocab leaves room for 4 labels
    ServeConfig { vocab: 320, dim: 64, n_layers: 3, seed: 5 }
}

#[test]
fn batched_equals_solo_bitwise_across_pools() {
    let cfg = serve_cfg();
    let reqs = serve::synth_requests(cfg.vocab, 24, 9);

    // reference: serial compute, one request at a time
    let reference = ServeEngine::new(ServeModel::from_seed(cfg).unwrap(), ParallelCtx::serial());
    let want = keys(&reference.serve_sequential(&reqs).unwrap());

    for &(workers, steal_seed) in &[(1usize, 13u64), (4, 999_331), (16, u64::MAX)] {
        let pool = WorkerPool::leaked_with_steal_seed(workers, steal_seed);
        // thread budget >= 4 so a 1-worker pool still gets real dispatch
        let ctx = ParallelCtx::with_pool(workers.max(4), pool);
        let engine = ServeEngine::new(ServeModel::from_seed(cfg).unwrap(), ctx);

        let batched = keys(&engine.serve_batch(&reqs, pool).unwrap());
        assert_eq!(
            batched, want,
            "batched != solo-serial at {workers} workers (steal seed {steal_seed:#x})"
        );

        // each request served completely alone on the same engine: the
        // strongest form of the contract (batch of 1 vs batch of 24)
        for (i, req) in reqs.iter().enumerate() {
            let solo = resp_key(&engine.serve_one(req).unwrap());
            assert_eq!(
                solo, want[i],
                "request {i} alone diverged at {workers} workers (steal seed {steal_seed:#x})"
            );
            let single = keys(&engine.serve_batch(std::slice::from_ref(req), pool).unwrap());
            assert_eq!(single[0], want[i], "singleton batch diverged for request {i}");
        }
    }
}

#[test]
fn batch_composition_does_not_leak_between_requests() {
    // the same request embedded in two different stranger batches must
    // come back identical — wave membership is invisible to a column
    let cfg = serve_cfg();
    let engine = ServeEngine::new(ServeModel::from_seed(cfg).unwrap(), ParallelCtx::serial());
    let pool = WorkerPool::leaked_with_steal_seed(4, 31);

    let a = serve::synth_requests(cfg.vocab, 16, 1);
    let b = serve::synth_requests(cfg.vocab, 16, 2);
    let probe = serve::synth_requests(cfg.vocab, 4, 3);

    let mut batch_a = a.clone();
    batch_a.extend(probe.iter().cloned());
    let mut batch_b = b;
    batch_b.extend(probe.iter().cloned());

    let in_a = keys(&engine.serve_batch(&batch_a, pool).unwrap());
    let in_b = keys(&engine.serve_batch(&batch_b, pool).unwrap());
    assert_eq!(
        &in_a[a.len()..],
        &in_b[16..],
        "probe responses changed with the strangers batched around them"
    );
}

#[test]
fn delta_applied_diverges_from_base_and_stays_deterministic() {
    // train a real per-user delta with the multijob coordinator
    let dim = 64usize;
    let shapes = vec![(dim, dim); 3];
    let mcfg = MultiJobConfig {
        rank: 8,
        // interval 2 so subspace refreshes (which materialize the INT4
        // projection) land well inside 6 rounds
        sched: SchedulerConfig { base_interval: 2, ..SchedulerConfig::default() },
        ..MultiJobConfig::default()
    };
    let pool = WorkerPool::leaked_with_steal_seed(4, 7);
    let ctx = ParallelCtx::with_pool(4, pool);
    let mut co = MultiJobCoordinator::new(&shapes, mcfg, ctx);
    co.add_job(4242);
    for _ in 0..6 {
        co.round(pool).unwrap();
    }
    let delta = co.export_delta(0, "serve-test").unwrap();

    let cfg = serve_cfg();
    let reqs = serve::synth_requests(cfg.vocab, 12, 3);

    let base = ServeEngine::new(ServeModel::from_seed(cfg).unwrap(), ParallelCtx::serial());
    let base_keys = keys(&base.serve_sequential(&reqs).unwrap());

    let mut model = ServeModel::from_seed(cfg).unwrap();
    model.apply_delta(&delta).unwrap();
    assert!(model.has_delta(), "6 rounds at interval 2 must refresh at least one layer");
    assert!(model.delta_bytes() > 0);
    let served = ServeEngine::new(model, ParallelCtx::serial());
    let delta_solo = served.serve_sequential(&reqs).unwrap();
    assert_ne!(
        keys(&delta_solo),
        base_keys,
        "applying a trained delta must change served outputs"
    );

    // the determinism contract holds on the delta path too
    for &(workers, steal_seed) in &[(1usize, 13u64), (4, 999_331), (16, u64::MAX)] {
        let wpool = WorkerPool::leaked_with_steal_seed(workers, steal_seed);
        let batched = served.serve_batch(&reqs, wpool).unwrap();
        assert_eq!(
            keys(&batched),
            keys(&delta_solo),
            "delta-applied batched != solo at {workers} workers (steal seed {steal_seed:#x})"
        );
    }
}

#[test]
fn delta_shape_mismatch_is_rejected() {
    // a delta trained at a different layer geometry must never be served
    let mcfg = MultiJobConfig {
        rank: 8,
        sched: SchedulerConfig { base_interval: 2, ..SchedulerConfig::default() },
        ..MultiJobConfig::default()
    };
    let pool = WorkerPool::leaked_with_steal_seed(2, 3);
    let ctx = ParallelCtx::with_pool(2, pool);
    let mut co = MultiJobCoordinator::new(&[(32, 96), (32, 96), (32, 96)], mcfg, ctx);
    co.add_job(1);
    co.round(pool).unwrap();
    let delta = co.export_delta(0, "mismatch").unwrap();

    let mut model = ServeModel::from_seed(serve_cfg()).unwrap();
    let err = model.apply_delta(&delta).expect_err("(32, 96) delta vs dim-64 model must fail");
    assert!(
        err.to_string().contains("serve dim"),
        "error should name the shape mismatch: {err}"
    );
    assert!(!model.has_delta(), "failed apply must not leave a partial delta");
}

/// The CI stress shape: a 64-request mixed stream on a 16-worker pool
/// with a hostile steal seed must match the solo-serial reference and
/// stay finite.  (The 1000-request point runs in the serve bench.)
#[test]
fn serve_stress_sixteen_workers() {
    let cfg = serve_cfg();
    let reqs = serve::synth_requests(cfg.vocab, 64, 17);
    let reference = ServeEngine::new(ServeModel::from_seed(cfg).unwrap(), ParallelCtx::serial());
    let want = keys(&reference.serve_sequential(&reqs).unwrap());

    let pool = WorkerPool::leaked_with_steal_seed(16, 999_331);
    let ctx = ParallelCtx::with_pool(16, pool);
    let engine = ServeEngine::new(ServeModel::from_seed(cfg).unwrap(), ctx);
    let (resps, lat) = engine.serve_batch_timed(&reqs, pool).unwrap();
    assert_eq!(keys(&resps), want, "stress batch diverged from solo-serial");
    assert_eq!(lat.len(), reqs.len());
    assert!(lat.iter().all(|ms| ms.is_finite() && *ms >= 0.0));
    for r in &resps {
        if let ServeResponse::Score { nll, pred } = r {
            assert!(nll.iter().all(|x| x.is_finite()), "non-finite NLL in stress batch");
            assert!(pred.is_some());
        }
    }
}
