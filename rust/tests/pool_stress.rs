//! Concurrency stress suite for the work-stealing execution layer.
//!
//! `tests/parity.rs` pins the *numerics* of pooled execution; this file
//! pins its *liveness and fault isolation* under the nastiest composition
//! the optimizer stack produces: many submitter threads, each running
//! nested submissions (`par_map` whose items submit their own `par_rows`
//! matmuls to the SAME pool), with panicking tasks injected mid-stream.
//! Asserted, at 1, 4, and 16 workers:
//!
//! * **No deadlock.**  The helping-submitter rule (pop own deque, then
//!   steal) must keep every latch opening even when every worker is itself
//!   blocked inside a nested submission.  The test finishing IS the assert.
//! * **Panics resurface in the correct submitter, payload intact.**  A
//!   panic travels to the latch of the submission that owns the task — not
//!   to whichever thread happened to steal and run it — and arrives with
//!   its original message.  Concurrent submitters inject distinct payloads
//!   and each must catch exactly its own.
//! * **The pool survives.**  After the storm (including every injected
//!   panic), the same pool instance still executes work and still produces
//!   bitwise-correct results.
//!
//! Worker counts below, at, and above the submitter count are all covered:
//! 1 worker forces maximal helper execution, 16 forces maximal stealing.

use std::panic::{catch_unwind, AssertUnwindSafe};

use qgalore::coordinator::{HostDataflowTrainer, HostMethod, HostStepConfig};
use qgalore::linalg::{engine, par_map, Mat, ParallelCtx, WorkerPool};
use qgalore::scheduler::SchedulerConfig;
use qgalore::util::Pcg32;

const SUBMITTERS: usize = 8;
const ITERS: usize = 12;
const OUTER_ITEMS: usize = 6;

/// The full storm against one pool instance.
fn stress_on(pool: &'static WorkerPool, workers: usize) {
    let mut rng = Pcg32::seeded(900 + workers as u64);
    // small shapes: the point is scheduling pressure, not arithmetic
    let a = Mat::randn(48, 32, &mut rng);
    let b = Mat::randn(32, 24, &mut rng);
    let want = engine::matmul_ungated(&a, &b, ParallelCtx::serial());

    std::thread::scope(|s| {
        for ti in 0..SUBMITTERS {
            let (a, b, want) = (&a, &b, &want);
            s.spawn(move || {
                // nested shape from the galore wave scheduler: outer fan-out
                // over layers, each layer submitting its own matmul tasks
                let outer = ParallelCtx::with_pool(4, pool);
                let inner = ParallelCtx::with_pool(2, pool);
                let items: Vec<usize> = (0..OUTER_ITEMS).collect();
                for it in 0..ITERS {
                    if (ti + it) % 4 == 0 {
                        // panic injection: one outer item blows up while its
                        // siblings (and 7 other submitters) keep computing
                        let msg = format!("injected-{ti}-{it}");
                        let result = catch_unwind(AssertUnwindSafe(|| {
                            par_map(outer, &items, |&i| {
                                if i == 3 {
                                    panic!("{msg}");
                                }
                                engine::matmul_ungated(a, b, inner)
                            })
                        }));
                        let payload = result.expect_err("injected panic must resurface");
                        let text = payload
                            .downcast_ref::<String>()
                            .cloned()
                            .unwrap_or_else(|| "<non-string payload>".into());
                        assert_eq!(
                            text, msg,
                            "panic payload crossed submitters (workers={workers})"
                        );
                    } else {
                        let results =
                            par_map(outer, &items, |_| engine::matmul_ungated(a, b, inner));
                        for (ii, r) in results.iter().enumerate() {
                            assert_eq!(
                                r.data, want.data,
                                "item {ii} diverged under stress \
                                 (workers={workers}, submitter={ti}, iter={it})"
                            );
                        }
                    }
                }
            });
        }
    });

    // the pool is still alive and still bitwise-correct after the storm
    for t in [2usize, 4, 8] {
        let got = engine::matmul_ungated(&a, &b, ParallelCtx::with_pool(t, pool));
        assert_eq!(got.data, want.data, "pool unusable after stress (t={t})");
    }
}

#[test]
fn stress_1_worker() {
    stress_on(WorkerPool::leaked(1), 1);
}

#[test]
fn stress_4_workers() {
    stress_on(WorkerPool::leaked(4), 4);
}

#[test]
fn stress_16_workers() {
    stress_on(WorkerPool::leaked(16), 16);
}

#[test]
fn stress_16_workers_forced_hostile_steal_seeds() {
    // the Chase-Lev satellite case: the same storm at 16 workers, but with
    // the victim-choice PCG stream pinned to adversarial seeds (the
    // in-process form of QGALORE_STEAL_SEED).  Liveness, panic routing,
    // and bitwise results must all survive any steal order the seed buys.
    for seed in [0xDEAD_BEEFu64, u64::MAX] {
        stress_on(WorkerPool::leaked_with_steal_seed(16, seed), 16);
    }
}

#[test]
fn deep_nesting_on_a_tiny_pool_does_not_deadlock() {
    // three levels of nested submission on a 2-worker pool: par_map ->
    // par_map -> par_rows(matmul).  Every worker spends most of its life
    // blocked inside an inner latch; only helping keeps the system live.
    let pool: &'static WorkerPool = WorkerPool::leaked(2);
    let ctx = ParallelCtx::with_pool(3, pool);
    let mut rng = Pcg32::seeded(77);
    let a = Mat::randn(24, 24, &mut rng);
    let b = Mat::randn(24, 24, &mut rng);
    let want = engine::matmul_ungated(&a, &b, ParallelCtx::serial());
    let outer_items: Vec<usize> = (0..4).collect();
    let inner_items: Vec<usize> = (0..3).collect();
    let nested = par_map(ctx, &outer_items, |_| {
        par_map(ctx, &inner_items, |_| engine::matmul_ungated(&a, &b, ctx))
    });
    for level in nested {
        for r in level {
            assert_eq!(r.data, want.data, "deep nesting corrupted a result");
        }
    }
}

#[test]
fn panic_in_nested_inner_submission_reaches_the_outer_submitter() {
    // the panic fires two latch levels down (inside an inner par_map task
    // launched from an outer par_map task); it must still unwind cleanly
    // to THIS thread with the payload intact, and the pool must survive
    let pool: &'static WorkerPool = WorkerPool::leaked(4);
    let ctx = ParallelCtx::with_pool(4, pool);
    let outer_items: Vec<usize> = (0..4).collect();
    let inner_items: Vec<usize> = (0..4).collect();
    let result = catch_unwind(AssertUnwindSafe(|| {
        par_map(ctx, &outer_items, |&o| {
            par_map(ctx, &inner_items, |&i| {
                if o == 2 && i == 1 {
                    panic!("nested boom");
                }
                o * 10 + i
            })
        })
    }));
    let payload = result.expect_err("nested panic must resurface");
    assert_eq!(
        payload.downcast_ref::<&str>().copied().unwrap_or(""),
        "nested boom",
        "nested panic payload mangled"
    );
    // pool still usable
    let items: Vec<usize> = (0..8).collect();
    let doubled = par_map(ctx, &items, |&x| x * 2);
    assert_eq!(doubled, items.iter().map(|x| x * 2).collect::<Vec<_>>());
}

#[test]
fn dataflow_fault_injection_panic_resurfaces_and_pool_survives() {
    // a panic inside ONE layer's chain of the dataflow step graph must
    // surface as that step's Err (not poison the process or a worker),
    // the step counter must not advance, the pool must stay live and
    // bitwise-correct, and a FRESH trainer on the same pool must still
    // match the sequential reference exactly
    let pool: &'static WorkerPool = WorkerPool::leaked(4);
    let ctx = ParallelCtx::with_pool(4, pool);
    let shapes = [(16usize, 12usize), (16, 12), (12, 10), (12, 10)];
    let cfg = HostStepConfig {
        method: HostMethod::Galore,
        rank: 2,
        sched: SchedulerConfig { base_interval: 2, ..SchedulerConfig::default() },
        seed: 33,
        ..HostStepConfig::default()
    };

    // fault in a NON-DUE layer chain (at interval 2, nothing is due at
    // step 1: the fused grad->update node panics)
    let mut tr = HostDataflowTrainer::new(&shapes, cfg);
    tr.fail_at = Some((1, 2));
    tr.step_dataflow(ctx, pool).expect("step 0 must run clean");
    let err = tr.step_dataflow(ctx, pool).expect_err("injected fault must surface");
    assert!(
        err.to_string().contains("injected dataflow fault at layer 2"),
        "fault payload mangled: {err}"
    );
    assert_eq!(tr.current_step(), 1, "failed step must not advance the counter");

    // fault in a DUE layer's refresh+update node (step 2: every layer is
    // due again, so the panic fires downstream of a wave basis node)
    let mut tr2 = HostDataflowTrainer::new(&shapes, cfg);
    tr2.fail_at = Some((2, 1));
    for _ in 0..2 {
        tr2.step_dataflow(ctx, pool).expect("steps before the fault run clean");
    }
    let err2 = tr2.step_dataflow(ctx, pool).expect_err("due-chain fault must surface");
    assert!(
        err2.to_string().contains("injected dataflow fault at layer 1"),
        "due-chain fault payload mangled: {err2}"
    );

    // the pool survives: still alive and bitwise-correct
    let mut rng = Pcg32::seeded(123);
    let a = Mat::randn(48, 32, &mut rng);
    let b = Mat::randn(32, 24, &mut rng);
    let want = engine::matmul_ungated(&a, &b, ParallelCtx::serial());
    assert_eq!(engine::matmul_ungated(&a, &b, ctx).data, want.data, "pool unusable after fault");

    // and a fresh trainer on the same pool still matches the sequential
    // reference bit for bit — the aborted graph left no residue
    let mut seq = HostDataflowTrainer::new(&shapes, cfg);
    let mut df = HostDataflowTrainer::new(&shapes, cfg);
    for s in 0..3 {
        let a = seq.step_sequential(ParallelCtx::serial());
        let b = df.step_dataflow(ctx, pool).expect("clean trainer must step");
        assert_eq!(a.to_bits(), b.to_bits(), "post-fault trainer diverged at step {s}");
    }
    assert_eq!(seq.export_weights(), df.export_weights(), "post-fault weights diverged");
}
