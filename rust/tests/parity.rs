//! Parity suite for the blocked/parallel linalg engine and the fused
//! dequantize-matmul paths: every fast kernel must agree with the naive
//! single-threaded reference to <= 1e-5 rel-Frobenius across awkward shapes
//! (non-multiples of the block size, degenerate 1x1) and thread counts
//! 1/2/8. The engine preserves the reference's ascending-k accumulation
//! order, so the observed error is in fact 0 — the tolerance guards future
//! kernel rewrites that reorder arithmetic.
//!
//! The microkernel sweep (`microkernel_*`, `fused_dequant_bitwise_*`)
//! asserts the register-blocked kernel's stronger contract directly: for
//! every (m, n) tail class up to two MRxNR register tiles, k values
//! straddling the KC stripe boundary, every kernel body (AVX-512 / AVX2 /
//! portable / the autovec baseline) and 1/2/8 workers, results are BITWISE
//! equal to the naive reference — the fused INT4/INT8/2-bit paths are
//! bitwise equal to dequantize-then-reference, nibble tails included, and
//! every `*_prepacked` path is bitwise equal to its fused twin.
//!
//! The persistent worker-pool tests at the bottom assert the analogous
//! pool contract: results are BITWISE equal to serial for any pool size
//! (1/2/8 workers), across pool reuse, under concurrent submission from
//! several caller threads, and through the shape-batched subspace refresh.

use qgalore::linalg::{
    engine, left_subspace_batched, left_subspace_with, KernelPath, Mat, ParallelCtx, WorkerPool,
};
use qgalore::quant;
use qgalore::util::Pcg32;

const THREADS: [usize; 3] = [1, 2, 8];
const TOL: f32 = 1e-5;

/// Every explicit kernel body this machine can run (Simd only where the
/// CPU has avx2+fma; Autovec is the PR-1/2 baseline).  Simd512 is always
/// included: without avx512f (or on an old toolchain) it degrades to the
/// portable NR=16 body inside the dispatch, which must ALSO be bitwise.
fn kernel_paths() -> Vec<KernelPath> {
    let mut v = vec![KernelPath::Portable, KernelPath::Autovec, KernelPath::Simd512];
    if qgalore::linalg::simd_kernel_available() {
        v.push(KernelPath::Simd);
    }
    v
}

fn rel_frob(got: &Mat, want: &Mat) -> f32 {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols));
    got.rel_frobenius(want)
}

#[test]
fn matmul_parity_across_shapes_and_threads() {
    let mut rng = Pcg32::seeded(100);
    for (m, k, n) in [
        (1usize, 1usize, 1usize),
        (7, 13, 13),
        (13, 7, 3),
        (64, 64, 64),
        (129, 257, 63),
        (257, 129, 129),
    ] {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let want = a.matmul_naive(&b);
        for t in THREADS {
            let got = a.matmul_with(&b, ParallelCtx::new(t));
            let err = rel_frob(&got, &want);
            assert!(err <= TOL, "matmul {m}x{k}x{n} threads={t}: rel err {err}");
        }
    }
}

#[test]
fn t_matmul_parity_across_shapes_and_threads() {
    let mut rng = Pcg32::seeded(101);
    for (k, m, n) in [
        (1usize, 1usize, 1usize),
        (13, 7, 5),
        (7, 13, 13),
        (64, 64, 64),
        (257, 129, 65),
        (129, 257, 31),
    ] {
        let a = Mat::randn(k, m, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let want = a.t_matmul_naive(&b);
        for t in THREADS {
            let got = a.t_matmul_with(&b, ParallelCtx::new(t));
            let err = rel_frob(&got, &want);
            assert!(err <= TOL, "t_matmul {k}x{m}x{n} threads={t}: rel err {err}");
        }
    }
}

#[test]
fn default_matmul_matches_naive() {
    // the convenience Mat::matmul / Mat::t_matmul (global ctx) are the same
    // kernels — spot-check them on a large-ish shape
    let mut rng = Pcg32::seeded(102);
    let a = Mat::randn(129, 96, &mut rng);
    let b = Mat::randn(96, 71, &mut rng);
    assert!(rel_frob(&a.matmul(&b), &a.matmul_naive(&b)) <= TOL);
    let c = Mat::randn(96, 55, &mut rng);
    let d = Mat::randn(96, 33, &mut rng);
    assert!(rel_frob(&c.t_matmul(&d), &c.t_matmul_naive(&d)) <= TOL);
}

#[test]
fn dequant8_matmul_parity() {
    let mut rng = Pcg32::seeded(103);
    // numel constraint: < 256 (single block) or a multiple of 256
    for (m, k, n) in [(1usize, 1usize, 1usize), (7, 13, 9), (64, 64, 31), (128, 256, 65)] {
        let w = quant::quantize(&rng.normal_vec(m * k, 0.0, 1.0), 8);
        let x = Mat::randn(k, n, &mut rng);
        let want = Mat::from_vec(m, k, quant::dequantize(&w)).matmul_naive(&x);
        for t in THREADS {
            let got = quant::dequant8_matmul(&w, m, k, &x, ParallelCtx::new(t));
            let err = rel_frob(&got, &want);
            assert!(err <= TOL, "dequant8_matmul {m}x{k}x{n} threads={t}: {err}");
        }
    }
}

#[test]
fn dequant4_matmul_parity() {
    let mut rng = Pcg32::seeded(104);
    for (m, k, n) in [(1usize, 1usize, 1usize), (7, 13, 9), (64, 64, 31), (128, 256, 65)] {
        let p = quant::quantize4(&rng.normal_vec(m * k, 0.0, 0.25));
        let x = Mat::randn(k, n, &mut rng);
        let want = Mat::from_vec(m, k, quant::dequantize4(&p)).matmul_naive(&x);
        for t in THREADS {
            let got = quant::dequant4_matmul(&p, m, k, &x, ParallelCtx::new(t));
            let err = rel_frob(&got, &want);
            assert!(err <= TOL, "dequant4_matmul {m}x{k}x{n} threads={t}: {err}");
        }
    }
}

#[test]
fn dequant4_t_matmul_parity() {
    let mut rng = Pcg32::seeded(105);
    for (m, r, n) in [(1usize, 1usize, 1usize), (13, 7, 9), (64, 16, 31), (256, 64, 65)] {
        let p = quant::quantize4(&rng.normal_vec(m * r, 0.0, 0.25));
        let x = Mat::randn(m, n, &mut rng);
        let want = Mat::from_vec(m, r, quant::dequantize4(&p)).t_matmul_naive(&x);
        for t in THREADS {
            let got = quant::dequant4_t_matmul(&p, m, r, &x, ParallelCtx::new(t));
            let err = rel_frob(&got, &want);
            assert!(err <= TOL, "dequant4_t_matmul {m}x{r}x{n} threads={t}: {err}");
        }
    }
}

#[test]
fn randomized_parity_property() {
    // 60 random shapes x 3 thread counts, including shapes straddling the
    // parallelism threshold, all within tolerance of the references
    let mut rng = Pcg32::seeded(106);
    for case in 0..60u64 {
        let m = 1 + rng.below(150);
        let k = 1 + rng.below(150);
        let n = 1 + rng.below(150);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let want = a.matmul_naive(&b);
        let at = Mat::randn(k, m, &mut rng);
        let want_t = at.t_matmul_naive(&b);
        for t in THREADS {
            let ctx = ParallelCtx::new(t);
            assert!(
                rel_frob(&engine::matmul(&a, &b, ctx), &want) <= TOL,
                "case {case} matmul {m}x{k}x{n} t={t}"
            );
            assert!(
                rel_frob(&engine::t_matmul(&at, &b, ctx), &want_t) <= TOL,
                "case {case} t_matmul {k}x{m}x{n} t={t}"
            );
        }
    }
}

#[test]
fn microkernel_shape_sweep_bitwise() {
    // The microkernel acceptance sweep: EVERY (m % MR, n % NR) tail class
    // up to two register tiles (m in 1..=2*MR+1, n in 1..=2*NR+1), crossed
    // with k values straddling the KC=256 stripe boundary, on every kernel
    // body this machine has, at 1/2/8 workers — all bitwise equal to the
    // naive reference.
    let ks = [1usize, 2, 3, 7, 8, 255, 256, 257, 513];
    for path in kernel_paths() {
        let mut rng = Pcg32::seeded(300);
        for m in 1..=9usize {
            for n in 1..=17usize {
                for &k in &ks {
                    let a = Mat::randn(m, k, &mut rng);
                    let b = Mat::randn(k, n, &mut rng);
                    let want = a.matmul_naive(&b);
                    for t in THREADS {
                        let got = engine::matmul_with_kernel(&a, &b, ParallelCtx::new(t), path);
                        assert_eq!(
                            got.data, want.data,
                            "{path:?} matmul {m}x{k}x{n} t={t} not bitwise"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn microkernel_t_matmul_shape_sweep_bitwise() {
    // same sweep through the transposed-panel path (bounded sub-panel
    // transposes feeding the same microkernel)
    let ks = [1usize, 2, 3, 7, 8, 255, 256, 257, 513];
    for path in kernel_paths() {
        let mut rng = Pcg32::seeded(301);
        for m in 1..=9usize {
            for n in 1..=17usize {
                for &k in &ks {
                    let a = Mat::randn(k, m, &mut rng);
                    let b = Mat::randn(k, n, &mut rng);
                    let want = a.t_matmul_naive(&b);
                    for t in THREADS {
                        let got =
                            engine::t_matmul_with_kernel(&a, &b, ParallelCtx::new(t), path);
                        assert_eq!(
                            got.data, want.data,
                            "{path:?} t_matmul {k}x{m}x{n} t={t} not bitwise"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn microkernel_larger_shapes_bitwise_across_paths() {
    // multi-tile interiors plus tails, larger than the sweep's 2-tile
    // bound: every path must agree with the reference AND each other.
    // n = 31/32/33 straddle the Simd512 NR=16 tile boundary at two tiles
    // (the 1..=17 sweep above already covers every n % 16 tail class once)
    let mut rng = Pcg32::seeded(302);
    for (m, k, n) in [
        (33usize, 129usize, 47usize),
        (64, 300, 64),
        (129, 513, 65),
        (64, 300, 31),
        (40, 257, 32),
        (96, 200, 33),
    ] {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let want = a.matmul_naive(&b);
        for path in kernel_paths() {
            for t in THREADS {
                let got = engine::matmul_with_kernel(&a, &b, ParallelCtx::new(t), path);
                assert_eq!(got.data, want.data, "{path:?} {m}x{k}x{n} t={t}");
            }
        }
    }
}

#[test]
fn fused_dequant_bitwise_vs_unfused() {
    // The fused INT4/INT8 paths dequantize row-group (or transposed
    // column) panels into scratch and feed the microkernel: outputs must
    // equal dequantize-then-reference-matmul BIT FOR BIT, including
    // odd-column shapes whose INT4 rows start mid-byte (nibble tails).
    // numel must be < 256 (single block) or a multiple of 256.
    let mut rng = Pcg32::seeded(303);
    for (m, c, n) in [
        (1usize, 1usize, 1usize),
        (5, 7, 9),
        (3, 33, 5),    // odd cols, single block
        (9, 21, 17),   // odd cols, crosses a row-tile boundary
        (256, 3, 9),   // odd cols, multi-block, many row tiles
        (64, 64, 33),
        (128, 256, 65),
    ] {
        let raw = rng.normal_vec(m * c, 0.0, 0.3);
        let p4 = quant::quantize4(&raw);
        let w8 = quant::quantize(&raw, 8);
        let x = Mat::randn(c, n, &mut rng);
        let want4 = Mat::from_vec(m, c, quant::dequantize4(&p4)).matmul_naive(&x);
        let want8 = Mat::from_vec(m, c, quant::dequantize(&w8)).matmul_naive(&x);
        let xt = Mat::randn(m, n, &mut rng);
        let want4t = Mat::from_vec(m, c, quant::dequantize4(&p4)).t_matmul_naive(&xt);
        let want8t = Mat::from_vec(m, c, quant::dequantize(&w8)).t_matmul_naive(&xt);
        for t in THREADS {
            let ctx = ParallelCtx::new(t);
            assert_eq!(
                quant::dequant4_matmul(&p4, m, c, &x, ctx).data,
                want4.data,
                "dequant4_matmul {m}x{c}x{n} t={t} not bitwise"
            );
            assert_eq!(
                quant::dequant8_matmul(&w8, m, c, &x, ctx).data,
                want8.data,
                "dequant8_matmul {m}x{c}x{n} t={t} not bitwise"
            );
            assert_eq!(
                quant::dequant4_t_matmul(&p4, m, c, &xt, ctx).data,
                want4t.data,
                "dequant4_t_matmul {m}x{c}x{n} t={t} not bitwise"
            );
            assert_eq!(
                quant::dequant8_t_matmul(&w8, m, c, &xt, ctx).data,
                want8t.data,
                "dequant8_t_matmul {m}x{c}x{n} t={t} not bitwise"
            );
        }
    }
}

#[test]
fn prepacked_bitwise_vs_fused_across_tail_classes() {
    // Every *_prepacked entry point against its fused per-call-decode
    // twin, across the same tail-class sweep (odd INT4 nibble tails, odd
    // 2-bit tails, row-tile crossings, multi-block shapes): a PanelPack
    // built once at "refresh" must yield BITWISE the fused path's output
    // for every format, orientation, and worker count.
    let mut rng = Pcg32::seeded(304);
    for (m, c, n) in [
        (1usize, 1usize, 1usize),
        (5, 7, 9),
        (3, 33, 5),    // odd cols, single block
        (9, 21, 17),   // odd cols, crosses a row-tile boundary
        (256, 3, 9),   // odd cols, multi-block, many row tiles
        (64, 64, 33),
        (128, 256, 65),
    ] {
        let raw = rng.normal_vec(m * c, 0.0, 0.3);
        let p4 = quant::quantize4(&raw);
        let w8 = quant::quantize(&raw, 8);
        let p2 = quant::quantize2(&raw);
        let pk4 = qgalore::linalg::PanelPack::pack4(&p4, m, c);
        let pk8 = qgalore::linalg::PanelPack::pack8(&w8, m, c);
        let pk2 = qgalore::linalg::PanelPack::pack2(&p2, m, c);
        let x = Mat::randn(c, n, &mut rng);
        let xt = Mat::randn(m, n, &mut rng);
        for t in THREADS {
            let ctx = ParallelCtx::new(t);
            assert_eq!(
                quant::dequant4_matmul_prepacked(&p4, &pk4, m, c, &x, ctx).data,
                quant::dequant4_matmul(&p4, m, c, &x, ctx).data,
                "dequant4_matmul_prepacked {m}x{c}x{n} t={t} not bitwise"
            );
            assert_eq!(
                quant::dequant4_t_matmul_prepacked(&p4, &pk4, m, c, &xt, ctx).data,
                quant::dequant4_t_matmul(&p4, m, c, &xt, ctx).data,
                "dequant4_t_matmul_prepacked {m}x{c}x{n} t={t} not bitwise"
            );
            assert_eq!(
                quant::dequant8_matmul_prepacked(&w8, &pk8, m, c, &x, ctx).data,
                quant::dequant8_matmul(&w8, m, c, &x, ctx).data,
                "dequant8_matmul_prepacked {m}x{c}x{n} t={t} not bitwise"
            );
            assert_eq!(
                quant::dequant8_t_matmul_prepacked(&w8, &pk8, m, c, &xt, ctx).data,
                quant::dequant8_t_matmul(&w8, m, c, &xt, ctx).data,
                "dequant8_t_matmul_prepacked {m}x{c}x{n} t={t} not bitwise"
            );
            assert_eq!(
                quant::dequant2_matmul_prepacked(&p2, &pk2, m, c, &x, ctx).data,
                quant::dequant2_matmul(&p2, m, c, &x, ctx).data,
                "dequant2_matmul_prepacked {m}x{c}x{n} t={t} not bitwise"
            );
            assert_eq!(
                quant::dequant2_t_matmul_prepacked(&p2, &pk2, m, c, &xt, ctx).data,
                quant::dequant2_t_matmul(&p2, m, c, &xt, ctx).data,
                "dequant2_t_matmul_prepacked {m}x{c}x{n} t={t} not bitwise"
            );
        }
    }
}

#[test]
fn pool_bitwise_identity_and_reuse() {
    // one pool instance per size, REUSED across many calls and shapes: the
    // pool-executed decomposition must match serial bit for bit.
    // matmul_ungated bypasses the PAR_MIN_FLOPS serial gate, so even the
    // small shapes genuinely exercise pool dispatch.
    let mut rng = Pcg32::seeded(200);
    let shapes = [(7usize, 13usize, 5usize), (64, 64, 64), (129, 257, 65), (33, 1, 9)];
    let mats: Vec<(Mat, Mat)> = shapes
        .iter()
        .map(|&(m, k, n)| (Mat::randn(m, k, &mut rng), Mat::randn(k, n, &mut rng)))
        .collect();
    for workers in [1usize, 2, 8] {
        let pool: &'static WorkerPool = WorkerPool::leaked(workers);
        for round in 0..10 {
            for (a, b) in &mats {
                let want = engine::matmul_ungated(a, b, ParallelCtx::serial());
                for t in [2usize, 3, 8] {
                    let got = engine::matmul_ungated(a, b, ParallelCtx::with_pool(t, pool));
                    assert_eq!(
                        got.data, want.data,
                        "pool({workers}w) t={t} round={round} not bitwise-identical"
                    );
                }
            }
        }
    }
}

#[test]
fn over_decomposition_bitwise_across_slab_counts_and_pools() {
    // the over-decomposition contract on real pools: slab multipliers from
    // 1 (the pre-rewrite decomposition) to the 64 cap change only which
    // worker computes which rows — dense, transposed, and fused INT4/INT8
    // outputs must stay bitwise identical to serial on stealing AND FIFO
    // pools, at worker counts straddling the slab count.
    let mut rng = Pcg32::seeded(210);
    // dense: odd-shaped and driven ungated so even small slabs hit the pool
    let (m, c, n) = (96usize, 64usize, 33usize);
    let a = Mat::randn(m, c, &mut rng);
    let b = Mat::randn(c, n, &mut rng);
    let at = Mat::randn(c, m, &mut rng);
    // fused: an above-PAR_MIN_FLOPS shape, since the fused paths keep their
    // serial gate and would otherwise never reach the pool here
    let (fm, fc, fn_) = (256usize, 256usize, 64usize);
    assert!(fm * fc * fn_ >= engine::PAR_MIN_FLOPS);
    let p4 = quant::quantize4(&rng.normal_vec(fm * fc, 0.0, 0.3));
    let fx = Mat::randn(fc, fn_, &mut rng);
    let fxt = Mat::randn(fm, fn_, &mut rng);
    let serial = ParallelCtx::serial();
    let want_mm = engine::matmul_ungated(&a, &b, serial);
    let want_tm = engine::t_matmul_with_kernel(&at, &b, serial, KernelPath::Auto);
    let want4 = quant::dequant4_matmul(&p4, fm, fc, &fx, serial);
    let want4t = quant::dequant4_t_matmul(&p4, fm, fc, &fxt, serial);
    let pools: [&'static WorkerPool; 2] = [WorkerPool::leaked(4), WorkerPool::leaked_fifo(4)];
    for pool in pools {
        for spw in [1usize, 2, 4, 16, 64] {
            for t in [2usize, 8] {
                let ctx = ParallelCtx::with_pool(t, pool).with_slabs_per_worker(spw);
                assert_eq!(
                    engine::matmul_ungated(&a, &b, ctx).data,
                    want_mm.data,
                    "matmul t={t} spw={spw} ({}) not bitwise",
                    pool.kind()
                );
                assert_eq!(
                    engine::t_matmul_with_kernel(&at, &b, ctx, KernelPath::Auto).data,
                    want_tm.data,
                    "t_matmul t={t} spw={spw} ({}) not bitwise",
                    pool.kind()
                );
                assert_eq!(
                    quant::dequant4_matmul(&p4, fm, fc, &fx, ctx).data,
                    want4.data,
                    "dequant4_matmul t={t} spw={spw} ({}) not bitwise",
                    pool.kind()
                );
                assert_eq!(
                    quant::dequant4_t_matmul(&p4, fm, fc, &fxt, ctx).data,
                    want4t.data,
                    "dequant4_t_matmul t={t} spw={spw} ({}) not bitwise",
                    pool.kind()
                );
            }
        }
    }
}

#[test]
fn pool_concurrent_submission_from_many_callers() {
    let pool: &'static WorkerPool = WorkerPool::leaked(4);
    let mut rng = Pcg32::seeded(201);
    let a = Mat::randn(96, 64, &mut rng);
    let b = Mat::randn(64, 48, &mut rng);
    let want = engine::matmul_ungated(&a, &b, ParallelCtx::serial());
    std::thread::scope(|s| {
        for _ in 0..6 {
            s.spawn(|| {
                for t in [2usize, 4, 8] {
                    let got = engine::matmul_ungated(&a, &b, ParallelCtx::with_pool(t, pool));
                    assert_eq!(got.data, want.data, "concurrent submission diverged");
                }
            });
        }
    });
}

#[test]
fn pool_nested_submission_does_not_deadlock() {
    // the galore wave shape: an outer par_map whose tasks submit their own
    // inner matmuls to the SAME (smaller) pool.  The helping submitter is
    // what makes this safe; this test is the deadlock regression guard.
    let pool: &'static WorkerPool = WorkerPool::leaked(2);
    let outer = ParallelCtx::with_pool(4, pool);
    let inner = ParallelCtx::with_pool(2, pool);
    let mut rng = Pcg32::seeded(203);
    let a = Mat::randn(40, 40, &mut rng);
    let b = Mat::randn(40, 40, &mut rng);
    let want = engine::matmul_ungated(&a, &b, ParallelCtx::serial());
    let items: Vec<usize> = (0..8).collect();
    let results =
        qgalore::linalg::par_map(outer, &items, |_| engine::matmul_ungated(&a, &b, inner));
    for r in results {
        assert_eq!(r.data, want.data);
    }
}

#[test]
fn batched_refresh_matches_per_layer_bitwise() {
    // the left_subspace_batched contract: stacked (L*m, n) refresh produces
    // projections bitwise identical to L separate refreshes sharing the
    // same sketch rng, at every thread count
    let mut rng = Pcg32::seeded(202);
    let gs: Vec<Mat> = (0..5).map(|_| Mat::randn(48, 96, &mut rng)).collect();
    let grefs: Vec<&Mat> = gs.iter().collect();
    for t in [1usize, 2, 8] {
        let mut batch_rng = Pcg32::seeded(9);
        let batched = left_subspace_batched(&grefs, 8, 2, &mut batch_rng, ParallelCtx::new(t));
        assert_eq!(batched.len(), gs.len());
        for (li, (g, got)) in gs.iter().zip(&batched).enumerate() {
            let mut solo_rng = Pcg32::seeded(9);
            let want = left_subspace_with(g, 8, 2, &mut solo_rng, ParallelCtx::serial());
            assert_eq!(got.data, want.data, "layer {li} diverged from solo refresh (t={t})");
        }
    }
}

#[test]
fn left_subspace_identical_across_thread_counts() {
    // the subspace refresh must not depend on worker count: same seed, same
    // basis, bit for bit
    let mut rng = Pcg32::seeded(107);
    let g = Mat::randn(96, 128, &mut rng);
    let mut r1 = Pcg32::seeded(1);
    let mut r2 = Pcg32::seeded(1);
    let mut r8 = Pcg32::seeded(1);
    let q1 = qgalore::linalg::left_subspace_with(&g, 16, 2, &mut r1, ParallelCtx::new(1));
    let q2 = qgalore::linalg::left_subspace_with(&g, 16, 2, &mut r2, ParallelCtx::new(2));
    let q8 = qgalore::linalg::left_subspace_with(&g, 16, 2, &mut r8, ParallelCtx::new(8));
    assert_eq!(q1.data, q2.data, "thread count changed the refreshed basis");
    assert_eq!(q1.data, q8.data, "thread count changed the refreshed basis");
}
