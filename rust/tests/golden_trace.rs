//! Golden-trace end-to-end determinism test.
//!
//! Unit parity (tests/parity.rs) checks single kernel calls; a kernel
//! regression can still hide in the *composition* — scratch reuse across
//! calls, the += accumulate contract, state threaded through many steps.
//! This test runs a tiny fixed-seed Q-GaLore-style training loop entirely
//! host-side (least squares + INT4-projected momentum SGD, so no XLA
//! artifacts are needed) and asserts the per-step loss trace is BITWISE
//! stable:
//!
//! * across worker counts (1 vs 4 vs 8) — the `--threads` contract;
//! * across over-decomposition slab multipliers (1 slab/worker up to the
//!   64 cap) — the `QGALORE_SLABS_PER_WORKER` contract;
//! * across kernel bodies (AVX-512 / AVX2 / portable / the autovec
//!   baseline) via the process-global [`engine::set_kernel_override`] hook;
//! * across the work-stealing pool at 1/4/8/16 workers and under hostile
//!   victim-choice seeds (explicit + the `QGALORE_STEAL_SEED` env knob) —
//!   the bits cannot depend on which thread stole which task when;
//! * with the projection panel cache on vs off (prepacked application vs
//!   per-call fused decode) — the `QGALORE_PACK_CACHE` contract.
//!
//! The problem sizes are chosen so the forward/gradient products sit ABOVE
//! `PAR_MIN_FLOPS` (the parallel paths genuinely run) while the projection
//! products sit below it (the serial gate is exercised in the same trace).

use qgalore::coordinator::{HostDataflowTrainer, HostMethod, HostStepConfig};
use qgalore::linalg::{
    engine, left_subspace_with, set_pack_cache, KernelPath, Mat, PanelPack, ParallelCtx,
    WorkerPool, STEAL_SEED_ENV,
};
use qgalore::quant;
use qgalore::scheduler::SchedulerConfig;
use qgalore::util::Pcg32;

const STEPS: usize = 10;
const REFRESH_EVERY: usize = 4;
/// 128^3 = 2 * PAR_MIN_FLOPS fma per dense product: the fan-out is real.
const DIM: usize = 128;
const RANK: usize = 16;

/// One fixed-seed training run; returns the per-step loss trace as raw f32
/// bit patterns (bitwise comparison, not tolerance).
fn train_trace(ctx: ParallelCtx) -> Vec<u32> {
    train_trace_impl(ctx, false)
}

/// The same run applying the projection through an explicit [`PanelPack`]
/// built at each refresh (the panel-cache steady state): must be bitwise
/// identical to the per-call fused trace.
fn train_trace_packed(ctx: ParallelCtx) -> Vec<u32> {
    train_trace_impl(ctx, true)
}

fn train_trace_impl(ctx: ParallelCtx, use_pack: bool) -> Vec<u32> {
    let mut rng = Pcg32::seeded(77);
    // fixed data, built serially so the trace alone reflects `ctx`
    let x = Mat::randn(DIM, DIM, &mut rng);
    let w_true = Mat::randn(DIM, DIM, &mut rng);
    let y = x.matmul_with(&w_true, ParallelCtx::serial());

    let mut w = Mat::zeros(DIM, DIM);
    let mut p4: Option<quant::Quant4Tensor> = None;
    let mut pack: Option<PanelPack> = None;
    let mut momentum = Mat::zeros(RANK, DIM);
    let mut sketch_rng = Pcg32::seeded(123);
    let lr = 1.0 / (4.0 * DIM as f32);
    let mut trace = Vec::with_capacity(STEPS);
    for step in 0..STEPS {
        // forward + loss on the full batch
        let pred = x.matmul_with(&w, ctx);
        let err = pred.sub(&y);
        let loss = err.data.iter().map(|e| e * e).sum::<f32>() / err.data.len() as f32;
        trace.push(loss.to_bits());
        // gradient G = X^T E
        let g = x.t_matmul_with(&err, ctx);
        // periodic subspace refresh -> INT4-quantized projection (the
        // Q-GaLore storage format)
        if step % REFRESH_EVERY == 0 {
            let p = left_subspace_with(&g, RANK, 2, &mut sketch_rng, ctx);
            let q = quant::quantize4(&p.data);
            pack = use_pack.then(|| PanelPack::pack4(&q, DIM, RANK));
            p4 = Some(q);
            // momentum lives in projected coordinates; a new basis means a
            // fresh accumulator
            momentum = Mat::zeros(RANK, DIM);
        }
        let proj = p4.as_ref().expect("projection refreshed at step 0");
        // low-rank step: R = P^T G, EMA momentum, U = P M, W -= lr U —
        // both projection products run fused from INT4 storage, or through
        // the refresh-time panel pack in the packed variant
        let r = match &pack {
            Some(pk) => quant::dequant4_t_matmul_prepacked(proj, pk, DIM, RANK, &g, ctx),
            None => quant::dequant4_t_matmul(proj, DIM, RANK, &g, ctx),
        };
        for (m, rv) in momentum.data.iter_mut().zip(&r.data) {
            *m = 0.9 * *m + 0.1 * rv;
        }
        let u = match &pack {
            Some(pk) => quant::dequant4_matmul_prepacked(proj, pk, DIM, RANK, &momentum, ctx),
            None => quant::dequant4_matmul(proj, DIM, RANK, &momentum, ctx),
        };
        for (wv, uv) in w.data.iter_mut().zip(&u.data) {
            *wv -= lr * uv;
        }
    }
    trace
}

#[test]
fn golden_trace_locks_numerics() {
    // --- thread-count stability -------------------------------------------
    let t1 = train_trace(ParallelCtx::new(1));
    assert_eq!(t1.len(), STEPS);
    for t in [4usize, 8] {
        assert_eq!(
            train_trace(ParallelCtx::new(t)),
            t1,
            "loss trace changed between --threads 1 and --threads {t}"
        );
    }

    // --- kernel-path stability --------------------------------------------
    // All bodies are bitwise interchangeable, so flipping the process
    // override must leave the whole trace untouched.  The dataflow test in
    // this binary may run concurrently, but it relies only on the bitwise
    // interchangeability asserted here, so the flip cannot change what it
    // observes; restore the prior setting regardless.
    let prev = engine::kernel_override();
    // Simd512 is unconditional: without avx512f it degrades to the portable
    // NR=16 body inside the dispatch, which must also hold the trace bits
    let mut paths = vec![KernelPath::Portable, KernelPath::Autovec, KernelPath::Simd512];
    if engine::simd_kernel_available() {
        paths.push(KernelPath::Simd);
    }
    for path in paths {
        engine::set_kernel_override(path);
        let got = train_trace(ParallelCtx::new(4));
        engine::set_kernel_override(prev);
        assert_eq!(got, t1, "loss trace changed under kernel override {path:?}");
    }

    // --- slab-count (over-decomposition) stability ------------------------
    // par_rows/par_map cut ~slabs_per_worker slabs per budgeted worker by
    // default; the multiplier changes only who computes which rows, so the
    // whole trace must be bitwise stable from 1 slab/worker (the pre-
    // rewrite decomposition) to the 64 cap.
    for spw in [1usize, 2, 8, 64] {
        let got = train_trace(ParallelCtx::new(4).with_slabs_per_worker(spw));
        assert_eq!(got, t1, "loss trace changed at {spw} slabs per worker");
    }

    // --- stealing-pool stability ------------------------------------------
    // The work-stealing pool reorders task execution (LIFO own-pops, PCG
    // victim choice, round-robin placement), so this is the strongest form
    // of the determinism contract: the loss bits must survive any worker
    // count AND any steal interleaving.  Explicit pools, not the global
    // one, so both knobs are controlled per run.
    for workers in [1usize, 4, 8, 16] {
        let pool = WorkerPool::leaked_with_steal_seed(workers, 0xDEAD_BEEF);
        // thread budget >= 4 so a 1-worker pool still gets real dispatch
        // (a threads=1 ctx would gate to serial and never touch the pool)
        let got = train_trace(ParallelCtx::with_pool(workers.max(4), pool));
        assert_eq!(
            got, t1,
            "loss trace changed on the stealing pool at {workers} workers"
        );
    }
    // hostile steal orders: same 16-worker pool shape, adversarial
    // victim-choice seeds — if any trace bit depended on who stole what,
    // some seed here would flip it
    for seed in [1u64, u64::MAX] {
        let pool = WorkerPool::leaked_with_steal_seed(16, seed);
        let got = train_trace(ParallelCtx::with_pool(16, pool));
        assert_eq!(got, t1, "loss trace depends on steal order (seed {seed:#x})");
    }
    // and once through the env knob (what CI sets process-wide): the other
    // #[test] in this binary builds only explicit-seed pools and never
    // reads the env, so the set/restore pair cannot race it.  Restore —
    // not remove — so a CI-forced QGALORE_STEAL_SEED still governs pools
    // built after this.
    let prev_seed = std::env::var(STEAL_SEED_ENV).ok();
    std::env::set_var(STEAL_SEED_ENV, "314159");
    let pool = WorkerPool::leaked(8);
    match prev_seed {
        Some(v) => std::env::set_var(STEAL_SEED_ENV, v),
        None => std::env::remove_var(STEAL_SEED_ENV),
    }
    let got = train_trace(ParallelCtx::with_pool(8, pool));
    assert_eq!(got, t1, "loss trace changed under env-forced steal seed");

    // --- the trace is a real training signal ------------------------------
    let first = f32::from_bits(t1[0]);
    let last = f32::from_bits(t1[STEPS - 1]);
    assert!(first.is_finite() && last.is_finite(), "non-finite loss in trace");
    assert!(
        last < 0.9 * first,
        "rank-{RANK} projected training did not reduce loss ({first} -> {last})"
    );
}

/// The panel-cache golden pin: the SAME training loop applying its
/// projection through refresh-time [`PanelPack`]s must reproduce the fused
/// per-call trace bit for bit, across worker counts and hostile steal
/// seeds — and the dataflow trainer's bits must not change when the
/// process-global cache is forced off.
#[test]
fn golden_trace_panel_cache_invariant() {
    let t1 = train_trace(ParallelCtx::new(1));
    for workers in [1usize, 4, 8, 16] {
        for seed in [0xDEAD_BEEF_u64, u64::MAX] {
            let pool = WorkerPool::leaked_with_steal_seed(workers, seed);
            // budget >= 4 so a 1-worker pool still gets real dispatch
            let got = train_trace_packed(ParallelCtx::with_pool(workers.max(4), pool));
            assert_eq!(
                got, t1,
                "packed trace diverged at {workers} workers (steal seed {seed:#x})"
            );
        }
    }

    // cache ON vs OFF through the dataflow trainer (which consults the
    // process-global switch at refresh time).  Other tests in this binary
    // may run concurrently, but they rely only on the bitwise identity
    // asserted here, so the flip cannot change what they observe; restore
    // the default-on setting regardless.
    let cfg = df_config();
    let pool = WorkerPool::leaked_with_steal_seed(8, 0x00DF_5EED);
    let ctx = ParallelCtx::with_pool(8, pool);
    set_pack_cache(true);
    let mut on_tr = HostDataflowTrainer::new(&DF_SHAPES, cfg);
    let on: Vec<u32> = (0..DF_STEPS)
        .map(|_| on_tr.step_dataflow(ctx, pool).unwrap().to_bits())
        .collect();
    set_pack_cache(false);
    let mut off_tr = HostDataflowTrainer::new(&DF_SHAPES, cfg);
    let off: Vec<u32> = (0..DF_STEPS)
        .map(|_| off_tr.step_dataflow(ctx, pool).unwrap().to_bits())
        .collect();
    set_pack_cache(true);
    assert_eq!(off, on, "panel cache on/off changed the dataflow loss bits");
    assert_eq!(
        off_tr.export_weights(),
        on_tr.export_weights(),
        "panel cache on/off changed the dataflow weight bits"
    );
}

// ---------------------------------------------------------------------------
// Dataflow step graph determinism
// ---------------------------------------------------------------------------

/// Layer shapes for the dataflow golden run.  The (128, 96) group sits
/// ABOVE `PAR_MIN_FLOPS` (128*128*96 flops per grad product), so per-kernel
/// fan-out runs NESTED inside graph nodes on the same pool; the (48, 32)
/// group sits below the gate, so the serial path is exercised inside nodes
/// of the same graph.  Two shape groups also force two independent
/// shape-batched refresh waves per due step.
const DF_SHAPES: [(usize, usize); 6] =
    [(128, 96), (48, 32), (128, 96), (48, 32), (128, 96), (48, 32)];
const DF_STEPS: usize = 8;

fn df_config() -> HostStepConfig {
    HostStepConfig {
        method: HostMethod::Galore,
        rank: 8,
        lr: 0.2,
        noise_eps: 1e-3,
        // interval 3 + window 1 so refresh waves land mid-trace, not just
        // at step 0, and the adaptive doubling path runs inside the window
        sched: SchedulerConfig { base_interval: 3, window: 1, ..SchedulerConfig::default() },
        seed: 41,
    }
}

/// The strongest determinism contract in the repo: the DATAFLOW step —
/// layer chains racing on the stealing pool, shape-batched refresh waves as
/// graph nodes — must be bitwise identical to the sequential step, across
/// worker counts, hostile steal seeds, and slab multipliers.  Per-step loss
/// bits AND final weight bits are both compared.
#[test]
fn dataflow_step_graph_matches_sequential_bitwise() {
    let cfg = df_config();
    assert!(128 * 128 * 96 >= engine::PAR_MIN_FLOPS, "large group must fan out");
    assert!(48 * 48 * 32 < engine::PAR_MIN_FLOPS, "small group must stay serial-gated");

    // reference: the sequential step on the serial ctx
    let mut reference = HostDataflowTrainer::new(&DF_SHAPES, cfg);
    let want: Vec<u32> = (0..DF_STEPS)
        .map(|_| reference.step_sequential(ParallelCtx::serial()).to_bits())
        .collect();
    let want_w: Vec<u32> = reference.export_weights().iter().map(|x| x.to_bits()).collect();

    let check = |label: String, losses: Vec<u32>, trainer: &HostDataflowTrainer| {
        assert_eq!(losses, want, "loss trace diverged: {label}");
        let w: Vec<u32> = trainer.export_weights().iter().map(|x| x.to_bits()).collect();
        assert_eq!(w, want_w, "final weights diverged: {label}");
    };

    // sequential at parallel thread budgets: the refresh-wave partitioning
    // changes with the budget, the bits must not
    for t in [4usize, 8] {
        let mut tr = HostDataflowTrainer::new(&DF_SHAPES, cfg);
        let losses: Vec<u32> = (0..DF_STEPS)
            .map(|_| tr.step_sequential(ParallelCtx::new(t)).to_bits())
            .collect();
        check(format!("sequential, {t} threads"), losses, &tr);
    }

    // dataflow across worker counts (explicit steal seeds only: this test
    // must never read QGALORE_STEAL_SEED, see the env note above)
    for workers in [1usize, 4, 8, 16] {
        let pool = WorkerPool::leaked_with_steal_seed(workers, 0x00DF_5EED);
        let ctx = ParallelCtx::with_pool(workers.max(4), pool);
        let mut tr = HostDataflowTrainer::new(&DF_SHAPES, cfg);
        let losses: Vec<u32> = (0..DF_STEPS)
            .map(|_| tr.step_dataflow(ctx, pool).unwrap().to_bits())
            .collect();
        check(format!("dataflow, {workers} workers"), losses, &tr);
    }

    // hostile victim-choice seeds at 16 workers: if any bit depended on
    // which worker stole which chain when, some seed here would flip it
    for seed in [1u64, u64::MAX] {
        let pool = WorkerPool::leaked_with_steal_seed(16, seed);
        let ctx = ParallelCtx::with_pool(16, pool);
        let mut tr = HostDataflowTrainer::new(&DF_SHAPES, cfg);
        let losses: Vec<u32> = (0..DF_STEPS)
            .map(|_| tr.step_dataflow(ctx, pool).unwrap().to_bits())
            .collect();
        check(format!("dataflow, hostile steal seed {seed:#x}"), losses, &tr);
    }

    // slab multipliers: over-decomposition inside graph nodes, from 1
    // slab/worker to the 64 cap
    for spw in [1usize, 2, 8, 64] {
        let pool = WorkerPool::leaked_with_steal_seed(8, 0x00DF_5EED);
        let ctx = ParallelCtx::with_pool(8, pool).with_slabs_per_worker(spw);
        let mut tr = HostDataflowTrainer::new(&DF_SHAPES, cfg);
        let losses: Vec<u32> = (0..DF_STEPS)
            .map(|_| tr.step_dataflow(ctx, pool).unwrap().to_bits())
            .collect();
        check(format!("dataflow, {spw} slabs/worker"), losses, &tr);
    }

    // the trace is a real training signal, not a fixed point
    let first = f32::from_bits(want[0]);
    let last = f32::from_bits(want[DF_STEPS - 1]);
    assert!(first.is_finite() && last.is_finite(), "non-finite loss in dataflow trace");
    assert!(last < first, "host dataflow training did not reduce loss ({first} -> {last})");
}
