//! Integration tests over the real AOT artifacts (`make artifacts` first).
//!
//! These exercise the whole stack: manifest -> PJRT runtime -> HLO
//! executables -> optimizer state threading -> training loops, and
//! cross-check the HLO kernels against the rust host mirrors.

use qgalore::coordinator::{finetune, pretrain, FinetuneConfig, TrainConfig};
use qgalore::manifest::Manifest;
use qgalore::model::tiny_config;
use qgalore::optim::{BuildOptions, Method};
use qgalore::quant;
use qgalore::runtime::{HostTensor, Runtime};
use qgalore::scheduler::SchedulerConfig;
use qgalore::util::Pcg32;

const CFG: &str = "llama-tiny";

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e}");
            None
        }
    }
}

macro_rules! require_artifacts {
    () => {
        match manifest() {
            Some(m) => m,
            None => return,
        }
    };
}

#[test]
fn manifest_matches_model_abi() {
    let man = require_artifacts!();
    let entry = man.config(CFG).unwrap();
    let model = tiny_config(CFG).unwrap();
    let fp: Vec<(String, Vec<usize>)> = model
        .fp_params()
        .into_iter()
        .map(|p| (p.name, p.shape))
        .collect();
    let lin: Vec<(String, Vec<usize>)> = model
        .linear_params()
        .into_iter()
        .map(|p| (p.name, p.shape))
        .collect();
    assert_eq!(entry.fp_params, fp, "fp param ABI drift between python and rust");
    assert_eq!(entry.linear_params, lin, "linear param ABI drift");
    assert_eq!(entry.model.rank, model.rank);
    // init checkpoint covers exactly the ABI
    let total: usize = fp
        .iter()
        .chain(lin.iter())
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    assert_eq!(entry.init_numel, total);
}

#[test]
fn eval_fwd_on_init_is_near_uniform() {
    let man = require_artifacts!();
    let entry = man.config(CFG).unwrap();
    let init = man.load_init(CFG).unwrap();
    let mut rt = Runtime::new().unwrap();
    let eval = entry.artifacts.get("eval_fwd_fp").unwrap();
    let mut ops = Vec::new();
    let mut off = 0;
    for (_, shape) in entry.fp_params.iter().chain(entry.linear_params.iter()) {
        let n: usize = shape.iter().product();
        ops.push(HostTensor::F32(init[off..off + n].to_vec()));
        off += n;
    }
    let b = man.batch;
    let s = entry.model.max_seq_len;
    let mut rng = Pcg32::seeded(0);
    let toks: Vec<i32> =
        (0..b * s).map(|_| rng.below(entry.model.vocab_size) as i32).collect();
    let targs: Vec<i32> =
        (0..b * s).map(|_| rng.below(entry.model.vocab_size) as i32).collect();
    ops.push(HostTensor::I32(toks));
    ops.push(HostTensor::I32(targs));
    let outs = rt.execute(eval, &ops).unwrap();
    let loss = outs[0].scalar_f32().unwrap();
    let uniform = (entry.model.vocab_size as f32).ln();
    assert!((loss - uniform).abs() < 0.6, "init loss {loss} vs ln|V| {uniform}");
}

#[test]
fn fwd_bwd_loss_matches_eval_loss() {
    let man = require_artifacts!();
    let entry = man.config(CFG).unwrap();
    let init = man.load_init(CFG).unwrap();
    let mut rt = Runtime::new().unwrap();
    let mut ops = Vec::new();
    let mut off = 0;
    for (_, shape) in entry.fp_params.iter().chain(entry.linear_params.iter()) {
        let n: usize = shape.iter().product();
        ops.push(HostTensor::F32(init[off..off + n].to_vec()));
        off += n;
    }
    let b = man.batch;
    let s = entry.model.max_seq_len;
    let mut rng = Pcg32::seeded(1);
    ops.push(HostTensor::I32(
        (0..b * s).map(|_| rng.below(entry.model.vocab_size) as i32).collect(),
    ));
    ops.push(HostTensor::I32(
        (0..b * s).map(|_| rng.below(entry.model.vocab_size) as i32).collect(),
    ));
    let eval_loss = rt
        .execute(entry.artifacts.get("eval_fwd_fp").unwrap(), &ops)
        .unwrap()[0]
        .scalar_f32()
        .unwrap();
    let outs = rt
        .execute(entry.artifacts.get("fwd_bwd_fp").unwrap(), &ops)
        .unwrap();
    let fwd_loss = outs[0].scalar_f32().unwrap();
    assert!((eval_loss - fwd_loss).abs() < 1e-4, "{eval_loss} vs {fwd_loss}");
    // gradients present and finite
    assert_eq!(outs.len(), 1 + entry.fp_params.len() + entry.linear_params.len());
    for g in &outs[1..] {
        assert!(g.as_f32().unwrap().iter().all(|x| x.is_finite()));
    }
}

#[test]
fn adam8bit_artifact_matches_host_mirror() {
    let man = require_artifacts!();
    let mut rt = Runtime::new().unwrap();
    let numel = 1024usize;
    let spec = man.update(&format!("adam8bit_step_{numel}")).unwrap();
    let mut rng = Pcg32::seeded(2);
    let g = rng.normal_vec(numel, 0.0, 0.3);
    let w = rng.normal_vec(numel, 0.0, 1.0);
    let mut host_state = quant::Adam8State::zeros(numel);
    let (c1, c2) = (10.0f32, 1000.0f32);
    let lr = 0.01f32;

    let outs = rt
        .execute(
            spec,
            &[
                HostTensor::F32(g.clone()),
                HostTensor::I8(host_state.mq.clone()),
                HostTensor::F32(host_state.ms.clone()),
                HostTensor::U8(host_state.vq.clone()),
                HostTensor::F32(host_state.vs.clone()),
                HostTensor::F32(w.clone()),
                HostTensor::F32(vec![c1, c2]),
                HostTensor::F32(vec![lr]),
            ],
        )
        .unwrap();
    let w_hlo = outs[0].as_f32().unwrap();
    let up_host =
        quant::adam8_step_host(&g, &mut host_state, c1, c2, 0.9, 0.999, 1e-8);
    for i in 0..numel {
        let w_host = w[i] - lr * up_host[i];
        assert!(
            (w_hlo[i] - w_host).abs() < 1e-4,
            "i={i}: hlo {} host {}",
            w_hlo[i],
            w_host
        );
    }
    // requantized moment codes agree within one code (sqrt-map rounding ulp)
    let mq_hlo = outs[1].as_i8().unwrap();
    for i in 0..numel {
        assert!((mq_hlo[i] as i16 - host_state.mq[i] as i16).abs() <= 1);
    }
}

#[test]
fn qgalore_update_with_zero_lr_preserves_weights() {
    let man = require_artifacts!();
    let model = tiny_config(CFG).unwrap();
    let (m, n, r) = (model.dim, model.dim, model.rank);
    let spec = man.update(&format!("qgalore_update_{m}x{n}_r{r}")).unwrap();
    let mut rt = Runtime::new().unwrap();
    let mut rng = Pcg32::seeded(3);
    let w = rng.normal_vec(m * n, 0.0, 0.5);
    let wq = quant::quantize(&w, 8);
    let p = rng.normal_vec(m * r, 0.0, 0.1);
    let p4 = quant::quantize4(&p);
    let st = quant::Adam8State::zeros(r * n);
    let g = rng.normal_vec(m * n, 0.0, 1.0);
    let outs = rt
        .execute(
            spec,
            &[
                HostTensor::F32(g),
                HostTensor::U8(p4.packed),
                HostTensor::F32(p4.scale),
                HostTensor::F32(p4.zero),
                HostTensor::I8(st.mq),
                HostTensor::F32(st.ms),
                HostTensor::U8(st.vq),
                HostTensor::F32(st.vs),
                HostTensor::I8(wq.q.clone()),
                HostTensor::F32(wq.scale.clone()),
                HostTensor::F32(wq.zero.clone()),
                HostTensor::F32(vec![10.0, 1000.0]),
                HostTensor::F32(vec![0.0]), // lr = 0
                HostTensor::F32({
                    let mut nr = Pcg32::seeded(7);
                    (0..m * n).map(|_| nr.next_f32()).collect()
                }),
            ],
        )
        .unwrap();
    // with lr = 0 the only change is the SR requantization round-trip:
    // dequantized weights must agree within one quantization step.
    let wq2 = quant::QuantTensor::new(
        outs[0].as_i8().unwrap().to_vec(),
        outs[1].as_f32().unwrap().to_vec(),
        outs[2].as_f32().unwrap().to_vec(),
        8,
        wq.block,
    );
    let w_after = quant::dequantize(&wq2);
    let w_before = quant::dequantize(&wq);
    for (bi, (a, b)) in w_after
        .chunks(wq.block)
        .zip(w_before.chunks(wq.block))
        .enumerate()
    {
        let tol = wq.scale[bi] * 1.5 + 1e-5;
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() <= tol, "block {bi}: {x} vs {y} tol {tol}");
        }
    }
}

#[test]
fn qgalore_training_reduces_loss() {
    let man = require_artifacts!();
    let r = pretrain(
        &man,
        TrainConfig {
            cfg_name: CFG.into(),
            method: Method::QGaLore,
            steps: 40,
            lr_max: 0.01,
            warmup: 4,
            eval_every: 0,
            eval_batches: 4,
            n_documents: 256,
            seed: 5,
            opts: BuildOptions {
                seed: 5,
                sched: SchedulerConfig { base_interval: 8, ..Default::default() },
                ..Default::default()
            },
            log_every: 40,
            quiet: true,
            dataflow: false,
        },
    )
    .unwrap();
    let uniform = (tiny_config(CFG).unwrap().vocab_size as f32).ln();
    assert!(
        r.final_val_loss < uniform - 0.8,
        "val loss {} did not drop from {uniform}",
        r.final_val_loss
    );
    // lazy scheduler must have saved SVD calls vs the fixed schedule
    assert!(r.svd_count > 0);
    assert!(r.svd_fraction <= 1.0 + 1e-9);
    // export round-trips through the ABI
    let entry = man.config(CFG).unwrap();
    assert_eq!(r.final_params.len(), entry.init_numel);
}

#[test]
fn all_methods_take_training_steps() {
    let man = require_artifacts!();
    for method in Method::ALL {
        let r = pretrain(
            &man,
            TrainConfig {
                cfg_name: CFG.into(),
                method,
                steps: 4,
                lr_max: 0.005,
                warmup: 1,
                eval_every: 0,
                eval_batches: 2,
                n_documents: 128,
                seed: 6,
                opts: BuildOptions {
                    seed: 6,
                    sched: SchedulerConfig { base_interval: 2, ..Default::default() },
                    relora_merge_every: 2,
                    ..Default::default()
                },
                log_every: 10,
                quiet: true,
                dataflow: false,
            },
        )
        .unwrap_or_else(|e| panic!("{method} failed: {e}"));
        assert!(r.final_val_loss.is_finite(), "{method}");
        assert!(r.live_bytes > 0, "{method}");
    }
}

#[test]
fn finetune_beats_chance() {
    let man = require_artifacts!();
    // brief base pretrain, then a quick 2-way fine-tune: accuracy must beat
    // the 50% chance level with margin
    let base = pretrain(
        &man,
        TrainConfig {
            cfg_name: CFG.into(),
            method: Method::Full,
            steps: 60,
            lr_max: 0.01,
            warmup: 6,
            eval_every: 0,
            eval_batches: 2,
            n_documents: 256,
            seed: 7,
            opts: BuildOptions::default(),
            log_every: 100,
            quiet: true,
            dataflow: false,
        },
    )
    .unwrap();
    let r = finetune(
        &man,
        FinetuneConfig {
            cfg_name: CFG.into(),
            method: Method::QGaLore,
            n_labels: 2,
            steps: 200,
            lr: 0.01,
            seed: 7,
            task_salt: 99,
            n_eval_examples: 30,
            opts: BuildOptions {
                seed: 7,
                sched: SchedulerConfig { base_interval: 20, ..Default::default() },
                ..Default::default()
            },
            quiet: true,
        },
        &base.final_params,
    )
    .unwrap();
    assert!(r.accuracy > 0.65, "accuracy {} not above chance", r.accuracy);
}

#[test]
fn sr_ablation_rtn_artifact_differs() {
    let man = require_artifacts!();
    // both variants exist per unique layer shape
    let model = tiny_config(CFG).unwrap();
    for (m, n) in model.unique_linear_dims() {
        assert!(man
            .update(&format!("qgalore_update_{m}x{n}_r{}", model.rank))
            .is_ok());
        assert!(man
            .update(&format!("qgalore_rtn_update_{m}x{n}_r{}", model.rank))
            .is_ok());
    }
}
