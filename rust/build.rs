//! Toolchain capability sniff for the AVX-512 microkernel body.
//!
//! The `_mm512_*` f32 intrinsics the `KernelPath::Simd512` body uses
//! stabilized in rustc 1.89; this crate's MSRV is older.  Rather than gate
//! on a feature flag users would have to know about, probe the compiling
//! rustc's version and emit `qgalore_avx512_intrinsics` when the body can
//! compile.  On older toolchains `KernelPath::Simd512` still exists and
//! runs the portable NR=16-tiling body — same bits, narrower registers.
//!
//! The `rustc-check-cfg` declaration (so `cfg(qgalore_avx512_intrinsics)`
//! doesn't trip the unexpected-cfg lint under `-D warnings`) is itself
//! only understood by cargo >= 1.80 — the same release the lint shipped
//! in — so it is version-gated too: older toolchains neither declare nor
//! lint the cfg.

use std::process::Command;

/// Minor version of the `rustc` that will compile this crate (`RUSTC` env
/// when cargo sets it, plain `rustc` otherwise).  `None` when the version
/// string is unparseable — treated as "old" so we never emit a cfg the
/// compiler might reject.
fn rustc_minor_version() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (29483883e 2025-08-04)" — take the second field,
    // split on '.', strip any channel suffix ("89.0-beta.3" etc.)
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split('.');
    let major: u32 = parts.next()?.parse().ok()?;
    if major > 1 {
        // a hypothetical 2.x is newer than everything we gate on
        return Some(u32::MAX);
    }
    let minor_field = parts.next()?;
    let digits: String = minor_field.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    if let Some(minor) = rustc_minor_version() {
        if minor >= 80 {
            println!("cargo:rustc-check-cfg=cfg(qgalore_avx512_intrinsics)");
            // set externally (RUSTFLAGS="--cfg qgalore_modelcheck") to route
            // linalg::sync through the shadow atomics for schedule exploration
            println!("cargo:rustc-check-cfg=cfg(qgalore_modelcheck)");
        }
        if minor >= 89 {
            println!("cargo:rustc-cfg=qgalore_avx512_intrinsics");
        }
    }
}
